//! Visualization exploration: run a workflow, then walk the same
//! drill-down path the paper's §IV describes — dashboard → rank timeline →
//! function view → call stack — both as terminal renderings and through
//! the HTTP API. Pass `--serve` to keep the server up for a browser.
//!
//! ```text
//! cargo run --release --example viz_explore [-- --ranks 32 --serve]
//! ```

use chimbuko::cli::Args;
use chimbuko::config::Config;
use chimbuko::coordinator::{run, Mode, Workflow};
use chimbuko::provenance::{ProvDb, ProvQuery};
use chimbuko::viz::{ascii, http, RankStat, VizState};
use std::sync::{Arc, RwLock};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(false);
    let dir = std::env::temp_dir().join(format!("chimbuko-vizex-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let cfg = Config {
        ranks: args.usize_opt("ranks", 32),
        apps: 2,
        steps: args.usize_opt("steps", 40),
        calls_per_step: 130,
        seed: args.u64_opt("seed", 31337),
        out_dir: dir.to_str().unwrap().to_string(),
        ..Config::default()
    };
    let workflow = Workflow::nwchem(&cfg);
    let report = run(&cfg, &workflow, Mode::TauChimbuko)?;
    let db = ProvDb::load(&dir)?;
    let state = VizState::from_run(
        &report.snapshots,
        report.snapshot.clone(),
        db,
        workflow.registries.clone(),
    );

    // "Overview first": Fig 3 dashboard.
    println!("{}", ascii::dashboard(&state, RankStat::Stddev, 5));

    // "Zoom and filter": Fig 4 timeline of the most problematic ranks.
    let (top, _) = state.ranking(RankStat::Total, 3);
    let focus_ranks: Vec<(u32, u32)> = top.iter().map(|r| (r.app, r.rank)).collect();
    println!("{}", ascii::timeline(&state, &focus_ranks, 60));

    // "Details on demand": Figs 5 + 6 for the hottest anomaly's frame.
    let focus = state
        .db
        .query(&ProvQuery {
            anomalies_only: true,
            order_by_score: true,
            limit: Some(1),
            ..Default::default()
        })
        .first()
        .map(|r| (r.app, r.rank, r.step))
        .unwrap_or((0, 0, 0));
    println!("{}", ascii::function_view(&state, focus.0, focus.1, focus.2));
    println!("{}", ascii::call_stack(&state, focus.0, focus.1, focus.2));

    // The same path over HTTP.
    let state = Arc::new(RwLock::new(state));
    let mut server = http::VizServer::start("127.0.0.1:0", state)?;
    println!("HTTP drill-down against http://{}:", server.addr());
    for path in [
        "/api/stats".to_string(),
        "/api/dashboard?stat=std&n=5".to_string(),
        format!("/api/timeline?app={}&rank={}", focus.0, focus.1),
        format!("/api/callstack?app={}&rank={}&step={}", focus.0, focus.1, focus.2),
    ] {
        let (code, body) = http::http_get(server.addr(), &path)?;
        println!("  GET {path} → {code} ({} bytes)", body.len());
        anyhow::ensure!(code == 200, "endpoint failed");
    }

    if args.flag("serve") {
        println!("\nserving — open http://{} (Ctrl-C to stop)", server.addr());
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    server.stop();
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
