//! **End-to-end driver** (the EXPERIMENTS.md headline run): the NWChem-MD
//! + in-situ-analysis workflow at a realistic local scale, streamed
//! through SST into per-rank AD modules with the **XLA backend** (the
//! AOT-compiled JAX+Pallas artifact) when artifacts are present, parameter
//! server coordination, prescriptive provenance on disk, and the
//! visualization state queried over real HTTP at the end.
//!
//! Proves all layers compose: L1 Pallas kernel → L2 HLO artifact → L3
//! coordinator, with Python nowhere at runtime.
//!
//! ```text
//! make artifacts && cargo run --release --example nwchem_workflow
//!     [-- --ranks 64 --steps 40 --backend rust|xla]
//! ```

use chimbuko::cli::Args;
use chimbuko::config::{Config, DetectorBackend};
use chimbuko::coordinator::{run, Mode, RunReport, Workflow};
use chimbuko::provenance::ProvDb;
use chimbuko::util::fmt_bytes;
use chimbuko::viz::{ascii, http, RankStat, VizState};
use std::path::Path;
use std::sync::{Arc, RwLock};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(false);
    let dir = std::env::temp_dir().join(format!("chimbuko-nwchem-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();

    let artifacts_exist = Path::new("artifacts/manifest.json").exists();
    let backend = match args.get("backend") {
        Some("rust") => DetectorBackend::Rust,
        Some("xla") => DetectorBackend::Xla,
        _ if artifacts_exist => DetectorBackend::Xla,
        _ => {
            eprintln!("note: artifacts/ not built, falling back to rust backend");
            DetectorBackend::Rust
        }
    };
    let cfg = Config {
        ranks: args.usize_opt("ranks", 64),
        apps: 2,
        steps: args.usize_opt("steps", 40),
        calls_per_step: 130,
        backend,
        seed: args.u64_opt("seed", 20260710),
        out_dir: dir.to_str().unwrap().to_string(),
        ..Config::default()
    };

    println!("== NWChem-like workflow, end to end ==");
    let workflow = Workflow::nwchem(&cfg);
    println!(
        "apps: MD simulation ({} ranks) + in-situ analysis ({} ranks); backend: {}",
        workflow.ranks_of_app(0),
        workflow.ranks_of_app(1),
        cfg.backend.name()
    );

    // Baseline sizes for the reduction headline.
    let tau = run(&cfg, &workflow, Mode::Tau)?;
    let t0 = std::time::Instant::now();
    let chi = run(&cfg, &workflow, Mode::TauChimbuko)?;
    let wall = t0.elapsed().as_secs_f64();

    let events_per_sec = chi.total_events as f64 / wall;
    println!("\npipeline results:");
    println!("  wall time          : {wall:.2}s ({events_per_sec:.0} events/s analysed)");
    println!("  events             : {}", chi.total_events);
    println!("  executions         : {}", chi.total_execs);
    println!("  anomalies          : {} ({:.3}%)", chi.total_anomalies,
        100.0 * chi.total_anomalies as f64 / chi.total_execs.max(1) as f64);
    println!("  kept               : {}", chi.total_kept);
    println!("  TAU BP baseline    : {}", fmt_bytes(tau.bp_bytes));
    println!("  Chimbuko reduced   : {}", fmt_bytes(chi.reduced_bytes));
    println!(
        "  reduction factor   : ×{:.0}   (paper: ×14 filtered / ×148 unfiltered at scale)",
        RunReport::reduction_factor(tau.bp_bytes, chi.reduced_bytes)
    );
    println!("  AD latency/step    : mean {:.3}ms  max {:.3}ms",
        chi.ad_step_latency.mean() * 1e3, chi.ad_step_latency.max() * 1e3);
    println!("  SST backpressure   : {} writer waits", chi.writer_waits);
    println!("  stack errors       : {:?}", chi.stack_errors);

    // Build the viz state and serve it over HTTP briefly — a real client
    // request against the real server, then the terminal views.
    let db = ProvDb::load(&dir)?;
    let state = VizState::from_run(
        &chi.snapshots,
        chi.snapshot.clone(),
        db,
        workflow.registries.clone(),
    );
    let dashboard = ascii::dashboard(&state, RankStat::Stddev, 5);
    let state = Arc::new(RwLock::new(state));
    let mut server = http::VizServer::start("127.0.0.1:0", state)?;
    let (code, body) = http::http_get(server.addr(), "/api/stats")?;
    println!("\nviz server check: GET /api/stats → {code} ({} bytes)", body.len());
    let (code, _) = http::http_get(server.addr(), "/api/dashboard?stat=std&n=5")?;
    println!("viz server check: GET /api/dashboard → {code}");
    server.stop();

    println!("\n{dashboard}");
    std::fs::remove_dir_all(&dir).ok();
    println!("OK — all three layers composed (workload → SST → AD[{}] → PS → provenance → viz).",
        cfg.backend.name());
    Ok(())
}
