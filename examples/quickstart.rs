//! Quickstart: run a small two-app workflow through the full Chimbuko
//! pipeline and print what it found.
//!
//! ```text
//! cargo run --release --example quickstart [-- --ranks 8 --steps 20 --backend xla]
//! ```

use chimbuko::cli::Args;
use chimbuko::config::Config;
use chimbuko::coordinator::{run, Mode, RunReport, Workflow};
use chimbuko::provenance::{ProvDb, ProvQuery};
use chimbuko::util::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(false);
    let dir = std::env::temp_dir().join(format!("chimbuko-quickstart-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();

    let mut cfg = Config {
        ranks: args.usize_opt("ranks", 8),
        apps: 2,
        steps: args.usize_opt("steps", 20),
        calls_per_step: 130,
        out_dir: dir.to_str().unwrap().to_string(),
        ..Config::default()
    };
    if let Some(b) = args.get("backend") {
        cfg.apply("backend", b)?;
    }

    println!("== Chimbuko quickstart ==");
    println!(
        "workflow: {} ranks, {} steps, α = {}, k = {}, backend = {}",
        cfg.ranks,
        cfg.steps,
        cfg.alpha,
        cfg.k_neighbors,
        cfg.backend.name()
    );

    // 1. Baseline: what would TAU alone have written to disk?
    let workflow = Workflow::nwchem(&cfg);
    let tau: RunReport = run(&cfg, &workflow, Mode::Tau)?;

    // 2. The Chimbuko pipeline: stream → detect → reduce → provenance.
    let chi: RunReport = run(&cfg, &workflow, Mode::TauChimbuko)?;

    println!("\nresults:");
    println!("  events generated : {}", chi.total_events);
    println!("  executions       : {}", chi.total_execs);
    println!("  anomalies        : {}", chi.total_anomalies);
    println!("  kept for prov    : {} (anomalies + {}-neighbour context)", chi.total_kept, cfg.k_neighbors);
    println!("  raw trace (BP)   : {}", fmt_bytes(tau.bp_bytes));
    println!("  reduced output   : {}", fmt_bytes(chi.reduced_bytes));
    println!(
        "  data reduction   : ×{:.0}",
        RunReport::reduction_factor(tau.bp_bytes, chi.reduced_bytes)
    );
    println!("  wall time        : {:.2}s", chi.wall_seconds);

    // 3. Inspect the top anomalies from the provenance store.
    let db = ProvDb::load(&dir)?;
    let top = db.query(&ProvQuery {
        anomalies_only: true,
        order_by_score: true,
        limit: Some(5),
        ..Default::default()
    });
    println!("\ntop anomalies:");
    for r in top {
        println!(
            "  {:>7.1}σ  {:<14} app {} rank {:>3} step {:>3}  {:>9}µs ({} msgs)",
            r.score, r.func, r.app, r.rank, r.step, r.inclusive_us, r.n_messages
        );
    }

    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
