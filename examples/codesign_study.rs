//! Co-design study (the paper's motivating use case, §VI-A): run the same
//! workflow under several configurations, keep each run's prescriptive
//! provenance, and *mine provenance across runs* — which anomaly patterns
//! depend on which workflow configuration.
//!
//! ```text
//! cargo run --release --example codesign_study
//! ```

use chimbuko::config::Config;
use chimbuko::coordinator::{run, Mode, Workflow};
use chimbuko::provenance::{ProvDb, ProvQuery};
use chimbuko::trace::nwchem::InjectionConfig;
use std::collections::BTreeMap;

struct RunSummary {
    label: String,
    anomalies: u64,
    execs: u64,
    by_func: BTreeMap<String, u64>,
}

fn run_config(label: &str, ranks: usize, inj: InjectionConfig, seed: u64) -> anyhow::Result<RunSummary> {
    let dir = std::env::temp_dir().join(format!("chimbuko-codesign-{}-{}", std::process::id(), label));
    std::fs::remove_dir_all(&dir).ok();
    let cfg = Config {
        ranks,
        apps: 2,
        steps: 40,
        calls_per_step: 130,
        seed,
        out_dir: dir.to_str().unwrap().to_string(),
        ..Config::default()
    };
    let workflow = Workflow::nwchem_with_injection(&cfg, inj);
    let report = run(&cfg, &workflow, Mode::TauChimbuko)?;
    let db = ProvDb::load(&dir)?;
    let mut by_func = BTreeMap::new();
    for r in db.query(&ProvQuery { anomalies_only: true, ..Default::default() }) {
        *by_func.entry(r.func.clone()).or_insert(0u64) += 1;
    }
    std::fs::remove_dir_all(&dir).ok();
    Ok(RunSummary {
        label: label.to_string(),
        anomalies: report.total_anomalies,
        execs: report.total_execs,
        by_func,
    })
}

fn main() -> anyhow::Result<()> {
    println!("== Co-design study: anomaly patterns vs workflow configuration ==\n");

    // Three configurations of the same science workload.
    let configs = vec![
        (
            "baseline",
            16,
            InjectionConfig::default(),
        ),
        (
            "bad-io", // e.g. a misconfigured burst buffer: remote gets stall
            16,
            InjectionConfig {
                getxbl_tail_prob: 0.03,
                ..InjectionConfig::default()
            },
        ),
        (
            "imbalanced", // stronger rank-0 serialization in global sums
            16,
            InjectionConfig {
                rank0_straggle_prob: 0.08,
                ..InjectionConfig::default()
            },
        ),
    ];

    let mut summaries = Vec::new();
    for (label, ranks, inj) in configs {
        let s = run_config(label, ranks, inj, 99)?;
        println!(
            "run '{}': {} anomalies / {} executions ({:.3}%)",
            s.label,
            s.anomalies,
            s.execs,
            100.0 * s.anomalies as f64 / s.execs.max(1) as f64
        );
        summaries.push(s);
    }

    // Cross-run comparison: per-function anomaly profile.
    let mut funcs: Vec<String> = summaries
        .iter()
        .flat_map(|s| s.by_func.keys().cloned())
        .collect();
    funcs.sort();
    funcs.dedup();
    println!("\nper-function anomaly counts across runs:");
    print!("{:<16}", "function");
    for s in &summaries {
        print!("{:>12}", s.label);
    }
    println!();
    for f in &funcs {
        print!("{f:<16}");
        for s in &summaries {
            print!("{:>12}", s.by_func.get(f).copied().unwrap_or(0));
        }
        println!();
    }

    // The co-design conclusions the provenance supports.
    let count = |s: &RunSummary, f: &str| s.by_func.get(f).copied().unwrap_or(0);
    let base = &summaries[0];
    let bad_io = &summaries[1];
    let imbal = &summaries[2];
    println!("\nfindings:");
    println!(
        "  bad-io vs baseline: SP_GTXPBL anomalies {} → {} (remote-get sensitivity)",
        count(base, "SP_GTXPBL"),
        count(bad_io, "SP_GTXPBL")
    );
    println!(
        "  imbalanced vs baseline: MD_FINIT+CF_CMS anomalies {} → {} (rank-0 global sums)",
        count(base, "MD_FINIT") + count(base, "CF_CMS"),
        count(imbal, "MD_FINIT") + count(imbal, "CF_CMS")
    );
    anyhow::ensure!(
        count(bad_io, "SP_GTXPBL") > count(base, "SP_GTXPBL"),
        "bad-io run should show more remote-get anomalies"
    );
    anyhow::ensure!(
        count(imbal, "MD_FINIT") + count(imbal, "CF_CMS")
            > count(base, "MD_FINIT") + count(base, "CF_CMS"),
        "imbalanced run should show more rank-0 anomalies"
    );
    println!("\nOK — provenance comparison separates the two degradation modes.");
    Ok(())
}
