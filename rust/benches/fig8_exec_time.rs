//! Bench: regenerate **Fig 8** (execution time over MPI processes, three
//! configurations) and **Table I** (overhead percentages).
//!
//! `cargo bench --bench fig8_exec_time`
//!
//! Scales are simulated ranks on this box; the paper's knee appears where
//! simulated ranks outgrow physical cores. Absolute seconds are testbed-
//! local; shape (small overhead → growth past the knee, Chimbuko adds a
//! few points over TAU alone) is the reproduction target.

fn main() {
    let fast = std::env::var("CHIMBUKO_BENCH_FAST").as_deref() == Ok("1");
    let scales: Vec<usize> = if fast {
        vec![8, 32]
    } else {
        vec![80, 160, 320, 640, 1280, 2560]
    };
    let steps = if fast { 4 } else { 8 };
    let repeats = if fast { 1 } else { 5 };
    println!(
        "Fig 8 / Table I sweep: ranks {:?}, {} steps, {} repeats (paper: 15 repeats)",
        scales, steps, repeats
    );
    // Fixed total app compute (strong scaling) sized so analysis cost is
    // a few % at the smallest scale — like NWChem on Summit.
    let app_ms = if fast { 500 } else { 2_000 };
    let res = chimbuko::exp::run_fig8(&scales, steps, 130, repeats, app_ms).expect("fig8 sweep");
    print!("{}", res.render());

    if res.rows.len() >= 2 {
        let first = &res.rows[0];
        let last = res.rows.last().unwrap();
        println!("shape checks vs paper:");
        println!(
            "  overhead (with Chimbuko) {:.2}% at {} ranks → {:.2}% at {} ranks (paper 1.31% → 24.56%)",
            first.overhead_chimbuko_pct, first.ranks, last.overhead_chimbuko_pct, last.ranks
        );
        println!(
            "  Chimbuko − TAU delta at max scale: {:.2} points (paper ≈ +6)",
            last.overhead_chimbuko_pct - last.overhead_tau_pct
        );
    }
}
