//! Bench: regenerate the **Figs 10–13** case study — the `MD_FORCES`
//! launch delay (Fig 10), rank-0 `MD_FINIT`/`CF_CMS` concentration
//! (Figs 11–12) and the `SP_GTXPBL`/`SP_GETXBL` domain-decomposition
//! pattern on ranks ≠ 0 (Fig 13).
//!
//! `cargo bench --bench fig10_13_case_study`

use chimbuko::trace::nwchem::names;

fn main() {
    let fast = std::env::var("CHIMBUKO_BENCH_FAST").as_deref() == Ok("1");
    let (ranks, steps) = if fast { (8, 50) } else { (16, 120) };
    println!("case-study run: {ranks} ranks, {steps} steps\n");
    let res = chimbuko::exp::run_case_study(ranks, steps, 777).expect("case study");
    print!("{}", res.render());

    println!("\nfindings vs paper:");
    println!(
        "  Fig 10: anomalous MD_NEWTON {:.1}× normal (paper ~3×); MD_FORCES ratio {:.2} (≈1)",
        res.newton_anomalous_us as f64 / res.newton_normal_us.max(1) as f64,
        res.children_ratio
    );
    let share = |shares: &[chimbuko::exp::case_study::FuncShare], f: &str| {
        shares.iter().find(|s| s.func == f).map(|s| s.share).unwrap_or(0.0)
    };
    println!(
        "  Figs 11–12: rank-0 anomalies in MD_FINIT {:.0}% + CF_CMS {:.0}% (paper: dominant)",
        100.0 * share(&res.rank0_shares, names::MD_FINIT),
        100.0 * share(&res.rank0_shares, names::CF_CMS),
    );
    println!(
        "  Fig 13: ranks≠0 anomalies in SP_GTXPBL {:.0}% + SP_GETXBL {:.0}% (paper: dominant)",
        100.0 * share(&res.other_shares, names::SP_GTXPBL),
        100.0 * share(&res.other_shares, names::SP_GETXBL),
    );
}
