//! Ablations over the design choices DESIGN.md calls out:
//!
//! * `k` (context-window size) — reduction factor vs diagnostic context;
//! * `α` (threshold multiplier) — anomaly rate / reduction tradeoff;
//! * PS sync period — accuracy of the global view vs sync traffic;
//! * SST queue depth — backpressure events vs buffering.
//!
//! `cargo bench --bench ablations`

use chimbuko::bench::Table;
use chimbuko::config::Config;
use chimbuko::coordinator::{run, Mode, RunReport, Workflow};

fn base_cfg(fast: bool) -> Config {
    Config {
        ranks: if fast { 8 } else { 16 },
        apps: 2,
        steps: if fast { 15 } else { 40 },
        calls_per_step: 130,
        out_dir: String::new(),
        viz_enabled: false,
        ..Config::default()
    }
}

fn main() {
    let fast = std::env::var("CHIMBUKO_BENCH_FAST").as_deref() == Ok("1");

    // Baseline BP size for reduction factors.
    let cfg0 = base_cfg(fast);
    let w0 = Workflow::nwchem(&cfg0);
    let tau = run(&cfg0, &w0, Mode::Tau).expect("tau baseline");

    // --- k sweep -----------------------------------------------------------
    let mut t = Table::new(
        "Ablation — context window k (paper uses k = 5)",
        &["k", "kept", "reduced bytes", "×reduction", "kept/anomaly"],
    );
    for k in [0usize, 1, 3, 5, 10, 20] {
        let mut cfg = base_cfg(fast);
        cfg.k_neighbors = k;
        let w = Workflow::nwchem(&cfg);
        let r = run(&cfg, &w, Mode::TauChimbuko).expect("run");
        t.row(vec![
            k.to_string(),
            r.total_kept.to_string(),
            r.reduced_bytes.to_string(),
            format!("{:.0}", RunReport::reduction_factor(tau.bp_bytes, r.reduced_bytes)),
            format!("{:.1}", r.total_kept as f64 / r.total_anomalies.max(1) as f64),
        ]);
    }
    t.print();
    println!();

    // --- alpha sweep ---------------------------------------------------------
    let mut t = Table::new(
        "Ablation — threshold α (paper uses α = 6)",
        &["alpha", "anomalies", "rate %", "×reduction"],
    );
    for alpha in [2.0, 3.0, 4.5, 6.0, 9.0, 12.0] {
        let mut cfg = base_cfg(fast);
        cfg.alpha = alpha;
        let w = Workflow::nwchem(&cfg);
        let r = run(&cfg, &w, Mode::TauChimbuko).expect("run");
        t.row(vec![
            format!("{alpha}"),
            r.total_anomalies.to_string(),
            format!("{:.3}", 100.0 * r.total_anomalies as f64 / r.total_execs.max(1) as f64),
            format!("{:.0}", RunReport::reduction_factor(tau.bp_bytes, r.reduced_bytes)),
        ]);
    }
    t.print();
    println!();

    // --- detection algorithm (paper threshold vs §VIII HBOS extension) -------
    let mut t = Table::new(
        "Ablation — AD algorithm (threshold = paper, hbos = §VIII extension)",
        &["algorithm", "anomalies", "rate %", "×reduction"],
    );
    for algo in ["threshold", "hbos"] {
        let mut cfg = base_cfg(fast);
        cfg.apply("ad.algorithm", algo).unwrap();
        let w = Workflow::nwchem(&cfg);
        let r = run(&cfg, &w, Mode::TauChimbuko).expect("run");
        t.row(vec![
            algo.to_string(),
            r.total_anomalies.to_string(),
            format!("{:.3}", 100.0 * r.total_anomalies as f64 / r.total_execs.max(1) as f64),
            format!("{:.0}", RunReport::reduction_factor(tau.bp_bytes, r.reduced_bytes)),
        ]);
    }
    t.print();
    println!();

    // --- PS sync period ------------------------------------------------------
    let mut t = Table::new(
        "Ablation — PS sync period (steps between stat exchanges)",
        &["period", "anomalies", "wall s"],
    );
    for period in [1usize, 2, 5, 10] {
        let mut cfg = base_cfg(fast);
        cfg.ps_period_steps = period;
        let w = Workflow::nwchem(&cfg);
        let r = run(&cfg, &w, Mode::TauChimbuko).expect("run");
        t.row(vec![
            period.to_string(),
            r.total_anomalies.to_string(),
            format!("{:.3}", r.wall_seconds),
        ]);
    }
    t.print();
    println!();

    // --- SST queue depth -------------------------------------------------------
    let mut t = Table::new(
        "Ablation — SST queue depth (bounded staging buffer)",
        &["depth", "writer waits", "wall s"],
    );
    for depth in [1usize, 2, 4, 16, 64] {
        let mut cfg = base_cfg(fast);
        cfg.sst_queue_depth = depth;
        let w = Workflow::nwchem(&cfg);
        let r = run(&cfg, &w, Mode::TauChimbuko).expect("run");
        t.row(vec![
            depth.to_string(),
            r.writer_waits.to_string(),
            format!("{:.3}", r.wall_seconds),
        ]);
    }
    t.print();
}
