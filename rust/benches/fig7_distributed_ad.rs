//! Bench: regenerate **Fig 7** — distributed vs non-distributed AD
//! accuracy and execution time over 10–100 ranks.
//!
//! `cargo bench --bench fig7_distributed_ad`
//! (`CHIMBUKO_BENCH_FAST=1` shrinks the sweep for CI.)

fn main() {
    let fast = std::env::var("CHIMBUKO_BENCH_FAST").as_deref() == Ok("1");
    let scales: Vec<usize> = if fast {
        vec![10, 20]
    } else {
        vec![10, 20, 30, 40, 50, 60, 70, 80, 90, 100]
    };
    let steps = if fast { 8 } else { 120 };
    println!(
        "Fig 7 sweep: ranks {:?}, {} steps x 4 MD iterations/rank\n",
        scales, steps
    );
    let res = chimbuko::exp::run_fig7(&scales, steps, 4, 7);
    print!("{}", res.render());

    // Paper-shape checks (reported, not asserted, in bench mode).
    let first = res.rows.first().unwrap();
    let last = res.rows.last().unwrap();
    println!("\nshape checks vs paper:");
    println!(
        "  single-instance time grows {:.1}x from {} to {} ranks (paper: grows with ranks)",
        last.t_single / first.t_single.max(1e-12),
        first.ranks,
        last.ranks
    );
    println!(
        "  distributed per-instance mean: {:.2}ms → {:.2}ms (paper: ~flat, ~0.05s on Summit)",
        first.t_distributed_mean * 1e3,
        last.t_distributed_mean * 1e3
    );
    println!(
        "  mean accuracy {:.1}% (paper: 97.6%)",
        res.mean_accuracy() * 100.0
    );
}
