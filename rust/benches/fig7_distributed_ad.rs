//! Bench: regenerate **Fig 7** — distributed vs non-distributed AD
//! accuracy and execution time over 10–100 ranks.
//!
//! `cargo bench --bench fig7_distributed_ad`
//! (`CHIMBUKO_BENCH_FAST=1` shrinks the sweep for CI.)

fn main() {
    let fast = std::env::var("CHIMBUKO_BENCH_FAST").as_deref() == Ok("1");
    let scales: Vec<usize> = if fast {
        vec![10, 20]
    } else {
        vec![10, 20, 30, 40, 50, 60, 70, 80, 90, 100]
    };
    let steps = if fast { 8 } else { 120 };
    println!(
        "Fig 7 sweep: ranks {:?}, {} steps x 4 MD iterations/rank\n",
        scales, steps
    );
    let res = chimbuko::exp::run_fig7(&scales, steps, 4, 7);
    print!("{}", res.render());

    // Paper-shape checks (reported, not asserted, in bench mode).
    let first = res.rows.first().unwrap();
    let last = res.rows.last().unwrap();
    println!("\nshape checks vs paper:");
    println!(
        "  single-instance time grows {:.1}x from {} to {} ranks (paper: grows with ranks)",
        last.t_single / first.t_single.max(1e-12),
        first.ranks,
        last.ranks
    );
    println!(
        "  distributed per-instance mean: {:.2}ms → {:.2}ms (paper: ~flat, ~0.05s on Summit)",
        first.t_distributed_mean * 1e3,
        last.t_distributed_mean * 1e3
    );
    println!(
        "  mean accuracy {:.1}% (paper: 97.6%)",
        res.mean_accuracy() * 100.0
    );

    // --- PS shard sweep: sync throughput vs shard count -------------------
    let shard_counts: Vec<usize> = if fast { vec![1, 2, 4] } else { vec![1, 2, 4, 8] };
    let (clients, syncs, funcs) = if fast { (4, 200, 64) } else { (8, 2_000, 128) };
    println!(
        "\nPS shard sweep: shards {:?}, {} clients x {} syncs x {} funcs/delta\n",
        shard_counts, clients, syncs, funcs
    );
    let sweep = chimbuko::exp::run_ps_shard_sweep(&shard_counts, clients, syncs, funcs, 7);
    print!("{}", sweep.render());
    let first = sweep.rows.first().unwrap();
    let at4 = sweep
        .rows
        .iter()
        .find(|r| r.shards == 4)
        .unwrap_or_else(|| sweep.rows.last().unwrap());
    println!(
        "shape check: sync throughput 1 → {} shards: {:.0} → {:.0} syncs/s ({:.2}x)",
        at4.shards,
        first.syncs_per_sec,
        at4.syncs_per_sec,
        at4.syncs_per_sec / first.syncs_per_sec.max(1e-9)
    );

    // --- PS endpoint sweep: per-shard TCP endpoints (multi-process shape) --
    let endpoint_counts: Vec<usize> = if fast { vec![1, 2] } else { vec![1, 2, 4, 8] };
    let (ep_clients, ep_syncs) = if fast { (4, 100) } else { (8, 500) };
    println!(
        "\nPS endpoint sweep: endpoints {:?}, {} routed TCP clients x {} syncs x {} funcs/delta\n",
        endpoint_counts, ep_clients, ep_syncs, funcs
    );
    let eps = chimbuko::exp::run_ps_endpoint_sweep(&endpoint_counts, ep_clients, ep_syncs, funcs, 7)
        .expect("endpoint sweep");
    print!("{}", eps.render());
    let ep_first = eps.rows.first().unwrap();
    let ep_last = eps.rows.last().unwrap();
    println!(
        "shape check: sync throughput 1 → {} endpoints: {:.0} → {:.0} syncs/s ({:.2}x); \
         aggregator messages per sync: {:.3} (gated; was 1.0 pre-gating)",
        ep_last.endpoints,
        ep_first.syncs_per_sec,
        ep_last.syncs_per_sec,
        ep_last.syncs_per_sec / ep_first.syncs_per_sec.max(1e-9),
        ep_last.agg_msgs_per_sync,
    );

    // --- PS rebalance sweep: skewed workload, rebalancer off vs on --------
    let (rb_shards, rb_clients, rb_syncs) = if fast { (4, 2, 400) } else { (4, 4, 2_000) };
    println!(
        "\nPS rebalance sweep: {} shards, {} clients x {} skewed syncs per phase\n",
        rb_shards, rb_clients, rb_syncs
    );
    let reb = chimbuko::exp::run_ps_rebalance_sweep(rb_shards, rb_clients, rb_syncs, 7);
    print!("{}", reb.render());
    let off = &reb.rows[0];
    let on = &reb.rows[1];
    println!(
        "shape check: max/mean per-shard merge load {:.2} → {:.2} (static stays {:.2}); \
         acceptance: rebalanced ratio < 1.5",
        on.max_mean_before, on.max_mean_after, off.max_mean_after,
    );

    // --- PS connection sweep: live connections vs latency on the reactor --
    // The acceptance shape: p99 at the largest point within 2x of the
    // smallest, process threads independent of the connection count
    // (thread-per-connection failed both by 10k connections).
    let conn_counts: Vec<usize> = if fast { vec![50, 200] } else { vec![100, 1_000, 10_000] };
    let (cn_syncs, cn_funcs) = if fast { (2_000, 16) } else { (40_000, 32) };
    println!(
        "\nPS connection sweep: connections {:?}, {} syncs split across them x {} funcs/delta\n",
        conn_counts, cn_syncs, cn_funcs
    );
    let conns = chimbuko::exp::run_ps_conn_sweep(&conn_counts, cn_syncs, cn_funcs, 7)
        .expect("conn sweep");
    print!("{}", conns.render());
    let cn_first = conns.rows.first().unwrap();
    let cn_last = conns.rows.last().unwrap();
    println!(
        "shape check: p99 {} → {} connections: {:.0}µs → {:.0}µs ({:.2}x, acceptance < 2x); \
         peak threads {} → {} (reactor: {} event-loop threads, independent of connections); \
         shed {} (well-behaved load: must be 0)",
        cn_first.clients,
        cn_last.clients,
        cn_first.p99_us,
        cn_last.p99_us,
        cn_last.p99_us / cn_first.p99_us.max(1e-9),
        cn_first.peak_threads,
        cn_last.peak_threads,
        cn_last.reactor_threads,
        cn_last.shed,
    );

    // --- PS aggregation-tree sweep: step-report fold, flat vs tree --------
    // The acceptance shape: flat fold throughput bends as one thread
    // drains every rank's reports; the tree stays ~flat, and both
    // shapes flag the same global events (bit-equivalence).
    let at_ranks: Vec<usize> =
        if fast { vec![256, 1_024, 4_096] } else { vec![1_024, 4_096, 16_384, 65_536] };
    let (at_steps, at_fanout, at_producers) = if fast { (12, 4, 4) } else { (32, 8, 8) };
    println!(
        "\nPS aggregation-tree sweep: ranks {:?}, {} steps, fanout {} tree vs flat, {} producers\n",
        at_ranks, at_steps, at_fanout, at_producers
    );
    let aggtree = chimbuko::exp::run_aggtree_sweep(&at_ranks, at_steps, at_fanout, at_producers, 7)
        .expect("aggtree sweep");
    print!("{}", aggtree.render());
    let at_pairs: Vec<_> = aggtree.rows.chunks(2).collect();
    let (f_first, t_first) = (&at_pairs[0][0], &at_pairs[0][1]);
    let last = at_pairs.last().unwrap();
    let (f_last, t_last) = (&last[0], &last[1]);
    println!(
        "shape check: flat reports/s {} → {} ranks: {:.0} → {:.0}; \
         tree (fanout {}, depth {}): {:.0} → {:.0}; \
         events flat/tree at {} ranks: {}/{} (must match)",
        f_first.ranks,
        f_last.ranks,
        f_first.reports_per_sec,
        f_last.reports_per_sec,
        t_last.fanout,
        t_last.depth,
        t_first.reports_per_sec,
        t_last.reports_per_sec,
        f_last.ranks,
        f_last.events,
        t_last.events,
    );

    // --- chaos scenario: kill/restart a PS shard + the provDB shard -------
    // Needs the built `chimbuko` binary to spawn server children; skip
    // loudly (never silently) when it is not around.
    let mut artifact = chimbuko::exp::ps_bench_json(&sweep, &eps, &reb, &conns, &aggtree);
    match chimbuko::exp::find_chimbuko_bin() {
        Some(bin) => {
            let (ch_shards, ch_ranks, ch_steps) = if fast { (2, 4, 12) } else { (4, 8, 24) };
            println!(
                "\nchaos scenario: {} shards, {} ranks x {} steps, kill ps:0 and provdb:0\n",
                ch_shards, ch_ranks, ch_steps
            );
            let chaos = chimbuko::exp::run_chaos(&bin, ch_shards, ch_ranks, ch_steps, 7)
                .expect("chaos scenario");
            print!("{}", chaos.render());
            artifact.set("chaos_rows", chaos.rows_json());
        }
        None => println!(
            "\nchaos scenario SKIPPED: chimbuko binary not found \
             (build it or set CHIMBUKO_BIN); chaos_rows omitted"
        ),
    }

    let out = "BENCH_ps_shards.json";
    std::fs::write(out, artifact.to_pretty()).expect("writing BENCH_ps_shards.json");
    println!("wrote {out}");
}
