//! Microbenchmarks of the analysis hot path — the §Perf working set:
//! call-stack building, rust-detector batches, XLA-artifact batches,
//! PS sync round-trips, provenance serialization, BP encoding.
//!
//! `cargo bench --bench hotpath_micro`

use chimbuko::ad::{DetectEngine, DetectorConfig, RustDetector, StackBuilder};
use chimbuko::bench::Bench;
use chimbuko::ps;
use chimbuko::stats::StatsTable;
use chimbuko::trace::binfmt;
use chimbuko::trace::nwchem::{self, InjectionConfig};
use chimbuko::trace::RankTracer;
use chimbuko::util::rng::Rng;

fn main() {
    let mut b = Bench::from_env(30);

    // Workload: one rank's step frames.
    let (grammar, reg) = nwchem::md_grammar(5, &InjectionConfig::default());
    let mut tracer = RankTracer::new(grammar.clone(), 0, 1, 8, false, Rng::new(1));
    let frames: Vec<_> = (0..50).map(|_| tracer.step()).collect();
    let events_per_frame = frames[0].events.len() as u64;

    // --- trace generation ---
    let mut t2 = RankTracer::new(grammar.clone(), 0, 1, 8, false, Rng::new(2));
    b.run_throughput("gen: rank-step (filtered)", || {
        let f = t2.step();
        f.events.len() as u64
    });
    let mut t3 = RankTracer::new(grammar.clone(), 0, 1, 8, true, Rng::new(2));
    b.run_throughput("gen: rank-step (unfiltered)", || {
        let f = t3.step();
        f.events.len() as u64
    });

    // --- call-stack building ---
    b.run_throughput("stack: process frame", || {
        let mut sb = StackBuilder::new(0, 1);
        let mut n = 0u64;
        for f in &frames {
            n += sb.process(f).len() as u64;
        }
        n
    });

    // --- detection (rust backend) ---
    let mut sb = StackBuilder::new(0, 1);
    let batches: Vec<_> = frames.iter().map(|f| sb.process(f)).collect();
    let execs_total: u64 = batches.iter().map(|b| b.len() as u64).sum();
    b.run_throughput("detect[rust]: 50 frames", || {
        let mut d = RustDetector::new(DetectorConfig::default());
        for batch in &batches {
            let _ = DetectEngine::detect(&mut d, batch.clone());
        }
        execs_total
    });

    // --- detection (xla backend, if artifacts exist) ---
    let art = std::path::Path::new("artifacts");
    if art.join("manifest.json").exists() {
        let svc = chimbuko::runtime::RuntimeService::spawn(art).expect("runtime");
        b.run_throughput("detect[xla]: 50 frames", || {
            let mut d = chimbuko::runtime::XlaDetector::new(svc.handle(), 6.0, 10);
            for batch in &batches {
                let _ = DetectEngine::detect(&mut d, batch.clone());
            }
            execs_total
        });
        // Single padded batch through PJRT (per-call latency).
        let one = batches.iter().find(|b| !b.is_empty()).unwrap().clone();
        let per = one.len() as u64;
        b.run_throughput("detect[xla]: single batch", || {
            let mut d = chimbuko::runtime::XlaDetector::new(svc.handle(), 6.0, 10);
            let _ = DetectEngine::detect(&mut d, one.clone());
            per
        });
    } else {
        println!("(artifacts/ missing — skipping XLA benches; run `make artifacts`)");
    }

    // --- parameter-server sync ---
    let (client, handle) = ps::spawn(1, None, usize::MAX >> 1, 1);
    let mut delta = StatsTable::new();
    let mut rng = Rng::new(3);
    for _ in 0..200 {
        delta.push(rng.usize(13) as u32, rng.lognormal(6.0, 0.4));
    }
    b.run("ps: sync round-trip (13 funcs)", || {
        let _ = client.sync(0, 0, &delta);
    });
    client.shutdown();
    handle.join();

    // Routed across 4 shards (same delta, fan-out/fan-in path).
    let (client, handle) = ps::spawn(4, None, usize::MAX >> 1, 1);
    b.run("ps: sync round-trip (13 funcs, 4 shards)", || {
        let _ = client.sync(0, 0, &delta);
    });
    client.shutdown();
    handle.join();

    // --- provenance serialization ---
    let mut d = RustDetector::new(DetectorConfig::default());
    let labeled: Vec<_> = batches
        .iter()
        .flat_map(|batch| DetectEngine::detect(&mut d, batch.clone()))
        .collect();
    b.run_throughput("prov: serialize records to JSONL", || {
        let mut db = chimbuko::provenance::ProvDb::in_memory();
        db.append_step(&labeled, &reg).unwrap();
        labeled.len() as u64
    });

    // --- provenance codec: binary vs JSONL text (the provDB pipeline) ---
    use chimbuko::provenance::{codec, ProvRecord};
    let records: Vec<ProvRecord> = labeled
        .iter()
        .map(|l| ProvRecord::from_labeled(l, reg.name(l.rec.fid)))
        .collect();
    let mut enc_buf: Vec<u8> = Vec::new();
    b.run_throughput("prov: encode binary batch", || {
        enc_buf.clear();
        for r in &records {
            codec::encode(r, &mut enc_buf);
        }
        records.len() as u64
    });
    let mut encoded: Vec<u8> = Vec::new();
    for r in &records {
        codec::encode(r, &mut encoded);
    }
    b.run_throughput("prov: decode binary batch", || {
        let mut pos = 0usize;
        let mut n = 0u64;
        while pos < encoded.len() {
            let (_, used) = codec::decode(&encoded[pos..]).unwrap();
            pos += used;
            n += 1;
        }
        n
    });
    b.run_throughput("prov: validate binary batch (ingest boundary)", || {
        let mut pos = 0usize;
        let mut n = 0u64;
        while pos < encoded.len() {
            pos += codec::validate(&encoded[pos..]).unwrap();
            n += 1;
        }
        n
    });
    let lines: Vec<String> = records
        .iter()
        .map(|r| {
            let mut s = String::with_capacity(360);
            r.write_jsonl(&mut s);
            s
        })
        .collect();
    b.run_throughput("prov: parse JSONL batch", || {
        let mut n = 0u64;
        for line in &lines {
            let _ = ProvRecord::from_jsonl_line(line).unwrap();
            n += 1;
        }
        n
    });

    // --- columnar v2 segments: seal + scan (the provDB warm tier) ---
    let row_bufs: Vec<Vec<u8>> = records
        .iter()
        .map(|r| {
            let mut buf = Vec::with_capacity(192);
            codec::encode(r, &mut buf);
            buf
        })
        .collect();
    if !row_bufs.is_empty() {
        let rows: Vec<(u64, &[u8])> = row_bufs
            .iter()
            .enumerate()
            .map(|(i, buf)| (i as u64, buf.as_slice()))
            .collect();
        b.run_throughput("prov: seal columnar v2 segment", || {
            let (bytes, _) = codec::seal_segment_v2(&rows).unwrap();
            std::hint::black_box(bytes.len());
            rows.len() as u64
        });
        let (sealed, footer) = codec::seal_segment_v2(&rows).unwrap();
        b.run_throughput("prov: scan columnar v2 segment", || {
            let scan = codec::read_segment_v2(&sealed).unwrap();
            std::hint::black_box(scan.records.len());
            footer.n_records as u64
        });
    }

    // --- probe DSL: compile + per-record predicate eval ---
    use chimbuko::probe::Probe;
    const PROBE_SRC: &str =
        "probe hot: fn:*.*:exit / score >= 6.0 && anomaly / { capture(record); }";
    b.run("probe: compile one-liner", || {
        let _ = Probe::compile(PROBE_SRC).unwrap();
    });
    let probe = Probe::compile(PROBE_SRC).unwrap();
    // Identical framing loop for the compiled VM and the hard-coded
    // header read, so the pair isolates the predicate-eval overhead.
    b.run_throughput("probe: eval compiled predicate batch", || {
        let mut pos = 0usize;
        let mut n = 0u64;
        let mut hits = 0u64;
        while pos < encoded.len() {
            let used = codec::validate(&encoded[pos..]).unwrap();
            hits += u64::from(probe.matches(&encoded[pos..pos + used]));
            pos += used;
            n += 1;
        }
        std::hint::black_box(hits);
        n
    });
    b.run_throughput("probe: eval hard-coded header predicate batch", || {
        let mut pos = 0usize;
        let mut n = 0u64;
        let mut hits = 0u64;
        while pos < encoded.len() {
            let used = codec::validate(&encoded[pos..]).unwrap();
            let rec = &encoded[pos..pos + used];
            let score = f64::from_le_bytes(rec[36..44].try_into().unwrap());
            hits += u64::from(score >= 6.0 && rec[44] != codec::LABEL_NORMAL);
            pos += used;
            n += 1;
        }
        std::hint::black_box(hits);
        n
    });

    // --- BP encode ---
    b.run_throughput("bp: encode 50 frames", || {
        let mut w = chimbuko::adios::BpWriter::counting();
        for f in &frames {
            w.put_step(f).unwrap();
        }
        50 * events_per_frame
    });

    println!("\n({} events/frame, {} execs over 50 frames)", events_per_frame, execs_total);
}
