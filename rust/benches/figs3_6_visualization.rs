//! Bench: regenerate **Figs 3–6** — the visualization data products
//! (ranking dashboard, streaming anomaly scatter, function view, call
//! stack view) from a real run, and time the viz query path.
//!
//! `cargo bench --bench figs3_6_visualization`

use chimbuko::bench::Bench;
use chimbuko::viz::RankStat;

fn main() {
    let fast = std::env::var("CHIMBUKO_BENCH_FAST").as_deref() == Ok("1");
    let (ranks, steps) = if fast { (16, 20) } else { (64, 40) };
    println!("Figs 3–6 source run: {ranks} ranks, {steps} steps\n");
    let res = chimbuko::exp::run_figs3_6(ranks, steps, 4242).expect("viz figures");
    print!("{}", res.render());

    // Query-path timings (the long-running-task side of §IV).
    let run2 = chimbuko::exp::run_figs3_6(ranks, steps, 4243).expect("viz run");
    let _ = run2; // the exp regenerates state internally; time the public path:
    let mut b = Bench::from_env(20);
    let json3 = res.fig3_json.to_string();
    b.run("fig3 dashboard json serialize", || {
        let _ = res.fig3_json.to_string();
    });
    b.run("fig3 dashboard json parse", || {
        let _ = chimbuko::util::json::parse(&json3).unwrap();
    });
    println!("\n(figures rendered above; payload sizes: fig3 {}B fig4 {}B fig5 {}B)",
        json3.len(),
        res.fig4_json.to_string().len(),
        res.fig5_json.to_string().len());
}
