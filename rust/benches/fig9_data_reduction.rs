//! Bench: regenerate **Fig 9** — trace data size over MPI processes for
//! raw/filtered BP dumps vs Chimbuko-reduced JSON, plus the §VI-B headline
//! reduction factors.
//!
//! `cargo bench --bench fig9_data_reduction`

fn main() {
    let fast = std::env::var("CHIMBUKO_BENCH_FAST").as_deref() == Ok("1");
    let scales: Vec<usize> = if fast {
        vec![8, 16]
    } else {
        vec![80, 160, 320, 640, 1280, 2560]
    };
    let steps = if fast { 6 } else { 12 };
    println!("Fig 9 sweep: ranks {:?}, {} steps\n", scales, steps);
    let res = chimbuko::exp::run_fig9(&scales, steps, 130).expect("fig9 sweep");
    print!("{}", res.render());

    if let Some(last) = res.rows.last() {
        println!("shape checks vs paper (at max scale):");
        println!(
            "  instrumentation filtering shrinks raw {:.1}x (paper 2300/117.5 ≈ 19.6x)",
            last.raw_bytes as f64 / last.filtered_bytes.max(1) as f64
        );
        println!(
            "  reduction ×{:.0} unfiltered (paper ×148), ×{:.0} filtered (paper ×21)",
            last.factor_unfiltered(),
            last.factor_filtered()
        );
    }
}
