//! Bench: regenerate **Fig 9** — trace data size over MPI processes for
//! raw/filtered BP dumps vs Chimbuko-reduced JSON, plus the §VI-B headline
//! reduction factors — and the provDB service companion sweep (ingest
//! throughput, query latency, resident vs log bytes under retention),
//! written to `BENCH_provdb.json` alongside `BENCH_ps_shards.json`.
//!
//! `cargo bench --bench fig9_data_reduction`

fn main() {
    let fast = std::env::var("CHIMBUKO_BENCH_FAST").as_deref() == Ok("1");
    let scales: Vec<usize> = if fast {
        vec![8, 16]
    } else {
        vec![80, 160, 320, 640, 1280, 2560]
    };
    let steps = if fast { 6 } else { 12 };
    println!("Fig 9 sweep: ranks {:?}, {} steps\n", scales, steps);
    let res = chimbuko::exp::run_fig9(&scales, steps, 130).expect("fig9 sweep");
    print!("{}", res.render());

    if let Some(last) = res.rows.last() {
        println!("shape checks vs paper (at max scale):");
        println!(
            "  instrumentation filtering shrinks raw {:.1}x (paper 2300/117.5 ≈ 19.6x)",
            last.raw_bytes as f64 / last.filtered_bytes.max(1) as f64
        );
        println!(
            "  reduction ×{:.0} unfiltered (paper ×148), ×{:.0} filtered (paper ×21)",
            last.factor_unfiltered(),
            last.factor_filtered()
        );
    }

    // --- provDB service sweep: the serving side of the reduction ----------
    let shard_counts: Vec<usize> = if fast { vec![1, 2] } else { vec![1, 2, 4, 8] };
    let (clients, records, queries, max_per_rank) =
        if fast { (4, 2_000, 60, 500) } else { (8, 20_000, 300, 2_000) };
    println!(
        "\nprovDB sweep: shards {:?}, {} clients x {} records, retention {}/rank\n",
        shard_counts, clients, records, max_per_rank
    );
    let pdb = chimbuko::exp::run_provdb_bench(
        &shard_counts,
        clients,
        records,
        queries,
        max_per_rank,
        7,
    )
    .expect("provdb sweep");
    print!("{}", pdb.render());
    if let (Some(first), Some(last)) = (pdb.rows.first(), pdb.rows.last()) {
        println!(
            "shape check: ingest 1 → {} shards: {:.0} → {:.0} rec/s ({:.2}x); \
             resident {} of {} logged",
            last.shards,
            first.ingest_per_sec,
            last.ingest_per_sec,
            last.ingest_per_sec / first.ingest_per_sec.max(1e-9),
            chimbuko::util::fmt_bytes(last.resident_bytes),
            chimbuko::util::fmt_bytes(last.log_bytes),
        );
    }

    // --- codec A/B/C: jsonl vs binary vs sealed columnar v2 at 4 shards --
    let (c_clients, c_records, c_queries) =
        if fast { (4, 4_000, 48) } else { (8, 20_000, 240) };
    println!(
        "\ncodec sweep: 4 shards, {} clients x {} records, jsonl vs binary vs v2\n",
        c_clients, c_records
    );
    let codec = chimbuko::exp::run_codec_bench(4, c_clients, c_records, c_queries, 7)
        .expect("codec sweep");
    print!("{}", codec.render());
    println!(
        "shape check: binary ingest {:.2}x jsonl (target ≥ 2x); \
         stored bytes/record {:.1} (binary) vs {:.1} (jsonl) vs {:.1} (v2, \
         packing {:.2}x, target ≥ 1.5x)",
        codec.ingest_speedup(),
        codec
            .rows
            .iter()
            .find(|r| r.format == "binary")
            .map(|r| r.log_bytes_per_record)
            .unwrap_or(0.0),
        codec
            .rows
            .iter()
            .find(|r| r.format == "jsonl")
            .map(|r| r.log_bytes_per_record)
            .unwrap_or(0.0),
        codec
            .rows
            .iter()
            .find(|r| r.format == "binary_v2")
            .map(|r| r.log_bytes_per_record)
            .unwrap_or(0.0),
        codec.v2_packing_factor(),
    );

    // --- scan selectivity: zone-map pruning on sealed v2 segments --------
    let (s_ranks, s_records, s_seg, s_iters) =
        if fast { (2, 1_024, 128, 8) } else { (4, 4_096, 256, 40) };
    println!(
        "\nscan sweep: {} ranks x {} records, {} records/segment\n",
        s_ranks, s_records, s_seg
    );
    let scan = chimbuko::exp::run_scan_bench(s_ranks, s_records, s_seg, s_iters, 7)
        .expect("scan sweep");
    print!("{}", scan.render());
    if let (Some(first), Some(last)) = (scan.rows.first(), scan.rows.last()) {
        println!(
            "shape check: 1% window decodes {:.0} of {} records \
             ({:.1} segments pruned/query); 100% decodes {:.0}",
            first.records_decoded,
            scan.total_records,
            first.segments_skipped,
            last.records_decoded,
        );
    }

    // --- chaos scenario: provDB kill/restart with a bounded-loss ledger ---
    // Needs the built `chimbuko` binary to spawn server children; skip
    // loudly (never silently) when it is not around.
    let mut artifact = pdb.to_json();
    match chimbuko::exp::find_chimbuko_bin() {
        Some(bin) => {
            let (ch_shards, ch_ranks, ch_steps) = if fast { (2, 4, 12) } else { (2, 8, 24) };
            println!(
                "\nchaos scenario: {} shards, {} ranks x {} steps, kill ps:0 and provdb:0\n",
                ch_shards, ch_ranks, ch_steps
            );
            let chaos = chimbuko::exp::run_chaos(&bin, ch_shards, ch_ranks, ch_steps, 11)
                .expect("chaos scenario");
            print!("{}", chaos.render());
            artifact.set("chaos_rows", chaos.rows_json());
        }
        None => println!(
            "\nchaos scenario SKIPPED: chimbuko binary not found \
             (build it or set CHIMBUKO_BIN); chaos_rows omitted"
        ),
    }
    artifact.set("codec_rows", codec.rows_json());
    artifact.set("scan_rows", scan.to_json());
    let out = "BENCH_provdb.json";
    std::fs::write(out, artifact.to_pretty()).expect("writing BENCH_provdb.json");
    println!("wrote {out}");
}
