#!/usr/bin/env python3
"""Scoped clippy gate: fail on any warning whose primary span lands in one
of the given source files.

    cargo clippy --all-targets --message-format=json \
        | python3 scripts/clippy_gate.py src/util/net.rs src/ps/net.rs ...

The repo-wide `-D warnings` gate can be relaxed during large refactors;
this gate keeps the transport modules (reactor, framing, protocol
handlers) warning-clean unconditionally — they are the code most likely
to hide a real bug behind an "unused" or "needless" lint.
"""

import json
import sys


def main(argv):
    scoped = set(argv[1:])
    if not scoped:
        print("usage: clippy_gate.py <src/file.rs> [...] < clippy-json", file=sys.stderr)
        return 2
    hits = 0
    for line in sys.stdin:
        line = line.strip()
        if not line.startswith("{"):
            continue
        try:
            msg = json.loads(line)
        except json.JSONDecodeError:
            continue
        if msg.get("reason") != "compiler-message":
            continue
        diag = msg.get("message") or {}
        if diag.get("level") not in ("warning", "error"):
            continue
        for span in diag.get("spans") or []:
            if span.get("is_primary") and span.get("file_name") in scoped:
                hits += 1
                where = f"{span['file_name']}:{span.get('line_start', '?')}"
                print(f"{diag.get('level')}: {where}: {diag.get('message')}")
                break
    if hits:
        print(f"clippy gate: {hits} finding(s) in scoped transport modules", file=sys.stderr)
        return 1
    print(f"clippy gate: scoped modules clean ({', '.join(sorted(scoped))})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
