#!/usr/bin/env python3
"""Diff the codec_rows + scan_rows of a fresh BENCH_provdb.json against
the committed baseline and FAIL (exit non-zero) on codec regressions.

Usage: codec_diff.py <BENCH_provdb.json> <baseline.json>

Hard failures (exit 1):
  * missing/BROKEN schema: no codec_rows or no scan_rows in the fresh
    artifact (a codec change that forgets to emit them fails loudly);
  * binary log bytes/record not strictly smaller than jsonl's;
  * binary_v2 (sealed columnar segments) not >= 1.5x smaller than the
    binary row format — the v2 packing floor;
  * zone maps not pruning: a scan row at <= 10% selectivity with zero
    segments skipped, or decoding more than its proportional share of
    records (3x slack over max(selectivity, per-rank segment floor));
  * vs a non-provisional baseline: bytes/record worse by > 10%, or
    ingest throughput below 50% of baseline (runner noise allowance).

Soft warnings (printed, build passes): binary ingest below the absolute
2x-over-jsonl target — absolute rates depend on the runner class, the
baseline regression check above is the enforced one.

While the baseline carries "provisional": true (pre-CI estimates), the
vs-baseline deltas are informational only; the format-vs-format and
scan-selectivity invariants are enforced regardless, since they compare
the fresh run against itself.
"""

import json
import sys


def rows_by_key(rows):
    return {(r["format"], int(r["shards"])): r for r in rows}


def diff_codec_rows(fresh_by, base_by, provisional, failures):
    metrics = ["ingest_per_sec", "query_p50_us", "query_p99_us", "log_bytes_per_record"]
    print(f"{'codec@shards':<16}{'metric':<22}{'baseline':>14}{'fresh':>14}{'delta':>10}")
    for key in sorted(fresh_by):
        fr = fresh_by[key]
        br = base_by.get(key)
        for m in metrics:
            fv = float(fr.get(m, 0.0))
            if br is None:
                print(f"{key[0]}@{key[1]:<14}{m:<22}{'(new)':>14}{fv:>14.1f}{'':>10}")
                continue
            bv = float(br.get(m, 0.0))
            delta = (fv - bv) / bv * 100.0 if bv else float("inf")
            print(f"{key[0]}@{key[1]:<14}{m:<22}{bv:>14.1f}{fv:>14.1f}{delta:>+9.1f}%")
            if provisional:
                continue
            if m == "log_bytes_per_record" and bv and fv > bv * 1.10:
                failures.append(
                    f"{key[0]}@{key[1]}: log bytes/record {fv:.1f} is "
                    f">10% worse than baseline {bv:.1f}"
                )
            if m == "ingest_per_sec" and bv and fv < bv * 0.50:
                failures.append(
                    f"{key[0]}@{key[1]}: ingest {fv:.0f} rec/s fell below "
                    f"50% of baseline {bv:.0f}"
                )

    def rate(fmt):
        return max(
            (float(r["ingest_per_sec"]) for (f, _), r in fresh_by.items() if f == fmt),
            default=0.0,
        )

    def bytes_per_rec(fmt):
        return min(
            (float(r["log_bytes_per_record"]) for (f, _), r in fresh_by.items() if f == fmt),
            default=0.0,
        )

    speedup = rate("binary") / max(rate("jsonl"), 1e-9)
    print(f"\nbinary/jsonl ingest speedup: {speedup:.2f}x (target >= 2x)")
    if speedup < 2.0:
        print("WARNING: binary ingest below the 2x target (not enforced — see baseline check)")
    b_rec, j_rec = bytes_per_rec("binary"), bytes_per_rec("jsonl")
    if b_rec >= j_rec:
        failures.append(
            f"binary bytes/record {b_rec:.1f} is not smaller than jsonl {j_rec:.1f}"
        )
    v2_rec = bytes_per_rec("binary_v2")
    if v2_rec <= 0.0:
        failures.append("no binary_v2 row in codec_rows — the v2 sweep did not run")
    else:
        packing = b_rec / v2_rec if v2_rec else 0.0
        print(f"binary_v2 packing: {packing:.2f}x over binary rows (floor 1.5x)")
        if v2_rec * 1.5 > b_rec:
            failures.append(
                f"binary_v2 bytes/record {v2_rec:.1f} does not beat binary "
                f"{b_rec:.1f} by the 1.5x floor"
            )


def check_scan_rows(scan, base_scan, failures):
    rows = scan.get("rows") or []
    total = float(scan.get("total_records", 0.0))
    ranks = float(scan.get("ranks", 0.0))
    seg = float(scan.get("segment_records", 0.0))
    if not rows or total <= 0:
        failures.append("scan_rows is empty or lacks total_records")
        return
    base_rows = {int(r["selectivity_pct"]): r for r in (base_scan or {}).get("rows", [])}
    # A step window can never decode less than one segment per rank, so
    # the proportionality bound is against max(selectivity, that floor).
    seg_floor = (seg * ranks) / total if total else 1.0
    print(f"\n{'window':<10}{'p50(µs)':>10}{'p99(µs)':>10}{'decoded':>12}{'skipped':>10}{'base p50':>12}")
    for r in sorted(rows, key=lambda r: int(r["selectivity_pct"])):
        pct = int(r["selectivity_pct"])
        decoded = float(r["records_decoded"])
        skipped = float(r["segments_skipped"])
        br = base_rows.get(pct)
        base_p50 = f"{float(br['query_p50_us']):.1f}" if br else "(new)"
        print(
            f"{pct}%{'':<7}{float(r['query_p50_us']):>10.1f}"
            f"{float(r['query_p99_us']):>10.1f}{decoded:>12.0f}{skipped:>10.1f}{base_p50:>12}"
        )
        if pct <= 10:
            if skipped <= 0.0:
                failures.append(
                    f"scan {pct}%: zone maps pruned no segments (skipped=0)"
                )
            allowed = 3.0 * max(pct / 100.0, seg_floor)
            if decoded / total > allowed:
                failures.append(
                    f"scan {pct}%: decoded {decoded:.0f}/{total:.0f} records "
                    f"({decoded / total:.1%}) exceeds the proportional bound "
                    f"({allowed:.1%})"
                )


def main():
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    with open(sys.argv[1]) as f:
        fresh = json.load(f)
    with open(sys.argv[2]) as f:
        base = json.load(f)
    fresh_rows = fresh.get("codec_rows")
    base_rows = base.get("codec_rows")
    if not fresh_rows:
        print(f"ERROR: {sys.argv[1]} has no codec_rows — did the fig9 codec sweep run?")
        return 1
    if not base_rows:
        print(f"ERROR: {sys.argv[2]} has no codec_rows — baseline schema broken")
        return 1
    fresh_scan = fresh.get("scan_rows")
    if not fresh_scan:
        print(f"ERROR: {sys.argv[1]} has no scan_rows — did the scan sweep run?")
        return 1

    provisional = bool(base.get("provisional"))
    if provisional:
        print(
            "NOTE: baseline is PROVISIONAL (pre-CI estimates, not measured artifacts) —\n"
            "      vs-baseline deltas are informational; format-vs-format and\n"
            "      scan-selectivity invariants are still enforced. Seed the baseline\n"
            "      from this run's BENCH_provdb.json to arm the regression diff.\n"
        )

    failures = []
    diff_codec_rows(rows_by_key(fresh_rows), rows_by_key(base_rows), provisional, failures)
    check_scan_rows(fresh_scan, base.get("scan_rows"), failures)

    if failures:
        print("\nFAIL: codec regression checks failed:")
        for msg in failures:
            print(f"  * {msg}")
        return 1
    print("\nOK: codec + scan checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
