#!/usr/bin/env python3
"""Diff the codec_rows of a fresh BENCH_provdb.json against the committed
baseline so codec regressions are visible in the CI artifact trail.

Usage: codec_diff.py <BENCH_provdb.json> <baseline.json>

Prints a per-(format, shards) comparison table and flags (without
failing the build — CI runners are noisy) when:
  * binary ingest falls below 2x jsonl (the PR acceptance floor), or
  * binary log bytes/record is no longer strictly smaller than jsonl's.
Exits non-zero only when the files are missing or the schema is broken,
so a codec change that forgets to emit codec_rows fails loudly.
"""

import json
import sys


def rows_by_key(rows):
    return {(r["format"], int(r["shards"])): r for r in rows}


def main():
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    with open(sys.argv[1]) as f:
        fresh = json.load(f)
    with open(sys.argv[2]) as f:
        base = json.load(f)
    fresh_rows = fresh.get("codec_rows")
    base_rows = base.get("codec_rows")
    if not fresh_rows:
        print(f"ERROR: {sys.argv[1]} has no codec_rows — did the fig9 codec sweep run?")
        return 1
    if not base_rows:
        print(f"ERROR: {sys.argv[2]} has no codec_rows — baseline schema broken")
        return 1

    fresh_by, base_by = rows_by_key(fresh_rows), rows_by_key(base_rows)
    if base.get("provisional"):
        print(
            "NOTE: baseline is PROVISIONAL (pre-CI estimates, not measured artifacts) —\n"
            "      deltas below are not regression evidence; seed the baseline from this\n"
            "      run's BENCH_provdb.json codec_rows to arm the diff.\n"
        )
    metrics = ["ingest_per_sec", "query_p50_us", "query_p99_us", "log_bytes_per_record"]
    print(f"{'codec@shards':<16}{'metric':<22}{'baseline':>14}{'fresh':>14}{'delta':>10}")
    for key in sorted(fresh_by):
        fr = fresh_by[key]
        br = base_by.get(key)
        for m in metrics:
            fv = float(fr.get(m, 0.0))
            if br is None:
                print(f"{key[0]}@{key[1]:<14}{m:<22}{'(new)':>14}{fv:>14.1f}{'':>10}")
                continue
            bv = float(br.get(m, 0.0))
            delta = (fv - bv) / bv * 100.0 if bv else float("inf")
            print(f"{key[0]}@{key[1]:<14}{m:<22}{bv:>14.1f}{fv:>14.1f}{delta:>+9.1f}%")

    def rate(fmt):
        return max(
            (float(r["ingest_per_sec"]) for (f, _), r in fresh_by.items() if f == fmt),
            default=0.0,
        )

    def bytes_per_rec(fmt):
        return min(
            (float(r["log_bytes_per_record"]) for (f, _), r in fresh_by.items() if f == fmt),
            default=0.0,
        )

    speedup = rate("binary") / max(rate("jsonl"), 1e-9)
    print(f"\nbinary/jsonl ingest speedup: {speedup:.2f}x (target >= 2x)")
    if speedup < 2.0:
        print("WARNING: binary ingest below the 2x floor")
    if bytes_per_rec("binary") >= bytes_per_rec("jsonl"):
        print("WARNING: binary log bytes/record is not smaller than jsonl")
    return 0


if __name__ == "__main__":
    sys.exit(main())
