//! # Chimbuko — workflow-level scalable performance trace analysis
//!
//! Reproduction of *"Chimbuko: A Workflow-Level Scalable Performance Trace
//! Analysis Tool"* (2020) as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the Chimbuko coordination architecture:
//!   per-rank trace streams (an ADIOS2-SST-like step engine), on-node
//!   anomaly-detection modules, a barrier-free parameter server, a
//!   prescriptive-provenance store, and a visualization backend.
//! * **Layer 2 (JAX, build time)** — the anomaly-detection compute graph
//!   (`python/compile/model.py`), AOT-lowered to HLO text.
//! * **Layer 1 (Pallas, build time)** — the segment-statistics hot-spot
//!   kernel (`python/compile/kernels/anomaly.py`), lowered inside the L2
//!   graph; loaded and executed from Rust via PJRT (`runtime`).
//!
//! Python never runs on the analysis path; `make artifacts` produces
//! `artifacts/*.hlo.txt` once and the Rust binary is self-contained.
//!
//! ## Crate map
//!
//! | module | role |
//! |---|---|
//! | [`trace`] | event model + synthetic NWChem-MD workload generator |
//! | [`adios`] | step-based streaming substrate (SST-like + BP file engine) |
//! | [`stats`] | streaming moments with Pébay pairwise merging |
//! | [`ad`] | call-stack building + anomaly detection (Rust and XLA paths) |
//! | [`placement`] | epoch-versioned slot → shard routing tables |
//! | [`probe`] | probe DSL + predicate VM: compiled record filters |
//! | [`ps`] | the online AD parameter server |
//! | [`aggtree`] | hierarchical aggregation tree for O(100k)-rank fan-in |
//! | [`provenance`] | prescriptive provenance records, store and queries |
//! | [`provdb`] | the sharded, networked provenance database service |
//! | [`viz`] | visualization backend (HTTP API + terminal renderings) |
//! | [`runtime`] | PJRT artifact loading and the XLA service thread |
//! | [`coordinator`] | workflow topology + online/offline drivers |
//! | [`bench`] | criterion-lite measurement harness used by `cargo bench` |
//! | [`util`] | json / rng / logging / property-test substrates |

pub mod adios;
pub mod ad;
pub mod aggtree;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod exp;
pub mod placement;
pub mod probe;
pub mod provdb;
pub mod provenance;
pub mod ps;
pub mod runtime;
pub mod stats;
pub mod trace;
pub mod util;
pub mod viz;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// Version string reported by the CLI and the viz server.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
