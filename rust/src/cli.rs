//! Command-line argument parsing (no `clap` offline).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`, positional
//! arguments, typed getters with defaults, and auto-generated usage text.

use std::collections::BTreeMap;

/// Parsed arguments: one optional subcommand, flags, options, positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// First non-flag token, if declared as a subcommand position.
    pub subcommand: Option<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    /// `expect_subcommand` consumes the first positional as a subcommand.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I, expect_subcommand: bool) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "-v" || tok == "-vv" {
                // Short verbosity flags (the only single-dash tokens the
                // CLI accepts) — everything else single-dash stays a
                // positional so negative numbers etc. keep working.
                out.flags.push(tok[1..].to_string());
            } else if let Some(rest) = tok.strip_prefix("--") {
                if let Some(eq) = rest.find('=') {
                    out.options.insert(rest[..eq].to_string(), rest[eq + 1..].to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--") && n != "-v" && n != "-vv")
                    .unwrap_or(false)
                {
                    let val = it.next().unwrap();
                    out.options.insert(rest.to_string(), val);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else if expect_subcommand && out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positionals.push(tok);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env(expect_subcommand: bool) -> Args {
        Args::parse(std::env::args().skip(1), expect_subcommand)
    }

    /// Boolean flag: `--verbose` (bare) or `--verbose=true`.
    ///
    /// Note a bare `--verbose` followed by a non-`--` token consumes that
    /// token as a value (`--verbose true`); place bare flags after
    /// positionals or use the `=` form to disambiguate.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || matches!(
                self.options.get(name).map(|s| s.as_str()),
                Some("true" | "1" | "yes")
            )
    }

    /// String option with default.
    pub fn str_opt(&self, name: &str, default: &str) -> String {
        self.options.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string option.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// u64 option with default; panics with a friendly message on junk.
    pub fn u64_opt(&self, name: &str, default: u64) -> u64 {
        match self.options.get(name) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| die(&format!("--{name} expects an integer, got '{v}'"))),
        }
    }

    /// usize option with default.
    pub fn usize_opt(&self, name: &str, default: usize) -> usize {
        self.u64_opt(name, default as u64) as usize
    }

    /// f64 option with default.
    pub fn f64_opt(&self, name: &str, default: f64) -> f64 {
        match self.options.get(name) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| die(&format!("--{name} expects a number, got '{v}'"))),
        }
    }

    /// Comma-separated list of u64, e.g. `--ranks 10,20,40`.
    pub fn u64_list(&self, name: &str, default: &[u64]) -> Vec<u64> {
        match self.options.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim().parse().unwrap_or_else(|_| {
                        die(&format!("--{name} expects comma-separated integers, got '{v}'"))
                    })
                })
                .collect(),
        }
    }

    /// Positional arguments (after the subcommand).
    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    /// Debug-log verbosity: `-vv` → 2 (trace), `-v` → 1 (debug), else 0.
    /// Every subcommand accepts these; `main` maps the level onto
    /// [`crate::util::log::set_level`] before dispatch.
    pub fn verbosity(&self) -> u8 {
        if self.flag("vv") {
            2
        } else if self.flag("v") {
            1
        } else {
            0
        }
    }
}

fn die(msg: &str) -> ! {
    eprintln!("error: {msg}");
    std::process::exit(2)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str], sub: bool) -> Args {
        Args::parse(tokens.iter().map(|s| s.to_string()), sub)
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["run", "pos1", "--ranks", "64", "--out=/tmp/x", "--verbose"], true);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.u64_opt("ranks", 1), 64);
        assert_eq!(a.str_opt("out", ""), "/tmp/x");
        assert!(a.flag("verbose"));
        assert_eq!(a.positionals(), &["pos1".to_string()]);
        // `=` form also works as a flag.
        let b = parse(&["--quiet=true", "tail"], false);
        assert!(b.flag("quiet"));
        assert!(!b.flag("loud"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[], false);
        assert_eq!(a.u64_opt("ranks", 8), 8);
        assert_eq!(a.f64_opt("alpha", 6.0), 6.0);
        assert!(!a.flag("verbose"));
        assert!(a.subcommand.is_none());
    }

    #[test]
    fn trailing_flag_not_eating_next_flag() {
        let a = parse(&["--fast", "--ranks", "4"], false);
        assert!(a.flag("fast"));
        assert_eq!(a.u64_opt("ranks", 0), 4);
    }

    #[test]
    fn short_verbosity_flags() {
        // `-v`/`-vv` are flags everywhere they appear: they must not be
        // eaten as a subcommand, a positional, or an option value.
        let a = parse(&["exp", "-v", "chaos"], true);
        assert_eq!(a.subcommand.as_deref(), Some("exp"));
        assert_eq!(a.verbosity(), 1);
        assert_eq!(a.positionals(), &["chaos".to_string()]);

        let b = parse(&["--out", "-vv", "run"], true);
        assert_eq!(b.verbosity(), 2);
        assert!(b.get("out").is_none(), "-vv must not become --out's value");
        assert_eq!(b.subcommand.as_deref(), Some("run"));

        assert_eq!(parse(&[], false).verbosity(), 0);
    }

    #[test]
    fn list_option() {
        let a = parse(&["--scales", "10,20,40"], false);
        assert_eq!(a.u64_list("scales", &[]), vec![10, 20, 40]);
        assert_eq!(a.u64_list("missing", &[1, 2]), vec![1, 2]);
    }
}
