//! Run configuration: a typed struct covering every knob of the pipeline,
//! loadable from a simple `key = value` file (TOML-subset; the offline
//! registry has no toml/serde) with `#` comments and section headers that
//! become key prefixes (`[ad]` + `alpha = 6` → `ad.alpha`).

use crate::util::json::Json;
use anyhow::{bail, Context};
use std::collections::BTreeMap;
use std::path::Path;

/// Which labelling algorithm the detector uses.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum AdAlgorithm {
    /// μ ± α·σ thresholding (the paper's method).
    Threshold,
    /// Histogram-based outlier score (the paper's §VIII extension).
    Hbos,
}

impl AdAlgorithm {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "threshold" | "sstd" => Ok(AdAlgorithm::Threshold),
            "hbos" => Ok(AdAlgorithm::Hbos),
            other => bail!("unknown AD algorithm '{other}' (threshold|hbos)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            AdAlgorithm::Threshold => "threshold",
            AdAlgorithm::Hbos => "hbos",
        }
    }
}

/// Which detector backend executes the AD math.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum DetectorBackend {
    /// Pure-Rust streaming statistics (baseline / fallback).
    Rust,
    /// AOT-compiled JAX+Pallas artifact via PJRT (the paper's hot path here).
    Xla,
}

impl DetectorBackend {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "rust" => Ok(DetectorBackend::Rust),
            "xla" => Ok(DetectorBackend::Xla),
            other => bail!("unknown detector backend '{other}' (rust|xla)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DetectorBackend::Rust => "rust",
            DetectorBackend::Xla => "xla",
        }
    }
}

/// Trace output engine for the instrumented app (paper: ADIOS2 SST vs BP).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum TraceEngine {
    /// In-situ step stream consumed by on-node AD (ADIOS2 SST analogue).
    Sst,
    /// Dump-to-disk engine (ADIOS2 BP analogue) — the "TAU only" baseline.
    Bp,
}

impl TraceEngine {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "sst" => Ok(TraceEngine::Sst),
            "bp" => Ok(TraceEngine::Bp),
            other => bail!("unknown trace engine '{other}' (sst|bp)"),
        }
    }
}

/// Full pipeline configuration. Field names mirror the paper's terms.
#[derive(Clone, Debug)]
pub struct Config {
    /// Simulated MPI ranks across the workflow.
    pub ranks: usize,
    /// Applications in the workflow (the paper demos 2: sim + analysis).
    pub apps: usize,
    /// Trace steps ("frames"; paper streams once per second).
    pub steps: usize,
    /// Function events per rank per step (ENTRY/EXIT pairs), before nesting.
    pub calls_per_step: usize,
    /// AD threshold multiplier α in μ ± α·σ (paper: 6).
    pub alpha: f64,
    /// Normal calls kept before/after each anomaly (paper: k = 5).
    pub k_neighbors: usize,
    /// Parameter-server sync-and-broadcast cadence in steps (paper: 1 s).
    pub ps_period_steps: usize,
    /// Parameter-server stat shards (hash-routed threads; 1 = the
    /// single-consumer layout, >1 scales sync throughput with cores).
    pub ps_shards: usize,
    /// Remote PS shard endpoints (`ps-shard-server` addresses,
    /// comma-separated in config; index == shard id). Non-empty switches
    /// the PS to the multi-process topology: stat shards live in those
    /// processes and this process keeps only the aggregator/front-end.
    pub ps_endpoints: Vec<String>,
    /// TCP connections per remote PS shard endpoint. The driver's AD
    /// workers pick `rank % pool`, so they no longer serialize behind a
    /// single write→read window per shard (the `rust/docs/ps.md`
    /// limitation before the pool).
    pub ps_conn_pool: usize,
    /// Skew-check cadence of the PS rebalancer, milliseconds; 0 (default)
    /// disables live rebalancing (placement stays at epoch 0).
    pub ps_rebalance_interval_ms: u64,
    /// Rebalance trigger: act when the windowed per-shard merge load has
    /// max/mean above this ratio (must be ≥ 1).
    pub ps_rebalance_max_ratio: f64,
    /// Minimum windowed merge count before the rebalancer judges skew
    /// (tiny windows are noise); 0 = judge every window.
    pub ps_rebalance_min_merges: u64,
    /// Aggregation-tree fanout. 0 or 1 (default) keeps the flat
    /// single-thread aggregator; ≥ 2 spreads step folding across a
    /// hierarchical tree of aggregator nodes when the rank count spans
    /// at least two leaves. Bit-equivalent output — purely a fan-in
    /// scaling knob. See `rust/docs/aggtree.md`.
    pub ps_agg_fanout: usize,
    /// Remote aggregation-tree leaf endpoints (`agg-node` addresses,
    /// comma-separated in config; index == leaf index, "" = in-process).
    /// Only meaningful with `ps.agg_fanout` ≥ 2.
    pub ps_agg_endpoints: Vec<String>,
    /// Wall-clock viz publish cadence in milliseconds (the paper's 1 s);
    /// 0 disables. Runs alongside the report-count cadence so viz
    /// freshness is decoupled from rank count.
    pub publish_interval_ms: u64,
    /// Provenance database service address ("host:port"); when non-empty
    /// the AD modules write records there over TCP instead of the local
    /// per-worker store, and the viz layer queries it on demand.
    pub provdb_addr: String,
    /// Shards for a provDB service this process spawns
    /// (`provdb-server` subcommand, driver tests).
    pub provdb_shards: usize,
    /// ProvClient write batch: records buffered per wire round-trip.
    pub provdb_batch: usize,
    /// ProvDB retention: retained records per (app, rank); 0 = unbounded.
    pub provdb_max_per_rank: usize,
    /// ProvDB rolling-segment bound: hot records per (app, rank) before
    /// the partition seals into a columnar v2 `.provseg` segment;
    /// 0 = never seal (single append file, the pre-v2 layout).
    pub provdb_segment_records: usize,
    /// ProvDB expiry window (µs of virtual time); records older than
    /// `partition max entry − window` are dropped at flush, whole
    /// sealed segments at a time via their zone maps. 0 = keep forever.
    pub provdb_retain_window_us: u64,
    /// ProvDB record format: the binary codec (default) or the JSONL
    /// escape hatch (`log_format = jsonl`). Controls the append-log
    /// layout of a `provdb-server` started from this config (classic
    /// `*.jsonl` files vs `.provseg` segments) and the wire encoding the
    /// driver's AD workers use when `provdb.addr` is set.
    pub provdb_log_format: crate::provenance::RecordFormat,
    /// Detector backend.
    pub backend: DetectorBackend,
    /// Labelling algorithm (threshold = the paper's; hbos = extension).
    pub algorithm: AdAlgorithm,
    /// Trace engine for the generated trace.
    pub engine: TraceEngine,
    /// Apply the paper's "filtered" function list (drop high-frequency,
    /// short-duration functions at instrumentation time).
    pub filtered: bool,
    /// Seed for workload generation + anomaly injection.
    pub seed: u64,
    /// Output directory (provenance, reduced JSON, viz dumps).
    pub out_dir: String,
    /// Directory holding `*.hlo.txt` AOT artifacts.
    pub artifacts_dir: String,
    /// AD batch capacity (events per XLA invocation; AOT-baked).
    pub batch_capacity: usize,
    /// Function-table capacity (AOT-baked slot count).
    pub func_capacity: usize,
    /// Bounded step-queue depth between app and AD (SST buffering).
    pub sst_queue_depth: usize,
    /// Total CPU milliseconds of *application compute* simulated across
    /// the whole run (strong scaling: split over ranks × steps, so
    /// per-rank work shrinks as ranks grow — like a fixed problem size on
    /// Summit). 0 disables app compute (pure analysis benchmarks).
    pub app_work_ms_total: u64,
    /// Viz server bind address, e.g. "127.0.0.1:0" (0 = ephemeral port).
    pub viz_addr: String,
    /// Emit per-step anomaly statistics to the viz ingest path.
    pub viz_enabled: bool,
    /// Probe file installed into the provDB service at run start (one or
    /// more probe definitions, `rust/docs/probe.md` grammar). Empty (the
    /// default) installs nothing. Requires `provdb.addr`.
    pub probe_file: String,
    /// Inline sampling probe gating the AD workers' provenance sink: kept
    /// records matching the predicate are down-sampled by the probe's
    /// `sample` clause before they reach the store / wire. Empty disables
    /// the gate (every kept record is written, the pre-probe behaviour).
    pub probe_sample: String,
    /// Inline trigger probe the PS aggregator evaluates against global
    /// anomaly events; matching events are pushed to the provDB service
    /// immediately instead of waiting for the next sync period. Empty
    /// disables triggers. Requires `provdb.addr`.
    pub probe_trigger: String,
    /// Event-loop threads per TCP server (PS front-end, PS shard
    /// endpoints, provDB, viz): the poll(2) reactor serves every
    /// connection on this fixed pool, so server thread count is
    /// independent of client count.
    pub net_reactor_threads: usize,
    /// Per-connection reply-backlog bound, bytes. A connection whose
    /// unflushed replies exceed this has further requests shed with a
    /// `Busy` control frame instead of queueing unboundedly.
    pub net_conn_queue_bytes: usize,
    /// Server-wide reply-backlog bound, bytes, summed over all of a
    /// server's connections; above it every new request is shed.
    pub net_server_queue_bytes: usize,
    /// Chaos plane (`rust/docs/chaos.md`): seed for the deterministic
    /// [`FaultPlan`](crate::util::fault::FaultPlan); 0 reuses the run
    /// seed. The plan only activates when at least one fault knob below
    /// is non-zero (or `chaos.kills` is non-empty).
    pub chaos_seed: u64,
    /// Sever an incoming connection's read burst every ~N bursts; 0 off.
    pub chaos_sever_every: u64,
    /// Stall the server read path every ~N bursts, by `chaos.stall_ms`.
    pub chaos_stall_every: u64,
    pub chaos_stall_ms: u64,
    /// Delay a reply every ~N admitted frames, by `chaos.delay_ms`.
    pub chaos_delay_every: u64,
    pub chaos_delay_ms: u64,
    /// Tear the tail off every ~Nth sealed `.provseg` segment, leaving
    /// it `chaos.torn_tail_bytes` short of complete.
    pub chaos_torn_every: u64,
    pub chaos_torn_tail_bytes: u64,
    /// Scheduled child-process kills, comma-separated `target:index@step`
    /// specs (`ps:0@6,provdb:0@10`); executed by the chaos supervisor.
    pub chaos_kills: String,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            ranks: 8,
            apps: 2,
            steps: 20,
            calls_per_step: 200,
            alpha: 6.0,
            k_neighbors: 5,
            ps_period_steps: 1,
            ps_shards: 4,
            ps_endpoints: Vec::new(),
            ps_conn_pool: 4,
            ps_rebalance_interval_ms: 0,
            ps_rebalance_max_ratio: 1.5,
            ps_rebalance_min_merges: 256,
            ps_agg_fanout: 0,
            ps_agg_endpoints: Vec::new(),
            publish_interval_ms: 0,
            provdb_addr: String::new(),
            provdb_shards: 4,
            provdb_batch: 64,
            provdb_max_per_rank: 0,
            provdb_segment_records: 8192,
            provdb_retain_window_us: 0,
            provdb_log_format: crate::provenance::RecordFormat::Binary,
            backend: DetectorBackend::Rust,
            algorithm: AdAlgorithm::Threshold,
            engine: TraceEngine::Sst,
            filtered: true,
            seed: 1234,
            out_dir: "chimbuko_out".into(),
            artifacts_dir: "artifacts".into(),
            batch_capacity: 256,
            func_capacity: 64,
            sst_queue_depth: 4,
            app_work_ms_total: 0,
            viz_addr: "127.0.0.1:0".into(),
            viz_enabled: true,
            probe_file: String::new(),
            probe_sample: String::new(),
            probe_trigger: String::new(),
            net_reactor_threads: 2,
            net_conn_queue_bytes: 1 << 20,
            net_server_queue_bytes: 64 << 20,
            chaos_seed: 0,
            chaos_sever_every: 0,
            chaos_stall_every: 0,
            chaos_stall_ms: 20,
            chaos_delay_every: 0,
            chaos_delay_ms: 5,
            chaos_torn_every: 0,
            chaos_torn_tail_bytes: 5,
            chaos_kills: String::new(),
        }
    }
}

impl Config {
    /// Parse a `key = value` config file (TOML subset, see module docs).
    pub fn from_file(path: &Path) -> anyhow::Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_str(&text)
    }

    /// Parse config text.
    pub fn from_str(text: &str) -> anyhow::Result<Config> {
        let kv = parse_kv(text)?;
        let mut cfg = Config::default();
        for (key, value) in &kv {
            cfg.apply(key, value)
                .with_context(|| format!("config key '{key}'"))?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply one key (dotted form) — also used for CLI overrides.
    pub fn apply(&mut self, key: &str, value: &str) -> anyhow::Result<()> {
        let v = value.trim().trim_matches('"');
        match key {
            "ranks" => self.ranks = v.parse()?,
            "apps" => self.apps = v.parse()?,
            "steps" => self.steps = v.parse()?,
            "calls_per_step" => self.calls_per_step = v.parse()?,
            "seed" => self.seed = v.parse()?,
            "out_dir" => self.out_dir = v.to_string(),
            "artifacts_dir" => self.artifacts_dir = v.to_string(),
            "filtered" => self.filtered = parse_bool(v)?,
            "engine" => self.engine = TraceEngine::parse(v)?,
            "ad.alpha" | "alpha" => self.alpha = v.parse()?,
            "ad.k_neighbors" | "k" => self.k_neighbors = v.parse()?,
            "ad.backend" | "backend" => self.backend = DetectorBackend::parse(v)?,
            "ad.algorithm" | "algorithm" => self.algorithm = AdAlgorithm::parse(v)?,
            "ad.batch_capacity" => self.batch_capacity = v.parse()?,
            "ad.func_capacity" => self.func_capacity = v.parse()?,
            "ps.period_steps" => self.ps_period_steps = v.parse()?,
            "ps.shards" => self.ps_shards = v.parse()?,
            "ps.endpoints" => {
                self.ps_endpoints = v
                    .split(',')
                    .map(|s| s.trim().trim_matches('"').to_string())
                    .filter(|s| !s.is_empty())
                    .collect();
            }
            "ps.agg_fanout" => self.ps_agg_fanout = v.parse()?,
            "ps.agg_endpoints" => {
                // Unlike ps.endpoints, empty slots are kept: "" in slot i
                // means leaf i stays in-process.
                self.ps_agg_endpoints = if v.trim().is_empty() {
                    Vec::new()
                } else {
                    v.split(',').map(|s| s.trim().trim_matches('"').to_string()).collect()
                };
            }
            "ps.conn_pool" => self.ps_conn_pool = v.parse()?,
            "ps.rebalance_interval_ms" => self.ps_rebalance_interval_ms = v.parse()?,
            "ps.rebalance_max_ratio" => self.ps_rebalance_max_ratio = v.parse()?,
            "ps.rebalance_min_merges" => self.ps_rebalance_min_merges = v.parse()?,
            "ps.publish_interval_ms" => self.publish_interval_ms = v.parse()?,
            "provdb.addr" => self.provdb_addr = v.to_string(),
            "provdb.shards" => self.provdb_shards = v.parse()?,
            "provdb.batch" => self.provdb_batch = v.parse()?,
            "provdb.max_records_per_rank" => self.provdb_max_per_rank = v.parse()?,
            "provdb.segment_records" => self.provdb_segment_records = v.parse()?,
            "provdb.retain_window_us" => self.provdb_retain_window_us = v.parse()?,
            "provdb.log_format" => {
                self.provdb_log_format = crate::provenance::RecordFormat::parse(v)?
            }
            "sst.queue_depth" => self.sst_queue_depth = v.parse()?,
            "app_work_ms_total" => self.app_work_ms_total = v.parse()?,
            "viz.addr" => self.viz_addr = v.to_string(),
            "viz.enabled" => self.viz_enabled = parse_bool(v)?,
            "probe.file" => self.probe_file = v.to_string(),
            "probe.sample" => self.probe_sample = v.to_string(),
            "probe.trigger" => self.probe_trigger = v.to_string(),
            "net.reactor_threads" => self.net_reactor_threads = v.parse()?,
            "net.conn_queue_bytes" => self.net_conn_queue_bytes = v.parse()?,
            "net.server_queue_bytes" => self.net_server_queue_bytes = v.parse()?,
            "chaos.seed" => self.chaos_seed = v.parse()?,
            "chaos.sever_every" => self.chaos_sever_every = v.parse()?,
            "chaos.stall_every" => self.chaos_stall_every = v.parse()?,
            "chaos.stall_ms" => self.chaos_stall_ms = v.parse()?,
            "chaos.delay_every" => self.chaos_delay_every = v.parse()?,
            "chaos.delay_ms" => self.chaos_delay_ms = v.parse()?,
            "chaos.torn_every" => self.chaos_torn_every = v.parse()?,
            "chaos.torn_tail_bytes" => self.chaos_torn_tail_bytes = v.parse()?,
            "chaos.kills" => self.chaos_kills = v.to_string(),
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Reject configurations the pipeline cannot run.
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.ranks == 0 {
            bail!("ranks must be > 0");
        }
        if self.apps == 0 || self.apps > self.ranks {
            bail!("apps must be in 1..=ranks (got {} apps, {} ranks)", self.apps, self.ranks);
        }
        if self.steps == 0 {
            bail!("steps must be > 0");
        }
        if self.alpha <= 0.0 {
            bail!("ad.alpha must be positive");
        }
        if self.batch_capacity == 0 || self.func_capacity == 0 {
            bail!("batch/function capacities must be > 0");
        }
        if self.ps_period_steps == 0 {
            bail!("ps.period_steps must be > 0");
        }
        if self.ps_shards == 0 || self.ps_shards > crate::placement::SLOTS {
            bail!("ps.shards must be in 1..={}", crate::placement::SLOTS);
        }
        if self.ps_conn_pool == 0 {
            bail!("ps.conn_pool must be > 0");
        }
        if self.ps_rebalance_max_ratio < 1.0 {
            bail!("ps.rebalance_max_ratio must be >= 1.0");
        }
        if self.ps_agg_fanout == 1 {
            bail!("ps.agg_fanout must be 0 (flat) or >= 2 (tree)");
        }
        if !self.ps_agg_endpoints.is_empty() && self.ps_agg_fanout < 2 {
            bail!("ps.agg_endpoints requires ps.agg_fanout >= 2");
        }
        if self.provdb_shards == 0 || self.provdb_shards > crate::placement::SLOTS {
            // Placement routes through SLOTS fixed slots; more shards
            // than slots would leave the excess permanently empty.
            bail!("provdb.shards must be in 1..={}", crate::placement::SLOTS);
        }
        if self.provdb_batch == 0 {
            bail!("provdb.batch must be > 0");
        }
        if self.sst_queue_depth == 0 {
            bail!("sst.queue_depth must be > 0");
        }
        if self.net_reactor_threads == 0 {
            bail!("net.reactor_threads must be > 0");
        }
        if self.net_conn_queue_bytes < 4096 {
            // Below one reply's worth of headroom every request sheds.
            bail!("net.conn_queue_bytes must be >= 4096");
        }
        if self.net_server_queue_bytes < self.net_conn_queue_bytes {
            bail!("net.server_queue_bytes must be >= net.conn_queue_bytes");
        }
        // Inline probes must compile at config time, not mid-run. The
        // probe *file* is read (and each definition checked) at install
        // time, because the path need not exist where the config parses.
        if !self.probe_sample.is_empty() {
            crate::probe::Probe::compile(&self.probe_sample)
                .context("probe.sample does not compile")?;
        }
        if !self.probe_trigger.is_empty() {
            crate::probe::Probe::compile(&self.probe_trigger)
                .context("probe.trigger does not compile")?;
        }
        if (!self.probe_file.is_empty() || !self.probe_trigger.is_empty())
            && self.provdb_addr.is_empty()
        {
            bail!("probe.file / probe.trigger require provdb.addr to be set");
        }
        // The kill schedule must parse at config time, not mid-run.
        crate::util::fault::parse_kills(&self.chaos_kills).context("chaos.kills")?;
        if self.chaos_stall_every > 0 && self.chaos_stall_ms == 0 {
            bail!("chaos.stall_every requires chaos.stall_ms > 0");
        }
        if self.chaos_torn_every > 0 && self.chaos_torn_tail_bytes == 0 {
            bail!("chaos.torn_every requires chaos.torn_tail_bytes > 0");
        }
        Ok(())
    }

    /// Build the chaos [`FaultPlan`](crate::util::fault::FaultPlan) this
    /// config describes; `None` when every fault knob is off (the
    /// production default). `chaos.seed = 0` reuses the run seed so a
    /// single seed reproduces workload *and* fault schedule.
    pub fn fault_plan(&self) -> anyhow::Result<Option<crate::util::fault::FaultPlan>> {
        let plan = crate::util::fault::FaultPlan {
            seed: if self.chaos_seed != 0 { self.chaos_seed } else { self.seed },
            sever_every: self.chaos_sever_every,
            stall_every: self.chaos_stall_every,
            stall_ms: self.chaos_stall_ms,
            delay_every: self.chaos_delay_every,
            delay_ms: self.chaos_delay_ms,
            torn_every: self.chaos_torn_every,
            torn_tail_bytes: self.chaos_torn_tail_bytes,
            kills: crate::util::fault::parse_kills(&self.chaos_kills)?,
            ..Default::default()
        };
        Ok(if plan.any_faults() { Some(plan) } else { None })
    }

    /// Reactor sizing for every TCP server this config spawns.
    pub fn net_opts(&self) -> crate::util::net::ReactorOpts {
        crate::util::net::ReactorOpts::new(
            self.net_reactor_threads,
            self.net_conn_queue_bytes,
            self.net_server_queue_bytes,
        )
    }

    /// JSON dump (run metadata in provenance, `--print-config`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ranks", Json::num(self.ranks as f64)),
            ("apps", Json::num(self.apps as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("calls_per_step", Json::num(self.calls_per_step as f64)),
            ("alpha", Json::num(self.alpha)),
            ("k_neighbors", Json::num(self.k_neighbors as f64)),
            ("ps_period_steps", Json::num(self.ps_period_steps as f64)),
            ("ps_shards", Json::num(self.ps_shards as f64)),
            ("ps_endpoints", Json::str(&self.ps_endpoints.join(","))),
            ("ps_conn_pool", Json::num(self.ps_conn_pool as f64)),
            ("ps_rebalance_interval_ms", Json::num(self.ps_rebalance_interval_ms as f64)),
            ("ps_rebalance_max_ratio", Json::num(self.ps_rebalance_max_ratio)),
            ("ps_rebalance_min_merges", Json::num(self.ps_rebalance_min_merges as f64)),
            ("ps_agg_fanout", Json::num(self.ps_agg_fanout as f64)),
            ("ps_agg_endpoints", Json::str(&self.ps_agg_endpoints.join(","))),
            ("ps_publish_interval_ms", Json::num(self.publish_interval_ms as f64)),
            ("provdb_addr", Json::str(&self.provdb_addr)),
            ("provdb_shards", Json::num(self.provdb_shards as f64)),
            ("provdb_max_records_per_rank", Json::num(self.provdb_max_per_rank as f64)),
            ("provdb_segment_records", Json::num(self.provdb_segment_records as f64)),
            ("provdb_retain_window_us", Json::num(self.provdb_retain_window_us as f64)),
            ("provdb_log_format", Json::str(self.provdb_log_format.name())),
            ("backend", Json::str(self.backend.name())),
            ("algorithm", Json::str(self.algorithm.name())),
            (
                "engine",
                Json::str(match self.engine {
                    TraceEngine::Sst => "sst",
                    TraceEngine::Bp => "bp",
                }),
            ),
            ("probe_file", Json::str(&self.probe_file)),
            ("probe_sample", Json::str(&self.probe_sample)),
            ("probe_trigger", Json::str(&self.probe_trigger)),
            ("filtered", Json::Bool(self.filtered)),
            ("seed", Json::num(self.seed as f64)),
            ("out_dir", Json::str(&self.out_dir)),
            ("batch_capacity", Json::num(self.batch_capacity as f64)),
            ("func_capacity", Json::num(self.func_capacity as f64)),
            ("net_reactor_threads", Json::num(self.net_reactor_threads as f64)),
            ("net_conn_queue_bytes", Json::num(self.net_conn_queue_bytes as f64)),
            ("net_server_queue_bytes", Json::num(self.net_server_queue_bytes as f64)),
            ("chaos_seed", Json::num(self.chaos_seed as f64)),
            ("chaos_sever_every", Json::num(self.chaos_sever_every as f64)),
            ("chaos_stall_every", Json::num(self.chaos_stall_every as f64)),
            ("chaos_delay_every", Json::num(self.chaos_delay_every as f64)),
            ("chaos_torn_every", Json::num(self.chaos_torn_every as f64)),
            ("chaos_kills", Json::str(&self.chaos_kills)),
        ])
    }
}

fn parse_bool(v: &str) -> anyhow::Result<bool> {
    match v {
        "true" | "1" | "yes" => Ok(true),
        "false" | "0" | "no" => Ok(false),
        other => bail!("expected boolean, got '{other}'"),
    }
}

/// Parse `key = value` lines with `[section]` prefixes and `#` comments.
fn parse_kv(text: &str) -> anyhow::Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(sec) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            section = sec.trim().to_string();
            continue;
        }
        let Some(eq) = line.find('=') else {
            bail!("config line {} is not 'key = value': '{raw}'", lineno + 1);
        };
        let key = line[..eq].trim();
        let value = line[eq + 1..].trim();
        if key.is_empty() {
            bail!("empty key at config line {}", lineno + 1);
        }
        let full = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        out.insert(full, value.to_string());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn parse_full_file() {
        let text = r#"
# chimbuko run config
ranks = 64
steps = 30
engine = bp
filtered = false

[ad]
alpha = 5.5        # threshold
backend = rust
k_neighbors = 3

[ps]
period_steps = 2
shards = 3

[viz]
enabled = false
"#;
        let c = Config::from_str(text).unwrap();
        assert_eq!(c.ranks, 64);
        assert_eq!(c.steps, 30);
        assert_eq!(c.engine, TraceEngine::Bp);
        assert!(!c.filtered);
        assert_eq!(c.alpha, 5.5);
        assert_eq!(c.k_neighbors, 3);
        assert_eq!(c.ps_period_steps, 2);
        assert_eq!(c.ps_shards, 3);
        assert!(!c.viz_enabled);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(Config::from_str("bogus = 1").is_err());
    }

    #[test]
    fn ps_topology_keys_parse() {
        let text = r#"
[ps]
shards = 2
endpoints = 127.0.0.1:5561, 127.0.0.1:5562
publish_interval_ms = 1000
"#;
        let c = Config::from_str(text).unwrap();
        assert_eq!(c.ps_shards, 2);
        assert_eq!(c.ps_endpoints, vec!["127.0.0.1:5561", "127.0.0.1:5562"]);
        assert_eq!(c.publish_interval_ms, 1000);
        // Defaults: in-process shards, wall-clock cadence off.
        assert!(Config::default().ps_endpoints.is_empty());
        assert_eq!(Config::default().publish_interval_ms, 0);
        // The endpoint list round-trips through the JSON dump.
        let j = c.to_json();
        assert_eq!(
            j.get("ps_endpoints").unwrap().as_str(),
            Some("127.0.0.1:5561,127.0.0.1:5562")
        );
    }

    #[test]
    fn ps_rebalance_keys_parse_and_validate() {
        let text = r#"
[ps]
conn_pool = 2
rebalance_interval_ms = 500
rebalance_max_ratio = 1.3
rebalance_min_merges = 64
"#;
        let c = Config::from_str(text).unwrap();
        assert_eq!(c.ps_conn_pool, 2);
        assert_eq!(c.ps_rebalance_interval_ms, 500);
        assert_eq!(c.ps_rebalance_max_ratio, 1.3);
        assert_eq!(c.ps_rebalance_min_merges, 64);
        // Defaults: pool of 4, live rebalancing off.
        assert_eq!(Config::default().ps_conn_pool, 4);
        assert_eq!(Config::default().ps_rebalance_interval_ms, 0);
        assert!(Config::from_str("[ps]\nconn_pool = 0").is_err());
        assert!(Config::from_str("[ps]\nrebalance_max_ratio = 0.5").is_err());
    }

    #[test]
    fn aggtree_keys_parse_and_validate() {
        let text = r#"
[ps]
agg_fanout = 4
agg_endpoints = 127.0.0.1:5571, , 127.0.0.1:5573
"#;
        let c = Config::from_str(text).unwrap();
        assert_eq!(c.ps_agg_fanout, 4);
        // Slot 1 is kept empty: that leaf stays in-process.
        assert_eq!(c.ps_agg_endpoints, vec!["127.0.0.1:5571", "", "127.0.0.1:5573"]);
        let j = c.to_json();
        assert_eq!(j.get("ps_agg_fanout").unwrap().as_f64(), Some(4.0));
        // Defaults: flat aggregator.
        assert_eq!(Config::default().ps_agg_fanout, 0);
        assert!(Config::default().ps_agg_endpoints.is_empty());
        assert!(Config::from_str("[ps]\nagg_fanout = 1").is_err());
        assert!(Config::from_str("[ps]\nagg_endpoints = 127.0.0.1:5571").is_err());
    }

    #[test]
    fn provdb_keys_parse_and_validate() {
        let text = r#"
[provdb]
addr = 127.0.0.1:5560
shards = 3
batch = 16
max_records_per_rank = 500
segment_records = 256
retain_window_us = 5000000
log_format = jsonl
"#;
        let c = Config::from_str(text).unwrap();
        assert_eq!(c.provdb_addr, "127.0.0.1:5560");
        assert_eq!(c.provdb_shards, 3);
        assert_eq!(c.provdb_batch, 16);
        assert_eq!(c.provdb_max_per_rank, 500);
        assert_eq!(c.provdb_segment_records, 256);
        assert_eq!(c.provdb_retain_window_us, 5_000_000);
        assert_eq!(c.provdb_log_format, crate::provenance::RecordFormat::Jsonl);
        assert!(Config::from_str("[provdb]\nshards = 0").is_err());
        assert!(Config::from_str("[provdb]\nbatch = 0").is_err());
        assert!(Config::from_str("[provdb]\nlog_format = xml").is_err());
        // Defaults: disabled, binary codec.
        assert!(Config::default().provdb_addr.is_empty());
        assert_eq!(
            Config::default().provdb_log_format,
            crate::provenance::RecordFormat::Binary
        );
    }

    #[test]
    fn net_keys_parse_and_validate() {
        let text = r#"
[net]
reactor_threads = 4
conn_queue_bytes = 65536
server_queue_bytes = 1048576
"#;
        let c = Config::from_str(text).unwrap();
        assert_eq!(c.net_reactor_threads, 4);
        let opts = c.net_opts();
        assert_eq!(opts.threads, 4);
        assert_eq!(opts.conn_queue_bytes, 65536);
        assert_eq!(opts.server_queue_bytes, 1048576);
        assert!(Config::from_str("[net]\nreactor_threads = 0").is_err());
        assert!(Config::from_str("[net]\nconn_queue_bytes = 16").is_err());
        assert!(
            Config::from_str("[net]\nconn_queue_bytes = 65536\nserver_queue_bytes = 8192")
                .is_err()
        );
        // Defaults: 2 loops, 1 MiB per connection, 64 MiB server-wide.
        let d = Config::default();
        assert_eq!(d.net_reactor_threads, 2);
        assert_eq!(d.net_conn_queue_bytes, 1 << 20);
        assert_eq!(d.net_server_queue_bytes, 64 << 20);
    }

    #[test]
    fn probe_keys_parse_and_validate() {
        let text = r#"
[provdb]
addr = 127.0.0.1:5560

[probe]
file = configs/probes.d/example.probe
sample = fn:*.*:exit / anomaly / sample 10%
trigger = fn:*.*:exit / score > 10.0 / { capture(record); }
"#;
        let c = Config::from_str(text).unwrap();
        assert_eq!(c.probe_file, "configs/probes.d/example.probe");
        assert!(c.probe_sample.contains("sample 10%"));
        assert!(c.probe_trigger.contains("score > 10.0"));
        // Inline probes are compiled at validate() time.
        assert!(Config::from_str("[probe]\nsample = fn:*.*:exit / score @@ /").is_err());
        assert!(Config::from_str("[probe]\ntrigger = not a probe").is_err());
        // file / trigger need a provDB to land in.
        assert!(Config::from_str("[probe]\nfile = x.probe").is_err());
        // Defaults: everything off.
        let d = Config::default();
        assert!(d.probe_file.is_empty() && d.probe_sample.is_empty());
        assert!(d.probe_trigger.is_empty());
    }

    #[test]
    fn chaos_keys_parse_and_validate() {
        let text = r#"
seed = 77

[chaos]
sever_every = 40
stall_every = 16
stall_ms = 10
torn_every = 2
torn_tail_bytes = 5
kills = ps:0@6, provdb:0@10
"#;
        let c = Config::from_str(text).unwrap();
        assert_eq!(c.chaos_sever_every, 40);
        assert_eq!(c.chaos_stall_ms, 10);
        assert_eq!(c.chaos_kills, "ps:0@6, provdb:0@10");
        let plan = c.fault_plan().unwrap().expect("live knobs must yield a plan");
        // chaos.seed = 0 reuses the run seed.
        assert_eq!(plan.seed, 77);
        assert_eq!(plan.kills.len(), 2);
        assert_eq!(plan.kills[0].at_step, 6);
        // Defaults: chaos entirely off.
        assert!(Config::default().fault_plan().unwrap().is_none());
        // A malformed kill schedule is rejected at config time.
        assert!(Config::from_str("[chaos]\nkills = disk:0@4").is_err());
        assert!(Config::from_str("[chaos]\nstall_every = 4\nstall_ms = 0").is_err());
        assert!(Config::from_str("[chaos]\ntorn_every = 2\ntorn_tail_bytes = 0").is_err());
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(Config::from_str("ranks = 0").is_err());
        assert!(Config::from_str("alpha = -1").is_err());
        assert!(Config::from_str("[ps]\nshards = 0").is_err());
        // Placement routes through 256 fixed slots; more shards than
        // slots would leave the excess permanently empty.
        assert!(Config::from_str("[ps]\nshards = 500").is_err());
        assert!(Config::from_str("[provdb]\nshards = 500").is_err());
        assert!(Config::from_str("engine = adios").is_err());
        assert!(Config::from_str("ranks = abc").is_err());
    }

    #[test]
    fn config_json_roundtrips_fields() {
        let j = Config::default().to_json();
        assert_eq!(j.get("alpha").unwrap().as_f64(), Some(6.0));
        assert_eq!(j.get("backend").unwrap().as_str(), Some("rust"));
        crate::util::json::parse(&j.to_string()).unwrap();
    }

    #[test]
    fn shipped_example_config_parses() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("configs/example.conf");
        let c = Config::from_file(&path).unwrap();
        assert_eq!(c.ranks, 32);
        assert_eq!(c.k_neighbors, 5);
        assert_eq!(c.algorithm, AdAlgorithm::Threshold);
        assert_eq!(c.viz_addr, "127.0.0.1:8787");
        assert_eq!(c.provdb_segment_records, 8192);
        assert_eq!(c.provdb_retain_window_us, 0);
    }

    #[test]
    fn cli_override_via_apply() {
        let mut c = Config::default();
        c.apply("backend", "xla").unwrap();
        assert_eq!(c.backend, DetectorBackend::Xla);
        assert!(c.apply("backend", "gpu").is_err());
    }
}
