//! Generic call-grammar trace generator.
//!
//! Substitutes for TAU-instrumented applications (we have no Summit, no
//! NWChem): a [`CallGrammar`] describes functions with duration models,
//! child calls, communication ops and *anomaly processes*; the
//! [`RankTracer`] walks the grammar once per step and emits a time-sorted
//! [`StepFrame`] exactly like a TAU/ADIOS2 stream would deliver. The AD
//! pipeline only ever sees the event stream, so behavioural fidelity to
//! the paper reduces to: sorted timestamps, properly nested ENTRY/EXIT,
//! comm events attributed to enclosing functions, and heavy-tailed /
//! injected anomalies on the right (rank, function) combinations.

use super::event::{
    CommEvent, CommKind, Event, EventCtx, FuncEvent, FuncKind, FuncRegistry, StepFrame,
};
use crate::util::rng::Rng;

/// Communication op performed inside a function body.
#[derive(Clone, Debug)]
pub struct CommSpec {
    pub kind: CommKind,
    /// Partner selection.
    pub partner: PartnerSel,
    /// Message tag.
    pub tag: u32,
    /// Mean payload bytes (exponential draw around it).
    pub mean_bytes: f64,
}

/// How a comm partner rank is chosen.
#[derive(Clone, Debug)]
pub enum PartnerSel {
    /// Fixed rank (e.g. reduction root 0).
    Fixed(u32),
    /// Ring neighbour at offset (rank ± off mod world).
    Neighbor(i32),
    /// Uniformly random other rank.
    Random,
}

/// One function's generative model.
#[derive(Clone, Debug)]
pub struct FuncSpec {
    pub fid: u32,
    /// Lognormal body-time parameters (µs): `exp(N(mu, sigma))`.
    pub mu: f64,
    pub sigma: f64,
    /// Child calls, in program order: `(fid, repeat_count)`.
    pub children: Vec<(u32, u32)>,
    /// Comm ops executed in the body.
    pub comms: Vec<CommSpec>,
    /// High-frequency helper called `hot_fanout` times from this body when
    /// the run is *unfiltered* (paper's dropped functions).
    pub hot_child: Option<(u32, u32)>,
}

impl FuncSpec {
    pub fn leaf(fid: u32, mu: f64, sigma: f64) -> Self {
        FuncSpec { fid, mu, sigma, children: Vec::new(), comms: Vec::new(), hot_child: None }
    }
}

/// A multiplicative or additive runtime perturbation, targeted at one
/// function and a rank predicate — this is how the case-study anomalies
/// (Figs 10–13) are injected.
#[derive(Clone, Debug)]
pub struct AnomalyProcess {
    /// Human-readable label (shows up in run metadata).
    pub name: String,
    /// Target function.
    pub fid: u32,
    /// Applies only when this predicate holds for the rank.
    pub ranks: RankPred,
    /// Per-invocation probability.
    pub prob: f64,
    /// Effect on the targeted invocation.
    pub effect: AnomalyEffect,
}

/// Rank predicate for anomaly targeting.
#[derive(Clone, Debug, PartialEq)]
pub enum RankPred {
    All,
    Only(u32),
    Except(u32),
}

impl RankPred {
    pub fn matches(&self, rank: u32) -> bool {
        match self {
            RankPred::All => true,
            RankPred::Only(r) => rank == *r,
            RankPred::Except(r) => rank != *r,
        }
    }
}

/// What an anomaly does to the targeted call.
#[derive(Clone, Debug)]
pub enum AnomalyEffect {
    /// Multiply body time by a factor drawn uniformly from the range.
    SlowBody { factor_lo: f64, factor_hi: f64 },
    /// Insert a delay (µs) *before* the call (launch delay — Fig 10's
    /// `MD_FORCES` pattern: the gap stretches the parent, not the child).
    LaunchDelay { us_lo: f64, us_hi: f64 },
    /// Replace body time with a Pareto draw (heavy tail — `SP_GETXBL`).
    HeavyTail { xm: f64, alpha: f64 },
}

/// A full application grammar: specs + roots + anomaly processes.
#[derive(Clone, Debug)]
pub struct CallGrammar {
    pub specs: Vec<FuncSpec>,
    /// Root function invoked once per iteration.
    pub root: u32,
    /// Root iterations per trace step.
    pub iters_per_step: u32,
    pub anomalies: Vec<AnomalyProcess>,
}

impl CallGrammar {
    fn spec(&self, fid: u32) -> &FuncSpec {
        &self.specs[fid as usize]
    }

    /// Validate: specs dense by fid, children/hot/anomaly fids in range,
    /// and the call graph is acyclic (generation would not terminate).
    pub fn validate(&self) -> anyhow::Result<()> {
        for (i, s) in self.specs.iter().enumerate() {
            anyhow::ensure!(s.fid as usize == i, "spec {i} has fid {}", s.fid);
            for (c, n) in &s.children {
                anyhow::ensure!((*c as usize) < self.specs.len(), "child fid {c} out of range");
                anyhow::ensure!(*n > 0, "child repeat 0 in spec {i}");
            }
            if let Some((c, _)) = s.hot_child {
                anyhow::ensure!((c as usize) < self.specs.len(), "hot fid {c} out of range");
            }
        }
        anyhow::ensure!((self.root as usize) < self.specs.len(), "root out of range");
        for a in &self.anomalies {
            anyhow::ensure!((a.fid as usize) < self.specs.len(), "anomaly fid out of range");
            anyhow::ensure!((0.0..=1.0).contains(&a.prob), "anomaly prob out of range");
        }
        // Cycle check: DFS from every node.
        let n = self.specs.len();
        let mut state = vec![0u8; n]; // 0 unvisited, 1 in-stack, 2 done
        fn dfs(g: &CallGrammar, f: usize, state: &mut [u8]) -> bool {
            if state[f] == 1 {
                return false;
            }
            if state[f] == 2 {
                return true;
            }
            state[f] = 1;
            for (c, _) in &g.specs[f].children {
                if !dfs(g, *c as usize, state) {
                    return false;
                }
            }
            state[f] = 2;
            true
        }
        for f in 0..n {
            anyhow::ensure!(dfs(self, f, &mut state), "call graph has a cycle at fid {f}");
        }
        Ok(())
    }
}

/// Per-rank trace generator: owns a virtual clock and an RNG stream and
/// produces one [`StepFrame`] per call to [`RankTracer::step`].
///
/// The grammar is held separately from the mutable walk state so the
/// recursive emitter borrows specs by reference — no per-call clones on
/// the hot path (§Perf).
pub struct RankTracer {
    grammar: CallGrammar,
    st: TracerState,
    next_step: u64,
}

/// Mutable walk state (clock + rng + identity).
struct TracerState {
    ctx: EventCtx,
    world: u32,
    /// Include hot (high-frequency) helpers — the *unfiltered* run.
    unfiltered: bool,
    clock_us: u64,
    rng: Rng,
}

impl RankTracer {
    pub fn new(
        grammar: CallGrammar,
        app: u32,
        rank: u32,
        world: u32,
        unfiltered: bool,
        rng: Rng,
    ) -> Self {
        RankTracer {
            grammar,
            st: TracerState {
                ctx: EventCtx { app, rank, thread: 0 },
                world,
                unfiltered,
                // Stagger clocks so ranks are not phase-locked.
                clock_us: 1_000_000 + (rank as u64) * 137,
                rng,
            },
            next_step: 0,
        }
    }

    /// Current virtual time (µs).
    pub fn now(&self) -> u64 {
        self.st.clock_us
    }

    /// Generate the next step frame.
    pub fn step(&mut self) -> StepFrame {
        let mut frame = StepFrame::new(self.st.ctx.app, self.st.ctx.rank, self.next_step);
        self.next_step += 1;
        for _ in 0..self.grammar.iters_per_step {
            self.st.emit_call(&self.grammar, self.grammar.root, &mut frame.events);
            // Inter-iteration idle time.
            self.st.clock_us += self.st.rng.range_u64(5, 50);
        }
        debug_assert!(frame.is_sorted());
        frame
    }
}

impl TracerState {
    /// Recursively emit one function invocation.
    fn emit_call(&mut self, g: &CallGrammar, fid: u32, out: &mut Vec<Event>) {
        // Launch-delay anomalies stretch the *gap* before ENTRY.
        let mut body_scale = 1.0f64;
        let mut heavy: Option<f64> = None;
        for a in &g.anomalies {
            if a.fid != fid || !a.ranks.matches(self.ctx.rank) {
                continue;
            }
            if !self.rng.chance(a.prob) {
                continue;
            }
            match a.effect {
                AnomalyEffect::LaunchDelay { us_lo, us_hi } => {
                    self.clock_us += self.rng.range_f64(us_lo, us_hi) as u64;
                }
                AnomalyEffect::SlowBody { factor_lo, factor_hi } => {
                    body_scale *= self.rng.range_f64(factor_lo, factor_hi);
                }
                AnomalyEffect::HeavyTail { xm, alpha } => {
                    heavy = Some(self.rng.pareto(xm, alpha));
                }
            }
        }

        let spec = g.spec(fid);
        out.push(Event::Func(FuncEvent {
            ctx: self.ctx,
            fid,
            kind: FuncKind::Entry,
            ts: self.clock_us,
        }));

        // Body time: lognormal (or heavy-tail override), split across the
        // segments between child calls.
        let body_us = match heavy {
            Some(h) => h,
            None => self.rng.lognormal(spec.mu, spec.sigma) * body_scale,
        };
        let segments = (spec.children.iter().map(|(_, n)| *n as usize).sum::<usize>()
            + spec.comms.len()
            + 1)
            .max(1);
        let seg_us = (body_us / segments as f64).max(1.0) as u64;

        // Comm ops first (paper: comm events map to the enclosing function).
        for comm in &spec.comms {
            self.clock_us += seg_us.max(1);
            let partner = match comm.partner {
                PartnerSel::Fixed(r) => r.min(self.world.saturating_sub(1)),
                PartnerSel::Neighbor(off) => {
                    let w = self.world.max(1) as i64;
                    (((self.ctx.rank as i64 + off as i64) % w + w) % w) as u32
                }
                PartnerSel::Random => {
                    if self.world <= 1 {
                        self.ctx.rank
                    } else {
                        let mut p = self.rng.usize(self.world as usize - 1) as u32;
                        if p >= self.ctx.rank {
                            p += 1;
                        }
                        p
                    }
                }
            };
            let bytes = self.rng.exponential(1.0 / comm.mean_bytes.max(1.0)).max(1.0) as u64;
            out.push(Event::Comm(CommEvent {
                ctx: self.ctx,
                kind: comm.kind,
                partner,
                tag: comm.tag,
                bytes,
                ts: self.clock_us,
            }));
        }

        // Children in program order.
        for &(child, reps) in &spec.children {
            for _ in 0..reps {
                self.clock_us += seg_us;
                self.emit_call(g, child, out);
            }
        }

        // Hot helpers (unfiltered runs only).
        if self.unfiltered {
            if let Some((hot, reps)) = spec.hot_child {
                let hs = g.spec(hot);
                for _ in 0..reps {
                    // Hot helpers are sub-µs..few-µs each.
                    self.clock_us += 1;
                    out.push(Event::Func(FuncEvent {
                        ctx: self.ctx,
                        fid: hot,
                        kind: FuncKind::Entry,
                        ts: self.clock_us,
                    }));
                    self.clock_us += self.rng.lognormal(hs.mu, hs.sigma).max(1.0) as u64;
                    out.push(Event::Func(FuncEvent {
                        ctx: self.ctx,
                        fid: hot,
                        kind: FuncKind::Exit,
                        ts: self.clock_us,
                    }));
                }
            }
        }

        self.clock_us += seg_us.max(1);
        out.push(Event::Func(FuncEvent {
            ctx: self.ctx,
            fid,
            kind: FuncKind::Exit,
            ts: self.clock_us,
        }));
    }
}

/// Build a tiny two-function grammar for unit tests and micro-benches.
pub fn toy_grammar() -> (CallGrammar, FuncRegistry) {
    let mut reg = FuncRegistry::new();
    let root = reg.register("ROOT", false);
    let work = reg.register("WORK", false);
    let hot = reg.register("HOT_HELPER", true);
    let specs = vec![
        FuncSpec {
            fid: root,
            mu: 3.0,
            sigma: 0.2,
            children: vec![(work, 2)],
            comms: vec![CommSpec {
                kind: CommKind::Send,
                partner: PartnerSel::Neighbor(1),
                tag: 1,
                mean_bytes: 1024.0,
            }],
            hot_child: None,
        },
        FuncSpec {
            fid: work,
            mu: 4.0,
            sigma: 0.3,
            children: vec![],
            comms: vec![],
            hot_child: Some((hot, 10)),
        },
        FuncSpec::leaf(hot, 0.5, 0.2),
    ];
    (
        CallGrammar { specs, root, iters_per_step: 3, anomalies: vec![] },
        reg,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::event::FuncKind;

    fn tracer(unfiltered: bool) -> RankTracer {
        let (g, _) = toy_grammar();
        RankTracer::new(g, 0, 2, 8, unfiltered, Rng::new(7))
    }

    #[test]
    fn frames_are_sorted_and_nested() {
        let mut t = tracer(false);
        for _ in 0..5 {
            let f = t.step();
            assert!(f.is_sorted());
            // Balanced ENTRY/EXIT per fid.
            let mut depth = std::collections::HashMap::new();
            for e in &f.events {
                if let Event::Func(fe) = e {
                    let d = depth.entry(fe.fid).or_insert(0i64);
                    *d += if fe.kind == FuncKind::Entry { 1 } else { -1 };
                    assert!(*d >= 0, "EXIT before ENTRY");
                }
            }
            assert!(depth.values().all(|&d| d == 0), "unbalanced frame");
        }
    }

    #[test]
    fn step_indices_increment() {
        let mut t = tracer(false);
        assert_eq!(t.step().step, 0);
        assert_eq!(t.step().step, 1);
        assert_eq!(t.step().step, 2);
    }

    #[test]
    fn unfiltered_has_many_more_events() {
        let filtered = tracer(false).step().func_event_count();
        let unfiltered = tracer(true).step().func_event_count();
        assert!(
            unfiltered as f64 > 3.0 * filtered as f64,
            "unfiltered {unfiltered} vs filtered {filtered}"
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let (g, _) = toy_grammar();
        let mut a = RankTracer::new(g.clone(), 0, 1, 4, true, Rng::new(9));
        let mut b = RankTracer::new(g, 0, 1, 4, true, Rng::new(9));
        assert_eq!(a.step().events, b.step().events);
    }

    #[test]
    fn comm_partner_in_world() {
        let (g, _) = toy_grammar();
        let mut t = RankTracer::new(g, 0, 0, 4, false, Rng::new(3));
        for _ in 0..10 {
            for e in t.step().events {
                if let Event::Comm(c) = e {
                    assert!(c.partner < 4);
                }
            }
        }
    }

    #[test]
    fn launch_delay_stretches_parent_not_child() {
        let (mut g, _) = toy_grammar();
        g.anomalies.push(AnomalyProcess {
            name: "delay".into(),
            fid: 1,
            ranks: RankPred::All,
            prob: 1.0,
            effect: AnomalyEffect::LaunchDelay { us_lo: 100_000.0, us_hi: 100_000.0 },
        });
        let mut t = RankTracer::new(g, 0, 0, 4, false, Rng::new(5));
        let f = t.step();
        // Parent (ROOT) spans must now include the forced 100ms gaps.
        let (first, last) = f.span().unwrap();
        assert!(last - first > 100_000, "span {}", last - first);
        // Child (WORK) own durations stay small.
        let mut entry = None;
        for e in &f.events {
            if let Event::Func(fe) = e {
                if fe.fid == 1 {
                    match fe.kind {
                        FuncKind::Entry => entry = Some(fe.ts),
                        FuncKind::Exit => {
                            let d = fe.ts - entry.take().unwrap();
                            assert!(d < 50_000, "child inflated: {d}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn heavy_tail_inflates_target() {
        let (mut g, _) = toy_grammar();
        g.anomalies.push(AnomalyProcess {
            name: "tail".into(),
            fid: 1,
            ranks: RankPred::Except(0),
            prob: 1.0,
            effect: AnomalyEffect::HeavyTail { xm: 1e6, alpha: 2.0 },
        });
        // Rank 0 excluded → small durations.
        let mut t0 = RankTracer::new(g.clone(), 0, 0, 4, false, Rng::new(5));
        let f0 = t0.step();
        // Rank 2 targeted → ≥ 1e6 µs bodies.
        let mut t2 = RankTracer::new(g, 0, 2, 4, false, Rng::new(5));
        let f2 = t2.step();
        let dur_of = |frame: &StepFrame| {
            let mut total = 0u64;
            let mut entry = None;
            for e in &frame.events {
                if let Event::Func(fe) = e {
                    if fe.fid == 1 {
                        match fe.kind {
                            FuncKind::Entry => entry = Some(fe.ts),
                            FuncKind::Exit => total += fe.ts - entry.take().unwrap(),
                        }
                    }
                }
            }
            total
        };
        assert!(dur_of(&f2) > 10 * dur_of(&f0).max(1));
    }

    #[test]
    fn grammar_validation_catches_cycles() {
        let (mut g, _) = toy_grammar();
        g.validate().unwrap();
        g.specs[1].children.push((0, 1)); // WORK → ROOT → WORK cycle
        assert!(g.validate().is_err());
    }

    #[test]
    fn grammar_validation_catches_bad_fids() {
        let (mut g, _) = toy_grammar();
        g.specs[0].children.push((99, 1));
        assert!(g.validate().is_err());
    }
}
