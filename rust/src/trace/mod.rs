//! Trace substrate: the event model (§III-A of the paper), the synthetic
//! NWChem-MD workload that substitutes for TAU-instrumented applications
//! on Summit, stream filtering, and the BP-like on-disk codec used by the
//! "TAU only" baseline of Fig 9.

pub mod binfmt;
pub mod event;
pub mod filter;
pub mod gen;
pub mod nwchem;

pub use event::{
    CommEvent, CommKind, Event, EventCtx, FuncEvent, FuncKind, FuncRegistry, StepFrame,
};
pub use gen::{CallGrammar, RankTracer};
