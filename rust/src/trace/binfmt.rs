//! BP-like binary trace codec.
//!
//! Stand-in for TAU's ADIOS2-BP trace dumps: the "NWChem + TAU" baseline of
//! Fig 9 writes every event to disk in this format, and its byte count is
//! the numerator of the paper's data-reduction factors. Layout per frame:
//!
//! ```text
//! [magic u32][version u16][app u32][rank u32][step u64][n_events u32]
//! n_events × records, each tagged:
//!   0x01 func: fid u32, kind u8, ts u64                    ([+ctx], 14 B)
//!   0x02 comm: kind u8, partner u32, tag u32, bytes u64, ts u64   (26 B)
//! ```
//!
//! TAU's binary trace record is ~24 B/event; ours is comparable, so raw
//! byte counts are a fair proxy for the paper's GB axes.

use super::event::{CommEvent, CommKind, Event, EventCtx, FuncEvent, FuncKind, StepFrame};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

const MAGIC: u32 = 0x43484D42; // "CHMB"
const VERSION: u16 = 1;
const TAG_FUNC: u8 = 0x01;
const TAG_COMM: u8 = 0x02;

/// Serialize one frame to a writer; returns bytes written.
pub fn write_frame<W: Write>(w: &mut W, frame: &StepFrame) -> Result<u64> {
    let mut buf = Vec::with_capacity(32 + frame.events.len() * 24);
    buf.extend_from_slice(&MAGIC.to_le_bytes());
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.extend_from_slice(&frame.app.to_le_bytes());
    buf.extend_from_slice(&frame.rank.to_le_bytes());
    buf.extend_from_slice(&frame.step.to_le_bytes());
    buf.extend_from_slice(&(frame.events.len() as u32).to_le_bytes());
    for ev in &frame.events {
        match ev {
            Event::Func(f) => {
                buf.push(TAG_FUNC);
                buf.extend_from_slice(&f.fid.to_le_bytes());
                buf.push(match f.kind {
                    FuncKind::Entry => 0,
                    FuncKind::Exit => 1,
                });
                buf.extend_from_slice(&f.ts.to_le_bytes());
            }
            Event::Comm(c) => {
                buf.push(TAG_COMM);
                buf.push(match c.kind {
                    CommKind::Send => 0,
                    CommKind::Recv => 1,
                });
                buf.extend_from_slice(&c.partner.to_le_bytes());
                buf.extend_from_slice(&c.tag.to_le_bytes());
                buf.extend_from_slice(&c.bytes.to_le_bytes());
                buf.extend_from_slice(&c.ts.to_le_bytes());
            }
        }
    }
    w.write_all(&buf).context("writing frame")?;
    Ok(buf.len() as u64)
}

/// Size in bytes `write_frame` would produce, without allocating.
pub fn frame_encoded_size(frame: &StepFrame) -> u64 {
    let mut size = 4 + 2 + 4 + 4 + 8 + 4;
    for ev in &frame.events {
        size += match ev {
            Event::Func(_) => 1 + 4 + 1 + 8,
            Event::Comm(_) => 1 + 1 + 4 + 4 + 8 + 8,
        };
    }
    size as u64
}

fn read_exact<R: Read, const N: usize>(r: &mut R) -> Result<[u8; N]> {
    let mut b = [0u8; N];
    r.read_exact(&mut b).context("short read")?;
    Ok(b)
}

/// Deserialize one frame; `Ok(None)` at clean EOF.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<StepFrame>> {
    let mut magic = [0u8; 4];
    match r.read_exact(&mut magic) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e.into()),
    }
    if u32::from_le_bytes(magic) != MAGIC {
        bail!("bad frame magic");
    }
    let version = u16::from_le_bytes(read_exact::<_, 2>(r)?);
    if version != VERSION {
        bail!("unsupported frame version {version}");
    }
    let app = u32::from_le_bytes(read_exact::<_, 4>(r)?);
    let rank = u32::from_le_bytes(read_exact::<_, 4>(r)?);
    let step = u64::from_le_bytes(read_exact::<_, 8>(r)?);
    let n = u32::from_le_bytes(read_exact::<_, 4>(r)?) as usize;
    if n > 100_000_000 {
        bail!("implausible event count {n}");
    }
    let ctx = EventCtx { app, rank, thread: 0 };
    let mut frame = StepFrame { app, rank, step, events: Vec::with_capacity(n) };
    for _ in 0..n {
        let tag = read_exact::<_, 1>(r)?[0];
        match tag {
            TAG_FUNC => {
                let fid = u32::from_le_bytes(read_exact::<_, 4>(r)?);
                let kind = match read_exact::<_, 1>(r)?[0] {
                    0 => FuncKind::Entry,
                    1 => FuncKind::Exit,
                    k => bail!("bad func kind {k}"),
                };
                let ts = u64::from_le_bytes(read_exact::<_, 8>(r)?);
                frame.events.push(Event::Func(FuncEvent { ctx, fid, kind, ts }));
            }
            TAG_COMM => {
                let kind = match read_exact::<_, 1>(r)?[0] {
                    0 => CommKind::Send,
                    1 => CommKind::Recv,
                    k => bail!("bad comm kind {k}"),
                };
                let partner = u32::from_le_bytes(read_exact::<_, 4>(r)?);
                let tag_ = u32::from_le_bytes(read_exact::<_, 4>(r)?);
                let bytes = u64::from_le_bytes(read_exact::<_, 8>(r)?);
                let ts = u64::from_le_bytes(read_exact::<_, 8>(r)?);
                frame
                    .events
                    .push(Event::Comm(CommEvent { ctx, kind, partner, tag: tag_, bytes, ts }));
            }
            t => bail!("bad event tag {t:#x}"),
        }
    }
    Ok(Some(frame))
}

/// Read all frames from a reader.
pub fn read_all<R: Read>(r: &mut R) -> Result<Vec<StepFrame>> {
    let mut frames = Vec::new();
    while let Some(f) = read_frame(r)? {
        frames.push(f);
    }
    Ok(frames)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::gen::{toy_grammar, RankTracer};
    use crate::util::prop::check_default;
    use crate::util::rng::Rng;

    fn sample_frames(n: usize, unfiltered: bool) -> Vec<StepFrame> {
        let (g, _) = toy_grammar();
        let mut t = RankTracer::new(g, 0, 2, 8, unfiltered, Rng::new(21));
        (0..n).map(|_| t.step()).collect()
    }

    #[test]
    fn roundtrip_single_frame() {
        let frames = sample_frames(1, true);
        let mut buf = Vec::new();
        let n = write_frame(&mut buf, &frames[0]).unwrap();
        assert_eq!(n as usize, buf.len());
        assert_eq!(n, frame_encoded_size(&frames[0]));
        let back = read_frame(&mut buf.as_slice()).unwrap().unwrap();
        assert_eq!(back.events, frames[0].events);
        assert_eq!(back.step, frames[0].step);
    }

    #[test]
    fn roundtrip_stream_of_frames() {
        let frames = sample_frames(7, false);
        let mut buf = Vec::new();
        for f in &frames {
            write_frame(&mut buf, f).unwrap();
        }
        let back = read_all(&mut buf.as_slice()).unwrap();
        assert_eq!(back.len(), frames.len());
        for (a, b) in back.iter().zip(&frames) {
            assert_eq!(a.events, b.events);
        }
    }

    #[test]
    fn empty_input_is_clean_eof() {
        assert!(read_frame(&mut (&[] as &[u8])).unwrap().is_none());
    }

    #[test]
    fn corrupt_magic_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &sample_frames(1, false)[0]).unwrap();
        buf[0] ^= 0xFF;
        assert!(read_frame(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn truncated_frame_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &sample_frames(1, false)[0]).unwrap();
        buf.truncate(buf.len() - 3);
        assert!(read_frame(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn encoded_size_matches_property() {
        check_default("binfmt-size", |rng, size| {
            let (g, _) = toy_grammar();
            let mut t = RankTracer::new(g, 0, 1, 4, size % 2 == 0, Rng::new(rng.next_u64()));
            let f = t.step();
            let mut buf = Vec::new();
            let n = write_frame(&mut buf, &f).map_err(|e| e.to_string())?;
            if n != frame_encoded_size(&f) || n as usize != buf.len() {
                return Err("size mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn bytes_per_event_is_tau_like() {
        // Sanity: ~14–26 B/event, comparable to TAU binary trace records.
        let f = &sample_frames(1, true)[0];
        let per_event = frame_encoded_size(f) as f64 / f.events.len() as f64;
        assert!(per_event > 10.0 && per_event < 30.0, "B/event {per_event}");
    }
}
