//! Post-hoc event filtering.
//!
//! The paper's *filtered* runs drop high-frequency, short-duration
//! functions at instrumentation time (chosen with NWChem domain
//! scientists). Our generator can already skip them (`unfiltered=false`);
//! this module additionally filters *existing* streams — used by offline
//! replay and by tests that need both views of one trace.

use super::event::{Event, FuncRegistry, StepFrame};

/// Remove function events whose fid is marked hot in `reg`.
/// Comm events are kept (TAU's MPI interposition is always on).
pub fn filter_frame(frame: &StepFrame, reg: &FuncRegistry) -> StepFrame {
    StepFrame {
        app: frame.app,
        rank: frame.rank,
        step: frame.step,
        events: frame
            .events
            .iter()
            .filter(|e| match e {
                Event::Func(f) => !reg.is_hot(f.fid),
                Event::Comm(_) => true,
            })
            .copied()
            .collect(),
    }
}

/// Filter a whole stream.
pub fn filter_frames(frames: &[StepFrame], reg: &FuncRegistry) -> Vec<StepFrame> {
    frames.iter().map(|f| filter_frame(f, reg)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::event::FuncKind;
    use crate::trace::gen::{toy_grammar, RankTracer};
    use crate::util::rng::Rng;

    #[test]
    fn filtering_removes_only_hot_functions() {
        let (g, reg) = toy_grammar();
        let mut t = RankTracer::new(g, 0, 1, 4, true, Rng::new(5));
        let raw = t.step();
        let filtered = filter_frame(&raw, &reg);
        assert!(filtered.func_event_count() < raw.func_event_count());
        assert_eq!(filtered.comm_event_count(), raw.comm_event_count());
        for e in &filtered.events {
            if let Event::Func(f) = e {
                assert!(!reg.is_hot(f.fid));
            }
        }
        // Still balanced and sorted.
        assert!(filtered.is_sorted());
        let mut depth = 0i64;
        for e in &filtered.events {
            if let Event::Func(f) = e {
                depth += if f.kind == FuncKind::Entry { 1 } else { -1 };
                assert!(depth >= 0);
            }
        }
        assert_eq!(depth, 0);
    }

    #[test]
    fn filtering_filtered_stream_is_identity() {
        let (g, reg) = toy_grammar();
        let mut t = RankTracer::new(g, 0, 1, 4, false, Rng::new(5));
        let f = t.step();
        let ff = filter_frame(&f, &reg);
        assert_eq!(f.events, ff.events);
    }
}
