//! Trace event model (paper §III-A).
//!
//! Two event families arrive from the instrumentation layer, both carrying
//! the common identifiers (application, MPI rank, thread) and a microsecond
//! timestamp:
//!
//! * **function events** — function id + ENTRY/EXIT;
//! * **communication events** — SEND/RECV with partner rank, tag and bytes.
//!
//! Events within one rank's stream are sorted by timestamp, which is what
//! lets the AD module reconstruct the call stack online.

use crate::util::json::Json;

/// Function event type.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum FuncKind {
    Entry,
    Exit,
}

/// Communication event type.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum CommKind {
    Send,
    Recv,
}

/// Common identifiers every event carries.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub struct EventCtx {
    /// Application index within the workflow (paper: two apps).
    pub app: u32,
    /// Global MPI rank.
    pub rank: u32,
    /// OS thread within the rank.
    pub thread: u32,
}

/// A function ENTRY/EXIT record.
#[derive(Copy, Clone, Debug, PartialEq)]
pub struct FuncEvent {
    pub ctx: EventCtx,
    /// Dense function identifier (see [`FuncRegistry`]).
    pub fid: u32,
    pub kind: FuncKind,
    /// Timestamp, microseconds on the rank's clock.
    pub ts: u64,
}

/// A communication SEND/RECV record.
#[derive(Copy, Clone, Debug, PartialEq)]
pub struct CommEvent {
    pub ctx: EventCtx,
    pub kind: CommKind,
    /// Peer rank.
    pub partner: u32,
    /// Message tag.
    pub tag: u32,
    /// Payload size in bytes.
    pub bytes: u64,
    pub ts: u64,
}

/// One record in a rank's time-sorted stream.
#[derive(Copy, Clone, Debug, PartialEq)]
pub enum Event {
    Func(FuncEvent),
    Comm(CommEvent),
}

impl Event {
    pub fn ts(&self) -> u64 {
        match self {
            Event::Func(f) => f.ts,
            Event::Comm(c) => c.ts,
        }
    }

    pub fn ctx(&self) -> EventCtx {
        match self {
            Event::Func(f) => f.ctx,
            Event::Comm(c) => c.ctx,
        }
    }
}

/// Maps function ids to names and instrumentation attributes.
///
/// `hot` marks high-frequency/short-duration functions that the paper's
/// *filtered* instrumentation drops at compile/run time (§VI-B2).
#[derive(Clone, Debug, Default)]
pub struct FuncRegistry {
    names: Vec<String>,
    hot: Vec<bool>,
}

impl FuncRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a function; returns its dense id. Idempotent on names.
    pub fn register(&mut self, name: &str, hot: bool) -> u32 {
        if let Some(fid) = self.lookup(name) {
            return fid;
        }
        self.names.push(name.to_string());
        self.hot.push(hot);
        (self.names.len() - 1) as u32
    }

    pub fn lookup(&self, name: &str) -> Option<u32> {
        self.names.iter().position(|n| n == name).map(|i| i as u32)
    }

    pub fn name(&self, fid: u32) -> &str {
        self.names.get(fid as usize).map(|s| s.as_str()).unwrap_or("<unknown>")
    }

    pub fn is_hot(&self, fid: u32) -> bool {
        self.hot.get(fid as usize).copied().unwrap_or(false)
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// JSON table for provenance metadata.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.names
                .iter()
                .enumerate()
                .map(|(i, n)| {
                    Json::obj(vec![
                        ("fid", Json::num(i as f64)),
                        ("name", Json::str(n.as_str())),
                        ("hot", Json::Bool(self.hot[i])),
                    ])
                })
                .collect(),
        )
    }
}

/// One streamed frame: all events of `(app, rank)` for one trace step,
/// time-sorted. This is the unit the SST engine moves and the on-node AD
/// module consumes (paper: once-per-second flush).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StepFrame {
    pub app: u32,
    pub rank: u32,
    /// Step ("frame") index; the viz timeline's x-axis.
    pub step: u64,
    pub events: Vec<Event>,
}

impl StepFrame {
    pub fn new(app: u32, rank: u32, step: u64) -> Self {
        StepFrame { app, rank, step, events: Vec::new() }
    }

    /// True if events are sorted by timestamp (AD module precondition).
    pub fn is_sorted(&self) -> bool {
        self.events.windows(2).all(|w| w[0].ts() <= w[1].ts())
    }

    /// Count of function events.
    pub fn func_event_count(&self) -> usize {
        self.events.iter().filter(|e| matches!(e, Event::Func(_))).count()
    }

    /// Count of communication events.
    pub fn comm_event_count(&self) -> usize {
        self.events.iter().filter(|e| matches!(e, Event::Comm(_))).count()
    }

    /// Time span `(first_ts, last_ts)` or None when empty.
    pub fn span(&self) -> Option<(u64, u64)> {
        match (self.events.first(), self.events.last()) {
            (Some(a), Some(b)) => Some((a.ts(), b.ts())),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> EventCtx {
        EventCtx { app: 0, rank: 3, thread: 0 }
    }

    #[test]
    fn registry_register_lookup() {
        let mut r = FuncRegistry::new();
        let a = r.register("MD_NEWTON", false);
        let b = r.register("VEC_AXPY", true);
        assert_eq!(r.register("MD_NEWTON", false), a);
        assert_eq!(r.lookup("VEC_AXPY"), Some(b));
        assert_eq!(r.name(a), "MD_NEWTON");
        assert!(r.is_hot(b));
        assert!(!r.is_hot(a));
        assert_eq!(r.name(999), "<unknown>");
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn registry_json_is_valid() {
        let mut r = FuncRegistry::new();
        r.register("A", false);
        r.register("B", true);
        let j = r.to_json().to_string();
        crate::util::json::parse(&j).unwrap();
    }

    #[test]
    fn frame_sorted_and_counts() {
        let mut f = StepFrame::new(0, 3, 7);
        f.events.push(Event::Func(FuncEvent {
            ctx: ctx(),
            fid: 0,
            kind: FuncKind::Entry,
            ts: 10,
        }));
        f.events.push(Event::Comm(CommEvent {
            ctx: ctx(),
            kind: CommKind::Send,
            partner: 1,
            tag: 9,
            bytes: 128,
            ts: 12,
        }));
        f.events.push(Event::Func(FuncEvent {
            ctx: ctx(),
            fid: 0,
            kind: FuncKind::Exit,
            ts: 20,
        }));
        assert!(f.is_sorted());
        assert_eq!(f.func_event_count(), 2);
        assert_eq!(f.comm_event_count(), 1);
        assert_eq!(f.span(), Some((10, 20)));
        f.events.swap(0, 2);
        assert!(!f.is_sorted());
    }

    #[test]
    fn empty_frame_span() {
        assert_eq!(StepFrame::new(0, 0, 0).span(), None);
    }
}
