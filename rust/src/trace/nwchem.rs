//! The NWChem-MD workflow grammar — our stand-in for the paper's Summit
//! case study (§VI). Two applications:
//!
//! * **app 0 — MD simulation** (a modified NWChem molecular dynamics run):
//!   each trace step runs several `MD_NEWTON` iterations whose call tree
//!   matches the functions the case study names —
//!   `MD_NEWTON → MD_FINIT → CF_CMS → GLOBAL_SUM×2`,
//!   `MD_NEWTON → MD_FORCES → {SP_GETXBL → SP_GTXPBL, CF_FORCES}`,
//!   `MD_NEWTON → MD_UPDATE`, plus a trajectory write streamed to app 1.
//! * **app 1 — in-situ analysis**: `ANALYZE_STEP → {TRAJ_READ, COMPUTE_RDF,
//!   IO_WRITE}` consuming the trajectory.
//!
//! Injected anomaly processes reproduce the three case-study findings:
//!
//! 1. sporadic **launch delay** before `MD_FORCES` that roughly triples the
//!    enclosing `MD_NEWTON` (Fig 10);
//! 2. **rank 0** straggling in `MD_FINIT`/`CF_CMS` (global sums + rank-0's
//!    special role, Figs 11–12);
//! 3. **heavy-tailed** `SP_GTXPBL`/`SP_GETXBL` on ranks ≠ 0 (domain-
//!    decomposition remote gets, Fig 13).
//!
//! The *hot* helpers (`VEC_AXPY`, `PAIRLIST_SCAN`, `TIMER_TICK`, `HIST_BIN`)
//! model the high-frequency short functions the real study filtered out of
//! instrumentation; including them is the paper's "unfiltered" mode and
//! drives the ~20× raw-size gap of Fig 9.

use super::event::FuncRegistry;
use super::gen::{
    AnomalyEffect, AnomalyProcess, CallGrammar, CommSpec, FuncSpec, PartnerSel, RankPred,
};
use crate::trace::event::CommKind;

/// Well-known function names (kept identical to the paper's figures so the
/// viz views and case-study benches can assert on them).
pub mod names {
    pub const MD_NEWTON: &str = "MD_NEWTON";
    pub const MD_FINIT: &str = "MD_FINIT";
    pub const CF_CMS: &str = "CF_CMS";
    pub const GLOBAL_SUM: &str = "GLOBAL_SUM";
    pub const MD_FORCES: &str = "MD_FORCES";
    pub const SP_GETXBL: &str = "SP_GETXBL";
    pub const SP_GTXPBL: &str = "SP_GTXPBL";
    pub const CF_FORCES: &str = "CF_FORCES";
    pub const MD_UPDATE: &str = "MD_UPDATE";
    pub const TRAJ_WRITE: &str = "TRAJ_WRITE";
    pub const ANALYZE_STEP: &str = "ANALYZE_STEP";
    pub const TRAJ_READ: &str = "TRAJ_READ";
    pub const COMPUTE_RDF: &str = "COMPUTE_RDF";
    pub const IO_WRITE: &str = "IO_WRITE";
    pub const VEC_AXPY: &str = "VEC_AXPY";
    pub const PAIRLIST_SCAN: &str = "PAIRLIST_SCAN";
    pub const TIMER_TICK: &str = "TIMER_TICK";
    pub const HIST_BIN: &str = "HIST_BIN";
}

/// Tunable anomaly-injection rates (defaults reproduce the case study at
/// an AD-friendly anomaly fraction well under 1%).
#[derive(Clone, Debug)]
pub struct InjectionConfig {
    /// P(launch delay before `MD_FORCES`) per invocation, any rank.
    pub forces_delay_prob: f64,
    /// P(rank-0 straggle) per `CF_CMS`/`MD_FINIT` invocation.
    pub rank0_straggle_prob: f64,
    /// P(heavy-tail `SP_GTXPBL`) per invocation on ranks ≠ 0.
    pub getxbl_tail_prob: f64,
}

impl Default for InjectionConfig {
    fn default() -> Self {
        InjectionConfig {
            forces_delay_prob: 0.004,
            rank0_straggle_prob: 0.02,
            getxbl_tail_prob: 0.006,
        }
    }
}

/// Disable all injection (clean baseline for accuracy tests).
impl InjectionConfig {
    pub fn none() -> Self {
        InjectionConfig {
            forces_delay_prob: 0.0,
            rank0_straggle_prob: 0.0,
            getxbl_tail_prob: 0.0,
        }
    }
}

/// Build the MD-simulation grammar (app 0) and its function registry.
///
/// `iters_per_step` controls event volume per frame; typical filtered
/// volume is ~26 function events + 4 comm events per iteration.
pub fn md_grammar(iters_per_step: u32, inj: &InjectionConfig) -> (CallGrammar, FuncRegistry) {
    let mut reg = FuncRegistry::new();
    let md_newton = reg.register(names::MD_NEWTON, false);
    let md_finit = reg.register(names::MD_FINIT, false);
    let cf_cms = reg.register(names::CF_CMS, false);
    let global_sum = reg.register(names::GLOBAL_SUM, false);
    let md_forces = reg.register(names::MD_FORCES, false);
    let sp_getxbl = reg.register(names::SP_GETXBL, false);
    let sp_gtxpbl = reg.register(names::SP_GTXPBL, false);
    let cf_forces = reg.register(names::CF_FORCES, false);
    let md_update = reg.register(names::MD_UPDATE, false);
    let traj_write = reg.register(names::TRAJ_WRITE, false);
    let vec_axpy = reg.register(names::VEC_AXPY, true);
    let pairlist = reg.register(names::PAIRLIST_SCAN, true);
    let timer = reg.register(names::TIMER_TICK, true);

    // Duration scales (µs, lognormal): medians chosen so one MD_NEWTON
    // iteration lands near 3–5 ms of virtual time, matching the case
    // study's ~ms-scale function views.
    let specs = vec![
        FuncSpec {
            fid: md_newton,
            mu: 4.5, // ~90µs own time
            sigma: 0.25,
            children: vec![(md_finit, 1), (md_forces, 1), (md_update, 1), (traj_write, 1)],
            comms: vec![],
            hot_child: Some((timer, 16)),
        },
        FuncSpec {
            fid: md_finit,
            mu: 4.8,
            sigma: 0.25,
            children: vec![(cf_cms, 1)],
            comms: vec![],
            hot_child: Some((vec_axpy, 48)),
        },
        FuncSpec {
            fid: cf_cms,
            // Center-of-mass: two global sums dominate.
            mu: 5.2,
            sigma: 0.3,
            children: vec![(global_sum, 2)],
            comms: vec![],
            hot_child: Some((vec_axpy, 32)),
        },
        FuncSpec {
            fid: global_sum,
            mu: 5.0,
            sigma: 0.35,
            children: vec![],
            comms: vec![
                CommSpec {
                    kind: CommKind::Send,
                    partner: PartnerSel::Fixed(0),
                    tag: 17,
                    mean_bytes: 64.0,
                },
                CommSpec {
                    kind: CommKind::Recv,
                    partner: PartnerSel::Fixed(0),
                    tag: 18,
                    mean_bytes: 64.0,
                },
            ],
            hot_child: None,
        },
        FuncSpec {
            fid: md_forces,
            mu: 6.6, // ~700µs — the dominant compute
            sigma: 0.25,
            children: vec![(sp_getxbl, 1), (cf_forces, 1)],
            comms: vec![],
            hot_child: Some((pairlist, 64)),
        },
        FuncSpec {
            fid: sp_getxbl,
            mu: 4.6,
            sigma: 0.3,
            children: vec![(sp_gtxpbl, 1)],
            comms: vec![],
            hot_child: None,
        },
        FuncSpec {
            fid: sp_gtxpbl,
            // Remote gets: solvent + solute fetches from neighbours.
            mu: 5.4,
            sigma: 0.4,
            children: vec![],
            comms: vec![
                CommSpec {
                    kind: CommKind::Recv,
                    partner: PartnerSel::Neighbor(1),
                    tag: 31,
                    mean_bytes: 32.0 * 1024.0,
                },
                CommSpec {
                    kind: CommKind::Recv,
                    partner: PartnerSel::Neighbor(-1),
                    tag: 32,
                    mean_bytes: 32.0 * 1024.0,
                },
            ],
            hot_child: None,
        },
        FuncSpec {
            fid: cf_forces,
            mu: 6.2,
            sigma: 0.25,
            children: vec![],
            comms: vec![],
            hot_child: Some((vec_axpy, 96)),
        },
        FuncSpec {
            fid: md_update,
            mu: 5.0,
            sigma: 0.25,
            children: vec![],
            comms: vec![],
            hot_child: Some((vec_axpy, 40)),
        },
        FuncSpec {
            fid: traj_write,
            mu: 4.2,
            sigma: 0.5,
            children: vec![],
            comms: vec![CommSpec {
                kind: CommKind::Send,
                partner: PartnerSel::Random,
                tag: 99, // trajectory stream to the analysis app
                mean_bytes: 256.0 * 1024.0,
            }],
            hot_child: None,
        },
        FuncSpec::leaf(vec_axpy, 2.2, 0.3),
        FuncSpec::leaf(pairlist, 2.5, 0.3),
        FuncSpec::leaf(timer, 1.6, 0.25),
    ];

    let anomalies = vec![
        AnomalyProcess {
            name: "md_forces_launch_delay".into(),
            fid: md_forces,
            ranks: RankPred::All,
            prob: inj.forces_delay_prob,
            // One MD_NEWTON ≈ 3.2ms virtual; a 7–10ms gap ≈ ~3× parent
            // (and safely past 6σ of the contaminated runtime mixture).
            effect: AnomalyEffect::LaunchDelay { us_lo: 7_000.0, us_hi: 10_000.0 },
        },
        AnomalyProcess {
            name: "rank0_md_finit_straggle".into(),
            fid: md_finit,
            ranks: RankPred::Only(0),
            prob: inj.rank0_straggle_prob,
            effect: AnomalyEffect::SlowBody { factor_lo: 8.0, factor_hi: 20.0 },
        },
        AnomalyProcess {
            name: "rank0_cf_cms_straggle".into(),
            fid: cf_cms,
            ranks: RankPred::Only(0),
            prob: inj.rank0_straggle_prob,
            effect: AnomalyEffect::SlowBody { factor_lo: 8.0, factor_hi: 20.0 },
        },
        AnomalyProcess {
            name: "sp_gtxpbl_heavy_tail".into(),
            fid: sp_gtxpbl,
            ranks: RankPred::Except(0),
            prob: inj.getxbl_tail_prob,
            // Short-ish tail: large vs SP_GTXPBL's own σ (Fig 13 flags)
            // without drowning MD_NEWTON's variance (Fig 10 still flags).
            effect: AnomalyEffect::HeavyTail { xm: 4_000.0, alpha: 2.5 },
        },
    ];

    let g = CallGrammar { specs, root: md_newton, iters_per_step, anomalies };
    g.validate().expect("md grammar must validate");
    (g, reg)
}

/// Build the in-situ analysis grammar (app 1).
pub fn analysis_grammar(iters_per_step: u32) -> (CallGrammar, FuncRegistry) {
    let mut reg = FuncRegistry::new();
    let analyze = reg.register(names::ANALYZE_STEP, false);
    let traj_read = reg.register(names::TRAJ_READ, false);
    let rdf = reg.register(names::COMPUTE_RDF, false);
    let io_write = reg.register(names::IO_WRITE, false);
    let hist = reg.register(names::HIST_BIN, true);

    let specs = vec![
        FuncSpec {
            fid: analyze,
            mu: 4.8,
            sigma: 0.3,
            children: vec![(traj_read, 1), (rdf, 1), (io_write, 1)],
            comms: vec![],
            hot_child: None,
        },
        FuncSpec {
            fid: traj_read,
            mu: 5.6,
            sigma: 0.45,
            children: vec![],
            comms: vec![CommSpec {
                kind: CommKind::Recv,
                partner: PartnerSel::Random,
                tag: 99,
                mean_bytes: 256.0 * 1024.0,
            }],
            hot_child: None,
        },
        FuncSpec {
            fid: rdf,
            mu: 6.4,
            sigma: 0.3,
            children: vec![],
            comms: vec![],
            hot_child: Some((hist, 96)),
        },
        FuncSpec {
            fid: io_write,
            mu: 5.2,
            sigma: 0.6, // I/O is naturally noisy
            children: vec![],
            comms: vec![],
            hot_child: None,
        },
        FuncSpec::leaf(hist, 2.0, 0.25),
    ];

    let anomalies = vec![AnomalyProcess {
        name: "io_write_stall".into(),
        fid: io_write,
        ranks: RankPred::All,
        prob: 0.003,
        effect: AnomalyEffect::HeavyTail { xm: 20_000.0, alpha: 2.0 },
    }];

    let g = CallGrammar { specs, root: analyze, iters_per_step, anomalies };
    g.validate().expect("analysis grammar must validate");
    (g, reg)
}

/// Workflow-level registry: app grammars use disjoint fid spaces per app,
/// so the global function key is `(app, fid)`. Helper joining both
/// registries for display.
pub fn workflow_registries() -> Vec<FuncRegistry> {
    let (_, r0) = md_grammar(1, &InjectionConfig::default());
    let (_, r1) = analysis_grammar(1);
    vec![r0, r1]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::event::{Event, FuncKind};
    use crate::trace::gen::RankTracer;
    use crate::util::rng::Rng;

    #[test]
    fn grammars_validate() {
        md_grammar(5, &InjectionConfig::default()).0.validate().unwrap();
        analysis_grammar(5).0.validate().unwrap();
    }

    #[test]
    fn md_step_contains_expected_call_tree() {
        let (g, reg) = md_grammar(1, &InjectionConfig::none());
        let mut t = RankTracer::new(g, 0, 1, 8, false, Rng::new(11));
        let f = t.step();
        let mut seen = std::collections::HashSet::new();
        for e in &f.events {
            if let Event::Func(fe) = e {
                seen.insert(reg.name(fe.fid).to_string());
            }
        }
        for n in [
            names::MD_NEWTON,
            names::MD_FINIT,
            names::CF_CMS,
            names::GLOBAL_SUM,
            names::MD_FORCES,
            names::SP_GETXBL,
            names::SP_GTXPBL,
            names::CF_FORCES,
            names::MD_UPDATE,
            names::TRAJ_WRITE,
        ] {
            assert!(seen.contains(n), "missing {n} in {seen:?}");
        }
        // Filtered run → no hot helpers.
        assert!(!seen.contains(names::VEC_AXPY));
    }

    #[test]
    fn unfiltered_md_step_includes_hot_helpers() {
        let (g, reg) = md_grammar(1, &InjectionConfig::none());
        let mut t = RankTracer::new(g, 0, 1, 8, true, Rng::new(11));
        let f = t.step();
        let names_seen: std::collections::HashSet<String> = f
            .events
            .iter()
            .filter_map(|e| match e {
                Event::Func(fe) => Some(reg.name(fe.fid).to_string()),
                _ => None,
            })
            .collect();
        assert!(names_seen.contains(names::VEC_AXPY));
        assert!(names_seen.contains(names::PAIRLIST_SCAN));
    }

    #[test]
    fn unfiltered_volume_ratio_is_order_20x() {
        let inj = InjectionConfig::none();
        let (g, _) = md_grammar(4, &inj);
        let filt = RankTracer::new(g.clone(), 0, 1, 8, false, Rng::new(3))
            .step()
            .func_event_count();
        let unf = RankTracer::new(g, 0, 1, 8, true, Rng::new(3))
            .step()
            .func_event_count();
        let ratio = unf as f64 / filt as f64;
        assert!(ratio > 4.0 && ratio < 60.0, "ratio {ratio}");
    }

    #[test]
    fn nesting_depth_matches_grammar() {
        // MD_NEWTON > MD_FORCES > SP_GETXBL > SP_GTXPBL = depth 4.
        let (g, reg) = md_grammar(1, &InjectionConfig::none());
        let mut t = RankTracer::new(g, 0, 0, 4, false, Rng::new(1));
        let f = t.step();
        let gtx = reg.lookup(names::SP_GTXPBL).unwrap();
        let mut depth = 0usize;
        let mut max_at_gtx = 0usize;
        for e in &f.events {
            if let Event::Func(fe) = e {
                match fe.kind {
                    FuncKind::Entry => {
                        depth += 1;
                        if fe.fid == gtx {
                            max_at_gtx = depth;
                        }
                    }
                    FuncKind::Exit => depth -= 1,
                }
            }
        }
        assert_eq!(max_at_gtx, 4, "SP_GTXPBL depth");
    }

    #[test]
    fn injection_targets_right_ranks() {
        let inj = InjectionConfig {
            forces_delay_prob: 0.0,
            rank0_straggle_prob: 1.0,
            getxbl_tail_prob: 0.0,
        };
        let (g, reg) = md_grammar(1, &inj);
        let finit = reg.lookup(names::MD_FINIT).unwrap();
        let dur = |rank: u32| {
            let (g2, _) = (g.clone(), ());
            let mut t = RankTracer::new(g2, 0, rank, 4, false, Rng::new(2));
            let f = t.step();
            let mut entry = 0u64;
            let mut d = 0u64;
            for e in &f.events {
                if let Event::Func(fe) = e {
                    if fe.fid == finit {
                        match fe.kind {
                            FuncKind::Entry => entry = fe.ts,
                            FuncKind::Exit => d += fe.ts - entry,
                        }
                    }
                }
            }
            d
        };
        assert!(dur(0) > 2 * dur(1), "rank0 {} rank1 {}", dur(0), dur(1));
    }
}
