//! **Fig 8 + Table I** — workflow execution time and tracing/analysis
//! overhead over MPI-process scales.
//!
//! Wraps [`coordinator::overhead::sweep`]: for each scale we run the same
//! virtual workload in the three modes and apply the paper's Eq. (1).
//! Absolute seconds are testbed-local; the *shape* targets are (a) small
//! overhead at low rank counts, (b) growth once simulated ranks exceed
//! physical cores (the paper's knee near 1000 ranks on Summit nodes),
//! (c) "with Chimbuko" ≥ "without Chimbuko" by a few points.

use crate::bench::Table;
use crate::config::Config;
use crate::coordinator::{sweep, OverheadRow};
use anyhow::Result;

#[derive(Clone, Debug)]
pub struct Fig8Result {
    pub rows: Vec<OverheadRow>,
}

impl Fig8Result {
    pub fn render(&self) -> String {
        let mut fig8 = Table::new(
            "Fig 8 — workflow execution time (seconds, this testbed)",
            &["# MPI", "app only", "app+TAU", "app+TAU+Chimbuko"],
        );
        for r in &self.rows {
            fig8.row(vec![
                r.ranks.to_string(),
                format!("{:.3}", r.t_app),
                format!("{:.3}", r.t_tau),
                format!("{:.3}", r.t_chimbuko),
            ]);
        }
        let mut t1 = Table::new(
            "Table I — overhead over app execution time (%)",
            &["# MPI", "without Chimbuko", "with Chimbuko"],
        );
        for r in &self.rows {
            t1.row(vec![
                r.ranks.to_string(),
                format!("{:.2}", r.overhead_tau_pct),
                format!("{:.2}", r.overhead_chimbuko_pct),
            ]);
        }
        format!(
            "{}\n{}\npaper Table I: without 1.85→18.27%, with 1.31→24.56% over 80→2560 ranks\n",
            fig8.render(),
            t1.render()
        )
    }
}

/// Run the sweep with a workload sized for the experiment budget.
///
/// `app_work_ms_total` simulates the strong-scaled application compute
/// (fixed problem size): per-rank work shrinks as ranks grow while the
/// per-rank trace rate stays constant — the mechanism behind the paper's
/// overhead growth toward 2560 ranks.
pub fn run_fig8(
    scales: &[usize],
    steps: usize,
    calls_per_step: usize,
    repeats: usize,
    app_work_ms_total: u64,
) -> Result<Fig8Result> {
    let base = Config {
        steps,
        calls_per_step,
        out_dir: String::new(), // in-memory reduced output
        viz_enabled: false,
        app_work_ms_total,
        ..Config::default()
    };
    Ok(Fig8Result { rows: sweep(&base, scales, repeats)? })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_sweep_has_sane_shape() {
        let res = run_fig8(&[2, 8], 5, 60, 1, 200).unwrap();
        assert_eq!(res.rows.len(), 2);
        for r in &res.rows {
            assert!(r.t_app > 0.0);
            assert!(r.t_chimbuko > 0.0);
        }
        let text = res.render();
        assert!(text.contains("Table I"));
        assert!(text.contains("Fig 8"));
    }
}
