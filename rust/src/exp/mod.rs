//! Paper experiments: one module per table/figure of the evaluation
//! (§VI), each exposing a `run_*` function returning a structured result
//! with a `render()` for the paper-style table/series. The `cargo bench`
//! targets and the `chimbuko exp` CLI both call into here, so benches,
//! CLI and tests exercise identical code.

pub mod case_study;
pub mod chaos;
pub mod fig7;
pub mod fig8_table1;
pub mod fig9;
pub mod figs3_6;

pub use case_study::{run_case_study, CaseStudyResult};
pub use chaos::{find_chimbuko_bin, run_chaos, ChaosResult, ChaosRow};
pub use fig7::{
    ps_bench_json, run_aggtree_sweep, run_fig7, run_ps_conn_sweep, run_ps_endpoint_sweep,
    run_ps_rebalance_sweep, run_ps_shard_sweep, AggTreeSweepResult, ConnSweepResult,
    EndpointSweepResult, Fig7Result, RebalanceSweepResult, ShardSweepResult,
};
pub use fig8_table1::{run_fig8, Fig8Result};
pub use fig9::{
    run_codec_bench, run_fig9, run_provdb_bench, run_scan_bench, CodecBenchResult, Fig9Result,
    ProvDbBenchResult, ScanBenchResult,
};
pub use figs3_6::{run_figs3_6, VizFiguresResult};
