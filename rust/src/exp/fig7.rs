//! **Fig 7** — distributed vs. non-distributed AD modules.
//!
//! The paper compares (a) one AD instance ingesting *all* ranks' trace
//! data (exact global statistics, runtime grows with ranks) against (b)
//! per-rank AD instances syncing local statistics through the parameter
//! server (runtime flat, accuracy within a few % of exact). We reproduce
//! both over a rank sweep and report anomaly-set agreement + wall times.
//!
//! Agreement metric: Jaccard overlap of the anomalous `call_id` sets
//! (the paper quotes "97.6% accuracy on average" without a formula;
//! Jaccard is the strictest symmetric choice, so it under- rather than
//! over-states reproduction quality).

use crate::ad::{DetectEngine, DetectorConfig, ExecRecord, RustDetector, StackBuilder};
use crate::bench::Table;
use crate::ps;
use crate::stats::RunStats;
use crate::trace::nwchem::{self, InjectionConfig};
use crate::trace::RankTracer;
use crate::util::rng::Rng;
use std::collections::HashSet;
use std::time::Instant;

/// One scale point of the sweep.
#[derive(Clone, Debug)]
pub struct Fig7Row {
    pub ranks: usize,
    /// Anomaly-set Jaccard overlap (distributed vs single), in [0, 1].
    pub accuracy: f64,
    /// Wall seconds: the single instance processing all ranks' data.
    pub t_single: f64,
    /// Wall seconds: slowest per-rank distributed instance (they run in
    /// parallel, so the max is the critical path).
    pub t_distributed_max: f64,
    /// Mean per-rank distributed time.
    pub t_distributed_mean: f64,
    pub anomalies_single: u64,
    pub anomalies_distributed: u64,
}

/// Full experiment result.
#[derive(Clone, Debug)]
pub struct Fig7Result {
    pub rows: Vec<Fig7Row>,
}

impl Fig7Result {
    pub fn mean_accuracy(&self) -> f64 {
        crate::util::mean(&self.rows.iter().map(|r| r.accuracy).collect::<Vec<_>>())
    }

    pub fn render(&self) -> String {
        let mut t = Table::new(
            "Fig 7 — distributed vs non-distributed AD",
            &[
                "# ranks",
                "accuracy",
                "t_single(s)",
                "t_dist_max(s)",
                "t_dist_mean(s)",
                "anoms(single)",
                "anoms(dist)",
            ],
        );
        for r in &self.rows {
            t.row(vec![
                r.ranks.to_string(),
                format!("{:.1}%", r.accuracy * 100.0),
                format!("{:.4}", r.t_single),
                format!("{:.4}", r.t_distributed_max),
                format!("{:.4}", r.t_distributed_mean),
                r.anomalies_single.to_string(),
                r.anomalies_distributed.to_string(),
            ]);
        }
        format!(
            "{}\nmean accuracy over scales: {:.1}% (paper: 97.6%)\n",
            t.render(),
            self.mean_accuracy() * 100.0
        )
    }
}

/// Per-rank record streams for one synthetic run.
fn generate_streams(
    ranks: usize,
    steps: usize,
    iters_per_step: u32,
    seed: u64,
) -> Vec<Vec<Vec<ExecRecord>>> {
    // streams[rank][step] = completed executions.
    let inj = InjectionConfig {
        forces_delay_prob: 0.01,
        rank0_straggle_prob: 0.05,
        getxbl_tail_prob: 0.02,
    };
    let (grammar, _) = nwchem::md_grammar(iters_per_step, &inj);
    let mut root = Rng::new(seed);
    (0..ranks)
        .map(|rank| {
            let mut tracer = RankTracer::new(
                grammar.clone(),
                0,
                rank as u32,
                ranks as u32,
                false,
                root.fork(rank as u64),
            );
            let mut sb = StackBuilder::new(0, rank as u32);
            (0..steps).map(|_| sb.process(&tracer.step())).collect()
        })
        .collect()
}

fn anomaly_ids(labels: &[crate::ad::Labeled], rank: u32) -> HashSet<(u32, u64)> {
    labels
        .iter()
        .filter(|l| l.label.is_anomaly())
        .map(|l| (rank, l.rec.call_id))
        .collect()
}

/// Run the sweep. `steps`/`iters_per_step` size the per-rank event volume.
pub fn run_fig7(scales: &[usize], steps: usize, iters_per_step: u32, seed: u64) -> Fig7Result {
    let cfg = DetectorConfig { alpha: 6.0, min_samples: 10 };
    let mut rows = Vec::new();
    for &ranks in scales {
        let streams = generate_streams(ranks, steps, iters_per_step, seed);

        // --- Non-distributed: one detector sees everything, step-major
        // (exactly what a single AD instance receiving all streams does).
        let t0 = Instant::now();
        let mut single = RustDetector::new(cfg);
        let mut single_anoms: HashSet<(u32, u64)> = HashSet::new();
        for step in 0..steps {
            for (rank, stream) in streams.iter().enumerate() {
                let labeled = DetectEngine::detect(&mut single, stream[step].clone());
                single_anoms.extend(anomaly_ids(&labeled, rank as u32));
            }
        }
        let t_single = t0.elapsed().as_secs_f64();

        // --- Distributed: per-rank detectors + parameter server sync.
        let (client, ps_handle) = ps::spawn(1, None, usize::MAX >> 1, ranks);
        let mut detectors: Vec<RustDetector> =
            (0..ranks).map(|_| RustDetector::new(cfg)).collect();
        let mut dist_anoms: HashSet<(u32, u64)> = HashSet::new();
        let mut per_rank_secs = vec![0.0f64; ranks];
        for step in 0..steps {
            for (rank, stream) in streams.iter().enumerate() {
                let t = Instant::now();
                let labeled =
                    DetectEngine::detect(&mut detectors[rank], stream[step].clone());
                dist_anoms.extend(anomaly_ids(&labeled, rank as u32));
                let delta = detectors[rank].take_pending();
                let (global, _events) = client.sync(0, rank as u32, &delta);
                detectors[rank].adopt_global(&global);
                per_rank_secs[rank] += t.elapsed().as_secs_f64();
            }
        }
        client.shutdown();
        ps_handle.join();

        let inter = single_anoms.intersection(&dist_anoms).count() as f64;
        let union = single_anoms.union(&dist_anoms).count() as f64;
        let accuracy = if union == 0.0 { 1.0 } else { inter / union };
        let t_max = per_rank_secs.iter().cloned().fold(0.0, f64::max);
        let mut dist_stats = RunStats::new();
        for &s in &per_rank_secs {
            dist_stats.push(s);
        }
        rows.push(Fig7Row {
            ranks,
            accuracy,
            t_single,
            t_distributed_max: t_max,
            t_distributed_mean: dist_stats.mean(),
            anomalies_single: single_anoms.len() as u64,
            anomalies_distributed: dist_anoms.len() as u64,
        });
    }
    Fig7Result { rows }
}

/// One point of the PS shard sweep: sync throughput and latency at a
/// given shard count.
#[derive(Clone, Debug)]
pub struct ShardSweepRow {
    pub shards: usize,
    /// Routed syncs completed per second across all clients.
    pub syncs_per_sec: f64,
    /// Per-sync round-trip latency percentiles, µs.
    pub p50_us: f64,
    pub p99_us: f64,
    pub total_syncs: u64,
    /// Aggregator event-fetch messages per sync. Version gating holds
    /// this at ~0 in the no-events steady state (it was 1.0 before the
    /// gate — one fetch round-trip per routed sync).
    pub agg_msgs_per_sync: f64,
    pub wall_seconds: f64,
}

/// Result of the shard sweep (the `BENCH_ps_shards.json` artifact).
#[derive(Clone, Debug)]
pub struct ShardSweepResult {
    pub rows: Vec<ShardSweepRow>,
    pub clients: usize,
    pub funcs_per_sync: usize,
}

impl ShardSweepResult {
    pub fn render(&self) -> String {
        let mut t = Table::new(
            "PS shard sweep — sync throughput vs shard count",
            &["shards", "syncs/s", "p50(µs)", "p99(µs)", "total syncs", "agg msg/sync", "wall(s)"],
        );
        for r in &self.rows {
            t.row(vec![
                r.shards.to_string(),
                format!("{:.0}", r.syncs_per_sec),
                format!("{:.1}", r.p50_us),
                format!("{:.1}", r.p99_us),
                r.total_syncs.to_string(),
                format!("{:.3}", r.agg_msgs_per_sync),
                format!("{:.3}", r.wall_seconds),
            ]);
        }
        format!(
            "{}({} client threads, {} functions per sync delta)\n",
            t.render(),
            self.clients,
            self.funcs_per_sync
        )
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("bench", Json::str("ps_shards")),
            ("clients", Json::num(self.clients as f64)),
            ("funcs_per_sync", Json::num(self.funcs_per_sync as f64)),
            ("rows", self.rows_json()),
        ])
    }

    /// Just the per-shard-count rows (used when composing the combined
    /// `BENCH_ps_shards.json` artifact with the endpoint sweep).
    pub fn rows_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::arr(
            self.rows
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("shards", Json::num(r.shards as f64)),
                        ("syncs_per_sec", Json::num(r.syncs_per_sec)),
                        ("p50_us", Json::num(r.p50_us)),
                        ("p99_us", Json::num(r.p99_us)),
                        ("total_syncs", Json::num(r.total_syncs as f64)),
                        ("agg_msgs_per_sync", Json::num(r.agg_msgs_per_sync)),
                        ("wall_seconds", Json::num(r.wall_seconds)),
                    ])
                })
                .collect(),
        )
    }
}

/// Sweep PS shard counts under a fixed concurrent sync load: `clients`
/// threads each issue `syncs_per_client` routed syncs whose deltas touch
/// `funcs_per_sync` functions. Reports throughput and round-trip latency
/// per shard count — the sync-throughput scaling argument of the
/// sharding refactor, measured.
pub fn run_ps_shard_sweep(
    shard_counts: &[usize],
    clients: usize,
    syncs_per_client: usize,
    funcs_per_sync: usize,
    seed: u64,
) -> ShardSweepResult {
    let mut rows = Vec::new();
    for &shards in shard_counts {
        let (client, handle) = ps::spawn(shards, None, usize::MAX >> 1, clients.max(1));
        let t0 = Instant::now();
        let mut joins = Vec::new();
        for c in 0..clients {
            let cl = client.clone();
            let mut rng = Rng::new(seed ^ (c as u64).wrapping_mul(0x9E37_79B9));
            joins.push(std::thread::spawn(move || {
                let mut lat_us = Vec::with_capacity(syncs_per_client);
                for _ in 0..syncs_per_client {
                    let mut delta = crate::stats::StatsTable::new();
                    for f in 0..funcs_per_sync {
                        delta.push(f as u32, rng.lognormal(6.0, 0.5));
                    }
                    let t = Instant::now();
                    let (global, _) = cl.sync(0, c as u32, &delta);
                    lat_us.push(t.elapsed().as_secs_f64() * 1e6);
                    assert_eq!(global.len(), funcs_per_sync, "reply must cover the delta");
                }
                lat_us
            }));
        }
        let mut lat_us: Vec<f64> = Vec::with_capacity(clients * syncs_per_client);
        for j in joins {
            lat_us.extend(j.join().expect("sweep client panicked"));
        }
        let wall = t0.elapsed().as_secs_f64();
        let agg_fetches = client.agg_fetch_count();
        client.shutdown();
        let fin = handle.join();
        let total_syncs = fin.sync_count;
        rows.push(ShardSweepRow {
            shards,
            syncs_per_sec: total_syncs as f64 / wall.max(1e-9),
            p50_us: crate::util::percentile(&lat_us, 50.0),
            p99_us: crate::util::percentile(&lat_us, 99.0),
            total_syncs,
            agg_msgs_per_sync: agg_fetches as f64 / (total_syncs as f64).max(1.0),
            wall_seconds: wall,
        });
    }
    ShardSweepResult { rows, clients, funcs_per_sync }
}

/// One point of the PS *endpoint* sweep: the same concurrent sync load,
/// but every stat shard behind its own TCP endpoint (the multi-process
/// topology, in-process for the bench) and routed clients connected
/// through a front-end hello.
#[derive(Clone, Debug)]
pub struct EndpointSweepRow {
    pub endpoints: usize,
    pub syncs_per_sec: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub total_syncs: u64,
    /// Aggregator messages per sync across all clients — the acceptance
    /// number for event-fetch gating (~0 with no events flowing).
    pub agg_msgs_per_sync: f64,
    pub wall_seconds: f64,
}

/// Result of the endpoint sweep (appended to `BENCH_ps_shards.json`).
#[derive(Clone, Debug)]
pub struct EndpointSweepResult {
    pub rows: Vec<EndpointSweepRow>,
    pub clients: usize,
    pub funcs_per_sync: usize,
}

impl EndpointSweepResult {
    pub fn render(&self) -> String {
        let mut t = Table::new(
            "PS endpoint sweep — sync throughput vs TCP endpoint count",
            &[
                "endpoints",
                "syncs/s",
                "p50(µs)",
                "p99(µs)",
                "total syncs",
                "agg msg/sync",
                "wall(s)",
            ],
        );
        for r in &self.rows {
            t.row(vec![
                r.endpoints.to_string(),
                format!("{:.0}", r.syncs_per_sec),
                format!("{:.1}", r.p50_us),
                format!("{:.1}", r.p99_us),
                r.total_syncs.to_string(),
                format!("{:.3}", r.agg_msgs_per_sync),
                format!("{:.3}", r.wall_seconds),
            ]);
        }
        format!(
            "{}({} routed TCP clients, {} functions per sync delta)\n",
            t.render(),
            self.clients,
            self.funcs_per_sync
        )
    }

    pub fn rows_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::arr(
            self.rows
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("endpoints", Json::num(r.endpoints as f64)),
                        ("syncs_per_sec", Json::num(r.syncs_per_sec)),
                        ("p50_us", Json::num(r.p50_us)),
                        ("p99_us", Json::num(r.p99_us)),
                        ("total_syncs", Json::num(r.total_syncs as f64)),
                        ("agg_msgs_per_sync", Json::num(r.agg_msgs_per_sync)),
                        ("wall_seconds", Json::num(r.wall_seconds)),
                    ])
                })
                .collect(),
        )
    }
}

/// The combined `BENCH_ps_shards.json` payload: the in-process shard
/// sweep, the per-endpoint TCP sweep, the skewed-workload rebalance
/// sweep, the reactor connection sweep, and the aggregation-tree
/// sweep, so the perf trajectory of all five lives in one artifact
/// across PRs.
pub fn ps_bench_json(
    shards: &ShardSweepResult,
    endpoints: &EndpointSweepResult,
    rebalance: &RebalanceSweepResult,
    conns: &ConnSweepResult,
    aggtree: &AggTreeSweepResult,
) -> crate::util::json::Json {
    use crate::util::json::Json;
    Json::obj(vec![
        ("bench", Json::str("ps_shards")),
        ("clients", Json::num(shards.clients as f64)),
        ("funcs_per_sync", Json::num(shards.funcs_per_sync as f64)),
        ("rows", shards.rows_json()),
        ("endpoint_clients", Json::num(endpoints.clients as f64)),
        ("endpoint_funcs_per_sync", Json::num(endpoints.funcs_per_sync as f64)),
        ("endpoint_rows", endpoints.rows_json()),
        ("rebalance_rows", rebalance.rows_json()),
        ("conn_total_syncs", Json::num(conns.total_syncs as f64)),
        ("conn_funcs_per_sync", Json::num(conns.funcs_per_sync as f64)),
        ("conn_rows", conns.rows_json()),
        ("aggtree_steps", Json::num(aggtree.steps as f64)),
        ("aggtree_producers", Json::num(aggtree.producers as f64)),
        ("aggtree_rows", aggtree.rows_json()),
    ])
}

/// One variant of the skewed-workload rebalance sweep: the same hot-slot
/// load with the rebalancer off vs on.
#[derive(Clone, Debug)]
pub struct RebalanceSweepRow {
    pub shards: usize,
    /// Whether a rebalance was fired between the two phases.
    pub rebalance: bool,
    /// Windowed per-shard merge load max/mean over phase 1 (skewed,
    /// pre-rebalance — the number that triggers the rebalancer).
    pub max_mean_before: f64,
    /// The same ratio over phase 2 (post-rebalance when `rebalance`).
    pub max_mean_after: f64,
    /// Placement epoch at the end of the run (0 = never rebalanced).
    pub epoch: u64,
    pub syncs_per_sec: f64,
    pub wall_seconds: f64,
}

/// Result of the rebalance sweep (appended to `BENCH_ps_shards.json`).
#[derive(Clone, Debug)]
pub struct RebalanceSweepResult {
    pub rows: Vec<RebalanceSweepRow>,
    pub shards: usize,
    pub clients: usize,
}

impl RebalanceSweepResult {
    pub fn render(&self) -> String {
        let mut t = Table::new(
            "PS rebalance sweep — skewed load, rebalancer off vs on",
            &["shards", "rebalance", "max/mean before", "max/mean after", "epoch", "syncs/s"],
        );
        for r in &self.rows {
            t.row(vec![
                r.shards.to_string(),
                if r.rebalance { "on" } else { "off" }.to_string(),
                format!("{:.2}", r.max_mean_before),
                format!("{:.2}", r.max_mean_after),
                r.epoch.to_string(),
                format!("{:.0}", r.syncs_per_sec),
            ]);
        }
        format!(
            "{}({} client threads; one hot fid in every delta + uniform tail)\n",
            t.render(),
            self.clients
        )
    }

    pub fn rows_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::arr(
            self.rows
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("shards", Json::num(r.shards as f64)),
                        ("rebalance", Json::Bool(r.rebalance)),
                        ("max_mean_before", Json::num(r.max_mean_before)),
                        ("max_mean_after", Json::num(r.max_mean_after)),
                        ("epoch", Json::num(r.epoch as f64)),
                        ("syncs_per_sec", Json::num(r.syncs_per_sec)),
                        ("wall_seconds", Json::num(r.wall_seconds)),
                    ])
                })
                .collect(),
        )
    }
}

/// Drive the skewed workload: every delta touches one hot function
/// (~1/3 of all merges) plus two draws from a 200-function uniform tail.
fn drive_skewed(client: &ps::PsClient, clients: usize, syncs_per_client: usize, seed: u64) {
    let mut joins = Vec::new();
    for c in 0..clients {
        let cl = client.clone();
        let mut rng = Rng::new(seed ^ (c as u64).wrapping_mul(0x9E37_79B9));
        joins.push(std::thread::spawn(move || {
            for _ in 0..syncs_per_client {
                let mut delta = crate::stats::StatsTable::new();
                delta.push(0, rng.lognormal(6.0, 0.5));
                delta.push(8 + rng.usize(200) as u32, rng.lognormal(6.0, 0.5));
                delta.push(8 + rng.usize(200) as u32, rng.lognormal(6.0, 0.5));
                cl.sync(0, c as u32, &delta);
            }
        }));
    }
    for j in joins {
        j.join().expect("rebalance sweep client panicked");
    }
}

/// Windowed per-shard merge loads between two cumulative per-slot
/// counter readings (`PsHandle::slot_merge_counters`). Counters are per
/// (shard, slot) and stay with the shard that did the merging, so this
/// is exact across migrations.
fn shard_window(
    prev: &[(u32, u32, u64)],
    now: &[(u32, u32, u64)],
    n_shards: usize,
) -> Vec<u64> {
    let prev: std::collections::HashMap<(u32, u32), u64> =
        prev.iter().map(|&(s, slot, m)| ((s, slot), m)).collect();
    let mut per = vec![0u64; n_shards];
    for &(shard, slot, m) in now {
        per[shard as usize] += m.saturating_sub(prev.get(&(shard, slot)).copied().unwrap_or(0));
    }
    per
}

/// The rebalance acceptance sweep: run the skewed workload twice on a
/// `shards`-shard constellation — phase 1 establishes the skew, then
/// (in the `on` variant) one skew-driven rebalance fires, then phase 2
/// measures the windowed per-shard load again. The `off` variant is the
/// static-placement baseline. Under this workload with ≥ 4 shards, the
/// rebalanced max/mean must land below 1.5 (asserted in the fig7 tests;
/// the rows land in `BENCH_ps_shards.json`).
pub fn run_ps_rebalance_sweep(
    shards: usize,
    clients: usize,
    syncs_per_client: usize,
    seed: u64,
) -> RebalanceSweepResult {
    let mut rows = Vec::new();
    for rebalance in [false, true] {
        let (client, handle) = ps::spawn(shards, None, usize::MAX >> 1, clients.max(1));
        let t0 = Instant::now();
        drive_skewed(&client, clients, syncs_per_client, seed);
        let c1 = handle.slot_merge_counters();
        let before = shard_window(&[], &c1, shards);
        let mut epoch = 0u64;
        if rebalance {
            if let Some(r) = handle.rebalance_once().expect("rebalance") {
                epoch = r.epoch;
            }
        }
        drive_skewed(&client, clients, syncs_per_client, seed ^ 0xA5A5);
        let c2 = handle.slot_merge_counters();
        let after = shard_window(&c1, &c2, shards);
        let wall = t0.elapsed().as_secs_f64();
        client.shutdown();
        let fin = handle.join();
        rows.push(RebalanceSweepRow {
            shards,
            rebalance,
            max_mean_before: crate::placement::load_ratio(&before),
            max_mean_after: crate::placement::load_ratio(&after),
            epoch,
            syncs_per_sec: fin.sync_count as f64 / wall.max(1e-9),
            wall_seconds: wall,
        });
    }
    RebalanceSweepResult { rows, shards, clients }
}

/// Sweep PS TCP *endpoint* counts under a fixed concurrent sync load:
/// for each count E, every stat shard is served at its own TCP endpoint
/// and a front-end announces the shard→addr map; `clients` routed
/// clients each issue `syncs_per_client` syncs touching `funcs_per_sync`
/// functions. Fig 7's deployment argument, measured end to end: sync
/// throughput scales with endpoints while the aggregator sees ~0
/// messages per sync (version-gated event fetch, no events flowing).
pub fn run_ps_endpoint_sweep(
    endpoint_counts: &[usize],
    clients: usize,
    syncs_per_client: usize,
    funcs_per_sync: usize,
    seed: u64,
) -> anyhow::Result<EndpointSweepResult> {
    let mut rows = Vec::new();
    for &endpoints in endpoint_counts {
        let (local_client, handle) = ps::spawn(endpoints, None, usize::MAX >> 1, clients.max(1));
        let shard_srvs = handle.serve_shard_endpoints()?;
        let addrs: Vec<String> = shard_srvs.iter().map(|s| s.addr().to_string()).collect();
        let front = crate::ps::net::PsTcpServer::start_with_topology(
            "127.0.0.1:0",
            local_client.clone(),
            addrs,
        )?;
        let front_addr = front.addr().to_string();
        let t0 = Instant::now();
        let mut joins = Vec::new();
        for c in 0..clients {
            let addr = front_addr.clone();
            let mut rng = Rng::new(seed ^ (c as u64).wrapping_mul(0x9E37_79B9));
            joins.push(std::thread::spawn(move || {
                let cl = crate::ps::PsClient::connect(&addr).expect("routed client connect");
                let mut lat_us = Vec::with_capacity(syncs_per_client);
                for _ in 0..syncs_per_client {
                    let mut delta = crate::stats::StatsTable::new();
                    for f in 0..funcs_per_sync {
                        delta.push(f as u32, rng.lognormal(6.0, 0.5));
                    }
                    let t = Instant::now();
                    let (global, _) = cl.sync(0, c as u32, &delta);
                    lat_us.push(t.elapsed().as_secs_f64() * 1e6);
                    assert_eq!(global.len(), funcs_per_sync, "reply must cover the delta");
                }
                (lat_us, cl.agg_fetch_count(), cl.sync_count_value())
            }));
        }
        let mut lat_us: Vec<f64> = Vec::with_capacity(clients * syncs_per_client);
        let mut agg_fetches = 0u64;
        let mut total_syncs = 0u64;
        for j in joins {
            let (lat, fetches, syncs) = j.join().expect("endpoint sweep client panicked");
            lat_us.extend(lat);
            agg_fetches += fetches;
            total_syncs += syncs;
        }
        let wall = t0.elapsed().as_secs_f64();
        drop(front);
        drop(shard_srvs);
        local_client.shutdown();
        handle.join();
        rows.push(EndpointSweepRow {
            endpoints,
            syncs_per_sec: total_syncs as f64 / wall.max(1e-9),
            p50_us: crate::util::percentile(&lat_us, 50.0),
            p99_us: crate::util::percentile(&lat_us, 99.0),
            total_syncs,
            agg_msgs_per_sync: agg_fetches as f64 / (total_syncs as f64).max(1.0),
            wall_seconds: wall,
        });
    }
    Ok(EndpointSweepResult { rows, clients, funcs_per_sync })
}

/// One point of the reactor connection sweep: `clients` live TCP
/// connections against one reactor-served shard endpoint.
#[derive(Clone, Debug)]
pub struct ConnSweepRow {
    pub clients: usize,
    pub syncs_per_sec: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    /// Requests the endpoint shed with `Busy` (0 expected: these
    /// clients drain their replies).
    pub shed: u64,
    /// Peak OS thread count of this process observed during the point.
    /// Flat across client counts is the reactor's acceptance criterion —
    /// the old thread-per-connection transport scaled this with N.
    pub peak_threads: u64,
    pub reactor_threads: usize,
    pub wall_seconds: f64,
}

/// Result of [`run_ps_conn_sweep`] (`conn_rows` in `BENCH_ps_shards.json`).
#[derive(Clone, Debug)]
pub struct ConnSweepResult {
    pub rows: Vec<ConnSweepRow>,
    /// Sync volume per point, split across the point's connections.
    pub total_syncs: usize,
    pub funcs_per_sync: usize,
}

impl ConnSweepResult {
    pub fn render(&self) -> String {
        let mut t = Table::new(
            "PS connection sweep — live connections vs latency on the reactor",
            &["clients", "syncs/s", "p50 µs", "p99 µs", "shed", "peak threads"],
        );
        for r in &self.rows {
            t.row(vec![
                r.clients.to_string(),
                format!("{:.0}", r.syncs_per_sec),
                format!("{:.0}", r.p50_us),
                format!("{:.0}", r.p99_us),
                r.shed.to_string(),
                r.peak_threads.to_string(),
            ]);
        }
        format!(
            "{}({} syncs total per point, {} functions each; {} event-loop threads serve every point)\n",
            t.render(),
            self.total_syncs,
            self.funcs_per_sync,
            self.rows.first().map(|r| r.reactor_threads).unwrap_or(0),
        )
    }

    pub fn rows_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::arr(
            self.rows
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("clients", Json::num(r.clients as f64)),
                        ("syncs_per_sec", Json::num(r.syncs_per_sec)),
                        ("p50_us", Json::num(r.p50_us)),
                        ("p99_us", Json::num(r.p99_us)),
                        ("shed", Json::num(r.shed as f64)),
                        ("peak_threads", Json::num(r.peak_threads as f64)),
                        ("reactor_threads", Json::num(r.reactor_threads as f64)),
                        ("wall_seconds", Json::num(r.wall_seconds)),
                    ])
                })
                .collect(),
        )
    }
}

/// Current OS thread count of this process (`/proc/self/status`); 0 when
/// the proc filesystem is unavailable (non-Linux dev machines).
fn process_threads() -> u64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find_map(|l| l.strip_prefix("Threads:").and_then(|v| v.trim().parse().ok()))
        })
        .unwrap_or(0)
}

/// Sweep *live-connection* counts against one reactor-served shard
/// endpoint. Each point dials `clients` TCP connections but drives them
/// from a fixed pool of at most 64 worker threads, and the total sync
/// volume is constant (split across connections) — so the sweep isolates
/// what the transport does as connections grow. Thread-per-connection
/// scaled threads (and scheduler pressure) with N; the reactor must hold
/// both the p99 sync latency and the process thread count flat.
pub fn run_ps_conn_sweep(
    client_counts: &[usize],
    total_syncs: usize,
    funcs_per_sync: usize,
    seed: u64,
) -> anyhow::Result<ConnSweepResult> {
    // 10k connections ≈ 20k descriptors across both ends of the
    // loopback; default soft limits (1024 on CI runners) refuse them.
    crate::util::net::raise_nofile_limit(1 << 16);
    let mut rows = Vec::new();
    for &clients in client_counts {
        let clients = clients.max(1);
        let opts = crate::util::net::ReactorOpts::default();
        let reactor_threads = opts.threads;
        let srv = crate::ps::net::PsShardTcpServer::spawn_standalone_with_opts(
            "127.0.0.1:0",
            0,
            1,
            opts,
        )?;
        let addr = srv.addr().to_string();
        let per_client = (total_syncs / clients).max(1);
        let workers = clients.min(64);
        let t0 = Instant::now();
        let mut joins = Vec::new();
        for w in 0..workers {
            let addr = addr.clone();
            let mut rng = Rng::new(seed ^ (w as u64).wrapping_mul(0x9E37_79B9));
            // Worker w owns connections w, w+workers, w+2·workers, …
            let mine = (clients - w).div_ceil(workers);
            joins.push(std::thread::spawn(move || {
                let mut wires: Vec<_> = (0..mine)
                    .map(|_| {
                        crate::ps::net::ShardWire::dial(&addr, 0, 1).expect("conn sweep dial")
                    })
                    .collect();
                // Sampled while every worker's connections are live, so
                // the max over workers sees the full-fan-out state.
                let threads_seen = process_threads();
                let mut lat_us = Vec::with_capacity(mine * per_client);
                for _ in 0..per_client {
                    for wire in wires.iter_mut() {
                        let mut st_entries = Vec::with_capacity(funcs_per_sync);
                        for f in 0..funcs_per_sync {
                            let mut st = RunStats::new();
                            st.push(rng.lognormal(6.0, 0.5));
                            st_entries.push((f as u32, st));
                        }
                        let t = Instant::now();
                        wire.send_sync(0, 0, &st_entries).expect("conn sweep sync");
                        match wire.recv_sync().expect("conn sweep sync reply") {
                            crate::ps::net::ShardSyncResp::Ok { entries, .. } => {
                                assert_eq!(
                                    entries.len(),
                                    funcs_per_sync,
                                    "reply must cover the delta"
                                );
                            }
                            crate::ps::net::ShardSyncResp::Rerouted { .. } => {
                                panic!("epoch 0 must be accepted")
                            }
                        }
                        lat_us.push(t.elapsed().as_secs_f64() * 1e6);
                    }
                }
                (lat_us, threads_seen)
            }));
        }
        let mut lat_us: Vec<f64> = Vec::with_capacity(clients * per_client);
        let mut peak_threads = 0u64;
        for j in joins {
            let (lat, threads_seen) = j.join().expect("conn sweep worker panicked");
            lat_us.extend(lat);
            peak_threads = peak_threads.max(threads_seen);
        }
        let wall = t0.elapsed().as_secs_f64();
        let shed = srv.net_stats().shed_count();
        drop(srv);
        rows.push(ConnSweepRow {
            clients,
            syncs_per_sec: lat_us.len() as f64 / wall.max(1e-9),
            p50_us: crate::util::percentile(&lat_us, 50.0),
            p99_us: crate::util::percentile(&lat_us, 99.0),
            shed,
            peak_threads,
            reactor_threads,
            wall_seconds: wall,
        });
    }
    Ok(ConnSweepResult { rows, total_syncs, funcs_per_sync })
}

/// One point of the aggregation-tree sweep: the same per-step report
/// fan-in drained by the flat single-thread aggregator vs the
/// hierarchical fold tree ([`crate::aggtree`]). Rows come in
/// flat/tree pairs sharing every workload parameter, so the
/// reports-per-second ratio at each rank count *is* the fan-in scaling
/// argument: flat bends once one thread folds every report, the tree
/// spreads the fold across `nodes - 1` workers.
#[derive(Clone, Debug)]
pub struct AggTreeSweepRow {
    pub ranks: usize,
    /// "flat" or "tree".
    pub mode: &'static str,
    /// Tree fanout (0 for flat rows).
    pub fanout: usize,
    /// Tree depth (1 for flat rows — the degenerate single-node tree).
    pub depth: usize,
    /// Aggregator node count (1 for flat rows).
    pub nodes: usize,
    pub reports_per_sec: f64,
    /// Globally flagged events — must match within a flat/tree pair
    /// (the tree is pinned bit-equivalent to flat).
    pub events: u64,
    pub wall_seconds: f64,
}

/// Result of the aggregation-tree sweep (appended to
/// `BENCH_ps_shards.json` as `aggtree_rows`).
#[derive(Clone, Debug)]
pub struct AggTreeSweepResult {
    pub rows: Vec<AggTreeSweepRow>,
    pub steps: usize,
    pub producers: usize,
}

impl AggTreeSweepResult {
    pub fn render(&self) -> String {
        let mut t = Table::new(
            "PS aggregation-tree sweep — step-report fold throughput, flat vs tree",
            &["ranks", "mode", "fanout", "depth", "nodes", "reports/s", "events", "wall(s)"],
        );
        for r in &self.rows {
            t.row(vec![
                r.ranks.to_string(),
                r.mode.to_string(),
                r.fanout.to_string(),
                r.depth.to_string(),
                r.nodes.to_string(),
                format!("{:.0}", r.reports_per_sec),
                r.events.to_string(),
                format!("{:.3}", r.wall_seconds),
            ]);
        }
        format!(
            "{}({} steps per rank, {} producer threads)\n",
            t.render(),
            self.steps,
            self.producers
        )
    }

    pub fn rows_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::arr(
            self.rows
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("ranks", Json::num(r.ranks as f64)),
                        ("mode", Json::str(r.mode)),
                        ("fanout", Json::num(r.fanout as f64)),
                        ("depth", Json::num(r.depth as f64)),
                        ("nodes", Json::num(r.nodes as f64)),
                        ("reports_per_sec", Json::num(r.reports_per_sec)),
                        ("events", Json::num(r.events as f64)),
                        ("wall_seconds", Json::num(r.wall_seconds)),
                    ])
                })
                .collect(),
        )
    }
}

/// Deterministic per-rank anomaly schedule for the aggtree sweep:
/// alternating 0/1 background (non-zero step-total variance) with a
/// 5-anomaly spike every 10th step — the spike total `5·ranks` clears
/// μ + 3σ of the alternating baseline with history to spare, so every
/// spike flags a global event in both aggregator shapes.
fn aggtree_anomalies(step: u64) -> u64 {
    if step % 10 == 9 {
        5
    } else {
        step % 2
    }
}

/// Sweep rank counts under the step-report fan-in load, flat aggregator
/// vs hierarchical tree: `producers` threads partition the rank space
/// and fire `steps` fire-and-forget reports per rank in step order,
/// and the wall clock runs through shutdown + join so each shape pays
/// for draining its own fold backlog.
pub fn run_aggtree_sweep(
    rank_counts: &[usize],
    steps: usize,
    fanout: usize,
    producers: usize,
    seed: u64,
) -> anyhow::Result<AggTreeSweepResult> {
    let producers = producers.max(1);
    let mut rows = Vec::new();
    for &ranks in rank_counts {
        for agg_fanout in [0usize, fanout] {
            let (client, handle) = ps::spawn_with(ps::PsOpts {
                shards: 1,
                publish_every: usize::MAX >> 1,
                reports_per_step: ranks,
                agg_fanout,
                ..ps::PsOpts::default()
            })?;
            let t0 = Instant::now();
            let chunk = ranks.div_ceil(producers);
            let mut joins = Vec::new();
            for p in 0..producers {
                let lo = (p * chunk).min(ranks);
                let hi = ((p + 1) * chunk).min(ranks);
                if lo == hi {
                    continue;
                }
                let cl = client.clone();
                let mut rng = Rng::new(seed ^ (p as u64).wrapping_mul(0x9E37_79B9));
                joins.push(std::thread::spawn(move || {
                    for step in 0..steps as u64 {
                        for rank in lo..hi {
                            cl.report(ps::StepStat {
                                app: 0,
                                rank: rank as u32,
                                step,
                                n_executions: 100 + rng.lognormal(3.0, 0.3) as u64,
                                n_anomalies: aggtree_anomalies(step),
                                ts_range: (step * 1_000, step * 1_000 + 999),
                            });
                        }
                    }
                }));
            }
            for j in joins {
                j.join().expect("aggtree producer panicked");
            }
            client.shutdown();
            let fin = handle.join();
            let wall = t0.elapsed().as_secs_f64();
            let spec = crate::aggtree::TreeSpec::plan(agg_fanout.max(2), ranks);
            let tree = agg_fanout >= 2 && spec.depth() >= 2;
            rows.push(AggTreeSweepRow {
                ranks,
                mode: if tree { "tree" } else { "flat" },
                fanout: if tree { agg_fanout } else { 0 },
                depth: if tree { spec.depth() } else { 1 },
                nodes: if tree { spec.nodes() } else { 1 },
                reports_per_sec: (ranks * steps) as f64 / wall.max(1e-9),
                events: fin.global_events.len() as u64,
                wall_seconds: wall,
            });
        }
    }
    Ok(AggTreeSweepResult { rows, steps, producers })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distributed_matches_single_closely_and_is_faster_per_instance() {
        let res = run_fig7(&[10, 20], 12, 3, 99);
        assert_eq!(res.rows.len(), 2);
        for row in &res.rows {
            assert!(row.anomalies_single > 0, "no anomalies at {} ranks", row.ranks);
            assert!(
                row.accuracy > 0.8,
                "accuracy {} at {} ranks",
                row.accuracy,
                row.ranks
            );
            // The per-instance distributed cost must be well under the
            // single-instance cost (which scales with total data).
            assert!(
                row.t_distributed_max < row.t_single,
                "dist max {} vs single {}",
                row.t_distributed_max,
                row.t_single
            );
        }
        // Single-instance time grows with rank count…
        assert!(res.rows[1].t_single > res.rows[0].t_single * 1.3);
        // …distributed per-instance time stays roughly flat (≤ 2.5×).
        let flat = res.rows[1].t_distributed_mean / res.rows[0].t_distributed_mean.max(1e-9);
        assert!(flat < 2.5, "distributed time grew {flat}x");
        let text = res.render();
        assert!(text.contains("Fig 7"));
        assert!(text.contains("97.6%"));
    }

    #[test]
    fn shard_sweep_produces_rows_and_json() {
        let res = run_ps_shard_sweep(&[1, 2], 4, 40, 32, 11);
        assert_eq!(res.rows.len(), 2);
        for row in &res.rows {
            assert_eq!(row.total_syncs, 4 * 40);
            assert!(row.syncs_per_sec > 0.0);
            assert!(row.p50_us > 0.0);
            assert!(row.p99_us >= row.p50_us);
            // Sync-only load: the version gate keeps the aggregator
            // completely out of the sync path.
            assert_eq!(row.agg_msgs_per_sync, 0.0, "gating must zero the fetch leg");
        }
        let text = res.render();
        assert!(text.contains("PS shard sweep"));
        let json = res.to_json();
        assert_eq!(json.get("bench").unwrap().as_str(), Some("ps_shards"));
        assert_eq!(json.get("rows").unwrap().as_arr().unwrap().len(), 2);
        crate::util::json::parse(&json.to_pretty()).unwrap();
    }

    #[test]
    fn endpoint_sweep_produces_rows_and_combined_json() {
        let shards = run_ps_shard_sweep(&[1], 2, 10, 16, 11);
        let eps = run_ps_endpoint_sweep(&[1, 2], 2, 10, 16, 11).unwrap();
        assert_eq!(eps.rows.len(), 2);
        for row in &eps.rows {
            assert_eq!(row.total_syncs, 2 * 10);
            assert!(row.syncs_per_sec > 0.0);
            assert!(row.p99_us >= row.p50_us);
            assert_eq!(
                row.agg_msgs_per_sync, 0.0,
                "no events → routed TCP clients never message the aggregator"
            );
        }
        let text = eps.render();
        assert!(text.contains("PS endpoint sweep"));
        let reb = run_ps_rebalance_sweep(2, 2, 50, 11);
        let conns = run_ps_conn_sweep(&[2], 8, 4, 11).unwrap();
        let aggtree = run_aggtree_sweep(&[8], 12, 2, 2, 11).unwrap();
        let combined = ps_bench_json(&shards, &eps, &reb, &conns, &aggtree);
        assert_eq!(combined.get("bench").unwrap().as_str(), Some("ps_shards"));
        assert_eq!(combined.get("rows").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(combined.get("endpoint_rows").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(combined.get("rebalance_rows").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(combined.get("conn_rows").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(combined.get("aggtree_rows").unwrap().as_arr().unwrap().len(), 2);
        crate::util::json::parse(&combined.to_pretty()).unwrap();
    }

    #[test]
    fn aggtree_sweep_pairs_flat_and_tree_rows() {
        let res = run_aggtree_sweep(&[8, 32], 24, 4, 2, 7).unwrap();
        assert_eq!(res.rows.len(), 4);
        for pair in res.rows.chunks(2) {
            let (flat, tree) = (&pair[0], &pair[1]);
            assert_eq!(flat.mode, "flat");
            assert_eq!(tree.mode, "tree");
            assert_eq!(flat.ranks, tree.ranks);
            assert!(flat.reports_per_sec > 0.0 && tree.reports_per_sec > 0.0);
            assert!(flat.events > 0, "spike schedule must flag global events");
            assert_eq!(
                flat.events, tree.events,
                "tree must flag exactly the events flat flags at {} ranks",
                flat.ranks
            );
            assert_eq!(flat.depth, 1);
            assert_eq!(flat.nodes, 1);
            assert!(tree.depth >= 2 && tree.nodes > 1);
        }
        let text = res.render();
        assert!(text.contains("aggregation-tree sweep"));
        assert_eq!(res.rows_json().as_arr().unwrap().len(), 4);
    }

    #[test]
    fn conn_sweep_keeps_threads_flat_and_sheds_nothing() {
        let res = run_ps_conn_sweep(&[4, 32], 64, 8, 17).unwrap();
        assert_eq!(res.rows.len(), 2);
        for row in &res.rows {
            assert!(row.syncs_per_sec > 0.0);
            assert!(row.p99_us >= row.p50_us);
            assert_eq!(row.shed, 0, "well-behaved clients must never be shed");
        }
        // Thread count must be a function of the worker cap and the
        // reactor, not of the connection count: 8× the connections may
        // not add more threads than the extra driver workers themselves
        // (old transport: one server thread per connection).
        let grew = res.rows[1].peak_threads.saturating_sub(res.rows[0].peak_threads);
        assert!(
            grew <= 28 + 4,
            "threads grew by {grew} for 28 extra driver workers — server is scaling per-connection"
        );
        let text = res.render();
        assert!(text.contains("PS connection sweep"));
        assert!(res.rows_json().as_arr().unwrap().len() == 2);
    }

    #[test]
    fn rebalance_sweep_meets_acceptance_ratio() {
        // The acceptance criterion: single-hot-function workload, 4
        // shards — the rebalanced max/mean per-shard merge load lands
        // below 1.5 while the static baseline stays skewed.
        let res = run_ps_rebalance_sweep(4, 2, 400, 7);
        assert_eq!(res.rows.len(), 2);
        let off = &res.rows[0];
        let on = &res.rows[1];
        assert!(!off.rebalance && on.rebalance);
        assert_eq!(off.epoch, 0, "static baseline must not rebalance");
        assert!(
            off.max_mean_before > 1.5 && on.max_mean_before > 1.5,
            "workload must be skewed (off {:.2}, on {:.2})",
            off.max_mean_before,
            on.max_mean_before
        );
        assert!(
            off.max_mean_after > 1.5,
            "static placement must stay skewed ({:.2})",
            off.max_mean_after
        );
        assert!(on.epoch > 0, "skew must trigger a rebalance");
        assert!(
            on.max_mean_after < 1.5,
            "rebalanced max/mean {:.2} must be below 1.5",
            on.max_mean_after
        );
        let text = res.render();
        assert!(text.contains("PS rebalance sweep"));
    }
}
