//! **Fig 9** — trace data size over MPI processes, and the §VI-B headline
//! reduction factors.
//!
//! Four byte counts per scale, exactly the paper's four series:
//!
//! * raw/unfiltered BP dump (all functions incl. high-frequency helpers);
//! * filtered BP dump (paper's instrumentation filtering);
//! * Chimbuko-reduced JSON from the unfiltered stream;
//! * Chimbuko-reduced JSON from the filtered stream.
//!
//! Paper anchors: 2300 GB → 15.5 GB (×148 unfiltered) and 117.5 GB →
//! 5.5 GB (×21 filtered) at 2560 ranks; ×95/×14 averages. We reproduce the
//! *ratios* (absolute GB scale with steps × calls_per_step).

use crate::bench::Table;
use crate::config::{Config, TraceEngine};
use crate::coordinator::{run, Mode, RunReport, Workflow};
use anyhow::Result;

#[derive(Clone, Debug)]
pub struct Fig9Row {
    pub ranks: usize,
    pub raw_bytes: u64,
    pub filtered_bytes: u64,
    pub reduced_from_raw_bytes: u64,
    pub reduced_from_filtered_bytes: u64,
}

impl Fig9Row {
    pub fn factor_unfiltered(&self) -> f64 {
        RunReport::reduction_factor(self.raw_bytes, self.reduced_from_raw_bytes)
    }

    pub fn factor_filtered(&self) -> f64 {
        RunReport::reduction_factor(self.filtered_bytes, self.reduced_from_filtered_bytes)
    }
}

#[derive(Clone, Debug)]
pub struct Fig9Result {
    pub rows: Vec<Fig9Row>,
}

impl Fig9Result {
    pub fn mean_factor_unfiltered(&self) -> f64 {
        crate::util::mean(&self.rows.iter().map(|r| r.factor_unfiltered()).collect::<Vec<_>>())
    }

    pub fn mean_factor_filtered(&self) -> f64 {
        crate::util::mean(&self.rows.iter().map(|r| r.factor_filtered()).collect::<Vec<_>>())
    }

    pub fn render(&self) -> String {
        let mut t = Table::new(
            "Fig 9 — trace data size over MPI processes",
            &[
                "# MPI",
                "raw (BP)",
                "filtered (BP)",
                "reduced(raw)",
                "reduced(filt)",
                "×raw",
                "×filt",
            ],
        );
        for r in &self.rows {
            t.row(vec![
                r.ranks.to_string(),
                crate::util::fmt_bytes(r.raw_bytes),
                crate::util::fmt_bytes(r.filtered_bytes),
                crate::util::fmt_bytes(r.reduced_from_raw_bytes),
                crate::util::fmt_bytes(r.reduced_from_filtered_bytes),
                format!("{:.0}", r.factor_unfiltered()),
                format!("{:.0}", r.factor_filtered()),
            ]);
        }
        format!(
            "{}\nmean reduction: ×{:.0} unfiltered / ×{:.0} filtered \
             (paper: ×95 avg, ×148 peak unfiltered; ×14 avg, ×21 peak filtered)\n",
            t.render(),
            self.mean_factor_unfiltered(),
            self.mean_factor_filtered()
        )
    }
}

/// Measure one scale point (two BP runs + two Chimbuko runs).
pub fn measure_scale(base: &Config, ranks: usize) -> Result<Fig9Row> {
    let mut cfg = base.clone();
    cfg.ranks = ranks;
    cfg.engine = TraceEngine::Bp;
    cfg.out_dir = String::new(); // byte counting, no disk

    // Unfiltered (raw) BP + reduced.
    cfg.filtered = false;
    let w = Workflow::nwchem(&cfg);
    let raw = run(&cfg, &w, Mode::Tau)?;
    let reduced_raw = run(&cfg, &w, Mode::TauChimbuko)?;

    // Filtered BP + reduced.
    cfg.filtered = true;
    let w = Workflow::nwchem(&cfg);
    let filtered = run(&cfg, &w, Mode::Tau)?;
    let reduced_filtered = run(&cfg, &w, Mode::TauChimbuko)?;

    Ok(Fig9Row {
        ranks,
        raw_bytes: raw.bp_bytes,
        filtered_bytes: filtered.bp_bytes,
        reduced_from_raw_bytes: reduced_raw.reduced_bytes,
        reduced_from_filtered_bytes: reduced_filtered.reduced_bytes,
    })
}

pub fn run_fig9(scales: &[usize], steps: usize, calls_per_step: usize) -> Result<Fig9Result> {
    let base = Config {
        steps,
        calls_per_step,
        viz_enabled: false,
        ..Config::default()
    };
    let mut rows = Vec::new();
    for &ranks in scales {
        rows.push(measure_scale(&base, ranks)?);
    }
    Ok(Fig9Result { rows })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduction_factors_have_paper_shape() {
        let res = run_fig9(&[8], 15, 130).unwrap();
        let row = &res.rows[0];
        // Raw ≫ filtered (instrumentation filtering ~10–25×).
        let filter_ratio = row.raw_bytes as f64 / row.filtered_bytes as f64;
        assert!(filter_ratio > 4.0, "filter ratio {filter_ratio}");
        // Chimbuko reduces both streams heavily.
        assert!(row.factor_filtered() > 3.0, "filtered factor {}", row.factor_filtered());
        assert!(
            row.factor_unfiltered() > row.factor_filtered(),
            "unfiltered {} vs filtered {}",
            row.factor_unfiltered(),
            row.factor_filtered()
        );
        let text = res.render();
        assert!(text.contains("Fig 9"));
    }
}
