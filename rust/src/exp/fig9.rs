//! **Fig 9** — trace data size over MPI processes, and the §VI-B headline
//! reduction factors.
//!
//! Four byte counts per scale, exactly the paper's four series:
//!
//! * raw/unfiltered BP dump (all functions incl. high-frequency helpers);
//! * filtered BP dump (paper's instrumentation filtering);
//! * Chimbuko-reduced JSON from the unfiltered stream;
//! * Chimbuko-reduced JSON from the filtered stream.
//!
//! Paper anchors: 2300 GB → 15.5 GB (×148 unfiltered) and 117.5 GB →
//! 5.5 GB (×21 filtered) at 2560 ranks; ×95/×14 averages. We reproduce the
//! *ratios* (absolute GB scale with steps × calls_per_step).

use crate::bench::Table;
use crate::config::{Config, TraceEngine};
use crate::coordinator::{run, Mode, RunReport, Workflow};
use crate::provdb::{spawn_store, spawn_store_fmt, ProvClient, ProvDbTcpServer, Retention};
use crate::provenance::{ProvQuery, ProvRecord, RecordFormat};
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::Result;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct Fig9Row {
    pub ranks: usize,
    pub raw_bytes: u64,
    pub filtered_bytes: u64,
    pub reduced_from_raw_bytes: u64,
    pub reduced_from_filtered_bytes: u64,
}

impl Fig9Row {
    pub fn factor_unfiltered(&self) -> f64 {
        RunReport::reduction_factor(self.raw_bytes, self.reduced_from_raw_bytes)
    }

    pub fn factor_filtered(&self) -> f64 {
        RunReport::reduction_factor(self.filtered_bytes, self.reduced_from_filtered_bytes)
    }
}

#[derive(Clone, Debug)]
pub struct Fig9Result {
    pub rows: Vec<Fig9Row>,
}

impl Fig9Result {
    pub fn mean_factor_unfiltered(&self) -> f64 {
        crate::util::mean(&self.rows.iter().map(|r| r.factor_unfiltered()).collect::<Vec<_>>())
    }

    pub fn mean_factor_filtered(&self) -> f64 {
        crate::util::mean(&self.rows.iter().map(|r| r.factor_filtered()).collect::<Vec<_>>())
    }

    pub fn render(&self) -> String {
        let mut t = Table::new(
            "Fig 9 — trace data size over MPI processes",
            &[
                "# MPI",
                "raw (BP)",
                "filtered (BP)",
                "reduced(raw)",
                "reduced(filt)",
                "×raw",
                "×filt",
            ],
        );
        for r in &self.rows {
            t.row(vec![
                r.ranks.to_string(),
                crate::util::fmt_bytes(r.raw_bytes),
                crate::util::fmt_bytes(r.filtered_bytes),
                crate::util::fmt_bytes(r.reduced_from_raw_bytes),
                crate::util::fmt_bytes(r.reduced_from_filtered_bytes),
                format!("{:.0}", r.factor_unfiltered()),
                format!("{:.0}", r.factor_filtered()),
            ]);
        }
        format!(
            "{}\nmean reduction: ×{:.0} unfiltered / ×{:.0} filtered \
             (paper: ×95 avg, ×148 peak unfiltered; ×14 avg, ×21 peak filtered)\n",
            t.render(),
            self.mean_factor_unfiltered(),
            self.mean_factor_filtered()
        )
    }
}

/// Measure one scale point (two BP runs + two Chimbuko runs).
pub fn measure_scale(base: &Config, ranks: usize) -> Result<Fig9Row> {
    let mut cfg = base.clone();
    cfg.ranks = ranks;
    cfg.engine = TraceEngine::Bp;
    cfg.out_dir = String::new(); // byte counting, no disk

    // Unfiltered (raw) BP + reduced.
    cfg.filtered = false;
    let w = Workflow::nwchem(&cfg);
    let raw = run(&cfg, &w, Mode::Tau)?;
    let reduced_raw = run(&cfg, &w, Mode::TauChimbuko)?;

    // Filtered BP + reduced.
    cfg.filtered = true;
    let w = Workflow::nwchem(&cfg);
    let filtered = run(&cfg, &w, Mode::Tau)?;
    let reduced_filtered = run(&cfg, &w, Mode::TauChimbuko)?;

    Ok(Fig9Row {
        ranks,
        raw_bytes: raw.bp_bytes,
        filtered_bytes: filtered.bp_bytes,
        reduced_from_raw_bytes: reduced_raw.reduced_bytes,
        reduced_from_filtered_bytes: reduced_filtered.reduced_bytes,
    })
}

pub fn run_fig9(scales: &[usize], steps: usize, calls_per_step: usize) -> Result<Fig9Result> {
    let base = Config {
        steps,
        calls_per_step,
        viz_enabled: false,
        ..Config::default()
    };
    let mut rows = Vec::new();
    for &ranks in scales {
        rows.push(measure_scale(&base, ranks)?);
    }
    Ok(Fig9Result { rows })
}

// ---- provDB service bench: the serving side of the reduction story -----
//
// Fig 9 measures how small the reduced output is; this companion bench
// measures how fast the provDB service absorbs and serves it, and how
// much of it stays resident under retention — the knobs that keep the
// store at "human-level processing" size.

/// One shard count's measurements.
#[derive(Clone, Debug)]
pub struct ProvDbBenchRow {
    pub shards: usize,
    /// Records ingested per second over TCP, all writer clients together.
    pub ingest_per_sec: f64,
    /// Query round-trip latency percentiles, µs.
    pub query_p50_us: f64,
    pub query_p99_us: f64,
    /// Retained records after ingest (post-retention).
    pub records: u64,
    /// provDB-resident bytes (retained JSONL) vs total log bytes.
    pub resident_bytes: u64,
    pub log_bytes: u64,
    pub evicted: u64,
}

/// Result of the provDB sweep (the `BENCH_provdb.json` artifact).
#[derive(Clone, Debug)]
pub struct ProvDbBenchResult {
    pub rows: Vec<ProvDbBenchRow>,
    pub clients: usize,
    pub records_per_client: usize,
    pub max_records_per_rank: usize,
}

impl ProvDbBenchResult {
    pub fn render(&self) -> String {
        let mut t = Table::new(
            "provDB service — ingest/query vs shard count",
            &[
                "shards",
                "ingest rec/s",
                "q p50(µs)",
                "q p99(µs)",
                "resident",
                "log",
                "evicted",
            ],
        );
        for r in &self.rows {
            t.row(vec![
                r.shards.to_string(),
                format!("{:.0}", r.ingest_per_sec),
                format!("{:.1}", r.query_p50_us),
                format!("{:.1}", r.query_p99_us),
                crate::util::fmt_bytes(r.resident_bytes),
                crate::util::fmt_bytes(r.log_bytes),
                r.evicted.to_string(),
            ]);
        }
        format!(
            "{}({} writer clients x {} records, retention ≤{} records/rank)\n",
            t.render(),
            self.clients,
            self.records_per_client,
            self.max_records_per_rank
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bench", Json::str("provdb")),
            ("clients", Json::num(self.clients as f64)),
            ("records_per_client", Json::num(self.records_per_client as f64)),
            ("max_records_per_rank", Json::num(self.max_records_per_rank as f64)),
            (
                "rows",
                Json::arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("shards", Json::num(r.shards as f64)),
                                ("ingest_per_sec", Json::num(r.ingest_per_sec)),
                                ("query_p50_us", Json::num(r.query_p50_us)),
                                ("query_p99_us", Json::num(r.query_p99_us)),
                                ("records", Json::num(r.records as f64)),
                                ("resident_bytes", Json::num(r.resident_bytes as f64)),
                                ("log_bytes", Json::num(r.log_bytes as f64)),
                                ("evicted", Json::num(r.evicted as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Synthetic provenance record shaped like the pipeline's output.
fn synth_record(rng: &mut Rng, rank: u32, i: u64) -> ProvRecord {
    let dur = rng.range_u64(50, 5_000);
    let entry = i * 10_000 + rng.range_u64(0, 5_000);
    let score = rng.range_f64(0.0, 12.0);
    ProvRecord {
        call_id: ((rank as u64) << 32) | i,
        app: 0,
        rank,
        thread: 0,
        fid: (i % 12) as u32,
        func: format!("F{}", i % 12),
        step: i / 16,
        entry_us: entry,
        exit_us: entry + dur,
        inclusive_us: dur,
        exclusive_us: dur / 2,
        depth: (i % 4) as u32,
        parent: None,
        n_children: 0,
        n_messages: 0,
        msg_bytes: 0,
        label: if score > 6.0 { "anomaly_high".to_string() } else { "normal".to_string() },
        score,
    }
}

/// Sweep provDB shard counts under a concurrent TCP write load, then
/// measure query latency against the populated store. One writer client
/// per simulated rank; `max_records_per_rank` = 0 disables retention.
pub fn run_provdb_bench(
    shard_counts: &[usize],
    clients: usize,
    records_per_client: usize,
    queries: usize,
    max_records_per_rank: usize,
    seed: u64,
) -> Result<ProvDbBenchResult> {
    let mut rows = Vec::new();
    for &shards in shard_counts {
        let (store, handle) =
            spawn_store(None, shards, Retention::from_knob(max_records_per_rank))?;
        let srv = ProvDbTcpServer::start("127.0.0.1:0", store.clone())?;
        let addr = srv.addr().to_string();

        let t0 = Instant::now();
        let mut joins = Vec::new();
        for c in 0..clients {
            let addr = addr.clone();
            let client_seed = seed ^ (c as u64).wrapping_mul(0x9E37_79B9);
            joins.push(std::thread::spawn(move || {
                let mut cl = ProvClient::connect(&addr).expect("provdb bench connect");
                let mut rng = Rng::new(client_seed);
                for i in 0..records_per_client {
                    let rec = synth_record(&mut rng, c as u32, i as u64);
                    cl.append(&rec).expect("provdb bench append");
                }
                cl.flush().expect("provdb bench flush");
            }));
        }
        for j in joins {
            j.join().expect("provdb bench writer panicked");
        }
        let ingest_wall = t0.elapsed().as_secs_f64();

        // Query mix: single-rank scans, top anomalies, step windows.
        let mut cl = ProvClient::connect(&addr)?;
        let mut lat_us = Vec::with_capacity(queries);
        let mut rng = Rng::new(seed);
        for qi in 0..queries {
            let q = match qi % 3 {
                0 => ProvQuery {
                    rank: Some((0, rng.usize(clients.max(1)) as u32)),
                    ..Default::default()
                },
                1 => ProvQuery {
                    anomalies_only: true,
                    order_by_score: true,
                    limit: Some(20),
                    ..Default::default()
                },
                _ => ProvQuery {
                    rank: Some((0, rng.usize(clients.max(1)) as u32)),
                    step_range: Some((0, 4)),
                    ..Default::default()
                },
            };
            let t = Instant::now();
            cl.query(&q)?;
            lat_us.push(t.elapsed().as_secs_f64() * 1e6);
        }

        let stats = store.stats();
        drop(srv);
        handle.join();
        rows.push(ProvDbBenchRow {
            shards,
            ingest_per_sec: (clients * records_per_client) as f64 / ingest_wall.max(1e-9),
            query_p50_us: crate::util::percentile(&lat_us, 50.0),
            query_p99_us: crate::util::percentile(&lat_us, 99.0),
            records: stats.records,
            resident_bytes: stats.resident_bytes,
            log_bytes: stats.log_bytes,
            evicted: stats.evicted,
        });
    }
    Ok(ProvDbBenchResult {
        rows,
        clients,
        records_per_client,
        max_records_per_rank,
    })
}

// ---- codec sweep: jsonl vs binary through the whole provDB pipeline ----
//
// Same store, same records, same query mix — only the record codec
// differs: the JSONL text pipeline (format + parse at every hop) vs the
// binary codec (encode once, validate at the trust boundary, store and
// reply in encoded form with header-level predicate pushdown), vs the
// sealed columnar v2 segment layout (delta+varint packed columns behind
// the same binary wire). The `codec_rows` of `BENCH_provdb.json` track
// this A/B/C across PRs.

/// One codec's measurements at a fixed shard count.
#[derive(Clone, Debug)]
pub struct CodecRow {
    pub format: &'static str,
    pub shards: usize,
    /// Records ingested per second over TCP, all writer clients together.
    pub ingest_per_sec: f64,
    /// Query round-trip latency percentiles, µs.
    pub query_p50_us: f64,
    pub query_p99_us: f64,
    /// Stored bytes per record after flush (the on-disk format size:
    /// retained rows for jsonl/binary, sealed columnar segments for
    /// binary_v2).
    pub log_bytes_per_record: f64,
    pub records: u64,
}

/// Result of the codec A/B sweep (merged into `BENCH_provdb.json` as
/// `codec_rows`).
#[derive(Clone, Debug)]
pub struct CodecBenchResult {
    pub rows: Vec<CodecRow>,
    pub shards: usize,
    pub clients: usize,
    pub records_per_client: usize,
}

impl CodecBenchResult {
    /// binary ÷ jsonl ingest throughput (the headline speedup).
    pub fn ingest_speedup(&self) -> f64 {
        let rate = |fmt: &str| {
            self.rows
                .iter()
                .find(|r| r.format == fmt)
                .map(|r| r.ingest_per_sec)
                .unwrap_or(0.0)
        };
        rate("binary") / rate("jsonl").max(1e-9)
    }

    /// binary ÷ binary_v2 stored bytes per record (the columnar packing
    /// win on top of the row codec).
    pub fn v2_packing_factor(&self) -> f64 {
        let bytes = |fmt: &str| {
            self.rows
                .iter()
                .find(|r| r.format == fmt)
                .map(|r| r.log_bytes_per_record)
                .unwrap_or(0.0)
        };
        bytes("binary") / bytes("binary_v2").max(1e-9)
    }

    pub fn render(&self) -> String {
        let mut t = Table::new(
            "provDB codec — jsonl vs binary vs sealed columnar v2",
            &[
                "codec",
                "ingest rec/s",
                "q p50(µs)",
                "q p99(µs)",
                "log B/rec",
                "records",
            ],
        );
        for r in &self.rows {
            t.row(vec![
                r.format.to_string(),
                format!("{:.0}", r.ingest_per_sec),
                format!("{:.1}", r.query_p50_us),
                format!("{:.1}", r.query_p99_us),
                format!("{:.1}", r.log_bytes_per_record),
                r.records.to_string(),
            ]);
        }
        format!(
            "{}({} shards, {} writer clients x {} records; binary ingest {:.2}x jsonl; \
             v2 packs {:.2}x over binary rows)\n",
            t.render(),
            self.shards,
            self.clients,
            self.records_per_client,
            self.ingest_speedup(),
            self.v2_packing_factor()
        )
    }

    pub fn rows_json(&self) -> Json {
        Json::arr(
            self.rows
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("format", Json::str(r.format)),
                        ("shards", Json::num(r.shards as f64)),
                        ("ingest_per_sec", Json::num(r.ingest_per_sec)),
                        ("query_p50_us", Json::num(r.query_p50_us)),
                        ("query_p99_us", Json::num(r.query_p99_us)),
                        ("log_bytes_per_record", Json::num(r.log_bytes_per_record)),
                        ("records", Json::num(r.records as f64)),
                    ])
                })
                .collect(),
        )
    }
}

/// A/B/C the record codec end to end at a fixed shard count: spawn a
/// store per variant (matching wire + log format), drive the same
/// synthetic write load through TCP clients, then measure a selective
/// query mix (rank scans, top anomalies, step windows — the shapes
/// predicate pushdown accelerates). The `binary_v2` variant is
/// dir-backed with a segment bound of one rank's records, so every
/// partition seals into a columnar v2 segment and the stored size is
/// the packed on-disk layout.
pub fn run_codec_bench(
    shards: usize,
    clients: usize,
    records_per_client: usize,
    queries: usize,
    seed: u64,
) -> Result<CodecBenchResult> {
    let variants: [(&'static str, RecordFormat, bool); 3] = [
        ("jsonl", RecordFormat::Jsonl, false),
        ("binary", RecordFormat::Binary, false),
        ("binary_v2", RecordFormat::Binary, true),
    ];
    let mut rows = Vec::new();
    for (name, format, sealed) in variants {
        let dir = if sealed {
            let d = std::env::temp_dir().join(format!(
                "chimbuko-fig9-codec-v2-{}-{shards}",
                std::process::id()
            ));
            std::fs::remove_dir_all(&d).ok();
            Some(d)
        } else {
            None
        };
        let retention = if sealed {
            Retention::default().with_segment_knob(records_per_client)
        } else {
            Retention::default()
        };
        let (store, handle) = spawn_store_fmt(dir.as_deref(), shards, retention, format)?;
        let srv = ProvDbTcpServer::start("127.0.0.1:0", store.clone())?;
        let addr = srv.addr().to_string();

        let t0 = Instant::now();
        let mut joins = Vec::new();
        for c in 0..clients {
            let addr = addr.clone();
            let client_seed = seed ^ (c as u64).wrapping_mul(0x9E37_79B9);
            joins.push(std::thread::spawn(move || {
                let mut cl = ProvClient::connect_with(&addr, crate::provdb::DEFAULT_BATCH, format)
                    .expect("codec bench connect");
                let mut rng = Rng::new(client_seed);
                for i in 0..records_per_client {
                    let rec = synth_record(&mut rng, c as u32, i as u64);
                    cl.append(&rec).expect("codec bench append");
                }
                cl.flush().expect("codec bench flush");
            }));
        }
        for j in joins {
            j.join().expect("codec bench writer panicked");
        }
        let ingest_wall = t0.elapsed().as_secs_f64();

        let mut cl = ProvClient::connect_with(&addr, crate::provdb::DEFAULT_BATCH, format)?;
        let mut lat_us = Vec::with_capacity(queries);
        let mut rng = Rng::new(seed);
        for qi in 0..queries {
            let q = match qi % 3 {
                0 => ProvQuery {
                    rank: Some((0, rng.usize(clients.max(1)) as u32)),
                    ..Default::default()
                },
                1 => ProvQuery {
                    anomalies_only: true,
                    order_by_score: true,
                    min_score: Some(9.0),
                    limit: Some(20),
                    ..Default::default()
                },
                _ => ProvQuery {
                    rank: Some((0, rng.usize(clients.max(1)) as u32)),
                    step_range: Some((0, 4)),
                    ..Default::default()
                },
            };
            let t = Instant::now();
            cl.query(&q)?;
            lat_us.push(t.elapsed().as_secs_f64() * 1e6);
        }

        store.flush();
        let stats = store.stats();
        drop(srv);
        handle.join();
        if let Some(d) = &dir {
            std::fs::remove_dir_all(d).ok();
        }
        let total = (clients * records_per_client) as f64;
        rows.push(CodecRow {
            format: name,
            shards,
            ingest_per_sec: total / ingest_wall.max(1e-9),
            query_p50_us: crate::util::percentile(&lat_us, 50.0),
            query_p99_us: crate::util::percentile(&lat_us, 99.0),
            // Resident == log bytes for the memory-only variants
            // (nothing is evicted); for binary_v2 it is the sealed
            // segment files on disk.
            log_bytes_per_record: stats.resident_bytes as f64 / total.max(1.0),
            records: stats.records,
        });
    }
    Ok(CodecBenchResult { rows, shards, clients, records_per_client })
}

// ---- scan-selectivity sweep: zone-map pruning on sealed segments ------
//
// The point of zone maps is that a selective query decodes only the
// segments its predicate can touch. This sweep seals a dir-backed store
// into uniform v2 segments, then measures step-window queries covering
// 1/10/50/100 % of the step domain: latency percentiles, how many
// records each query decoded, and how many segments the zone maps
// pruned (the `scan_rows` of `BENCH_provdb.json`).

/// One selectivity point of the scan sweep.
#[derive(Clone, Debug)]
pub struct ScanRow {
    /// Fraction of the step domain each query window covers, percent.
    pub selectivity_pct: u32,
    pub query_p50_us: f64,
    pub query_p99_us: f64,
    /// Mean records decoded per query (records in non-pruned segments —
    /// the hot tier is empty in this bench, so this is exact).
    pub records_decoded: f64,
    /// Mean segments pruned by zone map per query.
    pub segments_skipped: f64,
    /// Sealed segments in the store (constant across the sweep).
    pub segments_total: u64,
}

/// Result of the scan-selectivity sweep (merged into
/// `BENCH_provdb.json` as `scan_rows`).
#[derive(Clone, Debug)]
pub struct ScanBenchResult {
    pub rows: Vec<ScanRow>,
    pub ranks: usize,
    pub records_per_rank: usize,
    pub segment_records: usize,
    pub total_records: u64,
}

impl ScanBenchResult {
    pub fn render(&self) -> String {
        let mut t = Table::new(
            "provDB scan selectivity — zone-map segment skipping",
            &[
                "window",
                "q p50(µs)",
                "q p99(µs)",
                "decoded/query",
                "skipped/query",
                "segments",
            ],
        );
        for r in &self.rows {
            t.row(vec![
                format!("{}%", r.selectivity_pct),
                format!("{:.1}", r.query_p50_us),
                format!("{:.1}", r.query_p99_us),
                format!("{:.0}", r.records_decoded),
                format!("{:.1}", r.segments_skipped),
                r.segments_total.to_string(),
            ]);
        }
        format!(
            "{}({} ranks x {} records, {} records/segment, {} stored)\n",
            t.render(),
            self.ranks,
            self.records_per_rank,
            self.segment_records,
            self.total_records
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ranks", Json::num(self.ranks as f64)),
            ("records_per_rank", Json::num(self.records_per_rank as f64)),
            ("segment_records", Json::num(self.segment_records as f64)),
            ("total_records", Json::num(self.total_records as f64)),
            (
                "rows",
                Json::arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("selectivity_pct", Json::num(r.selectivity_pct as f64)),
                                ("query_p50_us", Json::num(r.query_p50_us)),
                                ("query_p99_us", Json::num(r.query_p99_us)),
                                ("records_decoded", Json::num(r.records_decoded)),
                                ("segments_skipped", Json::num(r.segments_skipped)),
                                ("segments_total", Json::num(r.segments_total as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Seal a dir-backed store into uniform v2 segments and sweep step-window
/// queries at 1/10/50/100 % selectivity. `records_per_rank` should be a
/// multiple of `segment_records` so the hot tier ends empty and every
/// stored record sits behind a zone map.
pub fn run_scan_bench(
    ranks: usize,
    records_per_rank: usize,
    segment_records: usize,
    iters: usize,
    seed: u64,
) -> Result<ScanBenchResult> {
    let dir = std::env::temp_dir()
        .join(format!("chimbuko-fig9-scan-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let retention = Retention::default().with_segment_knob(segment_records);
    let (store, handle) = spawn_store(Some(dir.as_path()), 1, retention)?;
    let mut rng = Rng::new(seed);
    // Step-ordered ingest (synth steps advance with i), so segment zone
    // maps carve the step domain into disjoint ranges per rank.
    for i in 0..records_per_rank {
        let batch: Vec<ProvRecord> =
            (0..ranks).map(|r| synth_record(&mut rng, r as u32, i as u64)).collect();
        store.ingest(batch);
    }
    store.flush();
    let base = store.stats();
    anyhow::ensure!(
        base.segments_total > 0 && base.records == (ranks * records_per_rank) as u64,
        "scan bench store must seal everything ({} segments, {} records)",
        base.segments_total,
        base.records
    );
    let max_step = (records_per_rank as u64 - 1) / 16; // synth_record: step = i/16
    let iters = iters.max(1);
    let mut rows = Vec::new();
    for pct in [1u32, 10, 50, 100] {
        let span = ((max_step + 1) * pct as u64 / 100).max(1);
        let s0 = store.stats();
        let mut lat_us = Vec::with_capacity(iters);
        let mut rng_q = Rng::new(seed ^ ((pct as u64) << 32));
        for _ in 0..iters {
            let lo = rng_q.range_u64(0, (max_step + 1).saturating_sub(span));
            let q = ProvQuery {
                step_range: Some((lo, lo + span - 1)),
                ..Default::default()
            };
            let t = Instant::now();
            let _ = store.query_encoded(&q);
            lat_us.push(t.elapsed().as_secs_f64() * 1e6);
        }
        let s1 = store.stats();
        let skipped = s1.segments_skipped - s0.segments_skipped;
        // Every record lives in a uniform segment, so decoded records =
        // non-pruned segments × records per segment.
        let scanned = s1.segments_total * iters as u64 - skipped;
        rows.push(ScanRow {
            selectivity_pct: pct,
            query_p50_us: crate::util::percentile(&lat_us, 50.0),
            query_p99_us: crate::util::percentile(&lat_us, 99.0),
            records_decoded: (scanned * segment_records as u64) as f64 / iters as f64,
            segments_skipped: skipped as f64 / iters as f64,
            segments_total: s1.segments_total,
        });
    }
    handle.join();
    std::fs::remove_dir_all(&dir).ok();
    Ok(ScanBenchResult {
        rows,
        ranks,
        records_per_rank,
        segment_records,
        total_records: (ranks * records_per_rank) as u64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduction_factors_have_paper_shape() {
        let res = run_fig9(&[8], 15, 130).unwrap();
        let row = &res.rows[0];
        // Raw ≫ filtered (instrumentation filtering ~10–25×).
        let filter_ratio = row.raw_bytes as f64 / row.filtered_bytes as f64;
        assert!(filter_ratio > 4.0, "filter ratio {filter_ratio}");
        // Chimbuko reduces both streams heavily.
        assert!(row.factor_filtered() > 3.0, "filtered factor {}", row.factor_filtered());
        assert!(
            row.factor_unfiltered() > row.factor_filtered(),
            "unfiltered {} vs filtered {}",
            row.factor_unfiltered(),
            row.factor_filtered()
        );
        let text = res.render();
        assert!(text.contains("Fig 9"));
    }

    #[test]
    fn provdb_bench_measures_ingest_query_and_retention() {
        let res = run_provdb_bench(&[1, 2], 3, 200, 30, 50, 11).unwrap();
        assert_eq!(res.rows.len(), 2);
        for row in &res.rows {
            assert!(row.ingest_per_sec > 0.0);
            assert!(row.query_p50_us > 0.0);
            assert!(row.query_p99_us >= row.query_p50_us);
            // Retention at 50/rank over 200 records/rank: 3 ranks × 50.
            assert_eq!(row.records, 150);
            assert_eq!(row.evicted, 450);
            assert!(row.resident_bytes < row.log_bytes);
        }
        let text = res.render();
        assert!(text.contains("provDB service"));
        let json = res.to_json();
        assert_eq!(json.get("bench").unwrap().as_str(), Some("provdb"));
        assert_eq!(json.get("rows").unwrap().as_arr().unwrap().len(), 2);
        crate::util::json::parse(&json.to_pretty()).unwrap();
    }

    #[test]
    fn codec_sweep_measures_all_formats() {
        let res = run_codec_bench(2, 2, 300, 12, 23).unwrap();
        assert_eq!(res.rows.len(), 3);
        let jsonl = res.rows.iter().find(|r| r.format == "jsonl").unwrap();
        let binary = res.rows.iter().find(|r| r.format == "binary").unwrap();
        let v2 = res.rows.iter().find(|r| r.format == "binary_v2").unwrap();
        for row in &res.rows {
            assert!(row.ingest_per_sec > 0.0, "{}", row.format);
            assert!(row.query_p50_us > 0.0);
            assert!(row.query_p99_us >= row.query_p50_us);
            assert_eq!(row.records, 600);
        }
        // The on-disk format wins are deterministic (the throughput win
        // is asserted by the bench artifact, not a unit test).
        assert!(
            binary.log_bytes_per_record < jsonl.log_bytes_per_record,
            "binary {} vs jsonl {} bytes/record",
            binary.log_bytes_per_record,
            jsonl.log_bytes_per_record
        );
        assert!(
            v2.log_bytes_per_record * 1.5 <= binary.log_bytes_per_record,
            "v2 {} vs binary {} bytes/record: packing must win ≥1.5x",
            v2.log_bytes_per_record,
            binary.log_bytes_per_record
        );
        assert!(res.ingest_speedup() > 0.0);
        assert!(res.v2_packing_factor() >= 1.5);
        let text = res.render();
        assert!(text.contains("provDB codec"));
        let rows = res.rows_json();
        assert_eq!(rows.as_arr().unwrap().len(), 3);
        crate::util::json::parse(&rows.to_string()).unwrap();
    }

    #[test]
    fn scan_sweep_prunes_selective_windows() {
        let res = run_scan_bench(2, 1024, 128, 4, 7).unwrap();
        assert_eq!(res.rows.len(), 4);
        assert_eq!(res.total_records, 2048);
        let r1 = &res.rows[0]; // 1 %
        let r100 = &res.rows[3]; // 100 %
        assert!(r1.segments_skipped > 0.0, "1% window must prune segments");
        assert!(
            r1.records_decoded < res.total_records as f64 / 2.0,
            "1% window decoded {} of {}",
            r1.records_decoded,
            res.total_records
        );
        assert_eq!(r100.segments_skipped, 0.0, "100% window touches everything");
        assert_eq!(r100.records_decoded, res.total_records as f64);
        for w in res.rows.windows(2) {
            assert!(
                w[0].records_decoded <= w[1].records_decoded,
                "decode volume must grow with selectivity"
            );
        }
        for r in &res.rows {
            assert!(r.query_p99_us >= r.query_p50_us);
            assert_eq!(r.segments_total, 16);
        }
        let text = res.render();
        assert!(text.contains("scan selectivity"));
        crate::util::json::parse(&res.to_json().to_pretty()).unwrap();
    }
}
