//! Chaos scenario: deterministic kill/restart of real server children
//! mid-run, with bounded-loss assertions (the robustness tentpole's
//! experiment axis — `rust/docs/chaos.md`).
//!
//! The scenario spawns the workflow's server children (`ps-shard-server`
//! × N plus one `provdb-server`) from the built `chimbuko` binary,
//! drives a *deterministic* workload against them from a single thread,
//! and executes a seeded [`FaultPlan`] kill schedule against one PS
//! shard and the provDB shard. It then proves the three bounded-loss
//! guarantees the chaos plane promises:
//!
//! 1. **Same seed, same schedule** — the kill steps come from the plan,
//!    and the plan's spec rides to every child via `CHIMBUKO_CHAOS`.
//! 2. **PS state converges bit-identically** — the killed shard is
//!    checkpointed (`KIND_EXTRACT`), respawned into the same endpoint
//!    slot, re-seeded (`KIND_INSTALL` merge), and the one sub-frame the
//!    router drops while its cached connection is dead is *counted* in
//!    `PsClient::sync_lost_count` and compensated by re-syncing exactly
//!    the killed shard's slice of the delta. The final keyed dumps of
//!    every shard must equal an unfaulted control run's, bit for bit.
//! 3. **provDB loss is exactly the in-flight window** — records written
//!    while the server is down fail the client's one resend and land in
//!    its `inflight_lost` ledger; everything flushed before the kill
//!    survives restart recovery from the `.provseg` log. Final retained
//!    records must equal `written − inflight_lost`, no silent gap.
//!
//! Every kill emits a [`ChaosRow`] (kill step, records lost, recovery
//! time) that the fig7/fig9 bench binaries merge into
//! `BENCH_ps_shards.json` / `BENCH_provdb.json` as `chaos_rows`.

use crate::bench::Table;
use crate::coordinator::{pick_addr, ChildSpec, Supervisor};
use crate::provdb::ProvClient;
use crate::provenance::{ProvRecord, RecordFormat};
use crate::ps::{self, shard_of, FuncKey};
use crate::stats::{RunStats, StatsTable};
use crate::util::fault::{FaultPlan, KillSpec, KillTarget};
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{ensure, Context, Result};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// Small client batch so the during-down window spans several shipped
/// batches (each one exercising the resend-once-then-count path).
const PROV_BATCH: usize = 4;

/// One kill/restart event's outcome.
#[derive(Clone, Debug)]
pub struct ChaosRow {
    /// Kill-spec namespace: `"ps"` or `"provdb"`.
    pub target: &'static str,
    /// Slot index within the class.
    pub index: usize,
    /// Sync step the kill fired at (from the plan — seed-deterministic).
    pub at_step: u64,
    /// Records/entries counted lost across the kill. For PS this is
    /// transient loss the harness compensated (counted, then re-synced);
    /// for provDB it is permanent in-flight-window loss.
    pub records_lost: u64,
    /// Kill instant → first healed operation (respawn ready + state
    /// re-seeded for PS; respawn ready + first acked flush for provDB).
    pub recovery_ms: f64,
}

impl ChaosRow {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("target", Json::str(self.target)),
            ("index", Json::num(self.index as f64)),
            ("at_step", Json::num(self.at_step as f64)),
            ("records_lost", Json::num(self.records_lost as f64)),
            ("recovery_ms", Json::num(self.recovery_ms)),
        ])
    }
}

/// Outcome of [`run_chaos`]: per-kill rows plus the ledger totals the
/// bounded-loss assertions were checked against.
pub struct ChaosResult {
    pub shards: usize,
    pub ranks: usize,
    pub steps: usize,
    pub seed: u64,
    pub rows: Vec<ChaosRow>,
    /// Total router entries counted lost (and compensated) across the
    /// PS kill — `> 0` proves the loss was *counted*, not silent.
    pub ps_sync_lost: u64,
    /// Final keyed dumps of every shard matched the unfaulted control
    /// run bit for bit (always true when `run_chaos` returns `Ok`).
    pub ps_state_identical: bool,
    /// provDB records the workload attempted to write.
    pub prov_written: u64,
    /// Records the client's resend-once path abandoned and counted.
    pub prov_lost: u64,
    /// Records the healed server retained at the end (post-recovery).
    pub prov_records: u64,
}

impl ChaosResult {
    pub fn render(&self) -> String {
        let mut t = Table::new(
            "Chaos plane — seeded kill/restart with bounded loss",
            &["target", "kill step", "records lost", "recovery (ms)"],
        );
        for r in &self.rows {
            t.row(vec![
                format!("{}:{}", r.target, r.index),
                r.at_step.to_string(),
                r.records_lost.to_string(),
                format!("{:.1}", r.recovery_ms),
            ]);
        }
        let mut out = t.render();
        out.push_str(&format!(
            "ps: final state identical to unfaulted control \
             ({} entries counted lost, all compensated)\n",
            self.ps_sync_lost
        ));
        out.push_str(&format!(
            "provdb: {} written − {} counted lost = {} retained (ledger exact)\n",
            self.prov_written, self.prov_lost, self.prov_records
        ));
        out
    }

    /// The `chaos_rows` array the bench binaries embed in their
    /// `BENCH_*.json` artifacts.
    pub fn rows_json(&self) -> Json {
        Json::arr(self.rows.iter().map(ChaosRow::to_json).collect())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bench", Json::str("chaos")),
            ("shards", Json::num(self.shards as f64)),
            ("ranks", Json::num(self.ranks as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("ps_sync_lost", Json::num(self.ps_sync_lost as f64)),
            ("ps_state_identical", Json::Bool(self.ps_state_identical)),
            ("prov_written", Json::num(self.prov_written as f64)),
            ("prov_lost", Json::num(self.prov_lost as f64)),
            ("prov_records", Json::num(self.prov_records as f64)),
            ("chaos_rows", self.rows_json()),
        ])
    }
}

/// Locate the built `chimbuko` binary for spawning server children:
/// `CHIMBUKO_BIN` wins, then the running executable itself (when `exp
/// chaos` runs inside the binary), then siblings of the current
/// executable's directory and its parents (bench/test executables live
/// in `target/<profile>/deps/`, the binary one level up).
pub fn find_chimbuko_bin() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("CHIMBUKO_BIN") {
        let p = PathBuf::from(p);
        if p.is_file() {
            return Some(p);
        }
    }
    let exe = std::env::current_exe().ok()?;
    if exe.file_stem().and_then(|s| s.to_str()) == Some("chimbuko") {
        return Some(exe);
    }
    let mut dir = exe.parent()?.to_path_buf();
    for _ in 0..3 {
        let cand = dir.join("chimbuko");
        if cand.is_file() {
            return Some(cand);
        }
        dir = match dir.parent() {
            Some(p) => p.to_path_buf(),
            None => break,
        };
    }
    None
}

/// Run the chaos scenario: an unfaulted control pass, then a faulted
/// pass killing PS shard 0 at `steps/3` and the provDB shard at
/// `2·steps/3`, asserting the bounded-loss guarantees along the way.
pub fn run_chaos(
    bin: &Path,
    shards: usize,
    ranks: usize,
    steps: usize,
    seed: u64,
) -> Result<ChaosResult> {
    let shards = shards.max(1);
    let ranks = ranks.max(1);
    ensure!(steps >= 6, "chaos scenario needs at least 6 steps (kills at ⅓ and ⅔)");
    let kills = vec![
        KillSpec { target: KillTarget::PsShard, index: 0, at_step: steps as u64 / 3 },
        KillSpec { target: KillTarget::ProvDb, index: 0, at_step: 2 * steps as u64 / 3 },
    ];
    // Unfaulted twin first: same seed, same deltas, no kills, no provDB.
    let control = drive(bin, shards, ranks, steps, seed, &[], false)
        .context("chaos control run failed")?;
    let faulted = drive(bin, shards, ranks, steps, seed, &kills, true)
        .context("chaos faulted run failed")?;

    let ps_state_identical = control.dumps == faulted.dumps;
    ensure!(
        ps_state_identical,
        "faulted PS state diverged from the unfaulted control run after healing"
    );
    ensure!(
        faulted.sync_lost > 0,
        "the PS kill produced no counted loss — the sub-frame vanished silently"
    );
    ensure!(
        faulted.prov_lost > 0,
        "the provDB kill produced no counted loss — the in-flight window vanished silently"
    );
    ensure!(
        faulted.prov_records == faulted.prov_written - faulted.prov_lost,
        "provDB ledger gap: {} retained != {} written − {} counted lost",
        faulted.prov_records,
        faulted.prov_written,
        faulted.prov_lost
    );

    Ok(ChaosResult {
        shards,
        ranks,
        steps,
        seed,
        rows: faulted.rows,
        ps_sync_lost: faulted.sync_lost,
        ps_state_identical,
        prov_written: faulted.prov_written,
        prov_lost: faulted.prov_lost,
        prov_records: faulted.prov_records,
    })
}

/// One pass's observable outcome (shared by control and faulted runs).
struct DriveOutcome {
    /// Final keyed dump of every shard, in shard order.
    dumps: Vec<Vec<(FuncKey, RunStats)>>,
    sync_lost: u64,
    rows: Vec<ChaosRow>,
    prov_written: u64,
    prov_lost: u64,
    prov_records: u64,
}

/// Deterministic per-(rank, step) stat delta: every fid present in every
/// delta, so a killed shard's slice of any delta is exactly its owned
/// fids — the compensation set is computable from [`shard_of`] alone.
fn synth_delta(seed: u64, rank: u32, step: u64, fids: u32) -> StatsTable {
    let mut rng =
        Rng::new(seed ^ ((rank as u64) << 32) ^ step.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let mut t = StatsTable::new();
    for fid in 0..fids {
        for _ in 0..4 {
            t.push(fid, rng.range_f64(1.0, 100.0));
        }
    }
    t
}

/// Synthetic provenance record (fig 9 shape; `i` must be unique per
/// rank across the run so `call_id` never collides).
fn chaos_record(seed: u64, rank: u32, i: u64) -> ProvRecord {
    let mut rng = Rng::new(seed ^ ((rank as u64) << 40) ^ i);
    let dur = rng.range_u64(50, 5_000);
    let entry = i * 10_000 + rng.range_u64(0, 5_000);
    let score = rng.range_f64(0.0, 12.0);
    ProvRecord {
        call_id: ((rank as u64) << 32) | i,
        app: 0,
        rank,
        thread: 0,
        fid: (i % 12) as u32,
        func: format!("F{}", i % 12),
        step: i / 4,
        entry_us: entry,
        exit_us: entry + dur,
        inclusive_us: dur,
        exclusive_us: dur / 2,
        depth: (i % 4) as u32,
        parent: None,
        n_children: 0,
        n_messages: 0,
        msg_bytes: 0,
        label: if score > 6.0 { "anomaly_high".to_string() } else { "normal".to_string() },
        score,
    }
}

fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

/// Spawn the server constellation, drive the deterministic workload,
/// execute the kill schedule, and return the final observable state.
fn drive(
    bin: &Path,
    shards: usize,
    ranks: usize,
    steps: usize,
    seed: u64,
    kills: &[KillSpec],
    with_prov: bool,
) -> Result<DriveOutcome> {
    let plan = FaultPlan::kills_only(seed, kills.to_vec());
    let mut sup = Supervisor::new(bin.to_path_buf());
    if !kills.is_empty() {
        // Deterministic-replay hand-off: children see the same plan.
        sup = sup.with_plan(&plan);
    }
    let mut endpoints = Vec::with_capacity(shards);
    for i in 0..shards {
        let addr = pick_addr()?;
        sup.spawn(ChildSpec::ps_shard(i, shards, &addr))?;
        endpoints.push(addr);
    }
    let prov_dir = std::env::temp_dir().join(format!(
        "chimbuko-chaos-{}-{}-{}",
        std::process::id(),
        seed,
        kills.len()
    ));
    let mut prov_addr = String::new();
    if with_prov {
        let _ = std::fs::remove_dir_all(&prov_dir);
        std::fs::create_dir_all(&prov_dir).context("creating provdb chaos dir")?;
        prov_addr = pick_addr()?;
        sup.spawn(ChildSpec::provdb(0, 1, &prov_addr, &prov_dir))?;
    }
    sup.await_ready()?;

    let (client, handle) = ps::spawn_with(ps::PsOpts {
        shards,
        endpoints,
        conn_pool: 1,
        publish_every: usize::MAX >> 1,
        reports_per_step: ranks,
        ..ps::PsOpts::default()
    })?;
    let mut prov = if with_prov {
        Some(ProvClient::connect_with(&prov_addr, PROV_BATCH, RecordFormat::Binary)?)
    } else {
        None
    };

    // Every shard owns several fids, so each sync fans a sub-frame to
    // every endpoint and a killed shard always has a non-empty slice.
    let fids = (shards as u32) * 6;
    let mut rows: Vec<ChaosRow> = Vec::new();
    // Set once a PS shard was killed: (row index, shard index). Sync
    // loss with no kill on record is an assertion failure — the ledger
    // must never tick outside the scheduled fault.
    let mut ps_healing: Option<(usize, usize)> = None;
    let mut prov_written = 0u64;
    let mut rec_seq = 0u64;

    for step in 0..steps as u64 {
        for k in kills.iter().filter(|k| k.at_step == step) {
            match k.target {
                KillTarget::PsShard => {
                    let t0 = Instant::now();
                    // Checkpoint → crash → same-slot respawn → re-seed.
                    let ckpt = sup.ps_extract(k.index, shards)?;
                    sup.kill(KillTarget::PsShard, k.index)?;
                    sup.respawn(KillTarget::PsShard, k.index)?;
                    sup.ps_install(k.index, shards, &ckpt)?;
                    rows.push(ChaosRow {
                        target: "ps",
                        index: k.index,
                        at_step: step,
                        records_lost: 0,
                        recovery_ms: ms(t0.elapsed()),
                    });
                    ps_healing = Some((rows.len() - 1, k.index));
                }
                KillTarget::ProvDb => {
                    let db = prov
                        .as_mut()
                        .context("provdb kill scheduled but run has no provdb")?;
                    // Durability barrier: everything acked so far must
                    // survive the crash via log recovery.
                    db.flush().context("pre-kill durability barrier")?;
                    let t0 = Instant::now();
                    sup.kill(KillTarget::ProvDb, k.index)?;
                    let lost0 = db.inflight_lost();
                    // Writes against the dead endpoint: each shipped
                    // batch fails its one resend and is counted.
                    let window = (PROV_BATCH as u64) * 2;
                    for _ in 0..window {
                        let rec = chaos_record(seed, 0, rec_seq);
                        rec_seq += 1;
                        let _ = db.append(&rec);
                        prov_written += 1;
                    }
                    let _ = db.flush(); // ship the remainder while down
                    sup.respawn(KillTarget::ProvDb, k.index)?;
                    // First healed barrier: one real record through the
                    // redial path, acked end to end.
                    let rec = chaos_record(seed, 0, rec_seq);
                    rec_seq += 1;
                    db.append(&rec).context("post-respawn append")?;
                    prov_written += 1;
                    db.flush().context("first healed flush")?;
                    let lost = db.inflight_lost() - lost0;
                    ensure!(
                        lost == window,
                        "during-down loss {lost} != in-flight window {window}"
                    );
                    rows.push(ChaosRow {
                        target: "provdb",
                        index: k.index,
                        at_step: step,
                        records_lost: lost,
                        recovery_ms: ms(t0.elapsed()),
                    });
                }
                KillTarget::AggNode => {} // not scheduled by this scenario
            }
        }

        // Drive the step: single thread, rank order — deterministic
        // merge order on every shard.
        for rank in 0..ranks as u32 {
            let delta = synth_delta(seed, rank, step, fids);
            let lost0 = client.sync_lost_count();
            client.sync(0, rank, &delta);
            let lost = client.sync_lost_count() - lost0;
            if lost == 0 {
                continue;
            }
            let (row_i, shard) = ps_healing
                .context("router counted sync loss with no PS kill on record")?;
            // The dropped sub-frame is exactly the killed shard's slice
            // of this delta (static placement, rebalancer off).
            let mut need = StatsTable::new();
            let mut n = 0u64;
            for (fid, st) in delta.iter() {
                if shard_of(0, fid, shards) == shard {
                    need.replace(fid, *st);
                    n += 1;
                }
            }
            ensure!(
                lost == n,
                "lost sub-frame {lost} entries != killed shard's slice {n}"
            );
            // Re-sync until the healed shard absorbs it. Retries landing
            // inside the reconnector's backoff window are counted too —
            // transient, compensated loss, visible in the row.
            let mut merged = false;
            for _ in 0..200 {
                std::thread::sleep(Duration::from_millis(20));
                let before = client.sync_lost_count();
                client.sync(0, rank, &need);
                if client.sync_lost_count() == before {
                    merged = true;
                    break;
                }
            }
            ensure!(merged, "killed PS shard never healed within the retry budget");
            rows[row_i].records_lost += client.sync_lost_count() - lost0;
        }

        // Steady provDB load: one record per rank per step.
        if let Some(db) = prov.as_mut() {
            for rank in 0..ranks as u32 {
                let rec = chaos_record(seed, rank, rec_seq);
                rec_seq += 1;
                db.append(&rec)
                    .with_context(|| format!("provdb append at step {step}"))?;
                prov_written += 1;
            }
        }
    }

    // Final observable state: keyed dump of every shard (shard order),
    // then the provDB ledger after a closing barrier.
    let mut dumps = Vec::with_capacity(shards);
    for i in 0..shards {
        dumps.push(sup.ps_extract(i, shards)?);
    }
    let sync_lost = client.sync_lost_count();
    client.shutdown();
    handle.join();
    let (prov_records, prov_lost) = match prov.as_mut() {
        Some(db) => {
            db.flush().context("closing provdb flush")?;
            let s = db.stats()?;
            (s.records, db.inflight_lost())
        }
        None => (0, 0),
    };
    sup.stop_all();
    if with_prov {
        let _ = std::fs::remove_dir_all(&prov_dir);
    }
    Ok(DriveOutcome { dumps, sync_lost, rows, prov_written, prov_lost, prov_records })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_delta_is_pure() {
        let a = synth_delta(7, 3, 11, 12);
        let b = synth_delta(7, 3, 11, 12);
        let ea: Vec<(u32, RunStats)> = a.iter().map(|(f, s)| (f, *s)).collect();
        let eb: Vec<(u32, RunStats)> = b.iter().map(|(f, s)| (f, *s)).collect();
        assert_eq!(ea, eb, "same (seed, rank, step) must give bit-identical deltas");
        let c = synth_delta(8, 3, 11, 12);
        let ec: Vec<(u32, RunStats)> = c.iter().map(|(f, s)| (f, *s)).collect();
        assert_ne!(ea, ec, "different seeds must differ");
        assert_eq!(a.len(), 12, "every fid present in every delta");
    }

    #[test]
    fn chaos_record_ids_are_unique_per_rank() {
        let a = chaos_record(1, 2, 10);
        let b = chaos_record(1, 2, 11);
        assert_ne!(a.call_id, b.call_id);
        assert_eq!(a.rank, 2);
    }

    #[test]
    fn rows_render_and_serialize() {
        let res = ChaosResult {
            shards: 2,
            ranks: 4,
            steps: 12,
            seed: 42,
            rows: vec![ChaosRow {
                target: "ps",
                index: 0,
                at_step: 4,
                records_lost: 12,
                recovery_ms: 31.5,
            }],
            ps_sync_lost: 12,
            ps_state_identical: true,
            prov_written: 100,
            prov_lost: 8,
            prov_records: 92,
        };
        let out = res.render();
        assert!(out.contains("ps:0"));
        assert!(out.contains("ledger exact"));
        let j = res.to_json().to_string();
        assert!(j.contains("\"chaos_rows\""));
        assert!(j.contains("\"ps_state_identical\":true"));
        assert_eq!(res.rows_json().to_string().matches("\"target\"").count(), 1);
    }
}
