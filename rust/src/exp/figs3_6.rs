//! **Figs 3–6** — the visualization views, regenerated as data products:
//! run a real workflow, feed the PS snapshots + provenance into
//! [`VizState`], and emit each figure as its ASCII rendering plus the JSON
//! payload the HTTP API serves.

use crate::config::Config;
use crate::coordinator::{run, Mode, Workflow};
use crate::provenance::{ProvDb, ProvQuery};
use crate::util::json::Json;
use crate::viz::{api, ascii, RankStat, VizState};
use anyhow::Result;

#[derive(Clone, Debug)]
pub struct VizFiguresResult {
    /// Fig 3 rendering + payload.
    pub fig3_ascii: String,
    pub fig3_json: Json,
    /// Fig 4.
    pub fig4_ascii: String,
    pub fig4_json: Json,
    /// Fig 5 (app, rank, step chosen = first anomalous frame).
    pub fig5_ascii: String,
    pub fig5_json: Json,
    /// Fig 6.
    pub fig6_ascii: String,
    /// Which (app, rank, step) the detail views show.
    pub focus: (u32, u32, u64),
    pub total_anomalies: u64,
}

impl VizFiguresResult {
    pub fn render(&self) -> String {
        format!(
            "{}\n{}\n{}\n{}\n(focus frame: app {}, rank {}, step {}; {} anomalies workflow-wide)\n",
            self.fig3_ascii,
            self.fig4_ascii,
            self.fig5_ascii,
            self.fig6_ascii,
            self.focus.0,
            self.focus.1,
            self.focus.2,
            self.total_anomalies
        )
    }
}

/// Run a workflow and regenerate the four viz figures from its outputs.
pub fn run_figs3_6(ranks: usize, steps: usize, seed: u64) -> Result<VizFiguresResult> {
    let dir = std::env::temp_dir().join(format!("chimbuko-viz-{}-{}", std::process::id(), seed));
    std::fs::remove_dir_all(&dir).ok();
    let cfg = Config {
        ranks,
        apps: 2,
        steps,
        calls_per_step: 130,
        seed,
        out_dir: dir.to_str().unwrap().to_string(),
        ..Config::default()
    };
    let workflow = Workflow::nwchem(&cfg);
    let report = run(&cfg, &workflow, Mode::TauChimbuko)?;

    let db = ProvDb::load(&dir)?;
    let state = VizState::from_run(
        &report.snapshots,
        report.snapshot.clone(),
        db,
        workflow.registries.clone(),
    );

    // Fig 3: dashboard by stddev (the paper's screenshot uses stddev).
    let fig3_ascii = ascii::dashboard(&state, RankStat::Stddev, 5);
    let fig3_json = api::dashboard(&state, RankStat::Stddev, 5);

    // Fig 4: streaming series for the top-3 ranks by total.
    let (top, _) = state.ranking(RankStat::Total, 3);
    let selected: Vec<(u32, u32)> = top.iter().map(|r| (r.app, r.rank)).collect();
    let fig4_ascii = ascii::timeline(&state, &selected, 60);
    let fig4_json = if let Some(&(app, rank)) = selected.first() {
        api::timeline(&state, app, rank)
    } else {
        Json::Obj(vec![])
    };

    // Figs 5–6: focus on the highest-score anomaly's frame.
    let focus = {
        let top_anoms = state.db.query(&ProvQuery {
            anomalies_only: true,
            order_by_score: true,
            limit: Some(1),
            ..Default::default()
        });
        match top_anoms.first() {
            Some(r) => (r.app, r.rank, r.step),
            None => (0, 0, 0),
        }
    };
    let fig5_ascii = ascii::function_view(&state, focus.0, focus.1, focus.2);
    let fig5_json = api::function_view(&state, focus.0, focus.1, focus.2);
    let fig6_ascii = ascii::call_stack(&state, focus.0, focus.1, focus.2);

    std::fs::remove_dir_all(&dir).ok();
    Ok(VizFiguresResult {
        fig3_ascii,
        fig3_json,
        fig4_ascii,
        fig4_json,
        fig5_ascii,
        fig5_json,
        fig6_ascii,
        focus,
        total_anomalies: report.total_anomalies,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_four_views_materialize() {
        let res = run_figs3_6(16, 25, 4242).unwrap();
        assert!(res.total_anomalies > 0, "workload produced no anomalies");
        assert!(res.fig3_ascii.contains("Ranking dashboard"));
        assert!(res.fig4_ascii.contains("anomaly counts"));
        assert!(res.fig5_ascii.contains("Function view"));
        assert!(res.fig6_ascii.contains("Call stack view"));
        // Focus frame shows at least the anomaly itself.
        assert!(res.fig5_ascii.contains('!'), "{}", res.fig5_ascii);
        assert!(res.fig6_ascii.contains("!!"), "{}", res.fig6_ascii);
        // JSON payloads parse.
        crate::util::json::parse(&res.fig3_json.to_string()).unwrap();
        crate::util::json::parse(&res.fig5_json.to_string()).unwrap();
    }
}
