//! **Figs 10–13** — the NWChemEx visual-analysis case study, reproduced as
//! checkable findings instead of screenshots:
//!
//! * **Fig 10**: an anomalous `MD_NEWTON` runs ~3× its normal time and the
//!   inflation is a *launch gap* before `MD_FORCES`, not inflated
//!   children — we locate such a pair (normal step vs anomalous step) and
//!   compare children runtimes.
//! * **Figs 11–12**: rank 0's anomalies concentrate in `MD_FINIT` /
//!   `CF_CMS` (global sums + rank 0's special role).
//! * **Fig 13**: on ranks ≠ 0, `SP_GTXPBL`/`SP_GETXBL` dominates the
//!   anomaly counts (domain-decomposition remote gets).

use crate::config::Config;
use crate::coordinator::{run, Mode, Workflow};
use crate::provenance::{ProvDb, ProvQuery, ProvRecord};
use crate::trace::nwchem::{names, InjectionConfig};
use crate::viz::{ascii, VizState};
use anyhow::Result;
use std::collections::HashMap;

/// One function's share of anomalies within a rank class.
#[derive(Clone, Debug)]
pub struct FuncShare {
    pub func: String,
    pub count: u64,
    pub share: f64,
}

#[derive(Clone, Debug)]
pub struct CaseStudyResult {
    /// Fig 10: (normal inclusive µs, anomalous inclusive µs, gap before
    /// MD_FORCES in the anomalous instance, children runtime ratio).
    pub newton_normal_us: u64,
    pub newton_anomalous_us: u64,
    pub forces_gap_us: u64,
    pub children_ratio: f64,
    /// Fig 10 call-stack renderings (normal vs anomalous step).
    pub fig10_normal: String,
    pub fig10_anomalous: String,
    /// Figs 11–12: rank-0 anomaly distribution by function.
    pub rank0_shares: Vec<FuncShare>,
    /// Fig 13: ranks ≠ 0 anomaly distribution by function.
    pub other_shares: Vec<FuncShare>,
    pub total_anomalies: u64,
}

impl CaseStudyResult {
    pub fn render(&self) -> String {
        let fmt_shares = |shares: &[FuncShare]| {
            shares
                .iter()
                .take(5)
                .map(|s| format!("    {:<14} {:>6} ({:.0}%)", s.func, s.count, s.share * 100.0))
                .collect::<Vec<_>>()
                .join("\n")
        };
        format!(
            "== Case study (Figs 10–13) ==\n\
             Fig 10 — MD_NEWTON launch-delay anomaly:\n\
                 normal MD_NEWTON   : {} µs\n\
                 anomalous MD_NEWTON: {} µs ({:.1}× normal; paper: ~3×)\n\
                 gap before MD_FORCES in anomalous instance: {} µs\n\
                 MD_FORCES runtime ratio (anom step / normal mean): {:.2} (≈1 ⇒ delay, not children)\n\
             {}\n{}\n\
             Figs 11–12 — rank 0 anomalies by function:\n{}\n\
             Fig 13 — ranks ≠ 0 anomalies by function:\n{}\n\
             total anomalies: {}\n",
            self.newton_normal_us,
            self.newton_anomalous_us,
            self.newton_anomalous_us as f64 / self.newton_normal_us.max(1) as f64,
            self.forces_gap_us,
            self.children_ratio,
            self.fig10_normal,
            self.fig10_anomalous,
            fmt_shares(&self.rank0_shares),
            fmt_shares(&self.other_shares),
            self.total_anomalies
        )
    }
}

fn shares_of(records: &[&ProvRecord]) -> Vec<FuncShare> {
    let mut counts: HashMap<String, u64> = HashMap::new();
    for r in records {
        *counts.entry(r.func.clone()).or_default() += 1;
    }
    let total: u64 = counts.values().sum();
    let mut v: Vec<FuncShare> = counts
        .into_iter()
        .map(|(func, count)| FuncShare {
            func,
            count,
            share: count as f64 / total.max(1) as f64,
        })
        .collect();
    v.sort_by(|a, b| b.count.cmp(&a.count));
    v
}

/// Run the case-study workload and extract the findings.
pub fn run_case_study(ranks: usize, steps: usize, seed: u64) -> Result<CaseStudyResult> {
    let dir = std::env::temp_dir().join(format!("chimbuko-case-{}-{}", std::process::id(), seed));
    std::fs::remove_dir_all(&dir).ok();
    let cfg = Config {
        ranks,
        apps: 1, // MD only, like the case study's NWChem focus
        steps,
        calls_per_step: 130,
        seed,
        out_dir: dir.to_str().unwrap().to_string(),
        ..Config::default()
    };
    // Boost injection so a short run shows every pattern clearly.
    let inj = InjectionConfig {
        forces_delay_prob: 0.01,
        rank0_straggle_prob: 0.06,
        getxbl_tail_prob: 0.02,
    };
    let workflow = Workflow::nwchem_with_injection(&cfg, inj);
    let report = run(&cfg, &workflow, Mode::TauChimbuko)?;
    let db = ProvDb::load(&dir)?;
    let state = VizState::from_run(
        &report.snapshots,
        report.snapshot.clone(),
        db,
        workflow.registries.clone(),
    );

    // ---- Fig 10: find the top anomalous MD_NEWTON, and a normal one. ----
    let reg = &workflow.registries[0];
    let newton_fid = reg.lookup(names::MD_NEWTON).expect("MD_NEWTON registered");
    let newton_anoms = state.db.query(&ProvQuery {
        fid: Some((0, newton_fid)),
        anomalies_only: true,
        order_by_score: true,
        ..Default::default()
    });
    anyhow::ensure!(
        !newton_anoms.is_empty(),
        "no MD_NEWTON anomalies detected — increase steps or injection"
    );
    // Fig 10 is specifically about the *launch-delay* pattern: among the
    // anomalous MD_NEWTONs pick the one with the largest gap before its
    // MD_FORCES child (rank-0 straggle anomalies also inflate MD_NEWTON
    // but show no gap — those are Figs 11–12's story).
    let forces_fid = reg.lookup(names::MD_FORCES).unwrap();
    let gap_of = |parent: &ProvRecord| -> u64 {
        let children: Vec<ProvRecord> = state
            .db
            .call_stack(parent.app, parent.rank, parent.step)
            .into_iter()
            .filter(|r| {
                r.entry_us >= parent.entry_us
                    && r.exit_us <= parent.exit_us
                    && r.call_id != parent.call_id
            })
            .collect();
        children
            .iter()
            .filter(|c| c.fid == forces_fid && c.depth == parent.depth + 1)
            .map(|f| {
                let prev_exit = children
                    .iter()
                    .filter(|c| c.exit_us <= f.entry_us)
                    .map(|c| c.exit_us)
                    .max()
                    .unwrap_or(parent.entry_us);
                f.entry_us - prev_exit
            })
            .max()
            .unwrap_or(0)
    };
    let anom = newton_anoms
        .iter()
        .max_by_key(|r| gap_of(r))
        .map(|r| (*r).clone())
        .unwrap();
    // A normal MD_NEWTON kept as context in provenance (label normal) —
    // prefer the same rank as the anomaly (the paper compares step 70 vs
    // step 86 of one rank) and instances with kept children.
    let newton_normals = state.db.query(&ProvQuery {
        fid: Some((0, newton_fid)),
        ..Default::default()
    });
    let normal = newton_normals
        .iter()
        .filter(|r| !r.is_anomaly())
        .max_by_key(|r| {
            let same_rank = (r.rank == anom.rank) as u64;
            // Typical normal instances cluster near the median; avoid
            // picking one inflated by a non-flagged tail.
            let not_inflated = (r.inclusive_us < anom.inclusive_us / 2) as u64;
            (same_rank << 1) + not_inflated
        })
        .map(|r| (*r).clone())
        .unwrap_or_else(|| anom.clone());

    // Children of the anomalous instance: records within its time span on
    // the same rank/step.
    let span_children = |parent: &ProvRecord| -> Vec<ProvRecord> {
        state
            .db
            .call_stack(parent.app, parent.rank, parent.step)
            .into_iter()
            .filter(|r| {
                r.entry_us >= parent.entry_us
                    && r.exit_us <= parent.exit_us
                    && r.call_id != parent.call_id
            })
            .collect()
    };
    let anom_children = span_children(&anom);
    // Children comparison (paper: "children remained quite similar"): the
    // anomalous instance's MD_FORCES runtime vs the population mean of
    // normal MD_FORCES executions kept anywhere in provenance.
    let normal_forces: Vec<u64> = state
        .db
        .query(&ProvQuery { fid: Some((0, forces_fid)), ..Default::default() })
        .iter()
        .filter(|r| !r.is_anomaly())
        .map(|r| r.inclusive_us)
        .collect();
    let normal_forces_mean = if normal_forces.is_empty() {
        1.0
    } else {
        normal_forces.iter().sum::<u64>() as f64 / normal_forces.len() as f64
    };
    let anom_forces = anom_children
        .iter()
        .filter(|c| c.fid == forces_fid)
        .map(|c| c.inclusive_us)
        .max()
        .unwrap_or(0);
    let children_ratio = anom_forces as f64 / normal_forces_mean;

    // Launch gap before MD_FORCES inside the anomalous MD_NEWTON: time
    // between the last event completing before it and the MD_FORCES entry.
    let forces_gap_us = gap_of(&anom);

    // Renderings of both frames, restricted to the two spans.
    let stack_of = |parent: &ProvRecord, title: &str| {
        let recs = state.db.call_stack(parent.app, parent.rank, parent.step);
        let filtered: Vec<ProvRecord> = recs
            .into_iter()
            .filter(|r| r.entry_us >= parent.entry_us && r.exit_us <= parent.exit_us)
            .collect();
        ascii::render_call_stack(&state, &filtered, title)
    };
    let fig10_normal = stack_of(
        &normal,
        &format!("normal step {} (rank {})", normal.step, normal.rank),
    );
    let fig10_anomalous = stack_of(
        &anom,
        &format!("anomalous step {} (rank {})", anom.step, anom.rank),
    );

    // ---- Figs 11–13: anomaly distribution by function per rank class. ----
    let all_anoms = state.db.query(&ProvQuery {
        anomalies_only: true,
        ..Default::default()
    });
    let rank0: Vec<&ProvRecord> = all_anoms.iter().filter(|r| r.rank == 0).collect();
    let others: Vec<&ProvRecord> = all_anoms.iter().filter(|r| r.rank != 0).collect();

    std::fs::remove_dir_all(&dir).ok();
    Ok(CaseStudyResult {
        newton_normal_us: normal.inclusive_us,
        newton_anomalous_us: anom.inclusive_us,
        forces_gap_us,
        children_ratio,
        fig10_normal,
        fig10_anomalous,
        rank0_shares: shares_of(&rank0),
        other_shares: shares_of(&others),
        total_anomalies: report.total_anomalies,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn case_study_reproduces_all_three_findings() {
        let res = run_case_study(8, 60, 777).unwrap();

        // Fig 10: anomalous newton ≫ normal, children similar, gap large.
        assert!(
            res.newton_anomalous_us as f64 > 2.0 * res.newton_normal_us as f64,
            "anomalous {} vs normal {}",
            res.newton_anomalous_us,
            res.newton_normal_us
        );
        assert!(res.forces_gap_us > 2_000, "gap {}", res.forces_gap_us);

        // Figs 11–12: rank 0 dominated by MD_FINIT / CF_CMS.
        let top0: Vec<&str> = res.rank0_shares.iter().take(2).map(|s| s.func.as_str()).collect();
        assert!(
            top0.contains(&names::MD_FINIT) || top0.contains(&names::CF_CMS),
            "rank0 top functions: {top0:?}"
        );

        // Fig 13: other ranks dominated by SP_GTXPBL (or wrapper SP_GETXBL).
        let top_others = res.other_shares.first().map(|s| s.func.as_str()).unwrap_or("");
        assert!(
            top_others == names::SP_GTXPBL
                || top_others == names::SP_GETXBL
                || top_others == names::MD_NEWTON, // launch delays also land here
            "other ranks top function: {top_others}"
        );
        let gtx_share: f64 = res
            .other_shares
            .iter()
            .filter(|s| s.func == names::SP_GTXPBL || s.func == names::SP_GETXBL)
            .map(|s| s.share)
            .sum();
        assert!(gtx_share > 0.3, "SP_G*XBL share on ranks≠0: {gtx_share}");

        let text = res.render();
        assert!(text.contains("Fig 10"));
        assert!(text.contains("MD_NEWTON"));
    }
}
