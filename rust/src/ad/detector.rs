//! Threshold anomaly detection on function execution times (paper §III-B).
//!
//! A completed execution of function *i* is anomalous when its runtime
//! falls outside `[μ_i − α·σ_i, μ_i + α·σ_i]` (α = 6 throughout the
//! paper). Statistics update online; a batch is labelled against the
//! statistics *after* merging the batch itself — exactly the semantics of
//! the AOT-compiled L1/L2 artifact, so the Rust and XLA backends are
//! interchangeable and testable against each other.
//!
//! Detection scores **inclusive runtime**: the case study's `MD_NEWTON`
//! anomaly is a child launch *gap*, visible only inclusively.

use super::stack::ExecRecord;
use crate::stats::{RunStats, StatsTable};

/// Label assigned to each execution.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Label {
    Normal,
    /// Above μ + α·σ.
    AnomalyHigh,
    /// Below μ − α·σ.
    AnomalyLow,
}

impl Label {
    pub fn is_anomaly(self) -> bool {
        !matches!(self, Label::Normal)
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Label::Normal => "normal",
            Label::AnomalyHigh => "anomaly_high",
            Label::AnomalyLow => "anomaly_low",
        }
    }
}

/// A labelled execution with its anomaly score (σ-distance from μ).
#[derive(Clone, Debug)]
pub struct Labeled {
    pub rec: ExecRecord,
    pub label: Label,
    /// `|x − μ| / σ` at labelling time (0 when σ = 0).
    pub score: f64,
}

/// Detector configuration.
#[derive(Clone, Copy, Debug)]
pub struct DetectorConfig {
    /// Threshold multiplier α.
    pub alpha: f64,
    /// Executions of a function required before labelling starts; below
    /// this everything is Normal (warm-up, mirrors the reference
    /// implementation's behaviour on cold statistics).
    pub min_samples: u64,
}

impl Default for DetectorConfig {
    fn default() -> Self {
        DetectorConfig { alpha: 6.0, min_samples: 10 }
    }
}

/// Pure-Rust detector: Welford/Pébay statistics + threshold labelling.
///
/// Also the reference semantics for the XLA backend (`runtime::exec`).
pub struct RustDetector {
    cfg: DetectorConfig,
    /// Statistics used for detection: global snapshot ⊕ local updates.
    view: StatsTable,
    /// Local updates not yet pushed to the parameter server.
    pending: StatsTable,
}

impl RustDetector {
    pub fn new(cfg: DetectorConfig) -> Self {
        RustDetector { cfg, view: StatsTable::new(), pending: StatsTable::new() }
    }

    /// Ingest + label one batch of completed executions (one step frame).
    ///
    /// Two phases, matching the L1 kernel: (1) merge every runtime into the
    /// statistics; (2) label each record against the merged statistics.
    pub fn detect(&mut self, records: Vec<ExecRecord>) -> Vec<Labeled> {
        for r in &records {
            let v = r.inclusive_us() as f64;
            self.view.push(r.fid, v);
            self.pending.push(r.fid, v);
        }
        records
            .into_iter()
            .map(|rec| {
                let (label, score) = self.label_of(rec.fid, rec.inclusive_us() as f64);
                Labeled { rec, label, score }
            })
            .collect()
    }

    /// Label a value against the current view (no state change).
    pub fn label_of(&self, fid: u32, value: f64) -> (Label, f64) {
        let Some(st) = self.view.get(fid) else {
            return (Label::Normal, 0.0);
        };
        if st.count() < self.cfg.min_samples {
            return (Label::Normal, 0.0);
        }
        let sd = st.stddev();
        let score = if sd > 0.0 { (value - st.mean()).abs() / sd } else { 0.0 };
        if sd == 0.0 {
            return (Label::Normal, score);
        }
        if value > st.mean() + self.cfg.alpha * sd {
            (Label::AnomalyHigh, score)
        } else if value < st.mean() - self.cfg.alpha * sd {
            (Label::AnomalyLow, score)
        } else {
            (Label::Normal, score)
        }
    }

    /// Take the pending local updates (to send to the parameter server).
    pub fn take_pending(&mut self) -> StatsTable {
        std::mem::take(&mut self.pending)
    }

    /// Adopt the parameter server's global snapshot as the new view
    /// (paper: "update local statistics with the global one").
    pub fn adopt_global(&mut self, global: &StatsTable) {
        for (fid, st) in global.iter() {
            self.view.replace(fid, *st);
        }
        // Pending keeps accumulating: it has already been folded into the
        // PS global before the snapshot came back, so clear-on-take only.
    }

    /// Current detection statistics.
    pub fn view(&self) -> &StatsTable {
        &self.view
    }

    pub fn config(&self) -> DetectorConfig {
        self.cfg
    }

    /// Import externally computed per-function stats (XLA backend path).
    pub fn import_stats(&mut self, fid: u32, st: RunStats) {
        self.view.replace(fid, st);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::event::EventCtx;
    use crate::util::rng::Rng;

    fn rec(fid: u32, dur: u64, id: u64) -> ExecRecord {
        let _ = EventCtx { app: 0, rank: 0, thread: 0 };
        ExecRecord {
            call_id: id,
            app: 0,
            rank: 0,
            thread: 0,
            fid,
            step: 0,
            entry_ts: 1000 * id,
            exit_ts: 1000 * id + dur,
            depth: 0,
            parent: None,
            n_children: 0,
            n_messages: 0,
            msg_bytes: 0,
            exclusive_us: dur,
        }
    }

    fn warmed_detector(fid: u32, n: usize, rng: &mut Rng) -> RustDetector {
        let mut d = RustDetector::new(DetectorConfig::default());
        let recs: Vec<ExecRecord> = (0..n)
            .map(|i| rec(fid, (1000.0 + rng.normal_ms(0.0, 20.0)) as u64, i as u64))
            .collect();
        d.detect(recs);
        d
    }

    #[test]
    fn outlier_is_flagged_high() {
        let mut rng = Rng::new(1);
        let mut d = warmed_detector(3, 200, &mut rng);
        let out = d.detect(vec![rec(3, 10_000, 999)]);
        assert_eq!(out[0].label, Label::AnomalyHigh);
        assert!(out[0].score > 6.0);
    }

    #[test]
    fn low_outlier_is_flagged_low() {
        let mut rng = Rng::new(2);
        let mut d = warmed_detector(3, 200, &mut rng);
        let out = d.detect(vec![rec(3, 1, 999)]);
        assert_eq!(out[0].label, Label::AnomalyLow);
    }

    #[test]
    fn normal_values_pass() {
        let mut rng = Rng::new(3);
        let mut d = warmed_detector(3, 200, &mut rng);
        let out = d.detect(vec![rec(3, 1010, 999)]);
        assert_eq!(out[0].label, Label::Normal);
    }

    #[test]
    fn warmup_suppresses_labels() {
        let mut d = RustDetector::new(DetectorConfig { alpha: 6.0, min_samples: 10 });
        // 5 samples then a huge value — still below min_samples at merge.
        let mut recs: Vec<ExecRecord> = (0..4).map(|i| rec(1, 100, i)).collect();
        recs.push(rec(1, 100_000, 99));
        let out = d.detect(recs);
        assert!(out.iter().all(|l| l.label == Label::Normal));
    }

    #[test]
    fn constant_runtime_never_anomalous() {
        let mut d = RustDetector::new(DetectorConfig::default());
        let recs: Vec<ExecRecord> = (0..50).map(|i| rec(2, 500, i)).collect();
        let out = d.detect(recs);
        assert!(out.iter().all(|l| l.label == Label::Normal));
        // Same value again: σ = 0 → normal by definition.
        let out = d.detect(vec![rec(2, 500, 99)]);
        assert_eq!(out[0].label, Label::Normal);
    }

    #[test]
    fn batch_label_uses_post_merge_stats() {
        // A batch whose own values shift the mean: labelling must use the
        // merged stats (kernel semantics), so a value normal under the
        // merged view stays normal even if it was extreme pre-batch.
        let mut d = RustDetector::new(DetectorConfig { alpha: 6.0, min_samples: 2 });
        d.detect((0..10).map(|i| rec(1, 100 + i, i as u64)).collect());
        // Batch of values around 200 — extreme vs pre-stats, but the batch
        // itself fattens σ.
        let out = d.detect((0..50).map(|i| rec(1, 200 + (i % 7), 100 + i as u64)).collect());
        let anom = out.iter().filter(|l| l.label.is_anomaly()).count();
        assert!(anom < 50, "post-merge labelling should not flag the whole batch");
    }

    #[test]
    fn pending_take_and_adopt_global() {
        let mut rng = Rng::new(4);
        let mut d = warmed_detector(7, 50, &mut rng);
        let pending = d.take_pending();
        assert_eq!(pending.total_count(), 50);
        assert_eq!(d.take_pending().total_count(), 0);
        // Adopt a global view with a different mean; labelling follows it.
        let mut global = StatsTable::new();
        for _ in 0..100 {
            global.push(7, 5000.0 + rng.normal_ms(0.0, 10.0));
        }
        d.adopt_global(&global);
        let (label, _) = d.label_of(7, 1000.0);
        assert_eq!(label, Label::AnomalyLow);
    }

    #[test]
    fn anomaly_rate_for_six_sigma_is_tiny() {
        let mut rng = Rng::new(5);
        let mut d = RustDetector::new(DetectorConfig::default());
        let recs: Vec<ExecRecord> = (0..20_000)
            .map(|i| rec(1, (10_000.0 + rng.normal_ms(0.0, 100.0)).max(1.0) as u64, i))
            .collect();
        let out = d.detect(recs);
        let anom = out.iter().filter(|l| l.label.is_anomaly()).count();
        // 6σ on a normal distribution ⇒ essentially zero false positives.
        assert!(anom <= 2, "got {anom} anomalies at 6σ on clean data");
    }
}
