//! The **on-node AD module** (paper §III-B1): consumes a rank's step
//! stream, reconstructs executions, labels anomalies, extracts the
//! anomaly-centred k-neighbour context window (the data-reduction step),
//! and exchanges statistics with the parameter server.

use super::detector::{Labeled, RustDetector};
use super::stack::{StackBuilder, StackErrors};
use crate::stats::StatsTable;
use crate::trace::StepFrame;
use std::collections::VecDeque;
use std::time::Instant;

/// Detection engine abstraction: the pure-Rust path and the AOT-compiled
/// XLA path (`runtime::XlaDetector`) implement the same batch semantics.
pub trait DetectEngine: Send {
    /// Merge a batch into the statistics, then label it (post-merge stats).
    fn detect(&mut self, records: Vec<super::stack::ExecRecord>) -> Vec<Labeled>;
    /// Drain local statistics accumulated since the last call.
    fn take_pending(&mut self) -> StatsTable;
    /// Replace the detection view with the parameter server's global.
    fn adopt_global(&mut self, global: &StatsTable);
    /// Current detection statistics (for tests/diagnostics).
    fn view(&self) -> &StatsTable;
}

impl DetectEngine for RustDetector {
    fn detect(&mut self, records: Vec<super::stack::ExecRecord>) -> Vec<Labeled> {
        RustDetector::detect(self, records)
    }

    fn take_pending(&mut self) -> StatsTable {
        RustDetector::take_pending(self)
    }

    fn adopt_global(&mut self, global: &StatsTable) {
        RustDetector::adopt_global(self, global)
    }

    fn view(&self) -> &StatsTable {
        RustDetector::view(self)
    }
}

/// Outcome of processing one step frame.
#[derive(Clone, Debug)]
pub struct StepResult {
    pub app: u32,
    pub rank: u32,
    pub step: u64,
    /// Executions completed this step.
    pub n_executions: u64,
    /// Anomalies among them.
    pub n_anomalies: u64,
    /// Records selected for provenance: anomalies plus ≤ k normal
    /// neighbours each side (exit order). This is what gets persisted —
    /// everything else is reduced to statistics and discarded.
    pub kept: Vec<Labeled>,
    /// Analysis wall time for this step (seconds).
    pub proc_seconds: f64,
}

/// The on-node AD module for one (app, rank) stream.
pub struct OnNodeAd {
    app: u32,
    rank: u32,
    stack: StackBuilder,
    engine: Box<dyn DetectEngine>,
    k: usize,
    /// Sliding window of the most recent ≤ k+1 labelled records and
    /// whether each was already emitted to `kept`.
    window: VecDeque<(Labeled, bool)>,
    /// Normal records still owed as "after" context.
    after_quota: usize,
    /// Cumulative counters.
    total_execs: u64,
    total_anomalies: u64,
    total_kept: u64,
}

impl OnNodeAd {
    pub fn new(app: u32, rank: u32, k: usize, engine: Box<dyn DetectEngine>) -> Self {
        OnNodeAd {
            app,
            rank,
            stack: StackBuilder::new(app, rank),
            engine,
            k,
            window: VecDeque::with_capacity(k + 1),
            after_quota: 0,
            total_execs: 0,
            total_anomalies: 0,
            total_kept: 0,
        }
    }

    /// Process one step frame end-to-end.
    pub fn process_step(&mut self, frame: &StepFrame) -> StepResult {
        let t0 = Instant::now();
        let completed = self.stack.process(frame);
        let labeled = self.engine.detect(completed);
        let mut kept: Vec<Labeled> = Vec::new();
        let mut n_anomalies = 0u64;
        for l in &labeled {
            self.push_windowed(l.clone(), &mut kept);
            if l.label.is_anomaly() {
                n_anomalies += 1;
            }
        }
        self.total_execs += labeled.len() as u64;
        self.total_anomalies += n_anomalies;
        self.total_kept += kept.len() as u64;
        StepResult {
            app: self.app,
            rank: self.rank,
            step: frame.step,
            n_executions: labeled.len() as u64,
            n_anomalies,
            kept,
            proc_seconds: t0.elapsed().as_secs_f64(),
        }
    }

    /// k-window selection in exit order (see [`StepResult::kept`]).
    fn push_windowed(&mut self, l: Labeled, kept: &mut Vec<Labeled>) {
        let is_anomaly = l.label.is_anomaly();
        // Keep at most k history entries before pushing, so an anomaly
        // emits exactly ≤ k predecessors.
        while self.window.len() > self.k {
            self.window.pop_front();
        }
        self.window.push_back((l, false));
        if is_anomaly {
            // Emit every not-yet-emitted record in the window: the ≤ k
            // records before the anomaly, plus the anomaly itself.
            for (rec, emitted) in self.window.iter_mut() {
                if !*emitted {
                    kept.push(rec.clone());
                    *emitted = true;
                }
            }
            self.after_quota = self.k;
        } else if self.after_quota > 0 {
            let (rec, emitted) = self.window.back_mut().unwrap();
            kept.push(rec.clone());
            *emitted = true;
            self.after_quota -= 1;
        }
    }

    /// Dump the not-yet-emitted part of the current context window — the
    /// §V global-event trigger: when the parameter server flags a
    /// globally detected event, *every* rank contributes its recent
    /// executions to provenance, anomalous or not.
    pub fn dump_window(&mut self) -> Vec<Labeled> {
        let mut out = Vec::new();
        for (l, emitted) in self.window.iter_mut() {
            if !*emitted {
                out.push(l.clone());
                *emitted = true;
            }
        }
        self.total_kept += out.len() as u64;
        out
    }

    /// Local statistics delta for the parameter server.
    pub fn take_pending(&mut self) -> StatsTable {
        self.engine.take_pending()
    }

    /// Adopt the global statistics snapshot from the parameter server.
    pub fn adopt_global(&mut self, global: &StatsTable) {
        self.engine.adopt_global(global)
    }

    pub fn view(&self) -> &StatsTable {
        self.engine.view()
    }

    pub fn stack_errors(&self) -> StackErrors {
        self.stack.errors()
    }

    pub fn totals(&self) -> (u64, u64, u64) {
        (self.total_execs, self.total_anomalies, self.total_kept)
    }

    pub fn rank(&self) -> u32 {
        self.rank
    }

    pub fn app(&self) -> u32 {
        self.app
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ad::detector::DetectorConfig;
    use crate::trace::event::{Event, EventCtx, FuncEvent, FuncKind};
    use crate::trace::gen::{toy_grammar, RankTracer};
    use crate::util::rng::Rng;

    fn module(k: usize) -> OnNodeAd {
        OnNodeAd::new(
            0,
            0,
            k,
            Box::new(RustDetector::new(DetectorConfig { alpha: 6.0, min_samples: 10 })),
        )
    }

    /// Frame with `durs[i]` as consecutive non-overlapping calls of fid 1.
    fn flat_frame(step: u64, durs: &[u64]) -> StepFrame {
        let ctx = EventCtx { app: 0, rank: 0, thread: 0 };
        let mut events = Vec::new();
        let mut t = step * 1_000_000;
        for &d in durs {
            events.push(Event::Func(FuncEvent { ctx, fid: 1, kind: FuncKind::Entry, ts: t }));
            t += d;
            events.push(Event::Func(FuncEvent { ctx, fid: 1, kind: FuncKind::Exit, ts: t }));
            t += 10;
        }
        StepFrame { app: 0, rank: 0, step, events }
    }

    #[test]
    fn clean_stream_keeps_nothing() {
        let mut m = module(5);
        let durs: Vec<u64> = (0..100).map(|i| 1000 + (i % 13)).collect();
        let r = m.process_step(&flat_frame(0, &durs));
        assert_eq!(r.n_executions, 100);
        assert_eq!(r.n_anomalies, 0);
        assert!(r.kept.is_empty(), "kept {} of clean stream", r.kept.len());
    }

    #[test]
    fn anomaly_keeps_k_before_and_after() {
        let mut m = module(5);
        // Warm up.
        let warm: Vec<u64> = (0..200).map(|i| 1000 + (i % 17)).collect();
        m.process_step(&flat_frame(0, &warm));
        // 20 normals, 1 huge, 20 normals.
        let mut durs: Vec<u64> = (0..20).map(|i| 1000 + i).collect();
        durs.push(500_000);
        durs.extend((0..20).map(|i| 1000 + i));
        let r = m.process_step(&flat_frame(1, &durs));
        assert_eq!(r.n_anomalies, 1);
        // 1 anomaly + 5 before + 5 after.
        assert_eq!(r.kept.len(), 11, "kept {:?}", r.kept.len());
        let anom_pos = r.kept.iter().position(|l| l.label.is_anomaly()).unwrap();
        assert_eq!(anom_pos, 5);
        // Context records are the immediate neighbours in exit order.
        let anom_id = r.kept[anom_pos].rec.call_id;
        for (i, l) in r.kept.iter().enumerate() {
            let off = i as i64 - anom_pos as i64;
            assert_eq!(l.rec.call_id as i64, anom_id as i64 + off);
        }
    }

    #[test]
    fn adjacent_anomalies_share_context_without_duplicates() {
        let mut m = module(3);
        let warm: Vec<u64> = (0..200).map(|i| 1000 + (i % 11)).collect();
        m.process_step(&flat_frame(0, &warm));
        // Two anomalies 2 apart: windows overlap.
        let mut durs: Vec<u64> = (0..10).map(|i| 1000 + i).collect();
        durs.push(400_000);
        durs.extend([1001, 1002]);
        durs.push(400_000);
        durs.extend((0..10).map(|i| 1000 + i));
        let r = m.process_step(&flat_frame(1, &durs));
        assert_eq!(r.n_anomalies, 2);
        // No duplicate call_ids in kept.
        let mut ids: Vec<u64> = r.kept.iter().map(|l| l.rec.call_id).collect();
        let n = ids.len();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), n, "duplicates in kept");
        // 3 before + A + 2 between + A + 3 after = 10.
        assert_eq!(r.kept.len(), 10);
    }

    #[test]
    fn window_spans_step_boundaries() {
        let mut m = module(4);
        let warm: Vec<u64> = (0..200).map(|i| 1000 + (i % 7)).collect();
        m.process_step(&flat_frame(0, &warm));
        // Anomaly as the last call of step 1 → after-context arrives in step 2.
        let mut durs: Vec<u64> = (0..6).map(|i| 1000 + i).collect();
        durs.push(300_000);
        let r1 = m.process_step(&flat_frame(1, &durs));
        assert_eq!(r1.n_anomalies, 1);
        assert_eq!(r1.kept.len(), 5); // 4 before + anomaly
        let r2 = m.process_step(&flat_frame(2, &[1000, 1001, 1002, 1003, 1004, 1005]));
        assert_eq!(r2.n_anomalies, 0);
        assert_eq!(r2.kept.len(), 4, "after-context must carry into next step");
    }

    #[test]
    fn data_reduction_on_generated_workload() {
        let (g, _) = toy_grammar();
        let mut tracer = RankTracer::new(g, 0, 0, 4, false, Rng::new(8));
        let mut m = module(5);
        let mut execs = 0u64;
        let mut kept = 0u64;
        for _ in 0..100 {
            let r = m.process_step(&tracer.step());
            execs += r.n_executions;
            kept += r.kept.len() as u64;
        }
        assert!(execs > 500);
        // Clean toy workload at 6σ: reduction is extreme.
        assert!(
            (kept as f64) < 0.05 * execs as f64,
            "kept {kept} of {execs} executions"
        );
    }

    #[test]
    fn dump_window_emits_recent_context_once() {
        let mut m = module(4);
        let warm: Vec<u64> = (0..50).map(|i| 1000 + (i % 9)).collect();
        m.process_step(&flat_frame(0, &warm));
        // Global-event trigger: dump the current window (all normal).
        let dump1 = m.dump_window();
        assert!(!dump1.is_empty());
        assert!(dump1.len() <= 5); // ≤ k+1
        assert!(dump1.iter().all(|l| !l.label.is_anomaly()));
        // Idempotent until new records arrive.
        assert!(m.dump_window().is_empty());
        m.process_step(&flat_frame(1, &[1001, 1002]));
        assert_eq!(m.dump_window().len(), 2);
    }

    #[test]
    fn totals_accumulate() {
        let mut m = module(2);
        let warm: Vec<u64> = (0..50).map(|_| 1000).collect();
        m.process_step(&flat_frame(0, &warm));
        m.process_step(&flat_frame(1, &warm));
        let (execs, anoms, kept) = m.totals();
        assert_eq!(execs, 100);
        assert_eq!(anoms, 0);
        assert_eq!(kept, 0);
    }
}
