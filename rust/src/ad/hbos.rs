//! HBOS detector — the paper's §VIII future work ("a more advanced AD
//! algorithm to extend the AD module"; the post-publication Chimbuko
//! releases shipped exactly this: Histogram-Based Outlier Score).
//!
//! Per function we keep a log-scale runtime histogram; an execution's
//! score is the negative log of its bin's probability mass,
//! `score = log(p_max / p(bin))`, and it is anomalous when the score
//! exceeds a threshold (default ln(1000) ≈ 6.9 — the bin is ≥ 1000× rarer
//! than the mode). Compared to μ±ασ this handles multi-modal runtimes
//! (e.g. cache-hit vs cache-miss populations) without flagging the minor
//! mode, while still catching far-tail events.
//!
//! Implements [`DetectEngine`], so it is config-selectable
//! (`ad.algorithm = hbos`) and composes with the same on-node module,
//! parameter server and provenance machinery. Statistics (`n, μ, M2`)
//! are still maintained for the PS dashboard; only *labelling* differs.

use super::detector::{Label, Labeled};
use super::module::DetectEngine;
use super::stack::ExecRecord;
use crate::stats::{Histogram, StatsTable};
use std::collections::HashMap;

/// HBOS configuration.
#[derive(Clone, Copy, Debug)]
pub struct HbosConfig {
    /// Score threshold: anomalous when `log(p_max/p) > threshold`.
    pub threshold: f64,
    /// Executions of a function required before labelling starts.
    pub min_samples: u64,
    /// Histogram resolution (log-scale buckets per decade).
    pub buckets_per_decade: usize,
}

impl Default for HbosConfig {
    fn default() -> Self {
        HbosConfig {
            threshold: (30.0f64).ln(),
            min_samples: 10,
            buckets_per_decade: 10,
        }
    }
}

/// Histogram-based outlier detector.
pub struct HbosDetector {
    cfg: HbosConfig,
    hists: HashMap<u32, FuncHist>,
    /// Moments mirror for the PS/dashboard contract.
    view: StatsTable,
    pending: StatsTable,
}

struct FuncHist {
    hist: Histogram,
    /// Largest single-bin count (mode mass), tracked incrementally.
    max_bin: u64,
}

impl HbosDetector {
    pub fn new(cfg: HbosConfig) -> Self {
        HbosDetector {
            cfg,
            hists: HashMap::new(),
            view: StatsTable::new(),
            pending: StatsTable::new(),
        }
    }

    /// Score a runtime against a function's histogram: `ln(max_bin/bin)`.
    fn score_of(&self, fid: u32, value: f64) -> Option<f64> {
        let fh = self.hists.get(&fid)?;
        if fh.hist.count() < self.cfg.min_samples {
            return None;
        }
        let bin = fh.hist.bucket_count(value);
        // Unseen bins get pseudo-count 0.5 (≈ one-sided Laplace smoothing).
        let p = (bin as f64).max(0.5);
        Some((fh.max_bin as f64 / p).ln())
    }
}

impl DetectEngine for HbosDetector {
    fn detect(&mut self, records: Vec<ExecRecord>) -> Vec<Labeled> {
        // Phase 1 — merge the batch (same post-merge semantics as the
        // threshold detector, so backends stay comparable).
        for r in &records {
            let v = r.inclusive_us() as f64;
            let fh = self
                .hists
                .entry(r.fid)
                .or_insert_with(|| FuncHist {
                    hist: Histogram::new(self.cfg.buckets_per_decade),
                    max_bin: 0,
                });
            fh.hist.record(v);
            fh.max_bin = fh.max_bin.max(fh.hist.bucket_count(v));
            self.view.push(r.fid, v);
            self.pending.push(r.fid, v);
        }
        // Phase 2 — label.
        records
            .into_iter()
            .map(|rec| {
                let v = rec.inclusive_us() as f64;
                let (label, score) = match self.score_of(rec.fid, v) {
                    None => (Label::Normal, 0.0),
                    Some(s) if s > self.cfg.threshold => {
                        // Direction from the moments mirror.
                        let dir = self
                            .view
                            .get(rec.fid)
                            .map(|st| v >= st.mean())
                            .unwrap_or(true);
                        (
                            if dir { Label::AnomalyHigh } else { Label::AnomalyLow },
                            s,
                        )
                    }
                    Some(s) => (Label::Normal, s),
                };
                Labeled { rec, label, score }
            })
            .collect()
    }

    fn take_pending(&mut self) -> StatsTable {
        std::mem::take(&mut self.pending)
    }

    fn adopt_global(&mut self, global: &StatsTable) {
        // Histograms stay local (the paper's PS exchanges moments only);
        // adopt the global moments for the dashboard mirror.
        for (fid, st) in global.iter() {
            self.view.replace(fid, *st);
        }
    }

    fn view(&self) -> &StatsTable {
        &self.view
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rec(fid: u32, dur: u64, id: u64) -> ExecRecord {
        ExecRecord {
            call_id: id,
            app: 0,
            rank: 0,
            thread: 0,
            fid,
            step: 0,
            entry_ts: id * 1000,
            exit_ts: id * 1000 + dur,
            depth: 0,
            parent: None,
            n_children: 0,
            n_messages: 0,
            msg_bytes: 0,
            exclusive_us: dur,
        }
    }

    #[test]
    fn far_outlier_is_flagged() {
        let mut d = HbosDetector::new(HbosConfig::default());
        let mut rng = Rng::new(1);
        let recs: Vec<ExecRecord> = (0..2000)
            .map(|i| rec(1, rng.normal_ms(1000.0, 30.0).max(1.0) as u64, i))
            .collect();
        DetectEngine::detect(&mut d, recs);
        let out = DetectEngine::detect(&mut d, vec![rec(1, 500_000, 9999)]);
        assert_eq!(out[0].label, Label::AnomalyHigh);
        assert!(out[0].score > HbosConfig::default().threshold);
    }

    #[test]
    fn bimodal_runtimes_do_not_flag_minor_mode() {
        // 80% fast path (~100µs), 20% slow path (~10ms): a 6σ threshold
        // detector flags nothing OR the whole slow mode depending on σ;
        // HBOS keeps both modes normal because both bins are populated.
        let mut d = HbosDetector::new(HbosConfig::default());
        let mut rng = Rng::new(2);
        let recs: Vec<ExecRecord> = (0..5000)
            .map(|i| {
                let dur = if rng.chance(0.2) {
                    rng.normal_ms(10_000.0, 300.0)
                } else {
                    rng.normal_ms(100.0, 5.0)
                };
                rec(3, dur.max(1.0) as u64, i)
            })
            .collect();
        let labeled = DetectEngine::detect(&mut d, recs);
        let anoms = labeled.iter().filter(|l| l.label.is_anomaly()).count();
        assert!(
            anoms < 10,
            "HBOS flagged {anoms} of a healthy bimodal distribution"
        );
        // …but a value far outside both modes still flags.
        let out = DetectEngine::detect(&mut d, vec![rec(3, 5_000_000, 99999)]);
        assert_eq!(out[0].label, Label::AnomalyHigh);
    }

    #[test]
    fn warmup_suppresses_labels() {
        let mut d = HbosDetector::new(HbosConfig::default());
        let out = DetectEngine::detect(
            &mut d,
            vec![rec(1, 100, 0), rec(1, 100, 1), rec(1, 1_000_000, 2)],
        );
        assert!(out.iter().all(|l| l.label == Label::Normal));
    }

    #[test]
    fn low_outlier_labels_low() {
        let mut d = HbosDetector::new(HbosConfig::default());
        let mut rng = Rng::new(3);
        let recs: Vec<ExecRecord> = (0..3000)
            .map(|i| rec(1, rng.normal_ms(100_000.0, 2_000.0).max(1.0) as u64, i))
            .collect();
        DetectEngine::detect(&mut d, recs);
        let out = DetectEngine::detect(&mut d, vec![rec(1, 10, 99999)]);
        assert_eq!(out[0].label, Label::AnomalyLow);
    }

    #[test]
    fn stats_mirror_matches_threshold_detector_contract() {
        let mut d = HbosDetector::new(HbosConfig::default());
        let recs: Vec<ExecRecord> = (0..100).map(|i| rec(2, 50 + i % 5, i)).collect();
        DetectEngine::detect(&mut d, recs);
        let st = d.view().get(2).unwrap();
        assert_eq!(st.count(), 100);
        let pending = d.take_pending();
        assert_eq!(pending.total_count(), 100);
        assert_eq!(d.take_pending().total_count(), 0);
    }
}
