//! Online call-stack reconstruction (paper §III-B1).
//!
//! Events in a rank's stream arrive time-sorted; the builder maintains one
//! stack per thread, pairs ENTRY/EXIT into completed *executions*, maps
//! communication events to the function on top of the stack, and tracks
//! per-execution child counts and inclusive/exclusive runtimes. Executions
//! complete in EXIT order — that order is also the order the k-neighbour
//! provenance window is defined over.
//!
//! The stack persists across step frames: a function spanning several
//! streamed steps (common for outer loops) completes in whichever step its
//! EXIT arrives.

use crate::trace::event::{CommKind, Event, FuncKind, StepFrame};

/// A completed function execution — the unit anomaly detection scores.
#[derive(Clone, Debug, PartialEq)]
pub struct ExecRecord {
    /// Unique, monotonically increasing id within one builder (per rank).
    pub call_id: u64,
    pub app: u32,
    pub rank: u32,
    pub thread: u32,
    pub fid: u32,
    /// Step frame in which the execution *completed*.
    pub step: u64,
    pub entry_ts: u64,
    pub exit_ts: u64,
    /// Stack depth at entry (root = 0).
    pub depth: u32,
    /// `call_id` of the enclosing execution, if any.
    pub parent: Option<u64>,
    /// Direct children count.
    pub n_children: u32,
    /// Communication events attributed to this execution (not children).
    pub n_messages: u32,
    /// Bytes moved by those messages.
    pub msg_bytes: u64,
    /// Exclusive runtime (µs): inclusive minus children inclusive.
    pub exclusive_us: u64,
}

impl ExecRecord {
    /// Inclusive runtime in µs.
    pub fn inclusive_us(&self) -> u64 {
        self.exit_ts - self.entry_ts
    }
}

/// Malformed-stream counters (instrumentation glitches must not kill the
/// analysis — the paper's tool keeps running through bad data).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StackErrors {
    /// EXIT with empty stack or mismatched fid.
    pub unmatched_exit: u64,
    /// Timestamp went backwards within a stream.
    pub time_regression: u64,
    /// Comm event with no enclosing function.
    pub orphan_comm: u64,
}

struct OpenFrame {
    call_id: u64,
    fid: u32,
    entry_ts: u64,
    n_children: u32,
    n_messages: u32,
    msg_bytes: u64,
    children_inclusive: u64,
}

/// Per-(app, rank) call-stack builder; handles all threads of the rank.
///
/// Thread stacks are a small linear-scanned vec, not a HashMap: ranks have
/// a handful of threads and the lookup sits on the per-event hot path.
pub struct StackBuilder {
    app: u32,
    rank: u32,
    stacks: Vec<(u32, Vec<OpenFrame>)>,
    next_call_id: u64,
    last_ts: u64,
    errors: StackErrors,
}

impl StackBuilder {
    pub fn new(app: u32, rank: u32) -> Self {
        StackBuilder {
            app,
            rank,
            stacks: Vec::new(),
            next_call_id: 0,
            last_ts: 0,
            errors: StackErrors::default(),
        }
    }

    #[inline]
    fn stack_of(
        stacks: &mut Vec<(u32, Vec<OpenFrame>)>,
        thread: u32,
    ) -> &mut Vec<OpenFrame> {
        // Fast path: most streams are single-threaded → index 0 hit.
        let pos = match stacks.iter().position(|(t, _)| *t == thread) {
            Some(p) => p,
            None => {
                stacks.push((thread, Vec::with_capacity(16)));
                stacks.len() - 1
            }
        };
        &mut stacks[pos].1
    }

    /// Feed one step frame; returns executions completed during it, in
    /// EXIT order.
    pub fn process(&mut self, frame: &StepFrame) -> Vec<ExecRecord> {
        let mut done = Vec::new();
        for ev in &frame.events {
            if ev.ts() < self.last_ts {
                self.errors.time_regression += 1;
            }
            self.last_ts = self.last_ts.max(ev.ts());
            match ev {
                Event::Func(f) => {
                    let next_id = self.next_call_id;
                    let stack = Self::stack_of(&mut self.stacks, f.ctx.thread);
                    match f.kind {
                        FuncKind::Entry => {
                            if let Some(top) = stack.last_mut() {
                                top.n_children += 1;
                            }
                            stack.push(OpenFrame {
                                call_id: next_id,
                                fid: f.fid,
                                entry_ts: f.ts,
                                n_children: 0,
                                n_messages: 0,
                                msg_bytes: 0,
                                children_inclusive: 0,
                            });
                            self.next_call_id += 1;
                        }
                        FuncKind::Exit => {
                            // Pop through mismatches (lost EXITs) up to the
                            // matching fid; count each as an error.
                            let matching =
                                stack.iter().rposition(|of| of.fid == f.fid);
                            match matching {
                                None => self.errors.unmatched_exit += 1,
                                Some(pos) => {
                                    let extra = stack.len() - 1 - pos;
                                    self.errors.unmatched_exit += extra as u64;
                                    // Discard frames opened above the match
                                    // (their EXIT never arrived).
                                    stack.truncate(pos + 1);
                                    let of = stack.pop().unwrap();
                                    let inclusive = f.ts.saturating_sub(of.entry_ts);
                                    let parent = stack.last().map(|p| p.call_id);
                                    if let Some(p) = stack.last_mut() {
                                        p.children_inclusive += inclusive;
                                    }
                                    done.push(ExecRecord {
                                        call_id: of.call_id,
                                        app: self.app,
                                        rank: self.rank,
                                        thread: f.ctx.thread,
                                        fid: of.fid,
                                        step: frame.step,
                                        entry_ts: of.entry_ts,
                                        exit_ts: f.ts,
                                        depth: stack.len() as u32,
                                        parent,
                                        n_children: of.n_children,
                                        n_messages: of.n_messages,
                                        msg_bytes: of.msg_bytes,
                                        exclusive_us: inclusive
                                            .saturating_sub(of.children_inclusive),
                                    });
                                }
                            }
                        }
                    }
                }
                Event::Comm(c) => {
                    let stack = Self::stack_of(&mut self.stacks, c.ctx.thread);
                    match stack.last_mut() {
                        Some(top) => {
                            top.n_messages += 1;
                            top.msg_bytes += c.bytes;
                            let _ = matches!(c.kind, CommKind::Send);
                        }
                        None => self.errors.orphan_comm += 1,
                    }
                }
            }
        }
        done
    }

    /// Functions currently open (spanning into the next step).
    pub fn open_depth(&self, thread: u32) -> usize {
        self.stacks
            .iter()
            .find(|(t, _)| *t == thread)
            .map(|(_, s)| s.len())
            .unwrap_or(0)
    }

    pub fn errors(&self) -> StackErrors {
        self.errors
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::event::{CommEvent, EventCtx, FuncEvent};
    use crate::trace::gen::{toy_grammar, RankTracer};
    use crate::trace::nwchem::{self, InjectionConfig};
    use crate::util::rng::Rng;

    fn fe(fid: u32, kind: FuncKind, ts: u64) -> Event {
        Event::Func(FuncEvent {
            ctx: EventCtx { app: 0, rank: 0, thread: 0 },
            fid,
            kind,
            ts,
        })
    }

    fn ce(bytes: u64, ts: u64) -> Event {
        Event::Comm(CommEvent {
            ctx: EventCtx { app: 0, rank: 0, thread: 0 },
            kind: CommKind::Send,
            partner: 1,
            tag: 0,
            bytes,
            ts,
        })
    }

    fn frame(events: Vec<Event>) -> StepFrame {
        StepFrame { app: 0, rank: 0, step: 0, events }
    }

    #[test]
    fn simple_nesting_inclusive_exclusive() {
        let mut b = StackBuilder::new(0, 0);
        // A[0..100] contains B[20..50] and C[60..70].
        let recs = b.process(&frame(vec![
            fe(0, FuncKind::Entry, 0),
            fe(1, FuncKind::Entry, 20),
            fe(1, FuncKind::Exit, 50),
            fe(2, FuncKind::Entry, 60),
            fe(2, FuncKind::Exit, 70),
            fe(0, FuncKind::Exit, 100),
        ]));
        assert_eq!(recs.len(), 3);
        // EXIT order: B, C, A.
        assert_eq!(recs[0].fid, 1);
        assert_eq!(recs[0].inclusive_us(), 30);
        assert_eq!(recs[0].exclusive_us, 30);
        assert_eq!(recs[0].depth, 1);
        assert_eq!(recs[2].fid, 0);
        assert_eq!(recs[2].inclusive_us(), 100);
        assert_eq!(recs[2].exclusive_us, 100 - 30 - 10);
        assert_eq!(recs[2].n_children, 2);
        assert_eq!(recs[2].depth, 0);
        assert_eq!(recs[0].parent, Some(recs[2].call_id));
        assert_eq!(recs[2].parent, None);
        assert_eq!(b.errors(), StackErrors::default());
    }

    #[test]
    fn comm_attributed_to_top_of_stack() {
        let mut b = StackBuilder::new(0, 0);
        let recs = b.process(&frame(vec![
            fe(0, FuncKind::Entry, 0),
            fe(1, FuncKind::Entry, 10),
            ce(4096, 15),
            fe(1, FuncKind::Exit, 20),
            ce(128, 25),
            fe(0, FuncKind::Exit, 30),
        ]));
        let b_rec = &recs[0];
        let a_rec = &recs[1];
        assert_eq!(b_rec.n_messages, 1);
        assert_eq!(b_rec.msg_bytes, 4096);
        assert_eq!(a_rec.n_messages, 1);
        assert_eq!(a_rec.msg_bytes, 128);
    }

    #[test]
    fn executions_span_frames() {
        let mut b = StackBuilder::new(0, 0);
        let r1 = b.process(&frame(vec![fe(0, FuncKind::Entry, 0)]));
        assert!(r1.is_empty());
        assert_eq!(b.open_depth(0), 1);
        let mut f2 = frame(vec![fe(0, FuncKind::Exit, 500)]);
        f2.step = 1;
        let r2 = b.process(&f2);
        assert_eq!(r2.len(), 1);
        assert_eq!(r2[0].step, 1);
        assert_eq!(r2[0].inclusive_us(), 500);
        assert_eq!(b.open_depth(0), 0);
    }

    #[test]
    fn unmatched_exit_counted_not_fatal() {
        let mut b = StackBuilder::new(0, 0);
        let recs = b.process(&frame(vec![
            fe(5, FuncKind::Exit, 10), // nothing open
            fe(0, FuncKind::Entry, 20),
            fe(0, FuncKind::Exit, 30),
        ]));
        assert_eq!(recs.len(), 1);
        assert_eq!(b.errors().unmatched_exit, 1);
    }

    #[test]
    fn lost_exit_recovered_by_fid_match() {
        let mut b = StackBuilder::new(0, 0);
        // A { B { (B's exit lost) } A-exit } — A must still complete.
        let recs = b.process(&frame(vec![
            fe(0, FuncKind::Entry, 0),
            fe(1, FuncKind::Entry, 10),
            fe(0, FuncKind::Exit, 50),
        ]));
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].fid, 0);
        assert_eq!(b.errors().unmatched_exit, 1);
    }

    #[test]
    fn threads_have_independent_stacks() {
        let mut b = StackBuilder::new(0, 0);
        let mk = |thread: u32, fid: u32, kind, ts| {
            Event::Func(FuncEvent {
                ctx: EventCtx { app: 0, rank: 0, thread },
                fid,
                kind,
                ts,
            })
        };
        let recs = b.process(&frame(vec![
            mk(0, 0, FuncKind::Entry, 0),
            mk(1, 0, FuncKind::Entry, 5),
            mk(0, 0, FuncKind::Exit, 10),
            mk(1, 0, FuncKind::Exit, 20),
        ]));
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].thread, 0);
        assert_eq!(recs[0].inclusive_us(), 10);
        assert_eq!(recs[1].thread, 1);
        assert_eq!(recs[1].inclusive_us(), 15);
    }

    #[test]
    fn generated_stream_is_clean_and_balanced() {
        let (g, _) = toy_grammar();
        let mut t = RankTracer::new(g, 0, 3, 8, true, Rng::new(2));
        let mut b = StackBuilder::new(0, 3);
        let mut total = 0usize;
        for _ in 0..10 {
            let f = t.step();
            let expected = f.func_event_count() / 2;
            let recs = b.process(&f);
            assert_eq!(recs.len(), expected);
            total += recs.len();
        }
        assert!(total > 0);
        assert_eq!(b.errors(), StackErrors::default());
        assert_eq!(b.open_depth(0), 0);
    }

    #[test]
    fn nwchem_md_depths_and_exclusive_sums() {
        let (g, reg) = nwchem::md_grammar(2, &InjectionConfig::none());
        let mut t = RankTracer::new(g, 0, 1, 8, false, Rng::new(4));
        let mut b = StackBuilder::new(0, 1);
        let recs = b.process(&t.step());
        // Exclusive sums to inclusive for each root MD_NEWTON.
        let newton = reg.lookup("MD_NEWTON").unwrap();
        for root in recs.iter().filter(|r| r.fid == newton) {
            let descendants: u64 = recs
                .iter()
                .filter(|r| {
                    r.entry_ts >= root.entry_ts && r.exit_ts <= root.exit_ts && r.call_id != root.call_id
                })
                .map(|r| r.exclusive_us)
                .sum();
            assert_eq!(root.exclusive_us + descendants, root.inclusive_us());
        }
    }
}
