//! Anomaly detection core (paper §III): online call-stack reconstruction,
//! μ±α·σ threshold detection with streaming statistics, and the on-node AD
//! module that performs the anomaly-centred data reduction.

pub mod detector;
pub mod hbos;
pub mod module;
pub mod stack;

pub use detector::{DetectorConfig, Label, Labeled, RustDetector};
pub use hbos::{HbosConfig, HbosDetector};
pub use module::{DetectEngine, OnNodeAd, StepResult};
pub use stack::{ExecRecord, StackBuilder, StackErrors};
