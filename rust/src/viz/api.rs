//! JSON API over [`VizState`] — the payloads the HTTP server returns and
//! the experiments dump. Mirrors the reference implementation's endpoints
//! (dashboard / per-rank streaming series / function view / call stack).

use super::{RankStat, VizState};
use crate::provenance::{ProvQuery, ProvRecord};
use crate::util::json::Json;

fn record_json(r: &ProvRecord) -> Json {
    r.to_json()
}

/// `/api/dashboard?stat=<s>&n=<n>` — Fig 3 payload.
pub fn dashboard(state: &VizState, stat: RankStat, n: usize) -> Json {
    let (top, bottom) = state.ranking(stat, n);
    let entry = |r: &crate::ps::RankSummary| {
        Json::obj(vec![
            ("app", Json::num(r.app as f64)),
            ("rank", Json::num(r.rank as f64)),
            ("value", Json::num(stat.of(r))),
            ("average", Json::num(r.step_counts.mean())),
            ("stddev", Json::num(r.step_counts.stddev())),
            ("maximum", Json::num(r.step_counts.max())),
            ("minimum", Json::num(r.step_counts.min())),
            ("total", Json::num(r.total_anomalies as f64)),
        ])
    };
    Json::obj(vec![
        ("stat", Json::str(stat.name())),
        ("total_anomalies", Json::num(state.latest.total_anomalies as f64)),
        ("total_executions", Json::num(state.latest.total_executions as f64)),
        ("top", Json::Arr(top.iter().map(|r| entry(r)).collect())),
        ("bottom", Json::Arr(bottom.iter().map(|r| entry(r)).collect())),
    ])
}

/// `/api/timeline?app=&rank=` — Fig 4 payload (one rank's series).
pub fn timeline(state: &VizState, app: u32, rank: u32) -> Json {
    Json::obj(vec![
        ("app", Json::num(app as f64)),
        ("rank", Json::num(rank as f64)),
        (
            "series",
            Json::Arr(
                state
                    .rank_series(app, rank)
                    .into_iter()
                    .map(|(step, n)| {
                        Json::obj(vec![
                            ("step", Json::num(step as f64)),
                            ("n_anomalies", Json::num(n as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// `/api/function?app=&rank=&step=` — Fig 5 payload.
pub fn function_view(state: &VizState, app: u32, rank: u32, step: u64) -> Json {
    let recs = state.db.call_stack(app, rank, step);
    Json::obj(vec![
        ("app", Json::num(app as f64)),
        ("rank", Json::num(rank as f64)),
        ("step", Json::num(step as f64)),
        ("executions", Json::Arr(recs.iter().map(|r| record_json(r)).collect())),
    ])
}

/// `/api/callstack?app=&rank=&step=` — Fig 6 payload (same records,
/// entry-ordered; the client renders nesting from depth/parent).
pub fn call_stack(state: &VizState, app: u32, rank: u32, step: u64) -> Json {
    function_view(state, app, rank, step)
}

/// `/api/anomalies?limit=` — top anomalies by score, workflow-wide.
pub fn top_anomalies(state: &VizState, limit: usize) -> Json {
    let recs = state.db.query(&ProvQuery {
        anomalies_only: true,
        order_by_score: true,
        limit: Some(limit),
        ..Default::default()
    });
    Json::obj(vec![
        ("count", Json::num(recs.len() as f64)),
        ("anomalies", Json::Arr(recs.iter().map(|r| record_json(r)).collect())),
    ])
}

/// `/api/provenance?...` — full declarative-query proxy over the
/// provenance source (local index or the provDB service); the query
/// echo makes the applied filters auditable client-side.
pub fn provenance(state: &VizState, q: &ProvQuery) -> Json {
    let recs = state.db.query(q);
    Json::obj(vec![
        ("query", q.to_json()),
        ("count", Json::num(recs.len() as f64)),
        ("records", Json::Arr(recs.iter().map(record_json).collect())),
    ])
}

/// `/api/metadata` — run-level static provenance (architecture,
/// configuration, function registries).
pub fn metadata(state: &VizState) -> Json {
    match state.db.metadata() {
        Some(m) => m,
        None => Json::obj(vec![("error", Json::str("no run metadata available"))]),
    }
}

/// `/api/globalevents` — globally detected events (§V trigger).
pub fn global_events(state: &VizState) -> Json {
    Json::obj(vec![(
        "events",
        Json::Arr(
            state
                .latest
                .global_events
                .iter()
                .map(|e| {
                    Json::obj(vec![
                        ("step", Json::num(e.step as f64)),
                        ("total_anomalies", Json::num(e.total_anomalies as f64)),
                        ("score", Json::num(e.score)),
                    ])
                })
                .collect(),
        ),
    )])
}

/// `/api/probes` — probes installed in the provDB service with their
/// per-probe match/shed/push counters. A local provenance source has no
/// probe table; the reply says so instead of faking an empty one.
pub fn probes(state: &VizState) -> Json {
    match state.db.probes() {
        Some(infos) => Json::obj(vec![
            ("count", Json::num(infos.len() as f64)),
            ("probes", Json::Arr(infos.iter().map(|i| i.to_json()).collect())),
        ]),
        None => Json::obj(vec![(
            "error",
            Json::str("no probe table (provenance source is not a provDB service)"),
        )]),
    }
}

/// `/api/ps_stats` — parameter-server shard load counters (merge/sync
/// counts per stat shard, from the latest published snapshot), the
/// placement view (epoch + slots owned per shard — how the rebalancer
/// has reshaped routing), and the aggregator-side totals. The skew the
/// rebalancer acts on is visible here: compare `merges` across shards.
/// With the hierarchical aggregation tree engaged (`ps.agg_fanout` ≥ 2)
/// `agg_nodes` lists each tree node's fold/push/shed counters; flat
/// aggregation leaves it empty.
pub fn ps_stats(state: &VizState) -> Json {
    let agg_nodes: Vec<Json> = state
        .latest
        .agg_nodes
        .iter()
        .map(|n| {
            Json::obj(vec![
                ("node", Json::num(n.node as f64)),
                ("depth", Json::num(n.depth as f64)),
                ("rank_lo", Json::num(n.rank_lo as f64)),
                ("rank_hi", Json::num(n.rank_hi as f64)),
                ("folds", Json::num(n.folds as f64)),
                ("pushed", Json::num(n.pushed as f64)),
                ("shed", Json::num(n.shed as f64)),
            ])
        })
        .collect();
    let loads = state
        .latest
        .shard_loads
        .iter()
        .map(|l| {
            Json::obj(vec![
                ("shard", Json::num(l.shard as f64)),
                ("syncs", Json::num(l.syncs as f64)),
                ("merges", Json::num(l.merges as f64)),
                ("functions", Json::num(l.functions as f64)),
                ("slots", Json::num(l.slots as f64)),
                ("shed", Json::num(l.shed as f64)),
                ("queue_depth", Json::num(l.queue_depth as f64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("shards", Json::num(state.latest.shard_loads.len() as f64)),
        ("placement_epoch", Json::num(state.latest.placement_epoch as f64)),
        ("shard_loads", Json::Arr(loads)),
        ("agg_nodes", Json::Arr(agg_nodes)),
        ("functions_tracked", Json::num(state.latest.functions_tracked as f64)),
        ("total_anomalies", Json::num(state.latest.total_anomalies as f64)),
        ("total_executions", Json::num(state.latest.total_executions as f64)),
        ("event_version", Json::num(state.latest.global_events.len() as f64)),
    ])
}

/// `/api/stats` — run-level counters.
pub fn stats(state: &VizState) -> Json {
    // One backend round-trip for every provenance counter (a remote
    // source would otherwise pay one shard fan-out per counter).
    let prov = state.db.counters();
    Json::obj(vec![
        ("version", Json::str(crate::VERSION)),
        ("total_anomalies", Json::num(state.latest.total_anomalies as f64)),
        ("total_executions", Json::num(state.latest.total_executions as f64)),
        ("functions_tracked", Json::num(state.latest.functions_tracked as f64)),
        ("ranks", Json::num(state.latest.ranks.len() as f64)),
        ("timeline_points", Json::num(state.timeline.len() as f64)),
        ("prov_records", Json::num(prov.records as f64)),
        ("prov_bytes", Json::num(prov.bytes as f64)),
        ("prov_segments", Json::num(prov.segments_total as f64)),
        ("prov_segments_skipped", Json::num(prov.segments_skipped as f64)),
        ("prov_zone_map_bytes", Json::num(prov.zone_map_bytes as f64)),
        ("prov_inflight_lost", Json::num(prov.inflight_lost as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::{RankSummary, VizSnapshot};
    use crate::stats::RunStats;
    use crate::util::json::parse;

    fn state() -> VizState {
        let mut st = VizState::new(vec![]);
        let mut c = RunStats::new();
        c.push(2.0);
        st.latest = VizSnapshot {
            ranks: vec![RankSummary { app: 0, rank: 1, step_counts: c, total_anomalies: 2 }],
            total_anomalies: 2,
            total_executions: 50,
            functions_tracked: 1,
            placement_epoch: 2,
            shard_loads: vec![crate::ps::ShardLoad {
                shard: 0,
                syncs: 4,
                merges: 9,
                functions: 1,
                slots: 256,
                shed: 3,
                queue_depth: 0,
            }],
            agg_nodes: vec![crate::ps::AggNodeLoad {
                node: 1,
                depth: 1,
                rank_lo: 0,
                rank_hi: 4,
                folds: 8,
                pushed: 2,
                shed: 1,
            }],
            ..VizSnapshot::default()
        };
        st.timeline = vec![(0, 1, 0, 2)];
        st
    }

    #[test]
    fn payloads_are_valid_json() {
        let st = state();
        for j in [
            dashboard(&st, RankStat::Total, 5),
            timeline(&st, 0, 1),
            function_view(&st, 0, 1, 0),
            call_stack(&st, 0, 1, 0),
            top_anomalies(&st, 10),
            stats(&st),
            ps_stats(&st),
            provenance(&st, &ProvQuery { anomalies_only: true, ..Default::default() }),
            metadata(&st),
        ] {
            parse(&j.to_string()).unwrap();
        }
    }

    #[test]
    fn stats_carries_the_loss_ledger() {
        // A local source has no remote connection: the ledger exists and
        // is zero (the chaos harness reads this key unconditionally).
        let st = state();
        let j = stats(&st);
        assert_eq!(j.get("prov_inflight_lost").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn ps_stats_exposes_shard_loads() {
        let st = state();
        let j = ps_stats(&st);
        assert_eq!(j.get("shards").unwrap().as_u64(), Some(1));
        let loads = j.get("shard_loads").unwrap().as_arr().unwrap();
        assert_eq!(loads.len(), 1);
        assert_eq!(loads[0].get("syncs").unwrap().as_u64(), Some(4));
        assert_eq!(loads[0].get("merges").unwrap().as_u64(), Some(9));
        assert_eq!(loads[0].get("slots").unwrap().as_u64(), Some(256));
        assert_eq!(loads[0].get("shed").unwrap().as_u64(), Some(3));
        assert_eq!(loads[0].get("queue_depth").unwrap().as_u64(), Some(0));
        assert_eq!(j.get("placement_epoch").unwrap().as_u64(), Some(2));
        assert_eq!(j.get("total_anomalies").unwrap().as_u64(), Some(2));
        let nodes = j.get("agg_nodes").unwrap().as_arr().unwrap();
        assert_eq!(nodes.len(), 1);
        assert_eq!(nodes[0].get("node").unwrap().as_u64(), Some(1));
        assert_eq!(nodes[0].get("rank_hi").unwrap().as_u64(), Some(4));
        assert_eq!(nodes[0].get("folds").unwrap().as_u64(), Some(8));
        assert_eq!(nodes[0].get("pushed").unwrap().as_u64(), Some(2));
        assert_eq!(nodes[0].get("shed").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn dashboard_fields() {
        let st = state();
        let j = dashboard(&st, RankStat::Total, 5);
        assert_eq!(j.get("total_anomalies").unwrap().as_u64(), Some(2));
        let top = j.get("top").unwrap().as_arr().unwrap();
        assert_eq!(top.len(), 1);
        assert_eq!(top[0].get("rank").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn timeline_series_shape() {
        let st = state();
        let j = timeline(&st, 0, 1);
        let series = j.get("series").unwrap().as_arr().unwrap();
        assert_eq!(series.len(), 1);
        assert_eq!(series[0].get("n_anomalies").unwrap().as_u64(), Some(2));
    }
}
