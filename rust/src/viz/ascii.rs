//! Terminal renderings of the paper's visualization views. Each function
//! returns a `String`, so experiments embed them in reports and tests
//! assert on their structure.

use super::{RankStat, VizState};
use crate::provenance::ProvRecord;

/// Fig 3 — ranking dashboard: top-N and bottom-N ranks by `stat`,
/// horizontal bars scaled to the max value.
pub fn dashboard(state: &VizState, stat: RankStat, n: usize) -> String {
    let (top, bottom) = state.ranking(stat, n);
    let max_v = top
        .first()
        .map(|r| stat.of(r))
        .unwrap_or(0.0)
        .max(1e-9);
    let mut out = String::new();
    out.push_str(&format!(
        "== Ranking dashboard — {} anomalies/step, top & bottom {} ranks ==\n",
        stat.name(),
        n
    ));
    out.push_str(&format!(
        "   workflow totals: {} anomalies / {} executions\n",
        state.latest.total_anomalies, state.latest.total_executions
    ));
    let bar = |v: f64| -> String {
        let w = ((v / max_v) * 40.0).round() as usize;
        "█".repeat(w.min(40))
    };
    out.push_str("-- most problematic --\n");
    for r in &top {
        out.push_str(&format!(
            "  app{} rank {:>5} | {:<40} {:.2}\n",
            r.app,
            r.rank,
            bar(stat.of(r)),
            stat.of(r)
        ));
    }
    out.push_str("-- least problematic --\n");
    for r in &bottom {
        out.push_str(&format!(
            "  app{} rank {:>5} | {:<40} {:.2}\n",
            r.app,
            r.rank,
            bar(stat.of(r)),
            stat.of(r)
        ));
    }
    out
}

/// Fig 4 — streaming per-step anomaly scatter for selected ranks. One
/// column per step bucket, one glyph per rank.
pub fn timeline(state: &VizState, ranks: &[(u32, u32)], width: usize) -> String {
    const GLYPHS: [char; 8] = ['o', 'x', '+', '*', '#', '@', '%', '&'];
    let mut out = String::new();
    out.push_str("== Streaming anomaly counts per step ==\n");
    let mut max_step = 0u64;
    let mut max_count = 0u64;
    let series: Vec<(u32, u32, Vec<(u64, u64)>)> = ranks
        .iter()
        .map(|&(app, rank)| {
            let s = state.rank_series(app, rank);
            for (st, c) in &s {
                max_step = max_step.max(*st);
                max_count = max_count.max(*c);
            }
            (app, rank, s)
        })
        .collect();
    let rows = 10usize;
    let cols = width.max(10);
    let mut grid = vec![vec![' '; cols]; rows + 1];
    for (i, (_, _, s)) in series.iter().enumerate() {
        let g = GLYPHS[i % GLYPHS.len()];
        for (step, count) in s {
            let col = if max_step == 0 {
                0
            } else {
                ((*step as f64 / max_step as f64) * (cols - 1) as f64) as usize
            };
            let row = if max_count == 0 {
                rows
            } else {
                rows - ((*count as f64 / max_count as f64) * rows as f64) as usize
            };
            grid[row.min(rows)][col.min(cols - 1)] = g;
        }
    }
    for (ri, row) in grid.iter().enumerate() {
        let y = if max_count == 0 {
            0.0
        } else {
            max_count as f64 * (rows - ri) as f64 / rows as f64
        };
        out.push_str(&format!("{:>6.1} |{}\n", y, row.iter().collect::<String>()));
    }
    out.push_str(&format!("        0 .. step {} →\n", max_step));
    for (i, (app, rank, _)) in series.iter().enumerate() {
        out.push_str(&format!(
            "        '{}' = app{} rank {}\n",
            GLYPHS[i % GLYPHS.len()],
            app,
            rank
        ));
    }
    out
}

/// Fig 5 — function-execution view for one (app, rank, step): entry time
/// (x) vs fid (y); anomalies rendered `!`, normals `·`.
pub fn function_view(state: &VizState, app: u32, rank: u32, step: u64) -> String {
    let recs = state.db.call_stack(app, rank, step);
    let mut out = String::new();
    out.push_str(&format!(
        "== Function view — app {app}, rank {rank}, frame {step} ({} kept executions) ==\n",
        recs.len()
    ));
    if recs.is_empty() {
        out.push_str("  (no provenance records for this frame — nothing was anomalous)\n");
        return out;
    }
    let t0 = recs.iter().map(|r| r.entry_us).min().unwrap();
    let t1 = recs.iter().map(|r| r.exit_us).max().unwrap().max(t0 + 1);
    let fids: Vec<u32> = {
        let mut v: Vec<u32> = recs.iter().map(|r| r.fid).collect();
        v.sort();
        v.dedup();
        v
    };
    let cols = 60usize;
    for &fid in fids.iter().rev() {
        let mut row = vec![' '; cols];
        for r in recs.iter().filter(|r| r.fid == fid) {
            let c = (((r.entry_us - t0) as f64 / (t1 - t0) as f64) * (cols - 1) as f64)
                as usize;
            row[c.min(cols - 1)] = if r.is_anomaly() { '!' } else { '·' };
        }
        out.push_str(&format!(
            "  {:<14} fid {:>3} |{}|\n",
            state.func_name(app, fid),
            fid,
            row.iter().collect::<String>()
        ));
    }
    out.push_str(&format!(
        "  entry {} .. {} µs ('!' = anomaly)\n",
        t0, t1
    ));
    out
}

/// Fig 6 / Figs 10–13 — call-stack view: entry-ordered, depth-indented
/// bars; anomalies marked; message counts shown as arrows.
pub fn call_stack(state: &VizState, app: u32, rank: u32, step: u64) -> String {
    let recs = state.db.call_stack(app, rank, step);
    render_call_stack(state, &recs, &format!("app {app}, rank {rank}, frame {step}"))
}

/// Render a call-stack view from explicit records (case-study reports).
pub fn render_call_stack(state: &VizState, recs: &[ProvRecord], title: &str) -> String {
    let mut out = String::new();
    out.push_str(&format!("== Call stack view — {title} ==\n"));
    if recs.is_empty() {
        out.push_str("  (empty)\n");
        return out;
    }
    let t0 = recs.iter().map(|r| r.entry_us).min().unwrap();
    let t1 = recs.iter().map(|r| r.exit_us).max().unwrap().max(t0 + 1);
    let cols = 48usize;
    for r in recs {
        let start =
            (((r.entry_us - t0) as f64 / (t1 - t0) as f64) * cols as f64) as usize;
        let len = (((r.exit_us - r.entry_us) as f64 / (t1 - t0) as f64) * cols as f64)
            .ceil()
            .max(1.0) as usize;
        let mut bar = vec![' '; cols];
        for c in bar.iter_mut().skip(start).take(len) {
            *c = '▬';
        }
        let mark = if r.is_anomaly() { "!!" } else { "  " };
        let arrows = if r.n_messages > 0 {
            format!("  ⇄{}msg/{}B", r.n_messages, r.msg_bytes)
        } else {
            String::new()
        };
        out.push_str(&format!(
            "{} {:indent$}{:<16} |{}| {:>8}µs{}\n",
            mark,
            "",
            state.func_name(r.app, r.fid),
            bar.iter().collect::<String>(),
            r.inclusive_us,
            arrows,
            indent = (r.depth as usize) * 2,
        ));
    }
    out.push_str(&format!("   span {} .. {} µs; '!!' = anomaly\n", t0, t1));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ad::{ExecRecord, Label, Labeled};
    use crate::provenance::ProvDb;
    use crate::ps::{RankSummary, StepStat, VizSnapshot};
    use crate::stats::RunStats;
    use crate::trace::FuncRegistry;

    fn demo_state() -> VizState {
        let mut reg = FuncRegistry::new();
        reg.register("MD_NEWTON", false);
        reg.register("MD_FORCES", false);
        let mut db = ProvDb::in_memory();
        let mk = |fid: u32, entry: u64, exit: u64, depth: u32, label: Label, id: u64| Labeled {
            rec: ExecRecord {
                call_id: id,
                app: 0,
                rank: 3,
                thread: 0,
                fid,
                step: 9,
                entry_ts: entry,
                exit_ts: exit,
                depth,
                parent: None,
                n_children: 1,
                n_messages: if fid == 1 { 2 } else { 0 },
                msg_bytes: 512,
                exclusive_us: exit - entry,
            },
            label,
            score: 8.0,
        };
        db.append_step(
            &[
                mk(0, 100, 900, 0, Label::AnomalyHigh, 1),
                mk(1, 200, 700, 1, Label::Normal, 2),
            ],
            &reg,
        )
        .unwrap();

        let mut st = VizState::new(vec![reg]);
        let mut counts = RunStats::new();
        counts.push(3.0);
        counts.push(1.0);
        st.latest = VizSnapshot {
            ranks: vec![RankSummary { app: 0, rank: 3, step_counts: counts, total_anomalies: 4 }],
            total_anomalies: 4,
            total_executions: 200,
            ..VizSnapshot::default()
        };
        st.timeline = vec![(0, 3, 0, 3), (0, 3, 1, 1)];
        let _ = StepStat {
            app: 0,
            rank: 3,
            step: 0,
            n_executions: 0,
            n_anomalies: 0,
            ts_range: (0, 0),
        };
        st.db = crate::viz::ProvSource::local(db);
        st
    }

    #[test]
    fn dashboard_renders_bars() {
        let s = demo_state();
        let out = dashboard(&s, RankStat::Total, 3);
        assert!(out.contains("Ranking dashboard"));
        assert!(out.contains("rank     3"));
        assert!(out.contains("█"));
        assert!(out.contains("most problematic"));
    }

    #[test]
    fn timeline_renders_series() {
        let s = demo_state();
        let out = timeline(&s, &[(0, 3)], 40);
        assert!(out.contains("anomaly counts"));
        assert!(out.contains("'o' = app0 rank 3"));
        assert!(out.contains('o'));
    }

    #[test]
    fn function_view_marks_anomalies() {
        let s = demo_state();
        let out = function_view(&s, 0, 3, 9);
        assert!(out.contains("MD_NEWTON"));
        assert!(out.contains('!'));
        assert!(out.contains('·'));
    }

    #[test]
    fn function_view_empty_frame() {
        let s = demo_state();
        let out = function_view(&s, 0, 3, 999);
        assert!(out.contains("nothing was anomalous"));
    }

    #[test]
    fn call_stack_indents_and_marks() {
        let s = demo_state();
        let out = call_stack(&s, 0, 3, 9);
        assert!(out.contains("!! MD_NEWTON"), "{out}");
        assert!(out.contains("  MD_FORCES") || out.contains("   MD_FORCES"));
        assert!(out.contains("⇄2msg"));
        assert!(out.contains("▬"));
    }
}
