//! Minimal HTTP/1.1 server for the visualization API (no web framework
//! offline; the paper's uWSGI/celery stack maps to: the shared poll(2)
//! reactor = the worker pool, shared [`VizState`] = the database, and
//! the JSON endpoints in [`api`](super::api)). Connections are served
//! one-request-per-connection (`Connection: close`), parsed by a
//! [`ConnDriver`] state machine on the reactor's event loops.
//!
//! Endpoints:
//!
//! ```text
//! GET /                      → HTML index with usage
//! GET /api/stats             → run counters
//! GET /api/ps_stats          → PS shard load counters (merge/sync per shard)
//! GET /api/dashboard?stat=total&n=5
//! GET /api/timeline?app=0&rank=3
//! GET /api/function?app=0&rank=3&step=9
//! GET /api/callstack?app=0&rank=3&step=9
//! GET /api/anomalies?limit=20
//! GET /api/provenance?app=&rank=&fid=&step=&step_lo=&step_hi=&min_score=&label=&anomalies=1&order=score&limit=
//! GET /api/metadata
//! GET /view/dashboard|timeline|callstack (ASCII renderings, text/plain)
//! ```
//!
//! Unknown `/api/*` paths return a JSON error object echoing the path;
//! everything else 404s as plain text.

use super::{api, ascii, RankStat, VizState};
use crate::provenance::ProvQuery;
use crate::util::json::Json;
use crate::util::net::{serve_reactor, ConnDriver, NetStats, ReactorOpts, TcpServerHandle};
use anyhow::Result;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// A header block larger than this with no terminator in sight is abuse,
/// not slow I/O; the connection is dropped.
const MAX_REQUEST_BYTES: usize = 64 << 10;

/// Running server handle; drop (or call [`VizServer::stop`]) to shut down.
/// Connections live on the shared [`serve_reactor`] event loops.
pub struct VizServer {
    inner: TcpServerHandle,
    requests: Arc<AtomicU64>,
}

impl VizServer {
    /// Bind `addr` (use port 0 for ephemeral) and serve `state`.
    pub fn start(addr: &str, state: Arc<RwLock<VizState>>) -> Result<VizServer> {
        let requests = Arc::new(AtomicU64::new(0));
        let req2 = requests.clone();
        let inner = serve_reactor(
            "chimbuko-viz",
            addr,
            ReactorOpts::default(),
            NetStats::new(),
            move || {
                Box::new(HttpDriver {
                    state: state.clone(),
                    requests: req2.clone(),
                    done: false,
                })
            },
        )?;
        Ok(VizServer { inner, requests })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.inner.addr()
    }

    pub fn request_count(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    pub fn stop(&mut self) {
        self.inner.stop();
    }
}

/// Per-connection HTTP state machine: accumulate bytes until the header
/// block terminator, answer the one request, close (`Connection: close`
/// semantics — GET requests carry no body, so the header block is the
/// whole request).
struct HttpDriver {
    state: Arc<RwLock<VizState>>,
    requests: Arc<AtomicU64>,
    done: bool,
}

impl ConnDriver for HttpDriver {
    fn on_data(&mut self, inbuf: &mut Vec<u8>, out: &mut Vec<u8>) -> bool {
        if self.done {
            // Already answered; anything else the peer pipelines is
            // discarded while the reply flushes out.
            inbuf.clear();
            return false;
        }
        let Some(end) = headers_end(inbuf) else {
            return inbuf.len() <= MAX_REQUEST_BYTES;
        };
        self.requests.fetch_add(1, Ordering::Relaxed);
        let head = String::from_utf8_lossy(&inbuf[..end]);
        let line = head.lines().next().unwrap_or("");
        let mut parts = line.split_whitespace();
        let method = parts.next().unwrap_or("");
        let target = parts.next().unwrap_or("/").to_string();
        let (status, ctype, body) = if method != "GET" {
            (405, "text/plain", "method not allowed\n".to_string())
        } else {
            route(&target, &self.state)
        };
        respond(out, status, ctype, &body);
        inbuf.clear();
        self.done = true;
        false // single-request connection: close once the reply flushes
    }
}

/// Offset one past the end-of-headers terminator, if present.
fn headers_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4)
        .position(|w| w == b"\r\n\r\n")
        .map(|i| i + 4)
        .or_else(|| buf.windows(2).position(|w| w == b"\n\n").map(|i| i + 2))
}

fn respond(out: &mut Vec<u8>, status: u16, ctype: &str, body: &str) {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        _ => "Internal Server Error",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {ctype}; charset=utf-8\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    out.extend_from_slice(head.as_bytes());
    out.extend_from_slice(body.as_bytes());
}

/// Parse `?k=v&k2=v2`.
fn query_of(target: &str) -> (&str, HashMap<String, String>) {
    match target.split_once('?') {
        None => (target, HashMap::new()),
        Some((path, qs)) => {
            let mut m = HashMap::new();
            for pair in qs.split('&') {
                if let Some((k, v)) = pair.split_once('=') {
                    m.insert(k.to_string(), v.to_string());
                }
            }
            (path, m)
        }
    }
}

fn route(target: &str, state: &Arc<RwLock<VizState>>) -> (u16, &'static str, String) {
    let (path, q) = query_of(target);
    let get_u32 = |k: &str, d: u32| q.get(k).and_then(|v| v.parse().ok()).unwrap_or(d);
    let get_u64 = |k: &str, d: u64| q.get(k).and_then(|v| v.parse().ok()).unwrap_or(d);
    let get_usize = |k: &str, d: usize| q.get(k).and_then(|v| v.parse().ok()).unwrap_or(d);
    let st = state.read().expect("viz state poisoned");
    let json = |j: Json| (200, "application/json", j.to_string());
    match path {
        "/" => (
            200,
            "text/html",
            format!(
                "<html><body><h1>Chimbuko viz v{}</h1><pre>\n\
                 GET /api/stats\n\
                 GET /api/ps_stats\n\
                 GET /api/dashboard?stat=total|avg|std|max|min&n=5\n\
                 GET /api/timeline?app=0&rank=0\n\
                 GET /api/function?app=0&rank=0&step=0\n\
                 GET /api/callstack?app=0&rank=0&step=0\n\
                 GET /api/anomalies?limit=20\n\
                 GET /api/provenance?app=&rank=&fid=&step=&step_lo=&step_hi=&min_score=&label=&anomalies=1&order=score&limit=\n\
                 GET /api/metadata\n\
                 GET /api/globalevents\n\
                 GET /api/probes\n\
                 GET /view/dashboard  /view/timeline?app=&rank=  /view/callstack?app=&rank=&step=\n\
                 </pre></body></html>\n",
                crate::VERSION
            ),
        ),
        "/api/stats" => json(api::stats(&st)),
        "/api/ps_stats" => json(api::ps_stats(&st)),
        "/api/dashboard" => {
            let stat = q
                .get("stat")
                .and_then(|s| RankStat::parse(s))
                .unwrap_or(RankStat::Total);
            json(api::dashboard(&st, stat, get_usize("n", 5)))
        }
        "/api/timeline" => json(api::timeline(&st, get_u32("app", 0), get_u32("rank", 0))),
        "/api/function" => json(api::function_view(
            &st,
            get_u32("app", 0),
            get_u32("rank", 0),
            get_u64("step", 0),
        )),
        "/api/callstack" => json(api::call_stack(
            &st,
            get_u32("app", 0),
            get_u32("rank", 0),
            get_u64("step", 0),
        )),
        "/api/anomalies" => json(api::top_anomalies(&st, get_usize("limit", 20))),
        "/api/provenance" => {
            let app = get_u32("app", 0);
            let pq = ProvQuery {
                // `app` alone filters by app; with `rank`/`fid` it
                // scopes those keys (and the standalone filter is then
                // redundant but consistent).
                app: q.get("app").and_then(|v| v.parse().ok()),
                rank: q.get("rank").and_then(|v| v.parse().ok()).map(|r| (app, r)),
                fid: q.get("fid").and_then(|v| v.parse().ok()).map(|f| (app, f)),
                step: q.get("step").and_then(|v| v.parse().ok()),
                step_range: if q.contains_key("step_lo") || q.contains_key("step_hi") {
                    Some((get_u64("step_lo", 0), get_u64("step_hi", u64::MAX)))
                } else {
                    None
                },
                ts_range: None,
                anomalies_only: q
                    .get("anomalies")
                    .map(|v| v == "1" || v == "true")
                    .unwrap_or(false),
                min_score: q.get("min_score").and_then(|v| v.parse().ok()),
                label: q.get("label").cloned(),
                order_by_score: q.get("order").map(|v| v == "score").unwrap_or(false),
                // Default-bounded: a parameterless request must not
                // serialize the whole store. `limit=0` asks for all.
                limit: match q.get("limit").and_then(|v| v.parse().ok()) {
                    Some(0) => None,
                    Some(n) => Some(n),
                    None => Some(100),
                },
            };
            json(api::provenance(&st, &pq))
        }
        "/api/metadata" => json(api::metadata(&st)),
        "/api/globalevents" => json(api::global_events(&st)),
        "/api/probes" => json(api::probes(&st)),
        "/view/dashboard" => {
            let stat = q
                .get("stat")
                .and_then(|s| RankStat::parse(s))
                .unwrap_or(RankStat::Total);
            (200, "text/plain", ascii::dashboard(&st, stat, get_usize("n", 5)))
        }
        "/view/timeline" => (
            200,
            "text/plain",
            ascii::timeline(&st, &[(get_u32("app", 0), get_u32("rank", 0))], 60),
        ),
        "/view/callstack" => (
            200,
            "text/plain",
            ascii::call_stack(&st, get_u32("app", 0), get_u32("rank", 0), get_u64("step", 0)),
        ),
        p if p.starts_with("/api/") => (
            404,
            "application/json",
            Json::obj(vec![
                ("error", Json::str("unknown API path")),
                ("path", Json::str(p)),
            ])
            .to_string(),
        ),
        _ => (404, "text/plain", "not found\n".to_string()),
    }
}

/// Tiny blocking HTTP GET against a local server (tests + examples).
pub fn http_get(addr: std::net::SocketAddr, path: &str) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    write!(stream, "GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n")?;
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let mut body_len = 0usize;
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 || h == "\r\n" || h == "\n" {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
            body_len = v.trim().parse().unwrap_or(0);
        }
    }
    let mut body = vec![0u8; body_len];
    std::io::Read::read_exact(&mut reader, &mut body)?;
    Ok((status, String::from_utf8_lossy(&body).to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::{RankSummary, VizSnapshot};
    use crate::stats::RunStats;

    fn served_state() -> Arc<RwLock<VizState>> {
        let mut st = VizState::new(vec![]);
        let mut c = RunStats::new();
        c.push(1.0);
        st.latest = VizSnapshot {
            ranks: vec![RankSummary { app: 0, rank: 0, step_counts: c, total_anomalies: 1 }],
            total_anomalies: 1,
            total_executions: 10,
            shard_loads: vec![crate::ps::ShardLoad {
                shard: 0,
                syncs: 2,
                merges: 5,
                functions: 3,
                slots: 256,
                shed: 0,
                queue_depth: 0,
            }],
            ..VizSnapshot::default()
        };
        Arc::new(RwLock::new(st))
    }

    #[test]
    fn ps_stats_endpoint() {
        let mut srv = VizServer::start("127.0.0.1:0", served_state()).unwrap();
        let (code, body) = http_get(srv.addr(), "/api/ps_stats").unwrap();
        assert_eq!(code, 200);
        let j = crate::util::json::parse(&body).unwrap();
        assert_eq!(j.get("shards").unwrap().as_u64(), Some(1));
        let loads = j.get("shard_loads").unwrap().as_arr().unwrap();
        assert_eq!(loads[0].get("merges").unwrap().as_u64(), Some(5));
        srv.stop();
    }

    #[test]
    fn serves_json_endpoints() {
        let mut srv = VizServer::start("127.0.0.1:0", served_state()).unwrap();
        let addr = srv.addr();
        let (code, body) = http_get(addr, "/api/stats").unwrap();
        assert_eq!(code, 200);
        let j = crate::util::json::parse(&body).unwrap();
        assert_eq!(j.get("total_anomalies").unwrap().as_u64(), Some(1));

        let (code, body) = http_get(addr, "/api/dashboard?stat=total&n=3").unwrap();
        assert_eq!(code, 200);
        crate::util::json::parse(&body).unwrap();

        let (code, _) = http_get(addr, "/api/timeline?app=0&rank=0").unwrap();
        assert_eq!(code, 200);
        let (code, _) = http_get(addr, "/nope").unwrap();
        assert_eq!(code, 404);
        assert!(srv.request_count() >= 4);
        srv.stop();
    }

    #[test]
    fn unknown_api_path_returns_json_error_with_path() {
        let mut srv = VizServer::start("127.0.0.1:0", served_state()).unwrap();
        let (code, body) = http_get(srv.addr(), "/api/definitely-not-a-thing").unwrap();
        assert_eq!(code, 404);
        let j = crate::util::json::parse(&body).unwrap();
        assert_eq!(j.get("error").unwrap().as_str(), Some("unknown API path"));
        assert_eq!(
            j.get("path").unwrap().as_str(),
            Some("/api/definitely-not-a-thing")
        );
        // Non-API paths keep the plain-text 404.
        let (code, body) = http_get(srv.addr(), "/definitely-not-a-thing").unwrap();
        assert_eq!(code, 404);
        assert!(crate::util::json::parse(&body).is_err());
        srv.stop();
    }

    #[test]
    fn provenance_and_metadata_endpoints() {
        let mut srv = VizServer::start("127.0.0.1:0", served_state()).unwrap();
        let (code, body) = http_get(
            srv.addr(),
            "/api/provenance?rank=0&anomalies=1&order=score&limit=5",
        )
        .unwrap();
        assert_eq!(code, 200);
        let j = crate::util::json::parse(&body).unwrap();
        assert_eq!(j.get("count").unwrap().as_u64(), Some(0));
        // The echoed query reflects the parsed filters.
        let q = j.get("query").unwrap();
        assert_eq!(q.get("anomalies_only").unwrap().as_bool(), Some(true));
        assert_eq!(q.get("limit").unwrap().as_u64(), Some(5));
        // Empty state: metadata degrades to a JSON error object.
        let (code, body) = http_get(srv.addr(), "/api/metadata").unwrap();
        assert_eq!(code, 200);
        let j = crate::util::json::parse(&body).unwrap();
        assert!(j.get("error").is_some());
        srv.stop();
    }

    #[test]
    fn serves_ascii_views_and_index() {
        let mut srv = VizServer::start("127.0.0.1:0", served_state()).unwrap();
        let addr = srv.addr();
        let (code, body) = http_get(addr, "/").unwrap();
        assert_eq!(code, 200);
        assert!(body.contains("Chimbuko viz"));
        let (code, body) = http_get(addr, "/view/dashboard").unwrap();
        assert_eq!(code, 200);
        assert!(body.contains("Ranking dashboard"));
        srv.stop();
    }

    #[test]
    fn global_events_endpoint() {
        let state = served_state();
        state.write().unwrap().latest.global_events.push(chimbuko_global_event());
        let mut srv = VizServer::start("127.0.0.1:0", state).unwrap();
        let (code, body) = http_get(srv.addr(), "/api/globalevents").unwrap();
        assert_eq!(code, 200);
        let j = crate::util::json::parse(&body).unwrap();
        let evs = j.get("events").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].get("step").unwrap().as_u64(), Some(12));
        srv.stop();
    }

    fn chimbuko_global_event() -> crate::ps::GlobalEvent {
        crate::ps::GlobalEvent { step: 12, total_anomalies: 40, score: 5.5 }
    }

    #[test]
    fn probes_endpoint_lists_installed_probes() {
        // A local source has no probe table: JSON error object.
        let mut srv = VizServer::start("127.0.0.1:0", served_state()).unwrap();
        let (code, body) = http_get(srv.addr(), "/api/probes").unwrap();
        assert_eq!(code, 200);
        let j = crate::util::json::parse(&body).unwrap();
        assert!(j.get("error").is_some());
        srv.stop();

        // Against a provDB service: the installed probe shows with its
        // counters.
        let (store, db_handle) =
            crate::provdb::spawn_store(None, 1, crate::provdb::Retention::default()).unwrap();
        let mut db_srv = crate::provdb::ProvDbTcpServer::start("127.0.0.1:0", store).unwrap();
        let db_addr = db_srv.addr().to_string();
        let mut cl = crate::provdb::ProvClient::connect(&db_addr).unwrap();
        cl.install_probe(
            &crate::probe::Probe::compile("probe hot: fn:*.*:exit / score >= 6.0 /").unwrap(),
        )
        .unwrap();
        let state = served_state();
        state.write().unwrap().db = crate::viz::ProvSource::remote(&db_addr).unwrap();
        let mut srv = VizServer::start("127.0.0.1:0", state).unwrap();
        let (code, body) = http_get(srv.addr(), "/api/probes").unwrap();
        assert_eq!(code, 200);
        let j = crate::util::json::parse(&body).unwrap();
        assert_eq!(j.get("count").unwrap().as_u64(), Some(1));
        let probes = j.get("probes").unwrap().as_arr().unwrap();
        assert_eq!(probes[0].get("name").unwrap().as_str(), Some("hot"));
        assert_eq!(probes[0].get("matches").unwrap().as_u64(), Some(0));
        srv.stop();
        db_srv.stop();
        db_handle.join();
    }

    #[test]
    fn concurrent_requests() {
        let mut srv = VizServer::start("127.0.0.1:0", served_state()).unwrap();
        let addr = srv.addr();
        let mut joins = Vec::new();
        for _ in 0..8 {
            joins.push(std::thread::spawn(move || {
                let (code, _) = http_get(addr, "/api/stats").unwrap();
                assert_eq!(code, 200);
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        srv.stop();
    }
}
