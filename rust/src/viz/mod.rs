//! Visualization backend (paper §IV).
//!
//! Two client classes, same as the paper: *data senders* (the parameter
//! server's snapshots + the provenance store) feed [`VizState`]; *users*
//! query it — through the JSON/HTTP API ([`http`]) or the terminal
//! renderings ([`ascii`]) that reproduce the paper's views:
//!
//! * Fig 3 — ranking dashboard (top/bottom-N ranks by a selectable
//!   statistic of per-step anomaly counts);
//! * Fig 4 — streaming per-step anomaly scatter for selected ranks;
//! * Fig 5 — function-execution view for one (app, rank, frame);
//! * Fig 6 / 10–13 — call-stack view with anomaly highlighting.

pub mod api;
pub mod ascii;
pub mod http;

use crate::provdb::ProvClient;
use crate::provenance::{ProvDb, ProvQuery, ProvRecord};
use crate::ps::{RankSummary, VizSnapshot};
use crate::trace::FuncRegistry;
use crate::util::json::Json;
use crate::util::net::{NetStats, Reconnector};
use std::sync::{Arc, Mutex};

/// Where the viz layer's provenance detail queries go: a local in-process
/// [`ProvDb`] index (post-mortem `serve`, finished runs) or the networked
/// provenance database service ([`crate::provdb`]). Either way the query
/// surface is the same — [`ProvQuery`] filters, call-stack
/// reconstruction, run metadata — so every endpoint serves both.
pub enum ProvSource {
    Local {
        db: ProvDb,
        meta: Option<Json>,
    },
    /// A provDB service connection behind the shared
    /// [`Reconnector`](crate::util::net::Reconnector): a failed request
    /// drops the connection and the next request redials (with backoff),
    /// so one backend restart never permanently degrades the viz server.
    /// Records cross the wire in the binary codec and are decoded here —
    /// the viz layer is the JSON *edge*: `/api/provenance` is where
    /// provenance first becomes JSON.
    Remote {
        client: Mutex<Reconnector<ProvClient>>,
        /// Transport counter sheet the reconnector tallies on. It outlives
        /// any one `ProvClient` (a redial drops the client and its
        /// internal ledgers), so in-flight losses across backend restarts
        /// stay visible in `/api/stats`.
        stats: Arc<NetStats>,
    },
}

impl ProvSource {
    /// Local index, no run metadata.
    pub fn local(db: ProvDb) -> ProvSource {
        ProvSource::Local { db, meta: None }
    }

    /// Local index plus run metadata (loaded from `metadata.json`).
    pub fn local_with_meta(db: ProvDb, meta: Option<Json>) -> ProvSource {
        ProvSource::Local { db, meta }
    }

    /// Proxy queries to the provDB service at `addr`; connects eagerly
    /// (fail fast on a bad address) and reconnects with backoff after
    /// failures (the shared [`Reconnector`] — the same recovery loop the
    /// PS router uses).
    pub fn remote(addr: &str) -> anyhow::Result<ProvSource> {
        let stats = NetStats::new();
        let client = Reconnector::connected(addr, |a: &str| ProvClient::connect(a))?
            .with_stats(stats.clone());
        Ok(ProvSource::Remote { client: Mutex::new(client), stats })
    }

    /// Run `op` against the remote connection, (re)connecting as needed.
    /// On error the connection is dropped so the next call redials; the
    /// caller degrades to an empty result meanwhile.
    fn with_remote<T>(
        slot: &Mutex<Reconnector<ProvClient>>,
        op: impl FnOnce(&mut ProvClient) -> anyhow::Result<T>,
    ) -> Option<T> {
        match slot.lock().expect("provdb client lock").with(op) {
            Ok(v) => Some(v),
            Err(e) => {
                crate::log_warn!("viz", "provdb request failed (will reconnect): {e:#}");
                None
            }
        }
    }

    /// Run a query; remote errors degrade to an empty result (the HTTP
    /// layer must not die with a flaky backend).
    pub fn query(&self, q: &ProvQuery) -> Vec<ProvRecord> {
        match self {
            ProvSource::Local { db, .. } => db.query(q).into_iter().cloned().collect(),
            ProvSource::Remote { client, .. } => {
                Self::with_remote(client, |c| c.query(q)).unwrap_or_default()
            }
        }
    }

    /// All records of `(app, rank)` for `step`, entry-ordered.
    pub fn call_stack(&self, app: u32, rank: u32, step: u64) -> Vec<ProvRecord> {
        match self {
            ProvSource::Local { db, .. } => {
                db.call_stack(app, rank, step).into_iter().cloned().collect()
            }
            ProvSource::Remote { client, .. } => {
                Self::with_remote(client, |c| c.call_stack(app, rank, step))
                    .unwrap_or_default()
            }
        }
    }

    /// Record count (remote: retained records).
    pub fn len(&self) -> usize {
        match self {
            ProvSource::Local { db, .. } => db.len(),
            ProvSource::Remote { client, .. } => Self::with_remote(client, |c| c.stats())
                .map(|s| s.records as usize)
                .unwrap_or(0),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Everything `/api/stats` reads, in a single backend round-trip (a
    /// remote source would otherwise pay one shard fan-out per counter).
    /// A local index has no warm tier — its segment counters are zero.
    pub fn counters(&self) -> ProvCounters {
        match self {
            ProvSource::Local { db, .. } => ProvCounters {
                records: db.len(),
                bytes: db.bytes_written(),
                ..ProvCounters::default()
            },
            ProvSource::Remote { client, stats } => {
                let lost = stats.inflight_lost_count();
                Self::with_remote(client, |c| c.stats())
                    .map(|s| ProvCounters {
                        records: s.records as usize,
                        bytes: s.log_bytes,
                        segments_total: s.segments_total,
                        segments_skipped: s.segments_skipped,
                        zone_map_bytes: s.zone_map_bytes,
                        inflight_lost: lost,
                    })
                    .unwrap_or(ProvCounters { inflight_lost: lost, ..ProvCounters::default() })
            }
        }
    }

    /// Reduced-output bytes (remote: total log bytes).
    pub fn bytes_written(&self) -> u64 {
        match self {
            ProvSource::Local { db, .. } => db.bytes_written(),
            ProvSource::Remote { client, .. } => Self::with_remote(client, |c| c.stats())
                .map(|s| s.log_bytes)
                .unwrap_or(0),
        }
    }

    /// Installed probes + per-probe counters (`/api/probes`). Only the
    /// provDB service holds a probe table — a local index answers `None`
    /// (distinct from a reachable service with zero probes, `Some([])`).
    pub fn probes(&self) -> Option<Vec<crate::provdb::ProbeInfo>> {
        match self {
            ProvSource::Local { .. } => None,
            ProvSource::Remote { client, .. } => Self::with_remote(client, |c| c.list_probes()),
        }
    }

    /// Run metadata, if available.
    pub fn metadata(&self) -> Option<Json> {
        match self {
            ProvSource::Local { meta, .. } => meta.clone(),
            ProvSource::Remote { client, .. } => {
                Self::with_remote(client, |c| c.metadata()).flatten()
            }
        }
    }
}

/// Provenance-store counters for `/api/stats`, whatever the source.
/// The segment fields describe the provDB warm tier (sealed columnar
/// segments + zone-map pruning); they stay zero for a local index.
#[derive(Clone, Copy, Debug, Default)]
pub struct ProvCounters {
    /// Retained records.
    pub records: usize,
    /// Reduced-output bytes (remote: total log bytes).
    pub bytes: u64,
    /// Sealed warm segments currently adopted.
    pub segments_total: u64,
    /// Segments pruned by zone map across all queries so far.
    pub segments_skipped: u64,
    /// Bytes of resident zone-map footers.
    pub zone_map_bytes: u64,
    /// Requests this viz server's provDB connection abandoned mid-flight
    /// (transport ledger; survives backend restarts). 0 for a local index.
    pub inflight_lost: u64,
}

/// Statistic selector for the ranking dashboard (paper Fig 3 offers
/// average / stddev / maximum / minimum / total).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum RankStat {
    Average,
    Stddev,
    Maximum,
    Minimum,
    Total,
}

impl RankStat {
    pub fn parse(s: &str) -> Option<RankStat> {
        Some(match s {
            "average" | "avg" | "mean" => RankStat::Average,
            "stddev" | "std" => RankStat::Stddev,
            "maximum" | "max" => RankStat::Maximum,
            "minimum" | "min" => RankStat::Minimum,
            "total" => RankStat::Total,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            RankStat::Average => "average",
            RankStat::Stddev => "stddev",
            RankStat::Maximum => "maximum",
            RankStat::Minimum => "minimum",
            RankStat::Total => "total",
        }
    }

    /// Extract the statistic from a rank summary.
    pub fn of(self, r: &RankSummary) -> f64 {
        match self {
            RankStat::Average => r.step_counts.mean(),
            RankStat::Stddev => r.step_counts.stddev(),
            RankStat::Maximum => r.step_counts.max(),
            RankStat::Minimum => r.step_counts.min(),
            RankStat::Total => r.total_anomalies as f64,
        }
    }
}

/// In-memory state the server queries; built from a finished run or fed
/// incrementally by the PS snapshot stream.
pub struct VizState {
    /// Latest snapshot (dashboard source).
    pub latest: VizSnapshot,
    /// Per-rank timeline accumulated from `fresh_steps` of every snapshot:
    /// (app, rank, step, n_anomalies).
    pub timeline: Vec<(u32, u32, u64, u64)>,
    /// Provenance source for detail queries (local index or the
    /// networked provDB service).
    pub db: ProvSource,
    /// Per-app function tables.
    pub registries: Vec<FuncRegistry>,
}

impl VizState {
    pub fn new(registries: Vec<FuncRegistry>) -> VizState {
        VizState {
            latest: VizSnapshot::default(),
            timeline: Vec::new(),
            db: ProvSource::local(ProvDb::in_memory()),
            registries,
        }
    }

    /// Build from a finished run.
    pub fn from_run(
        snapshots: &[VizSnapshot],
        final_snapshot: VizSnapshot,
        db: ProvDb,
        registries: Vec<FuncRegistry>,
    ) -> VizState {
        let mut s = VizState::new(registries);
        for snap in snapshots {
            s.ingest(snap.clone());
        }
        s.latest = final_snapshot;
        s.db = ProvSource::local(db);
        s
    }

    /// Ingest one PS snapshot (data-sender path). Since the delta
    /// refactor the PS publishes *snapshot deltas* (changed ranks, new
    /// events, absolute totals); these fold incrementally into `latest`
    /// so ingest cost tracks what changed, not the rank count. Full
    /// snapshots (final state, tests) still replace wholesale.
    pub fn ingest(&mut self, snap: VizSnapshot) {
        for st in &snap.fresh_steps {
            self.timeline.push((st.app, st.rank, st.step, st.n_anomalies));
        }
        if snap.delta {
            self.latest.fold_delta(&snap);
        } else {
            self.latest = snap;
        }
    }

    /// Top/bottom `n` ranks by `stat` (Fig 3's dashboard selection).
    pub fn ranking(&self, stat: RankStat, n: usize) -> (Vec<&RankSummary>, Vec<&RankSummary>) {
        let mut sorted: Vec<&RankSummary> = self.latest.ranks.iter().collect();
        sorted.sort_by(|a, b| {
            stat.of(b)
                .partial_cmp(&stat.of(a))
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.rank.cmp(&b.rank))
        });
        let top: Vec<&RankSummary> = sorted.iter().take(n).copied().collect();
        let mut bottom: Vec<&RankSummary> =
            sorted.iter().rev().take(n).copied().collect();
        bottom.reverse();
        (top, bottom)
    }

    /// Per-step anomaly series for one rank (Fig 4's scatter).
    pub fn rank_series(&self, app: u32, rank: u32) -> Vec<(u64, u64)> {
        let mut v: Vec<(u64, u64)> = self
            .timeline
            .iter()
            .filter(|(a, r, _, _)| *a == app && *r == rank)
            .map(|(_, _, s, n)| (*s, *n))
            .collect();
        v.sort_by_key(|(s, _)| *s);
        v
    }

    /// Function name lookup.
    pub fn func_name(&self, app: u32, fid: u32) -> &str {
        self.registries
            .get(app as usize)
            .map(|r| r.name(fid))
            .unwrap_or("<unknown>")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::StepStat;
    use crate::stats::RunStats;

    fn summary(rank: u32, counts: &[f64]) -> RankSummary {
        let mut s = RunStats::new();
        for &c in counts {
            s.push(c);
        }
        RankSummary {
            app: 0,
            rank,
            step_counts: s,
            total_anomalies: counts.iter().sum::<f64>() as u64,
        }
    }

    fn state_with_ranks() -> VizState {
        let mut st = VizState::new(vec![]);
        st.latest = VizSnapshot {
            ranks: vec![
                summary(0, &[1.0, 1.0]),
                summary(1, &[9.0, 0.0]), // max total & stddev
                summary(2, &[0.0, 0.0]),
                summary(3, &[2.0, 2.0]),
            ],
            total_anomalies: 15,
            total_executions: 1000,
            ..VizSnapshot::default()
        };
        st
    }

    #[test]
    fn ranking_by_each_stat() {
        let st = state_with_ranks();
        let (top, bottom) = st.ranking(RankStat::Total, 2);
        assert_eq!(top[0].rank, 1);
        assert_eq!(top[1].rank, 3);
        assert_eq!(bottom.len(), 2);
        assert_eq!(bottom[1].rank, 2);

        let (top, _) = st.ranking(RankStat::Stddev, 1);
        assert_eq!(top[0].rank, 1);
        let (top, _) = st.ranking(RankStat::Average, 1);
        assert_eq!(top[0].rank, 1);
        let (top, _) = st.ranking(RankStat::Minimum, 1);
        assert_eq!(top[0].rank, 3); // min per-step count = 2
    }

    #[test]
    fn ranking_more_than_available() {
        let st = state_with_ranks();
        let (top, bottom) = st.ranking(RankStat::Total, 100);
        assert_eq!(top.len(), 4);
        assert_eq!(bottom.len(), 4);
    }

    #[test]
    fn timeline_accumulates_across_snapshots() {
        let mut st = VizState::new(vec![]);
        for step in 0..3u64 {
            st.ingest(VizSnapshot {
                fresh_steps: vec![StepStat {
                    app: 0,
                    rank: 7,
                    step,
                    n_executions: 10,
                    n_anomalies: step,
                    ts_range: (0, 1),
                }],
                ..VizSnapshot::default()
            });
        }
        assert_eq!(st.rank_series(0, 7), vec![(0, 0), (1, 1), (2, 2)]);
        assert!(st.rank_series(0, 8).is_empty());
    }

    #[test]
    fn delta_snapshots_fold_incrementally() {
        let mut st = VizState::new(vec![]);
        // First delta: ranks 0 and 1 appear.
        st.ingest(VizSnapshot {
            ranks: vec![summary(0, &[1.0]), summary(1, &[2.0])],
            total_anomalies: 3,
            total_executions: 100,
            delta: true,
            ..VizSnapshot::default()
        });
        assert_eq!(st.latest.ranks.len(), 2);
        assert_eq!(st.latest.total_anomalies, 3);
        // Second delta: only rank 1 changed — rank 0 must survive, rank 1
        // must be replaced (cumulative stats), totals adopted.
        st.ingest(VizSnapshot {
            ranks: vec![summary(1, &[2.0, 5.0])],
            total_anomalies: 8,
            total_executions: 200,
            delta: true,
            ..VizSnapshot::default()
        });
        assert_eq!(st.latest.ranks.len(), 2, "unchanged ranks must survive deltas");
        assert_eq!(st.latest.total_anomalies, 8);
        assert_eq!(st.latest.total_executions, 200);
        let r1 = st.latest.ranks.iter().find(|r| r.rank == 1).unwrap();
        assert_eq!(r1.total_anomalies, 7, "changed rank replaced, not summed");
        assert_eq!(st.latest.ranks.iter().find(|r| r.rank == 0).unwrap().total_anomalies, 1);
        // A new rank arriving later inserts in sorted position.
        st.ingest(VizSnapshot {
            ranks: vec![summary(2, &[4.0])],
            total_anomalies: 12,
            total_executions: 300,
            delta: true,
            ..VizSnapshot::default()
        });
        let order: Vec<u32> = st.latest.ranks.iter().map(|r| r.rank).collect();
        assert_eq!(order, vec![0, 1, 2]);
        // A full (non-delta) snapshot replaces wholesale.
        st.ingest(VizSnapshot { total_anomalies: 1, ..VizSnapshot::default() });
        assert!(st.latest.ranks.is_empty());
        assert_eq!(st.latest.total_anomalies, 1);
    }

    #[test]
    fn stat_parse_names() {
        for (s, w) in [
            ("avg", RankStat::Average),
            ("stddev", RankStat::Stddev),
            ("max", RankStat::Maximum),
            ("min", RankStat::Minimum),
            ("total", RankStat::Total),
        ] {
            assert_eq!(RankStat::parse(s), Some(w));
        }
        assert_eq!(RankStat::parse("bogus"), None);
    }
}
