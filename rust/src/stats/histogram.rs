//! Log-scale latency histogram — used by the viz dashboard for runtime
//! distributions and by the perf harness for percentile reporting without
//! retaining raw samples.

/// Histogram over `[1µs, ~1e6s)` with `buckets_per_decade` log buckets.
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: Vec<u64>,
    buckets_per_decade: usize,
    total: u64,
    underflow: u64,
}

const DECADES: usize = 12;

impl Histogram {
    pub fn new(buckets_per_decade: usize) -> Self {
        assert!(buckets_per_decade > 0);
        Histogram {
            counts: vec![0; DECADES * buckets_per_decade],
            buckets_per_decade,
            total: 0,
            underflow: 0,
        }
    }

    fn bucket_of(&self, v: f64) -> Option<usize> {
        if !(v >= 1.0) {
            return None; // underflow or NaN
        }
        let idx = (v.log10() * self.buckets_per_decade as f64) as usize;
        Some(idx.min(self.counts.len() - 1))
    }

    /// Record one value (µs).
    pub fn record(&mut self, v: f64) {
        self.total += 1;
        match self.bucket_of(v) {
            Some(i) => self.counts[i] += 1,
            None => self.underflow += 1,
        }
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Approximate quantile (bucket upper edge), `q ∈ [0,1]`.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut seen = self.underflow;
        if seen >= target {
            return 1.0;
        }
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 10f64.powf((i as f64 + 1.0) / self.buckets_per_decade as f64);
            }
        }
        f64::INFINITY
    }

    /// Observations currently in the bucket `v` falls into (0 for
    /// underflow/NaN values) — the HBOS detector's probability lookup.
    pub fn bucket_count(&self, v: f64) -> u64 {
        match self.bucket_of(v) {
            Some(i) => self.counts[i],
            None => self.underflow,
        }
    }

    /// Merge another histogram (same shape).
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.buckets_per_decade, other.buckets_per_decade);
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.underflow += other.underflow;
    }

    /// Non-empty buckets as `(lower_edge, count)` for rendering.
    pub fn nonzero_buckets(&self) -> Vec<(f64, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (10f64.powf(i as f64 / self.buckets_per_decade as f64), c))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn quantiles_bracket_distribution() {
        let mut h = Histogram::new(10);
        let mut rng = Rng::new(2);
        for _ in 0..50_000 {
            h.record(rng.lognormal(6.0, 0.5)); // ~ e^6 ≈ 400µs center
        }
        let p50 = h.quantile(0.5);
        // Median of lognormal(6, .5) = e^6 ≈ 403; log-bucket edges are
        // within one bucket (10^.1 ≈ 1.26×).
        assert!(p50 > 300.0 && p50 < 550.0, "p50 {p50}");
        assert!(h.quantile(0.99) > p50);
        assert!(h.quantile(0.0) <= p50);
    }

    #[test]
    fn underflow_and_empty() {
        let mut h = Histogram::new(4);
        assert_eq!(h.quantile(0.5), 0.0);
        h.record(0.5);
        h.record(f64::NAN);
        assert_eq!(h.count(), 2);
        assert_eq!(h.quantile(1.0), 1.0);
    }

    #[test]
    fn merge_adds_counts() {
        let mut a = Histogram::new(8);
        let mut b = Histogram::new(8);
        for v in [10.0, 100.0, 1000.0] {
            a.record(v);
            b.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), 6);
        assert_eq!(a.nonzero_buckets().iter().map(|(_, c)| c).sum::<u64>(), 6);
    }

    #[test]
    fn huge_values_clamp_to_last_bucket() {
        let mut h = Histogram::new(4);
        h.record(1e30);
        assert_eq!(h.count(), 1);
        assert!(h.quantile(1.0).is_finite());
    }
}
