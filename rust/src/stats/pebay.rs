//! One-pass streaming moments with Pébay pairwise merging.
//!
//! `RunStats` carries `(n, μ, M2, min, max)`. `push` is Welford's update;
//! `merge` is Pébay's parallel combination (Sandia report SAND2008-6212,
//! the paper's ref. [14]):
//!
//! ```text
//! δ   = μ_b − μ_a
//! n   = n_a + n_b
//! μ   = μ_a + δ·n_b/n
//! M2  = M2_a + M2_b + δ²·n_a·n_b/n
//! ```
//!
//! Both paths are numerically stable for the μs-scale runtimes we feed in.

/// Streaming summary of a scalar population.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RunStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for RunStats {
    fn default() -> Self {
        RunStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }
}

impl RunStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build directly from raw moments (used when deserializing PS messages
    /// and when importing results computed by the XLA artifact).
    pub fn from_raw(n: u64, mean: f64, m2: f64, min: f64, max: f64) -> Self {
        RunStats { n, mean, m2, min, max }
    }

    /// Welford single-observation update.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Pébay pairwise merge: `self ← self ⊕ other`.
    pub fn merge(&mut self, other: &RunStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *other;
            return;
        }
        let na = self.n as f64;
        let nb = other.n as f64;
        let n = na + nb;
        let delta = other.mean - self.mean;
        self.mean += delta * nb / n;
        self.m2 += other.m2 + delta * delta * na * nb / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Merged copy without mutating inputs.
    pub fn merged(mut self, other: &RunStats) -> RunStats {
        self.merge(other);
        self
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sum of squared deviations from the mean (aka M2).
    pub fn m2(&self) -> f64 {
        self.m2
    }

    /// Sample variance (n−1 denominator); 0 for n < 2.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check_default, vec_of};
    use crate::util::rng::Rng;

    fn naive(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = if xs.len() < 2 {
            0.0
        } else {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0)
        };
        (mean, var)
    }

    fn from_slice(xs: &[f64]) -> RunStats {
        let mut s = RunStats::new();
        for &x in xs {
            s.push(x);
        }
        s
    }

    #[test]
    fn welford_matches_naive() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let s = from_slice(&xs);
        let (m, v) = naive(&xs);
        assert!((s.mean() - m).abs() < 1e-12);
        assert!((s.variance() - v).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.count(), 8);
    }

    #[test]
    fn empty_stats_are_inert() {
        let mut a = from_slice(&[1.0, 2.0]);
        let empty = RunStats::new();
        let before = a;
        a.merge(&empty);
        assert_eq!(a, before);
        let mut b = RunStats::new();
        b.merge(&before);
        assert_eq!(b, before);
        assert_eq!(RunStats::new().variance(), 0.0);
        assert_eq!(RunStats::new().min(), 0.0);
    }

    #[test]
    fn merge_equals_concatenation_property() {
        check_default("pebay-merge-eq-concat", |rng, size| {
            let xs = vec_of(rng, size, |r| r.range_f64(-50.0, 50.0));
            let ys = vec_of(rng, 1 + size / 2, |r| r.lognormal(2.0, 1.0));
            let merged = from_slice(&xs).merged(&from_slice(&ys));
            let mut all = xs.clone();
            all.extend_from_slice(&ys);
            let whole = from_slice(&all);
            if merged.count() != whole.count() {
                return Err("count".into());
            }
            if (merged.mean() - whole.mean()).abs() > 1e-9 * (1.0 + whole.mean().abs()) {
                return Err(format!("mean {} vs {}", merged.mean(), whole.mean()));
            }
            if (merged.variance() - whole.variance()).abs()
                > 1e-8 * (1.0 + whole.variance().abs())
            {
                return Err(format!("var {} vs {}", merged.variance(), whole.variance()));
            }
            if merged.min() != whole.min() || merged.max() != whole.max() {
                return Err("minmax".into());
            }
            Ok(())
        });
    }

    #[test]
    fn merge_is_commutative_property() {
        check_default("pebay-commutative", |rng, size| {
            let xs = vec_of(rng, size, |r| r.range_f64(0.0, 1e6));
            let ys = vec_of(rng, size.max(1), |r| r.range_f64(0.0, 1e6));
            let ab = from_slice(&xs).merged(&from_slice(&ys));
            let ba = from_slice(&ys).merged(&from_slice(&xs));
            if (ab.mean() - ba.mean()).abs() > 1e-9 * (1.0 + ab.mean().abs()) {
                return Err("mean not commutative".into());
            }
            if (ab.m2() - ba.m2()).abs() > 1e-6 * (1.0 + ab.m2().abs()) {
                return Err("m2 not commutative".into());
            }
            Ok(())
        });
    }

    #[test]
    fn merge_is_associative_property() {
        check_default("pebay-associative", |rng, size| {
            let a = from_slice(&vec_of(rng, size, |r| r.normal_ms(100.0, 15.0)));
            let b = from_slice(&vec_of(rng, size.max(1), |r| r.normal_ms(-3.0, 2.0)));
            let c = from_slice(&vec_of(rng, 1 + size / 3, |r| r.pareto(1.0, 3.0)));
            let left = a.merged(&b).merged(&c);
            let right = a.merged(&b.merged(&c));
            if (left.mean() - right.mean()).abs() > 1e-9 * (1.0 + left.mean().abs()) {
                return Err("mean not associative".into());
            }
            if (left.m2() - right.m2()).abs() > 1e-6 * (1.0 + left.m2().abs()) {
                return Err("m2 not associative".into());
            }
            Ok(())
        });
    }

    #[test]
    fn stable_for_large_offsets() {
        // Runtimes near 1e9 µs with tiny variance — catastrophic for the
        // naive sum-of-squares formula, fine for Welford/Pébay.
        let mut rng = Rng::new(5);
        let xs: Vec<f64> = (0..10_000).map(|_| 1e9 + rng.normal()).collect();
        let half = xs.len() / 2;
        let merged = from_slice(&xs[..half]).merged(&from_slice(&xs[half..]));
        assert!((merged.variance() - 1.0).abs() < 0.1, "var {}", merged.variance());
    }

    #[test]
    fn from_raw_roundtrip() {
        let s = from_slice(&[1.0, 2.0, 3.0]);
        let r = RunStats::from_raw(s.count(), s.mean(), s.m2(), s.min(), s.max());
        assert_eq!(s, r);
    }
}
