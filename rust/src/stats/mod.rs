//! Streaming statistics with one-pass parallel merging.
//!
//! The parameter server and the on-node AD modules exchange per-function
//! `(n, μ, M2, min, max)` summaries and combine them with Pébay's update
//! formulas (the paper's ref. [14]) — commutative and barrier-free, which
//! is what makes the distributed AD architecture work.

mod histogram;
mod pebay;

pub use histogram::Histogram;
pub use pebay::RunStats;

use std::collections::HashMap;

/// Function-id range served by the dense fast path. Real workflows have a
/// few dozen instrumented functions (the AOT artifact bakes 64 slots), so
/// the hot detect loop runs on direct indexing; exotic fids spill to a map.
const DENSE_FUNCS: usize = 256;

/// Per-function statistics table keyed by a dense function id.
///
/// This is the object both the on-node AD module (local view) and the
/// parameter server (global view) maintain; merging tables is elementwise
/// [`RunStats::merge`]. Storage is a dense array for `fid < 256` (the AD
/// hot path — no hashing) with a HashMap spill for larger ids.
#[derive(Clone, Debug, Default)]
pub struct StatsTable {
    dense: Vec<RunStats>,
    spill: HashMap<u32, RunStats>,
}

impl StatsTable {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    fn slot_mut(&mut self, fid: u32) -> &mut RunStats {
        if (fid as usize) < DENSE_FUNCS {
            let i = fid as usize;
            if i >= self.dense.len() {
                self.dense.resize(i + 1, RunStats::new());
            }
            &mut self.dense[i]
        } else {
            self.spill.entry(fid).or_default()
        }
    }

    /// Observe one execution time for function `fid`.
    #[inline]
    pub fn push(&mut self, fid: u32, value: f64) {
        self.slot_mut(fid).push(value);
    }

    /// Stats for a function, if any observation exists.
    #[inline]
    pub fn get(&self, fid: u32) -> Option<&RunStats> {
        if (fid as usize) < DENSE_FUNCS {
            self.dense.get(fid as usize).filter(|s| s.count() > 0)
        } else {
            self.spill.get(&fid)
        }
    }

    /// Merge another table into this one (Pébay elementwise).
    pub fn merge(&mut self, other: &StatsTable) {
        for (fid, st) in other.iter() {
            self.slot_mut(fid).merge(st);
        }
    }

    /// Merge a single function summary (what PS receives from AD modules).
    pub fn merge_one(&mut self, fid: u32, st: &RunStats) {
        self.slot_mut(fid).merge(st);
    }

    /// Replace a function summary (what AD receives back from PS).
    pub fn replace(&mut self, fid: u32, st: RunStats) {
        *self.slot_mut(fid) = st;
    }

    /// Number of functions tracked (with ≥ 1 observation).
    pub fn len(&self) -> usize {
        self.dense.iter().filter(|s| s.count() > 0).count() + self.spill.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Iterate (fid, stats) over observed functions, dense ids first.
    pub fn iter(&self) -> impl Iterator<Item = (u32, &RunStats)> {
        self.dense
            .iter()
            .enumerate()
            .filter(|(_, s)| s.count() > 0)
            .map(|(i, s)| (i as u32, s))
            .chain(self.spill.iter().map(|(f, s)| (*f, s)))
    }

    /// Total observation count across all functions.
    pub fn total_count(&self) -> u64 {
        self.iter().map(|(_, s)| s.count()).sum()
    }

    /// Anomaly thresholds `(lo, hi) = μ ∓ α·σ` for `fid` (paper §III-B1).
    /// `None` until the function has ≥ 2 observations.
    pub fn thresholds(&self, fid: u32, alpha: f64) -> Option<(f64, f64)> {
        let st = self.get(fid)?;
        if st.count() < 2 {
            return None;
        }
        let sd = st.stddev();
        Some((st.mean() - alpha * sd, st.mean() + alpha * sd))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check_default, vec_of};

    #[test]
    fn table_push_and_thresholds() {
        let mut t = StatsTable::new();
        for v in [10.0, 12.0, 11.0, 9.0, 10.0, 11.0, 200.0_f64.sqrt()] {
            t.push(7, v);
        }
        let (lo, hi) = t.thresholds(7, 6.0).unwrap();
        let st = t.get(7).unwrap();
        assert!(lo < st.mean() && st.mean() < hi);
        assert!(t.thresholds(99, 6.0).is_none());
    }

    #[test]
    fn threshold_needs_two_samples() {
        let mut t = StatsTable::new();
        t.push(1, 5.0);
        assert!(t.thresholds(1, 6.0).is_none());
        t.push(1, 6.0);
        assert!(t.thresholds(1, 6.0).is_some());
    }

    #[test]
    fn merge_tables_equals_union_stream() {
        check_default("table-merge", |rng, size| {
            let xs = vec_of(rng, size, |r| (r.usize(5) as u32, r.range_f64(0.0, 100.0)));
            let ys = vec_of(rng, size, |r| (r.usize(5) as u32, r.range_f64(0.0, 100.0)));
            let mut a = StatsTable::new();
            let mut b = StatsTable::new();
            let mut union = StatsTable::new();
            for &(f, v) in &xs {
                a.push(f, v);
                union.push(f, v);
            }
            for &(f, v) in &ys {
                b.push(f, v);
                union.push(f, v);
            }
            a.merge(&b);
            for (fid, st) in union.iter() {
                let got = a.get(fid).ok_or("missing fid after merge")?;
                if got.count() != st.count() {
                    return Err(format!("count mismatch fid {fid}"));
                }
                if (got.mean() - st.mean()).abs() > 1e-9 {
                    return Err(format!("mean mismatch fid {fid}"));
                }
                if (got.variance() - st.variance()).abs() > 1e-6 {
                    return Err(format!("variance mismatch fid {fid}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn total_count_sums() {
        let mut t = StatsTable::new();
        t.push(0, 1.0);
        t.push(0, 2.0);
        t.push(3, 1.0);
        assert_eq!(t.total_count(), 3);
        assert_eq!(t.len(), 2);
    }
}
