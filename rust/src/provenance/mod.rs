//! **Prescriptive provenance** (paper §V): the AD prescribes which events
//! get provenance — anomalies plus their k-neighbour context — and this
//! module turns them into durable, queryable records.
//!
//! Layout on disk (all JSON, matching the paper's reduced output format):
//!
//! ```text
//! <out_dir>/metadata.json          run-level static provenance
//! <out_dir>/prov_app<A>_rank<R>.jsonl   one record per kept execution
//! ```
//!
//! The byte count of everything written here is the *reduced* data size in
//! Fig 9. An in-memory index supports the visualization queries (call
//! stack by (app, rank, step), per-function views, top anomalies) and the
//! offline `replay` mode reloads the JSONL files into the same index.
//!
//! JSON is the *edge* format only: between the AD driver and the provDB
//! query reply, records travel and persist in the binary [`codec`]
//! layout (`.provseg` segment logs), which `replay`/[`ProvDb::load`]
//! also read back.

pub mod codec;
pub mod compare;
mod record;
mod store;

pub use codec::RecordFormat;
pub use compare::{compare, RunComparison};
pub use record::ProvRecord;
pub(crate) use store::{list_partition_files, scan_jsonl_file, scan_segment_file};
pub use store::{ProvDb, ProvQuery, RunMetadata};
