//! Cross-run provenance comparison (paper §VI-A: "this information can be
//! mined to discover how anomalous patterns depend on the workflow
//! configuration" — the co-design use case).
//!
//! Compares two stored runs' prescriptive provenance: per-function anomaly
//! profiles, per-rank-class distributions, and runtime-distribution shifts
//! for functions present in both runs.

use super::store::ProvDb;
use super::ProvQuery;
use crate::stats::RunStats;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// One function's anomaly profile within a run.
#[derive(Clone, Debug, Default)]
pub struct FuncProfile {
    pub anomalies: u64,
    pub rank0_anomalies: u64,
    /// Runtime stats over the *anomalous* executions.
    pub anom_runtime: RunStats,
    /// Runtime stats over kept normal executions (context records).
    pub normal_runtime: RunStats,
}

/// A side-by-side comparison of two runs.
#[derive(Clone, Debug)]
pub struct RunComparison {
    pub label_a: String,
    pub label_b: String,
    pub total_anomalies: (u64, u64),
    pub per_func: BTreeMap<String, (FuncProfile, FuncProfile)>,
}

fn profile_of(db: &ProvDb) -> BTreeMap<String, FuncProfile> {
    let mut out: BTreeMap<String, FuncProfile> = BTreeMap::new();
    for rec in db.query(&ProvQuery::default()) {
        let p = out.entry(rec.func.clone()).or_default();
        if rec.is_anomaly() {
            p.anomalies += 1;
            if rec.rank == 0 {
                p.rank0_anomalies += 1;
            }
            p.anom_runtime.push(rec.inclusive_us as f64);
        } else {
            p.normal_runtime.push(rec.inclusive_us as f64);
        }
    }
    out
}

/// Compare two provenance stores.
pub fn compare(label_a: &str, db_a: &ProvDb, label_b: &str, db_b: &ProvDb) -> RunComparison {
    let pa = profile_of(db_a);
    let pb = profile_of(db_b);
    let mut funcs: Vec<String> = pa.keys().chain(pb.keys()).cloned().collect();
    funcs.sort();
    funcs.dedup();
    let mut per_func = BTreeMap::new();
    for f in funcs {
        per_func.insert(
            f.clone(),
            (
                pa.get(&f).cloned().unwrap_or_default(),
                pb.get(&f).cloned().unwrap_or_default(),
            ),
        );
    }
    RunComparison {
        label_a: label_a.to_string(),
        label_b: label_b.to_string(),
        total_anomalies: (db_a.anomaly_count(), db_b.anomaly_count()),
        per_func,
    }
}

impl RunComparison {
    /// Functions whose anomaly count changed by ≥ `factor`× (either way),
    /// most-changed first — the "what regressed between configs" list.
    pub fn regressions(&self, factor: f64) -> Vec<(String, u64, u64)> {
        let mut v: Vec<(String, u64, u64, f64)> = self
            .per_func
            .iter()
            .filter_map(|(f, (a, b))| {
                let (ca, cb) = (a.anomalies, b.anomalies);
                let lo = ca.min(cb).max(1) as f64;
                let hi = ca.max(cb) as f64;
                if hi / lo >= factor && hi > 2.0 {
                    Some((f.clone(), ca, cb, hi / lo))
                } else {
                    None
                }
            })
            .collect();
        v.sort_by(|x, y| y.3.partial_cmp(&x.3).unwrap());
        v.into_iter().map(|(f, a, b, _)| (f, a, b)).collect()
    }

    /// Human-readable report.
    pub fn render(&self) -> String {
        let mut out = format!(
            "== Provenance comparison: '{}' vs '{}' ==\n   total anomalies: {} vs {}\n",
            self.label_a, self.label_b, self.total_anomalies.0, self.total_anomalies.1
        );
        out.push_str(&format!(
            "{:<16} {:>10} {:>10}   {:>12} {:>12}\n",
            "function", self.label_a, self.label_b, "anom µs (a)", "anom µs (b)"
        ));
        for (f, (a, b)) in &self.per_func {
            if a.anomalies == 0 && b.anomalies == 0 {
                continue;
            }
            out.push_str(&format!(
                "{:<16} {:>10} {:>10}   {:>12.0} {:>12.0}\n",
                f,
                a.anomalies,
                b.anomalies,
                a.anom_runtime.mean(),
                b.anom_runtime.mean()
            ));
        }
        let regs = self.regressions(2.0);
        if !regs.is_empty() {
            out.push_str("regressions (≥2× change):\n");
            for (f, a, b) in regs {
                out.push_str(&format!("   {f}: {a} → {b}\n"));
            }
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("a", Json::str(self.label_a.as_str())),
            ("b", Json::str(self.label_b.as_str())),
            (
                "total_anomalies",
                Json::arr(vec![
                    Json::num(self.total_anomalies.0 as f64),
                    Json::num(self.total_anomalies.1 as f64),
                ]),
            ),
            (
                "functions",
                Json::Arr(
                    self.per_func
                        .iter()
                        .filter(|(_, (a, b))| a.anomalies + b.anomalies > 0)
                        .map(|(f, (a, b))| {
                            Json::obj(vec![
                                ("func", Json::str(f.as_str())),
                                ("anomalies_a", Json::num(a.anomalies as f64)),
                                ("anomalies_b", Json::num(b.anomalies as f64)),
                                ("rank0_a", Json::num(a.rank0_anomalies as f64)),
                                ("rank0_b", Json::num(b.rank0_anomalies as f64)),
                                ("anom_mean_us_a", Json::num(a.anom_runtime.mean())),
                                ("anom_mean_us_b", Json::num(b.anom_runtime.mean())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ad::{ExecRecord, Label, Labeled};
    use crate::provenance::ProvRecord;

    fn mk(fid: u32, func: &str, rank: u32, dur: u64, label: Label, id: u64) -> ProvRecord {
        ProvRecord::from_labeled(
            &Labeled {
                rec: ExecRecord {
                    call_id: id,
                    app: 0,
                    rank,
                    thread: 0,
                    fid,
                    step: 0,
                    entry_ts: id * 100,
                    exit_ts: id * 100 + dur,
                    depth: 0,
                    parent: None,
                    n_children: 0,
                    n_messages: 0,
                    msg_bytes: 0,
                    exclusive_us: dur,
                },
                label,
                score: 7.0,
            },
            func,
        )
    }

    fn db(anoms_f1: u64, anoms_f2: u64) -> ProvDb {
        let mut db = ProvDb::in_memory();
        let mut id = 0;
        for _ in 0..anoms_f1 {
            id += 1;
            db.append_record(mk(1, "SP_GTXPBL", 1, 9000, Label::AnomalyHigh, id)).unwrap();
        }
        for _ in 0..anoms_f2 {
            id += 1;
            db.append_record(mk(2, "CF_CMS", 0, 2000, Label::AnomalyHigh, id)).unwrap();
        }
        id += 1;
        db.append_record(mk(1, "SP_GTXPBL", 1, 200, Label::Normal, id)).unwrap();
        db
    }

    #[test]
    fn comparison_counts_and_regressions() {
        let a = db(3, 2);
        let b = db(12, 2);
        let cmp = compare("baseline", &a, "bad-io", &b);
        assert_eq!(cmp.total_anomalies, (5, 14));
        let (pa, pb) = &cmp.per_func["SP_GTXPBL"];
        assert_eq!(pa.anomalies, 3);
        assert_eq!(pb.anomalies, 12);
        assert!(pa.anom_runtime.mean() > 1000.0);
        let regs = cmp.regressions(2.0);
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].0, "SP_GTXPBL");
        let text = cmp.render();
        assert!(text.contains("SP_GTXPBL"));
        assert!(text.contains("regressions"));
        crate::util::json::parse(&cmp.to_json().to_string()).unwrap();
    }

    #[test]
    fn rank0_attribution() {
        let a = db(1, 5);
        let cmp = compare("x", &a, "y", &a);
        let (pa, _) = &cmp.per_func["CF_CMS"];
        assert_eq!(pa.rank0_anomalies, 5);
    }

    #[test]
    fn empty_runs_compare_cleanly() {
        let a = ProvDb::in_memory();
        let b = ProvDb::in_memory();
        let cmp = compare("a", &a, "b", &b);
        assert_eq!(cmp.total_anomalies, (0, 0));
        assert!(cmp.regressions(2.0).is_empty());
    }
}
