//! Binary codec for [`ProvRecord`] — the zero-Json provenance pipeline.
//!
//! The JSONL form (see [`record`](super::record)) is the *edge* format:
//! `/api/provenance`, `metadata.json`, offline dumps. Everything between
//! the AD driver and the query reply — the provDB wire protocol, the
//! shard-resident store, and the `.provseg` segment log — carries records
//! in this length-prefixed binary layout instead, patterned on
//! [`trace::binfmt`](crate::trace::binfmt):
//!
//! ```text
//! record   := header payload
//! header   := app u32 | rank u32 | fid u32 | step u64 | entry_us u64
//!           | exit_us u64 | score f64 | label u8 | payload_len u32
//!           (49 bytes, fixed offsets)
//! payload  := call_id u64 | thread u32 | inclusive_us u64
//!           | exclusive_us u64 | depth u32 | parent (u8 tag [+ u64])
//!           | n_children u32 | n_messages u32 | msg_bytes u64
//!           | func (u32 len + UTF-8) | [label (u32 len + UTF-8) if tag 255]
//! ```
//!
//! All integers are little-endian. The header carries every field a
//! [`ProvQuery`] can filter on, so the shard query engine evaluates
//! predicates against the fixed offsets and decodes the payload only for
//! matches ([`matches_header`] — predicate pushdown). Well-known labels
//! travel as a one-byte tag; anything else rides the payload under
//! [`LABEL_OTHER`].
//!
//! On disk the segment log (`prov_app<A>_rank<R>.provseg`) is a file
//! header ([`SEG_MAGIC`] + codec version) followed by records, each
//! trailed by a CRC-32 of its bytes ([`crc32`]); [`read_segment`]
//! validates both and tolerates a torn tail write (crash mid-append).
//! Batches on the wire are version-tagged with [`CODEC_VERSION`] so the
//! layout can evolve without silent misdecodes.

use super::record::ProvRecord;
use super::store::ProvQuery;
use crate::util::wire::Cursor;
use anyhow::{bail, ensure, Context, Result};

/// Version tag carried by wire batches and segment-file headers.
pub const CODEC_VERSION: u16 = 1;

/// Fixed header size in bytes (see the module docs for the layout).
pub const HEADER_LEN: usize = 49;

/// Untrusted-input cap on a single record's payload: headers are
/// peer-/disk-supplied, so readers refuse implausible lengths before any
/// allocation (function names are registry strings, nowhere near this).
pub const MAX_PAYLOAD: usize = 1 << 20;

/// `.provseg` file magic ("CPSG").
pub const SEG_MAGIC: u32 = 0x4753_5043;

/// `.provseg` file header: magic + codec version.
pub const SEG_HEADER_LEN: usize = 6;

/// Label tags for the fixed header. [`LABEL_OTHER`] marks a label outside
/// the well-known set; its text then travels in the payload.
pub const LABEL_NORMAL: u8 = 0;
pub const LABEL_ANOMALY_HIGH: u8 = 1;
pub const LABEL_ANOMALY_LOW: u8 = 2;
pub const LABEL_OTHER: u8 = 255;

/// Record-encoding selector for the provDB log and wire: the binary
/// codec (default) or the JSONL escape hatch (`--log-format jsonl`,
/// config `provdb.log_format`).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum RecordFormat {
    Binary,
    Jsonl,
}

impl RecordFormat {
    pub fn parse(s: &str) -> Result<RecordFormat> {
        match s {
            "binary" | "bin" => Ok(RecordFormat::Binary),
            "jsonl" | "json" => Ok(RecordFormat::Jsonl),
            other => bail!("unknown record format '{other}' (binary|jsonl)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            RecordFormat::Binary => "binary",
            RecordFormat::Jsonl => "jsonl",
        }
    }
}

/// Header tag of a label string.
pub fn label_tag(label: &str) -> u8 {
    match label {
        "normal" => LABEL_NORMAL,
        "anomaly_high" => LABEL_ANOMALY_HIGH,
        "anomaly_low" => LABEL_ANOMALY_LOW,
        _ => LABEL_OTHER,
    }
}

/// Label string of a well-known tag (`None` for [`LABEL_OTHER`]/junk).
pub fn label_of_tag(tag: u8) -> Option<&'static str> {
    match tag {
        LABEL_NORMAL => Some("normal"),
        LABEL_ANOMALY_HIGH => Some("anomaly_high"),
        LABEL_ANOMALY_LOW => Some("anomaly_low"),
        _ => None,
    }
}

/// The fixed per-record header — every [`ProvQuery`]-filterable field.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RecHeader {
    pub app: u32,
    pub rank: u32,
    pub fid: u32,
    pub step: u64,
    pub entry_us: u64,
    pub exit_us: u64,
    pub score: f64,
    pub label_tag: u8,
    pub payload_len: u32,
}

impl RecHeader {
    /// Total encoded record size (header + payload).
    pub fn record_len(&self) -> usize {
        HEADER_LEN + self.payload_len as usize
    }

    /// Mirrors [`ProvRecord::is_anomaly`]: any label other than "normal".
    pub fn is_anomaly(&self) -> bool {
        self.label_tag != LABEL_NORMAL
    }
}

fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    out.extend_from_slice(&(b.len() as u32).to_le_bytes());
    out.extend_from_slice(b);
}

/// Append one encoded record to `out` (which callers reuse across
/// batches — the encode path allocates nothing beyond buffer growth).
pub fn encode(rec: &ProvRecord, out: &mut Vec<u8>) {
    let start = out.len();
    out.extend_from_slice(&rec.app.to_le_bytes());
    out.extend_from_slice(&rec.rank.to_le_bytes());
    out.extend_from_slice(&rec.fid.to_le_bytes());
    out.extend_from_slice(&rec.step.to_le_bytes());
    out.extend_from_slice(&rec.entry_us.to_le_bytes());
    out.extend_from_slice(&rec.exit_us.to_le_bytes());
    out.extend_from_slice(&rec.score.to_le_bytes());
    let tag = label_tag(&rec.label);
    out.push(tag);
    out.extend_from_slice(&[0u8; 4]); // payload_len, backpatched below
    let payload_start = out.len();
    out.extend_from_slice(&rec.call_id.to_le_bytes());
    out.extend_from_slice(&rec.thread.to_le_bytes());
    out.extend_from_slice(&rec.inclusive_us.to_le_bytes());
    out.extend_from_slice(&rec.exclusive_us.to_le_bytes());
    out.extend_from_slice(&rec.depth.to_le_bytes());
    match rec.parent {
        Some(p) => {
            out.push(1);
            out.extend_from_slice(&p.to_le_bytes());
        }
        None => out.push(0),
    }
    out.extend_from_slice(&rec.n_children.to_le_bytes());
    out.extend_from_slice(&rec.n_messages.to_le_bytes());
    out.extend_from_slice(&rec.msg_bytes.to_le_bytes());
    put_bytes(out, rec.func.as_bytes());
    if tag == LABEL_OTHER {
        put_bytes(out, rec.label.as_bytes());
    }
    let plen = (out.len() - payload_start) as u32;
    out[start + 45..start + 49].copy_from_slice(&plen.to_le_bytes());
}

/// Parse the fixed header at the start of `buf`.
pub fn read_header(buf: &[u8]) -> Result<RecHeader> {
    if buf.len() < HEADER_LEN {
        bail!("truncated record header ({} of {HEADER_LEN} bytes)", buf.len());
    }
    let u32_at = |o: usize| u32::from_le_bytes(buf[o..o + 4].try_into().unwrap());
    let u64_at = |o: usize| u64::from_le_bytes(buf[o..o + 8].try_into().unwrap());
    Ok(RecHeader {
        app: u32_at(0),
        rank: u32_at(4),
        fid: u32_at(8),
        step: u64_at(12),
        entry_us: u64_at(20),
        exit_us: u64_at(28),
        score: f64::from_le_bytes(buf[36..44].try_into().unwrap()),
        label_tag: buf[44],
        payload_len: u32_at(45),
    })
}

/// Sort-key accessors over a validated encoded record — fixed-offset
/// reads so result ordering never parses whole headers per comparison.
pub fn score_of(buf: &[u8]) -> f64 {
    f64::from_le_bytes(buf[36..44].try_into().unwrap())
}

pub fn entry_us_of(buf: &[u8]) -> u64 {
    u64::from_le_bytes(buf[20..28].try_into().unwrap())
}

pub fn label_tag_of(buf: &[u8]) -> u8 {
    buf[44]
}

/// Borrow-only view of a payload — the single parse implementation both
/// [`validate`] (no allocation, trust boundary) and [`decode`] build on,
/// so the two can never drift: anything that passes the wire check also
/// decodes.
struct RawPayload<'a> {
    call_id: u64,
    thread: u32,
    inclusive_us: u64,
    exclusive_us: u64,
    depth: u32,
    parent: Option<u64>,
    n_children: u32,
    n_messages: u32,
    msg_bytes: u64,
    func: &'a str,
    /// Set iff the header tag is [`LABEL_OTHER`].
    label: Option<&'a str>,
}

/// Parse (without allocating) the record at the start of `buf` whose
/// header is `h`, enforcing every structural rule: the payload cap and
/// bounds, parent/label tags, UTF-8 strings, the header/payload label
/// agreement (a tag-255 record whose text is a well-known label — only
/// forgeable by a hand-rolled peer, `encode()` never emits it — would
/// desync predicate pushdown and anomaly accounting from the decoded
/// record), and exact payload length.
fn parse_payload<'a>(h: &RecHeader, buf: &'a [u8]) -> Result<RawPayload<'a>> {
    ensure!(
        (h.payload_len as usize) <= MAX_PAYLOAD,
        "implausible record payload length {}",
        h.payload_len
    );
    ensure!(
        buf.len() >= h.record_len(),
        "truncated record payload ({} of {} bytes)",
        buf.len() - HEADER_LEN,
        h.payload_len
    );
    let mut c = Cursor::new(&buf[HEADER_LEN..h.record_len()]);
    let call_id = c.u64()?;
    let thread = c.u32()?;
    let inclusive_us = c.u64()?;
    let exclusive_us = c.u64()?;
    let depth = c.u32()?;
    let parent = match c.u8()? {
        0 => None,
        1 => Some(c.u64()?),
        t => bail!("bad parent tag {t}"),
    };
    let n_children = c.u32()?;
    let n_messages = c.u32()?;
    let msg_bytes = c.u64()?;
    let func = std::str::from_utf8(c.bytes()?).context("non-UTF-8 function name")?;
    let label = match h.label_tag {
        LABEL_NORMAL | LABEL_ANOMALY_HIGH | LABEL_ANOMALY_LOW => None,
        LABEL_OTHER => {
            let text = std::str::from_utf8(c.bytes()?).context("non-UTF-8 label")?;
            ensure!(
                label_tag(text) == LABEL_OTHER,
                "label tag 255 with well-known label text '{text}'"
            );
            Some(text)
        }
        t => bail!("bad label tag {t}"),
    };
    ensure!(c.remaining() == 0, "trailing bytes in record payload");
    Ok(RawPayload {
        call_id,
        thread,
        inclusive_us,
        exclusive_us,
        depth,
        parent,
        n_children,
        n_messages,
        msg_bytes,
        func,
        label,
    })
}

/// Structurally validate one encoded record at the start of `buf`
/// (bounds, payload cap, parent/label tags, UTF-8 — no allocation).
/// Returns the record's total length. This is the trust boundary check
/// for wire frames and segment files.
pub fn validate(buf: &[u8]) -> Result<usize> {
    let h = read_header(buf)?;
    parse_payload(&h, buf)?;
    Ok(h.record_len())
}

/// Decode one record from the start of `buf`; returns it with the number
/// of bytes consumed (records are self-delimiting via `payload_len`).
pub fn decode(buf: &[u8]) -> Result<(ProvRecord, usize)> {
    let h = read_header(buf)?;
    let p = parse_payload(&h, buf)?;
    let label = match p.label {
        Some(text) => text.to_string(),
        None => label_of_tag(h.label_tag)
            .expect("parse_payload admits only well-known tags here")
            .to_string(),
    };
    Ok((
        ProvRecord {
            call_id: p.call_id,
            app: h.app,
            rank: h.rank,
            thread: p.thread,
            fid: h.fid,
            func: p.func.to_string(),
            step: h.step,
            entry_us: h.entry_us,
            exit_us: h.exit_us,
            inclusive_us: p.inclusive_us,
            exclusive_us: p.exclusive_us,
            depth: p.depth,
            parent: p.parent,
            n_children: p.n_children,
            n_messages: p.n_messages,
            msg_bytes: p.msg_bytes,
            label,
            score: h.score,
        },
        h.record_len(),
    ))
}

/// Evaluate every [`ProvQuery`] filter against the fixed header alone.
/// `Some(v)` is the exact [`ProvQuery::matches`] verdict; `None` means
/// the header cannot decide (both the query's label filter and the
/// record's label are outside the well-known set). Every other filter
/// has passed by then, so the caller settles it by comparing the label
/// bytes at their fixed payload offset —
/// [`probe::vm::label_eq`](crate::probe::vm::label_eq) — without
/// decoding the record.
pub fn matches_header(q: &ProvQuery, h: &RecHeader) -> Option<bool> {
    if let Some(a) = q.app {
        if h.app != a {
            return Some(false);
        }
    }
    if let Some((a, r)) = q.rank {
        if h.app != a || h.rank != r {
            return Some(false);
        }
    }
    if let Some((a, f)) = q.fid {
        if h.app != a || h.fid != f {
            return Some(false);
        }
    }
    if let Some(s) = q.step {
        if h.step != s {
            return Some(false);
        }
    }
    if let Some((lo, hi)) = q.step_range {
        if h.step < lo || h.step > hi {
            return Some(false);
        }
    }
    if q.anomalies_only && !h.is_anomaly() {
        return Some(false);
    }
    if let Some(m) = q.min_score {
        // Exactly `score >= m` (NaN compares false, matching matches()).
        match h.score.partial_cmp(&m) {
            Some(std::cmp::Ordering::Less) | None => return Some(false),
            _ => {}
        }
    }
    if let Some((lo, hi)) = q.ts_range {
        if h.exit_us < lo || h.entry_us > hi {
            return Some(false);
        }
    }
    if let Some(l) = &q.label {
        let want = label_tag(l);
        if want != LABEL_OTHER {
            // Known query label: the record matches iff its tag matches
            // (a LABEL_OTHER record's text is by construction outside
            // the well-known set, so it cannot equal `l`).
            if h.label_tag != want {
                return Some(false);
            }
        } else if h.label_tag != LABEL_OTHER {
            // Custom query label vs a well-known record label: no match.
            return Some(false);
        } else {
            // Both custom: only the payload's label text can decide.
            return None;
        }
    }
    Some(true)
}

/// CRC-32 (IEEE 802.3) over `bytes` — the per-record trailer in
/// `.provseg` segment files.
pub fn crc32(bytes: &[u8]) -> u32 {
    use std::sync::OnceLock;
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        t
    });
    let mut crc = !0u32;
    for &b in bytes {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    !crc
}

/// The 6-byte `.provseg` file header.
pub fn seg_file_header() -> [u8; SEG_HEADER_LEN] {
    let mut h = [0u8; SEG_HEADER_LEN];
    h[..4].copy_from_slice(&SEG_MAGIC.to_le_bytes());
    h[4..].copy_from_slice(&CODEC_VERSION.to_le_bytes());
    h
}

/// One `.provseg` file scan: validated encoded records, plus what (if
/// anything) stopped the scan early — a torn tail (crash mid-append
/// leaves a partial record; everything before it is kept) or detected
/// corruption (CRC/structure failure; the scan keeps the records before
/// it rather than failing recovery wholesale).
pub struct SegmentScan {
    pub records: Vec<Vec<u8>>,
    /// Unparsed trailing bytes (torn tail write or corruption point on).
    pub torn_bytes: usize,
    /// Why the scan stopped before EOF, when it wasn't a clean tail cut.
    pub corrupt: Option<String>,
}

/// Parse a whole `.provseg` file image. Bad magic/version is a hard
/// error (not our file); anything wrong *inside* the record stream stops
/// the scan and is reported via [`SegmentScan::corrupt`] so restart
/// recovery degrades to a logged warning instead of refusing to start.
pub fn read_segment(buf: &[u8]) -> Result<SegmentScan> {
    if buf.len() < SEG_HEADER_LEN {
        // A crash between file creation and the first header flush
        // leaves a short/empty file — a torn tail, not foreign data.
        return Ok(SegmentScan { records: Vec::new(), torn_bytes: buf.len(), corrupt: None });
    }
    let magic = u32::from_le_bytes(buf[..4].try_into().unwrap());
    ensure!(magic == SEG_MAGIC, "bad segment magic {magic:#010x}");
    let version = u16::from_le_bytes(buf[4..6].try_into().unwrap());
    ensure!(version == CODEC_VERSION, "unsupported segment codec version {version}");
    let mut pos = SEG_HEADER_LEN;
    let mut records = Vec::new();
    let mut corrupt = None;
    while pos < buf.len() {
        let rest = &buf[pos..];
        if rest.len() < HEADER_LEN {
            break; // torn tail
        }
        let h = match read_header(rest) {
            Ok(h) => h,
            Err(e) => {
                corrupt = Some(format!("bad record header at byte {pos}: {e}"));
                break;
            }
        };
        if h.payload_len as usize > MAX_PAYLOAD {
            corrupt = Some(format!(
                "implausible record payload length {} at byte {pos}",
                h.payload_len
            ));
            break;
        }
        let total = h.record_len() + 4;
        if rest.len() < total {
            break; // torn tail
        }
        let rec = &rest[..h.record_len()];
        let want = u32::from_le_bytes(rest[h.record_len()..total].try_into().unwrap());
        if crc32(rec) != want {
            corrupt = Some(format!("CRC mismatch at byte {pos}"));
            break;
        }
        if let Err(e) = validate(rec) {
            corrupt = Some(format!("invalid record at byte {pos}: {e}"));
            break;
        }
        records.push(rec.to_vec());
        pos += total;
    }
    Ok(SegmentScan { records, torn_bytes: buf.len() - pos, corrupt })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(label: &str, score: f64) -> ProvRecord {
        ProvRecord {
            call_id: 42,
            app: 1,
            rank: 3,
            thread: 2,
            fid: 7,
            func: "MD_NEWTON_λ \"x\"".to_string(),
            step: 9,
            entry_us: 1000,
            exit_us: 1500,
            inclusive_us: 500,
            exclusive_us: 300,
            depth: 2,
            parent: Some(41),
            n_children: 1,
            n_messages: 2,
            msg_bytes: 4096,
            label: label.to_string(),
            score,
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        for (label, score) in [
            ("anomaly_high", 7.5),
            ("normal", 0.0),
            ("anomaly_low", -2.25),
            ("custom_label", 1e-12),
        ] {
            let mut r = rec(label, score);
            if score == 0.0 {
                r.parent = None;
                r.func = String::new(); // empty call stacks
            }
            let mut buf = Vec::new();
            encode(&r, &mut buf);
            assert_eq!(validate(&buf).unwrap(), buf.len());
            let (back, used) = decode(&buf).unwrap();
            assert_eq!(used, buf.len());
            assert_eq!(back, r);
            let h = read_header(&buf).unwrap();
            assert_eq!(h.app, r.app);
            assert_eq!(h.step, r.step);
            assert_eq!(h.score, r.score);
            assert_eq!(h.is_anomaly(), r.is_anomaly());
            assert_eq!(score_of(&buf), r.score);
            assert_eq!(entry_us_of(&buf), r.entry_us);
            assert_eq!(label_tag_of(&buf), label_tag(&r.label));
        }
    }

    #[test]
    fn self_delimiting_in_a_batch() {
        let a = rec("normal", 1.0);
        let b = rec("anomaly_high", 9.0);
        let mut buf = Vec::new();
        encode(&a, &mut buf);
        let split = buf.len();
        encode(&b, &mut buf);
        let (ra, ua) = decode(&buf).unwrap();
        assert_eq!(ua, split);
        let (rb, ub) = decode(&buf[ua..]).unwrap();
        assert_eq!(ua + ub, buf.len());
        assert_eq!(ra, a);
        assert_eq!(rb, b);
    }

    #[test]
    fn truncation_and_corruption_rejected() {
        let mut buf = Vec::new();
        encode(&rec("normal", 1.0), &mut buf);
        assert!(decode(&buf[..HEADER_LEN - 1]).is_err());
        assert!(decode(&buf[..buf.len() - 1]).is_err());
        assert!(validate(&buf[..buf.len() - 1]).is_err());
        // A lying payload length is refused before any allocation.
        let mut lying = buf.clone();
        lying[45..49].copy_from_slice(&(MAX_PAYLOAD as u32 + 1).to_le_bytes());
        assert!(validate(&lying).is_err());
        assert!(decode(&lying).is_err());
        // Bad label tag.
        let mut bad_tag = buf.clone();
        bad_tag[44] = 7;
        assert!(validate(&bad_tag).is_err());
    }

    #[test]
    fn forged_other_tag_with_well_known_label_rejected() {
        // Hand-roll what encode() never produces: tag 255 whose payload
        // label text is a well-known label. The header would claim
        // anomaly while the payload says "normal" — refused outright.
        let mut r = rec("placeholder_custom", 1.0);
        r.label = "zzz".to_string(); // custom → tag 255, label in payload
        let mut buf = Vec::new();
        encode(&r, &mut buf);
        // Patch the payload label text "zzz" → "normal" (adjusting the
        // length prefix that precedes it).
        let zzz = buf.len() - 3;
        buf.truncate(zzz - 4);
        put_bytes(&mut buf, b"normal");
        let plen = (buf.len() - HEADER_LEN) as u32;
        buf[45..49].copy_from_slice(&plen.to_le_bytes());
        assert_eq!(label_tag_of(&buf), LABEL_OTHER);
        assert!(validate(&buf).is_err());
        assert!(decode(&buf).is_err());
    }

    #[test]
    fn segment_roundtrip_with_crc_and_torn_tail() {
        let recs: Vec<ProvRecord> = (0..5)
            .map(|i| rec(if i % 2 == 0 { "normal" } else { "anomaly_low" }, i as f64))
            .collect();
        let mut file: Vec<u8> = seg_file_header().to_vec();
        let mut encoded = Vec::new();
        for r in &recs {
            let start = encoded.len();
            encode(r, &mut encoded);
            let one = &encoded[start..];
            file.extend_from_slice(one);
            file.extend_from_slice(&crc32(one).to_le_bytes());
        }
        let scan = read_segment(&file).unwrap();
        assert_eq!(scan.records.len(), 5);
        assert_eq!(scan.torn_bytes, 0);
        assert!(scan.corrupt.is_none());
        for (b, want) in scan.records.iter().zip(&recs) {
            assert_eq!(&decode(b).unwrap().0, want);
        }
        // Torn tail: drop the last 3 bytes — earlier records survive.
        let scan = read_segment(&file[..file.len() - 3]).unwrap();
        assert_eq!(scan.records.len(), 4);
        assert!(scan.torn_bytes > 0);
        assert!(scan.corrupt.is_none(), "a clean tail cut is not corruption");
        // Flipped byte inside a record: CRC stops the scan there, keeping
        // the records before it (recovery degrades, it doesn't die).
        let mut corrupt = file.clone();
        corrupt[SEG_HEADER_LEN + 20] ^= 0xFF;
        let scan = read_segment(&corrupt).unwrap();
        assert_eq!(scan.records.len(), 0);
        assert!(scan.corrupt.is_some());
        // A short/empty file (crash before the header flushed) is a torn
        // tail, not an error — restart recovery must keep going.
        let scan = read_segment(&[]).unwrap();
        assert!(scan.records.is_empty() && scan.torn_bytes == 0 && scan.corrupt.is_none());
        let scan = read_segment(&file[..3]).unwrap();
        assert!(scan.records.is_empty() && scan.torn_bytes == 3);
        // Wrong magic/version is a hard error (not our file).
        let mut bad = file.clone();
        bad[0] ^= 0xFF;
        assert!(read_segment(&bad).is_err());
        let mut badv = file;
        badv[4] = 0xEE;
        assert!(read_segment(&badv).is_err());
    }

    #[test]
    fn crc32_known_vector() {
        // IEEE CRC-32 of "123456789".
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn header_predicates_match_full_matches() {
        let r = rec("anomaly_high", 7.5);
        let mut buf = Vec::new();
        encode(&r, &mut buf);
        let h = read_header(&buf).unwrap();
        let qs = [
            ProvQuery::default(),
            ProvQuery { app: Some(1), ..Default::default() },
            ProvQuery { app: Some(2), ..Default::default() },
            ProvQuery { rank: Some((1, 3)), step: Some(9), ..Default::default() },
            ProvQuery { rank: Some((1, 4)), ..Default::default() },
            ProvQuery { fid: Some((1, 7)), ..Default::default() },
            ProvQuery { step_range: Some((8, 10)), ..Default::default() },
            ProvQuery { step_range: Some((10, 11)), ..Default::default() },
            ProvQuery { ts_range: Some((1400, 1600)), ..Default::default() },
            ProvQuery { ts_range: Some((1501, 1600)), ..Default::default() },
            ProvQuery { anomalies_only: true, ..Default::default() },
            ProvQuery { min_score: Some(7.5), ..Default::default() },
            ProvQuery { min_score: Some(7.6), ..Default::default() },
            ProvQuery { label: Some("anomaly_high".into()), ..Default::default() },
            ProvQuery { label: Some("normal".into()), ..Default::default() },
            ProvQuery { label: Some("weird".into()), ..Default::default() },
        ];
        for q in &qs {
            assert_eq!(
                matches_header(q, &h).expect("known-label record is always decidable"),
                q.matches(&r),
                "query {q:?}"
            );
        }
        // A custom-label record vs a custom query label is undecidable
        // from the header; everything else still decides.
        let custom = rec("weird", 1.0);
        let mut cbuf = Vec::new();
        encode(&custom, &mut cbuf);
        let ch = read_header(&cbuf).unwrap();
        assert_eq!(
            matches_header(
                &ProvQuery { label: Some("weird".into()), ..Default::default() },
                &ch
            ),
            None
        );
        assert_eq!(
            matches_header(
                &ProvQuery { label: Some("normal".into()), ..Default::default() },
                &ch
            ),
            Some(false)
        );
        // Custom labels are anomalies (label != "normal").
        assert_eq!(
            matches_header(&ProvQuery { anomalies_only: true, ..Default::default() }, &ch),
            Some(true)
        );
    }
}
