//! Binary codec for [`ProvRecord`] — the zero-Json provenance pipeline.
//!
//! The JSONL form (see [`record`](super::record)) is the *edge* format:
//! `/api/provenance`, `metadata.json`, offline dumps. Everything between
//! the AD driver and the query reply — the provDB wire protocol, the
//! shard-resident store, and the `.provseg` segment log — carries records
//! in this length-prefixed binary layout instead, patterned on
//! [`trace::binfmt`](crate::trace::binfmt):
//!
//! ```text
//! record   := header payload
//! header   := app u32 | rank u32 | fid u32 | step u64 | entry_us u64
//!           | exit_us u64 | score f64 | label u8 | payload_len u32
//!           (49 bytes, fixed offsets)
//! payload  := call_id u64 | thread u32 | inclusive_us u64
//!           | exclusive_us u64 | depth u32 | parent (u8 tag [+ u64])
//!           | n_children u32 | n_messages u32 | msg_bytes u64
//!           | func (u32 len + UTF-8) | [label (u32 len + UTF-8) if tag 255]
//! ```
//!
//! All integers are little-endian. The header carries every field a
//! [`ProvQuery`] can filter on, so the shard query engine evaluates
//! predicates against the fixed offsets and decodes the payload only for
//! matches ([`matches_header`] — predicate pushdown). Well-known labels
//! travel as a one-byte tag; anything else rides the payload under
//! [`LABEL_OTHER`].
//!
//! On disk the segment log (`prov_app<A>_rank<R>.provseg`) is a file
//! header ([`SEG_MAGIC`] + codec version) followed by records, each
//! trailed by a CRC-32 of its bytes ([`crc32`]); [`read_segment`]
//! validates both and tolerates a torn tail write (crash mid-append).
//! Batches on the wire are version-tagged with [`CODEC_VERSION`] so the
//! layout can evolve without silent misdecodes.

use super::record::ProvRecord;
use super::store::ProvQuery;
use crate::util::wire::Cursor;
use anyhow::{bail, ensure, Context, Result};

/// Version tag carried by wire batches and segment-file headers.
pub const CODEC_VERSION: u16 = 1;

/// Fixed header size in bytes (see the module docs for the layout).
pub const HEADER_LEN: usize = 49;

/// Untrusted-input cap on a single record's payload: headers are
/// peer-/disk-supplied, so readers refuse implausible lengths before any
/// allocation (function names are registry strings, nowhere near this).
pub const MAX_PAYLOAD: usize = 1 << 20;

/// `.provseg` file magic ("CPSG").
pub const SEG_MAGIC: u32 = 0x4753_5043;

/// `.provseg` file header: magic + codec version.
pub const SEG_HEADER_LEN: usize = 6;

/// Label tags for the fixed header. [`LABEL_OTHER`] marks a label outside
/// the well-known set; its text then travels in the payload.
pub const LABEL_NORMAL: u8 = 0;
pub const LABEL_ANOMALY_HIGH: u8 = 1;
pub const LABEL_ANOMALY_LOW: u8 = 2;
pub const LABEL_OTHER: u8 = 255;

/// Record-encoding selector for the provDB log and wire: the binary
/// codec (default) or the JSONL escape hatch (`--log-format jsonl`,
/// config `provdb.log_format`).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum RecordFormat {
    Binary,
    Jsonl,
}

impl RecordFormat {
    pub fn parse(s: &str) -> Result<RecordFormat> {
        match s {
            "binary" | "bin" => Ok(RecordFormat::Binary),
            "jsonl" | "json" => Ok(RecordFormat::Jsonl),
            other => bail!("unknown record format '{other}' (binary|jsonl)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            RecordFormat::Binary => "binary",
            RecordFormat::Jsonl => "jsonl",
        }
    }
}

/// Header tag of a label string.
pub fn label_tag(label: &str) -> u8 {
    match label {
        "normal" => LABEL_NORMAL,
        "anomaly_high" => LABEL_ANOMALY_HIGH,
        "anomaly_low" => LABEL_ANOMALY_LOW,
        _ => LABEL_OTHER,
    }
}

/// Label string of a well-known tag (`None` for [`LABEL_OTHER`]/junk).
pub fn label_of_tag(tag: u8) -> Option<&'static str> {
    match tag {
        LABEL_NORMAL => Some("normal"),
        LABEL_ANOMALY_HIGH => Some("anomaly_high"),
        LABEL_ANOMALY_LOW => Some("anomaly_low"),
        _ => None,
    }
}

/// The fixed per-record header — every [`ProvQuery`]-filterable field.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RecHeader {
    pub app: u32,
    pub rank: u32,
    pub fid: u32,
    pub step: u64,
    pub entry_us: u64,
    pub exit_us: u64,
    pub score: f64,
    pub label_tag: u8,
    pub payload_len: u32,
}

impl RecHeader {
    /// Total encoded record size (header + payload).
    pub fn record_len(&self) -> usize {
        HEADER_LEN + self.payload_len as usize
    }

    /// Mirrors [`ProvRecord::is_anomaly`]: any label other than "normal".
    pub fn is_anomaly(&self) -> bool {
        self.label_tag != LABEL_NORMAL
    }
}

fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    out.extend_from_slice(&(b.len() as u32).to_le_bytes());
    out.extend_from_slice(b);
}

/// Append one encoded record to `out` (which callers reuse across
/// batches — the encode path allocates nothing beyond buffer growth).
pub fn encode(rec: &ProvRecord, out: &mut Vec<u8>) {
    let start = out.len();
    out.extend_from_slice(&rec.app.to_le_bytes());
    out.extend_from_slice(&rec.rank.to_le_bytes());
    out.extend_from_slice(&rec.fid.to_le_bytes());
    out.extend_from_slice(&rec.step.to_le_bytes());
    out.extend_from_slice(&rec.entry_us.to_le_bytes());
    out.extend_from_slice(&rec.exit_us.to_le_bytes());
    out.extend_from_slice(&rec.score.to_le_bytes());
    let tag = label_tag(&rec.label);
    out.push(tag);
    out.extend_from_slice(&[0u8; 4]); // payload_len, backpatched below
    let payload_start = out.len();
    out.extend_from_slice(&rec.call_id.to_le_bytes());
    out.extend_from_slice(&rec.thread.to_le_bytes());
    out.extend_from_slice(&rec.inclusive_us.to_le_bytes());
    out.extend_from_slice(&rec.exclusive_us.to_le_bytes());
    out.extend_from_slice(&rec.depth.to_le_bytes());
    match rec.parent {
        Some(p) => {
            out.push(1);
            out.extend_from_slice(&p.to_le_bytes());
        }
        None => out.push(0),
    }
    out.extend_from_slice(&rec.n_children.to_le_bytes());
    out.extend_from_slice(&rec.n_messages.to_le_bytes());
    out.extend_from_slice(&rec.msg_bytes.to_le_bytes());
    put_bytes(out, rec.func.as_bytes());
    if tag == LABEL_OTHER {
        put_bytes(out, rec.label.as_bytes());
    }
    let plen = (out.len() - payload_start) as u32;
    out[start + 45..start + 49].copy_from_slice(&plen.to_le_bytes());
}

/// Parse the fixed header at the start of `buf`.
pub fn read_header(buf: &[u8]) -> Result<RecHeader> {
    if buf.len() < HEADER_LEN {
        bail!("truncated record header ({} of {HEADER_LEN} bytes)", buf.len());
    }
    let u32_at = |o: usize| u32::from_le_bytes(buf[o..o + 4].try_into().unwrap());
    let u64_at = |o: usize| u64::from_le_bytes(buf[o..o + 8].try_into().unwrap());
    Ok(RecHeader {
        app: u32_at(0),
        rank: u32_at(4),
        fid: u32_at(8),
        step: u64_at(12),
        entry_us: u64_at(20),
        exit_us: u64_at(28),
        score: f64::from_le_bytes(buf[36..44].try_into().unwrap()),
        label_tag: buf[44],
        payload_len: u32_at(45),
    })
}

/// Sort-key accessors over a validated encoded record — fixed-offset
/// reads so result ordering never parses whole headers per comparison.
pub fn score_of(buf: &[u8]) -> f64 {
    f64::from_le_bytes(buf[36..44].try_into().unwrap())
}

pub fn entry_us_of(buf: &[u8]) -> u64 {
    u64::from_le_bytes(buf[20..28].try_into().unwrap())
}

pub fn label_tag_of(buf: &[u8]) -> u8 {
    buf[44]
}

/// Borrow-only view of a payload — the single parse implementation both
/// [`validate`] (no allocation, trust boundary) and [`decode`] build on,
/// so the two can never drift: anything that passes the wire check also
/// decodes.
struct RawPayload<'a> {
    call_id: u64,
    thread: u32,
    inclusive_us: u64,
    exclusive_us: u64,
    depth: u32,
    parent: Option<u64>,
    n_children: u32,
    n_messages: u32,
    msg_bytes: u64,
    func: &'a str,
    /// Set iff the header tag is [`LABEL_OTHER`].
    label: Option<&'a str>,
}

/// Parse (without allocating) the record at the start of `buf` whose
/// header is `h`, enforcing every structural rule: the payload cap and
/// bounds, parent/label tags, UTF-8 strings, the header/payload label
/// agreement (a tag-255 record whose text is a well-known label — only
/// forgeable by a hand-rolled peer, `encode()` never emits it — would
/// desync predicate pushdown and anomaly accounting from the decoded
/// record), and exact payload length.
fn parse_payload<'a>(h: &RecHeader, buf: &'a [u8]) -> Result<RawPayload<'a>> {
    ensure!(
        (h.payload_len as usize) <= MAX_PAYLOAD,
        "implausible record payload length {}",
        h.payload_len
    );
    ensure!(
        buf.len() >= h.record_len(),
        "truncated record payload ({} of {} bytes)",
        buf.len() - HEADER_LEN,
        h.payload_len
    );
    let mut c = Cursor::new(&buf[HEADER_LEN..h.record_len()]);
    let call_id = c.u64()?;
    let thread = c.u32()?;
    let inclusive_us = c.u64()?;
    let exclusive_us = c.u64()?;
    let depth = c.u32()?;
    let parent = match c.u8()? {
        0 => None,
        1 => Some(c.u64()?),
        t => bail!("bad parent tag {t}"),
    };
    let n_children = c.u32()?;
    let n_messages = c.u32()?;
    let msg_bytes = c.u64()?;
    let func = std::str::from_utf8(c.bytes()?).context("non-UTF-8 function name")?;
    let label = match h.label_tag {
        LABEL_NORMAL | LABEL_ANOMALY_HIGH | LABEL_ANOMALY_LOW => None,
        LABEL_OTHER => {
            let text = std::str::from_utf8(c.bytes()?).context("non-UTF-8 label")?;
            ensure!(
                label_tag(text) == LABEL_OTHER,
                "label tag 255 with well-known label text '{text}'"
            );
            Some(text)
        }
        t => bail!("bad label tag {t}"),
    };
    ensure!(c.remaining() == 0, "trailing bytes in record payload");
    Ok(RawPayload {
        call_id,
        thread,
        inclusive_us,
        exclusive_us,
        depth,
        parent,
        n_children,
        n_messages,
        msg_bytes,
        func,
        label,
    })
}

/// Structurally validate one encoded record at the start of `buf`
/// (bounds, payload cap, parent/label tags, UTF-8 — no allocation).
/// Returns the record's total length. This is the trust boundary check
/// for wire frames and segment files.
pub fn validate(buf: &[u8]) -> Result<usize> {
    let h = read_header(buf)?;
    parse_payload(&h, buf)?;
    Ok(h.record_len())
}

/// Decode one record from the start of `buf`; returns it with the number
/// of bytes consumed (records are self-delimiting via `payload_len`).
pub fn decode(buf: &[u8]) -> Result<(ProvRecord, usize)> {
    let h = read_header(buf)?;
    let p = parse_payload(&h, buf)?;
    let label = match p.label {
        Some(text) => text.to_string(),
        None => label_of_tag(h.label_tag)
            .expect("parse_payload admits only well-known tags here")
            .to_string(),
    };
    Ok((
        ProvRecord {
            call_id: p.call_id,
            app: h.app,
            rank: h.rank,
            thread: p.thread,
            fid: h.fid,
            func: p.func.to_string(),
            step: h.step,
            entry_us: h.entry_us,
            exit_us: h.exit_us,
            inclusive_us: p.inclusive_us,
            exclusive_us: p.exclusive_us,
            depth: p.depth,
            parent: p.parent,
            n_children: p.n_children,
            n_messages: p.n_messages,
            msg_bytes: p.msg_bytes,
            label,
            score: h.score,
        },
        h.record_len(),
    ))
}

/// Evaluate every [`ProvQuery`] filter against the fixed header alone.
/// `Some(v)` is the exact [`ProvQuery::matches`] verdict; `None` means
/// the header cannot decide (both the query's label filter and the
/// record's label are outside the well-known set). Every other filter
/// has passed by then, so the caller settles it by comparing the label
/// bytes at their fixed payload offset —
/// [`probe::vm::label_eq`](crate::probe::vm::label_eq) — without
/// decoding the record.
pub fn matches_header(q: &ProvQuery, h: &RecHeader) -> Option<bool> {
    if let Some(a) = q.app {
        if h.app != a {
            return Some(false);
        }
    }
    if let Some((a, r)) = q.rank {
        if h.app != a || h.rank != r {
            return Some(false);
        }
    }
    if let Some((a, f)) = q.fid {
        if h.app != a || h.fid != f {
            return Some(false);
        }
    }
    if let Some(s) = q.step {
        if h.step != s {
            return Some(false);
        }
    }
    if let Some((lo, hi)) = q.step_range {
        if h.step < lo || h.step > hi {
            return Some(false);
        }
    }
    if q.anomalies_only && !h.is_anomaly() {
        return Some(false);
    }
    if let Some(m) = q.min_score {
        // Exactly `score >= m` (NaN compares false, matching matches()).
        match h.score.partial_cmp(&m) {
            Some(std::cmp::Ordering::Less) | None => return Some(false),
            _ => {}
        }
    }
    if let Some((lo, hi)) = q.ts_range {
        if h.exit_us < lo || h.entry_us > hi {
            return Some(false);
        }
    }
    if let Some(l) = &q.label {
        let want = label_tag(l);
        if want != LABEL_OTHER {
            // Known query label: the record matches iff its tag matches
            // (a LABEL_OTHER record's text is by construction outside
            // the well-known set, so it cannot equal `l`).
            if h.label_tag != want {
                return Some(false);
            }
        } else if h.label_tag != LABEL_OTHER {
            // Custom query label vs a well-known record label: no match.
            return Some(false);
        } else {
            // Both custom: only the payload's label text can decide.
            return None;
        }
    }
    Some(true)
}

/// CRC-32 (IEEE 802.3) over `bytes` — the per-record trailer in
/// `.provseg` segment files.
pub fn crc32(bytes: &[u8]) -> u32 {
    use std::sync::OnceLock;
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        t
    });
    let mut crc = !0u32;
    for &b in bytes {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    !crc
}

/// The 6-byte `.provseg` file header.
pub fn seg_file_header() -> [u8; SEG_HEADER_LEN] {
    let mut h = [0u8; SEG_HEADER_LEN];
    h[..4].copy_from_slice(&SEG_MAGIC.to_le_bytes());
    h[4..].copy_from_slice(&CODEC_VERSION.to_le_bytes());
    h
}

/// One `.provseg` file scan: validated encoded records, plus what (if
/// anything) stopped the scan early — a torn tail (crash mid-append
/// leaves a partial record; everything before it is kept) or detected
/// corruption (CRC/structure failure; the scan keeps the records before
/// it rather than failing recovery wholesale).
pub struct SegmentScan {
    pub records: Vec<Vec<u8>>,
    /// Unparsed trailing bytes (torn tail write or corruption point on).
    pub torn_bytes: usize,
    /// Why the scan stopped before EOF, when it wasn't a clean tail cut.
    pub corrupt: Option<String>,
}

/// Parse a whole `.provseg` file image. Bad magic/version is a hard
/// error (not our file); anything wrong *inside* the record stream stops
/// the scan and is reported via [`SegmentScan::corrupt`] so restart
/// recovery degrades to a logged warning instead of refusing to start.
pub fn read_segment(buf: &[u8]) -> Result<SegmentScan> {
    if buf.len() < SEG_HEADER_LEN {
        // A crash between file creation and the first header flush
        // leaves a short/empty file — a torn tail, not foreign data.
        return Ok(SegmentScan { records: Vec::new(), torn_bytes: buf.len(), corrupt: None });
    }
    let magic = u32::from_le_bytes(buf[..4].try_into().unwrap());
    ensure!(magic == SEG_MAGIC, "bad segment magic {magic:#010x}");
    let version = u16::from_le_bytes(buf[4..6].try_into().unwrap());
    ensure!(version == CODEC_VERSION, "unsupported segment codec version {version}");
    let mut pos = SEG_HEADER_LEN;
    let mut records = Vec::new();
    let mut corrupt = None;
    while pos < buf.len() {
        let rest = &buf[pos..];
        if rest.len() < HEADER_LEN {
            break; // torn tail
        }
        let h = match read_header(rest) {
            Ok(h) => h,
            Err(e) => {
                corrupt = Some(format!("bad record header at byte {pos}: {e}"));
                break;
            }
        };
        if h.payload_len as usize > MAX_PAYLOAD {
            corrupt = Some(format!(
                "implausible record payload length {} at byte {pos}",
                h.payload_len
            ));
            break;
        }
        let total = h.record_len() + 4;
        if rest.len() < total {
            break; // torn tail
        }
        let rec = &rest[..h.record_len()];
        let want = u32::from_le_bytes(rest[h.record_len()..total].try_into().unwrap());
        if crc32(rec) != want {
            corrupt = Some(format!("CRC mismatch at byte {pos}"));
            break;
        }
        if let Err(e) = validate(rec) {
            corrupt = Some(format!("invalid record at byte {pos}: {e}"));
            break;
        }
        records.push(rec.to_vec());
        pos += total;
    }
    Ok(SegmentScan { records, torn_bytes: buf.len() - pos, corrupt })
}

// ---------------------------------------------------------------------------
// Segment format v2 — sealed, columnar, zone-mapped.
//
// A *sealed* v2 segment rewrites a bounded run of records column-major
// with delta+varint packing and a fixed-size footer at the file tail:
//
// ```text
// file   := SEG_MAGIC u32 | version u16 (=2) | body | crc32(body) u32
//         | footer | crc32(footer) u32 | footer_len u32 | SEG2_FOOTER_MAGIC u32
// body   := n u32 | seq0 u64
//         | step    n × uvarint(zigzag(Δ))        (delta from previous, prev=0)
//         | entry   n × uvarint(zigzag(Δ))        (prev=0)
//         | dur     n × uvarint(zigzag(exit⊖entry)) (per record)
//         | fid,rank,app                          (3 × n uvarint)
//         | seq     n × uvarint(zigzag(Δ))        (prev=seq0; first is 0)
//         | score   n × f64 | label n × u8
//         | call_id n × uvarint(zigzag(Δ))        (prev=0)
//         | thread,inclusive,exclusive,depth      (4 × n uvarint)
//         | parent_bits ⌈n/8⌉ bytes | parent one uvarint(zigzag(p⊖call_id)) per set bit
//         | n_children,n_messages,msg_bytes       (3 × n uvarint)
//         | dict n_strings u32, then (uvarint len + UTF-8) × n_strings
//         | func_idx n × uvarint | label_idx one uvarint per LABEL_OTHER record
// footer := zone map (89 bytes) | n_records u32 | n_anomalies u32 | body_len u64
// ```
//
// The footer is readable from the file tail alone ([`read_seg2_footer_file`]),
// so recovery registers a sealed segment without touching its body, and the
// query engine consults the zone map ([`ZoneMap::may_match`]) to skip whole
// segments before decoding a single record. [`read_segment_v2`] recovers the
// longest decodable record prefix from a torn file (footer lost / body cut);
// a valid footer whose body CRC fails is reported as corruption with no
// records salvaged (column packing cannot localize a flip the way v1's
// per-record CRC can — callers sideline the original bytes instead).
// ---------------------------------------------------------------------------

/// Version tag of sealed columnar segments.
pub const CODEC_VERSION_V2: u16 = 2;

/// Trailing magic of a sealed v2 segment ("CPZ2").
pub const SEG2_FOOTER_MAGIC: u32 = 0x325A_5043;

/// Fixed footer size (zone map + counts + body length).
pub const SEG2_FOOTER_LEN: usize = 105;

/// Footer + its CRC + footer_len + trailing magic.
pub const SEG2_TAIL_LEN: usize = SEG2_FOOTER_LEN + 12;

/// The 6-byte file header of a sealed v2 segment.
pub fn seg2_file_header() -> [u8; SEG_HEADER_LEN] {
    let mut h = [0u8; SEG_HEADER_LEN];
    h[..4].copy_from_slice(&SEG_MAGIC.to_le_bytes());
    h[4..].copy_from_slice(&CODEC_VERSION_V2.to_le_bytes());
    h
}

/// Append `v` as a LEB128 unsigned varint.
pub fn write_uvarint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

/// Read one LEB128 unsigned varint.
pub fn read_uvarint(c: &mut Cursor) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let b = c.u8()?;
        ensure!(shift < 64, "uvarint longer than 10 bytes");
        ensure!(shift < 63 || b & 0x7F <= 1, "uvarint overflows u64");
        v |= ((b & 0x7F) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

/// ZigZag-map a wrapping delta so small signed steps stay small varints.
pub fn zigzag(delta: u64) -> u64 {
    let d = delta as i64;
    ((d << 1) ^ (d >> 63)) as u64
}

/// Inverse of [`zigzag`].
pub fn unzigzag(z: u64) -> u64 {
    ((z >> 1) as i64 ^ -((z & 1) as i64)) as u64
}

fn label_bit(tag: u8) -> u8 {
    match tag {
        LABEL_NORMAL => 1,
        LABEL_ANOMALY_HIGH => 2,
        LABEL_ANOMALY_LOW => 4,
        _ => 8,
    }
}

/// Per-segment min/max ranges over every header field a [`ProvQuery`] can
/// filter on, plus a bitset of label tags present — enough to prove "no
/// record in this segment can match" without reading the body.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ZoneMap {
    pub min_step: u64,
    pub max_step: u64,
    pub min_entry: u64,
    pub max_entry: u64,
    pub min_exit: u64,
    pub max_exit: u64,
    pub min_score: f64,
    pub max_score: f64,
    pub min_rank: u32,
    pub max_rank: u32,
    pub min_app: u32,
    pub max_app: u32,
    pub min_fid: u32,
    pub max_fid: u32,
    /// Bit 0 normal, 1 anomaly_high, 2 anomaly_low, 3 other/custom.
    pub label_bits: u8,
}

impl Default for ZoneMap {
    fn default() -> ZoneMap {
        ZoneMap {
            min_step: u64::MAX,
            max_step: 0,
            min_entry: u64::MAX,
            max_entry: 0,
            min_exit: u64::MAX,
            max_exit: 0,
            min_score: f64::INFINITY,
            max_score: f64::NEG_INFINITY,
            min_rank: u32::MAX,
            max_rank: 0,
            min_app: u32::MAX,
            max_app: 0,
            min_fid: u32::MAX,
            max_fid: 0,
            label_bits: 0,
        }
    }
}

impl ZoneMap {
    /// Widen the zone to cover one record header.
    pub fn add(&mut self, h: &RecHeader) {
        self.min_step = self.min_step.min(h.step);
        self.max_step = self.max_step.max(h.step);
        self.min_entry = self.min_entry.min(h.entry_us);
        self.max_entry = self.max_entry.max(h.entry_us);
        self.min_exit = self.min_exit.min(h.exit_us);
        self.max_exit = self.max_exit.max(h.exit_us);
        // NaN scores never satisfy `score >= m`, so ignoring them here
        // (both comparisons are false for NaN) keeps the zone sound.
        if h.score < self.min_score {
            self.min_score = h.score;
        }
        if h.score > self.max_score {
            self.max_score = h.score;
        }
        self.min_rank = self.min_rank.min(h.rank);
        self.max_rank = self.max_rank.max(h.rank);
        self.min_app = self.min_app.min(h.app);
        self.max_app = self.max_app.max(h.app);
        self.min_fid = self.min_fid.min(h.fid);
        self.max_fid = self.max_fid.max(h.fid);
        self.label_bits |= label_bit(h.label_tag);
    }

    /// Conservative pruning check: `false` proves no record in the
    /// segment can satisfy `q`; `true` means the segment must be
    /// scanned. Never returns `false` for a segment holding a match.
    pub fn may_match(&self, q: &ProvQuery) -> bool {
        let in32 = |v: u32, lo: u32, hi: u32| v >= lo && v <= hi;
        if let Some(a) = q.app {
            if !in32(a, self.min_app, self.max_app) {
                return false;
            }
        }
        if let Some((a, r)) = q.rank {
            if !in32(a, self.min_app, self.max_app) || !in32(r, self.min_rank, self.max_rank) {
                return false;
            }
        }
        if let Some((a, f)) = q.fid {
            if !in32(a, self.min_app, self.max_app) || !in32(f, self.min_fid, self.max_fid) {
                return false;
            }
        }
        if let Some(s) = q.step {
            if s < self.min_step || s > self.max_step {
                return false;
            }
        }
        if let Some((lo, hi)) = q.step_range {
            if hi < self.min_step || lo > self.max_step {
                return false;
            }
        }
        if let Some((lo, hi)) = q.ts_range {
            if self.max_exit < lo || self.min_entry > hi {
                return false;
            }
        }
        if q.anomalies_only && self.label_bits & !1 == 0 {
            return false;
        }
        if let Some(m) = q.min_score {
            // NaN bounds (empty zone) and NaN m both compare false —
            // conservative in exactly the right direction.
            if self.max_score < m {
                return false;
            }
        }
        if let Some(l) = &q.label {
            if self.label_bits & label_bit(label_tag(l)) == 0 {
                return false;
            }
        }
        true
    }
}

/// The fixed tail of a sealed v2 segment: zone map, record/anomaly
/// counts, and the body extent (which pins the exact file size).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Seg2Footer {
    pub zone: ZoneMap,
    pub n_records: u32,
    pub n_anomalies: u32,
    pub body_len: u64,
}

impl Seg2Footer {
    /// Total file size a segment with this footer must have.
    pub fn file_len(&self) -> u64 {
        (SEG_HEADER_LEN + 4 + SEG2_TAIL_LEN) as u64 + self.body_len
    }

    fn encode(&self) -> [u8; SEG2_FOOTER_LEN] {
        let mut out = Vec::with_capacity(SEG2_FOOTER_LEN);
        let z = &self.zone;
        for v in [z.min_step, z.max_step, z.min_entry, z.max_entry, z.min_exit, z.max_exit] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.extend_from_slice(&z.min_score.to_le_bytes());
        out.extend_from_slice(&z.max_score.to_le_bytes());
        for v in [z.min_rank, z.max_rank, z.min_app, z.max_app, z.min_fid, z.max_fid] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.push(z.label_bits);
        out.extend_from_slice(&self.n_records.to_le_bytes());
        out.extend_from_slice(&self.n_anomalies.to_le_bytes());
        out.extend_from_slice(&self.body_len.to_le_bytes());
        out.try_into().expect("footer layout is fixed-size")
    }

    fn parse(buf: &[u8]) -> Result<Seg2Footer> {
        ensure!(buf.len() == SEG2_FOOTER_LEN, "bad footer length {}", buf.len());
        let mut c = Cursor::new(buf);
        let zone = ZoneMap {
            min_step: c.u64()?,
            max_step: c.u64()?,
            min_entry: c.u64()?,
            max_entry: c.u64()?,
            min_exit: c.u64()?,
            max_exit: c.u64()?,
            min_score: c.f64()?,
            max_score: c.f64()?,
            min_rank: c.u32()?,
            max_rank: c.u32()?,
            min_app: c.u32()?,
            max_app: c.u32()?,
            min_fid: c.u32()?,
            max_fid: c.u32()?,
            label_bits: c.u8()?,
        };
        Ok(Seg2Footer {
            zone,
            n_records: c.u32()?,
            n_anomalies: c.u32()?,
            body_len: c.u64()?,
        })
    }
}

fn put_delta_zz(out: &mut Vec<u8>, prev: &mut u64, v: u64) {
    write_uvarint(out, zigzag(v.wrapping_sub(*prev)));
    *prev = v;
}

/// Seal `(seq, validated encoded record)` pairs into a complete v2
/// segment file image. Returns the bytes and the footer (the caller
/// keeps the footer as the segment's in-memory zone-map handle).
pub fn seal_segment_v2(records: &[(u64, &[u8])]) -> Result<(Vec<u8>, Seg2Footer)> {
    ensure!(!records.is_empty(), "cannot seal an empty segment");
    let mut parsed = Vec::with_capacity(records.len());
    for (seq, buf) in records {
        let h = read_header(buf)?;
        let p = parse_payload(&h, buf)?;
        parsed.push((*seq, h, p));
    }
    let n = parsed.len();
    let seq0 = parsed[0].0;

    // String dictionary: function names + custom labels, first-appearance
    // order so the column indices stay small for skewed registries.
    let mut dict: Vec<&str> = Vec::new();
    let mut index: std::collections::HashMap<&str, u64> = std::collections::HashMap::new();
    let mut intern_str = |s| -> u64 {
        if let Some(&i) = index.get(s) {
            return i;
        }
        let i = dict.len() as u64;
        dict.push(s);
        index.insert(s, i);
        i
    };
    let mut func_idx = Vec::with_capacity(n);
    let mut label_idx = Vec::new();
    for (_, h, p) in &parsed {
        func_idx.push(intern_str(p.func));
        if h.label_tag == LABEL_OTHER {
            label_idx.push(intern_str(p.label.expect("tag 255 carries a label")));
        }
    }

    let mut body = Vec::with_capacity(n * 32);
    body.extend_from_slice(&(n as u32).to_le_bytes());
    body.extend_from_slice(&seq0.to_le_bytes());
    let mut zone = ZoneMap::default();
    let mut anomalies = 0u32;
    for (_, h, _) in &parsed {
        zone.add(h);
        if h.is_anomaly() {
            anomalies += 1;
        }
    }
    let mut prev = 0u64;
    for (_, h, _) in &parsed {
        put_delta_zz(&mut body, &mut prev, h.step);
    }
    prev = 0;
    for (_, h, _) in &parsed {
        put_delta_zz(&mut body, &mut prev, h.entry_us);
    }
    for (_, h, _) in &parsed {
        write_uvarint(&mut body, zigzag(h.exit_us.wrapping_sub(h.entry_us)));
    }
    for (_, h, _) in &parsed {
        write_uvarint(&mut body, h.fid as u64);
    }
    for (_, h, _) in &parsed {
        write_uvarint(&mut body, h.rank as u64);
    }
    for (_, h, _) in &parsed {
        write_uvarint(&mut body, h.app as u64);
    }
    prev = seq0;
    for (seq, _, _) in &parsed {
        put_delta_zz(&mut body, &mut prev, *seq);
    }
    for (_, h, _) in &parsed {
        body.extend_from_slice(&h.score.to_le_bytes());
    }
    for (_, h, _) in &parsed {
        body.push(h.label_tag);
    }
    prev = 0;
    for (_, _, p) in &parsed {
        put_delta_zz(&mut body, &mut prev, p.call_id);
    }
    for (_, _, p) in &parsed {
        write_uvarint(&mut body, p.thread as u64);
    }
    for (_, _, p) in &parsed {
        write_uvarint(&mut body, p.inclusive_us);
    }
    for (_, _, p) in &parsed {
        write_uvarint(&mut body, p.exclusive_us);
    }
    for (_, _, p) in &parsed {
        write_uvarint(&mut body, p.depth as u64);
    }
    let mut bits = vec![0u8; n.div_ceil(8)];
    for (i, (_, _, p)) in parsed.iter().enumerate() {
        if p.parent.is_some() {
            bits[i / 8] |= 1 << (i % 8);
        }
    }
    body.extend_from_slice(&bits);
    for (_, _, p) in &parsed {
        if let Some(par) = p.parent {
            write_uvarint(&mut body, zigzag(par.wrapping_sub(p.call_id)));
        }
    }
    for (_, _, p) in &parsed {
        write_uvarint(&mut body, p.n_children as u64);
    }
    for (_, _, p) in &parsed {
        write_uvarint(&mut body, p.n_messages as u64);
    }
    for (_, _, p) in &parsed {
        write_uvarint(&mut body, p.msg_bytes);
    }
    body.extend_from_slice(&(dict.len() as u32).to_le_bytes());
    for s in &dict {
        write_uvarint(&mut body, s.len() as u64);
        body.extend_from_slice(s.as_bytes());
    }
    for i in &func_idx {
        write_uvarint(&mut body, *i);
    }
    for i in &label_idx {
        write_uvarint(&mut body, *i);
    }

    let footer = Seg2Footer {
        zone,
        n_records: n as u32,
        n_anomalies: anomalies,
        body_len: body.len() as u64,
    };
    let mut file = Vec::with_capacity(SEG_HEADER_LEN + body.len() + 4 + SEG2_TAIL_LEN);
    file.extend_from_slice(&seg2_file_header());
    file.extend_from_slice(&body);
    file.extend_from_slice(&crc32(&body).to_le_bytes());
    let fbytes = footer.encode();
    file.extend_from_slice(&fbytes);
    file.extend_from_slice(&crc32(&fbytes).to_le_bytes());
    file.extend_from_slice(&(SEG2_FOOTER_LEN as u32).to_le_bytes());
    file.extend_from_slice(&SEG2_FOOTER_MAGIC.to_le_bytes());
    Ok((file, footer))
}

/// Validate and parse the footer from a full v2 file image; `None` for
/// any inconsistency (truncated tail, bad magic/length/CRC, body extent
/// disagreeing with the file size) — the salvage path takes over then.
pub fn read_seg2_footer(buf: &[u8]) -> Option<Seg2Footer> {
    if buf.len() < SEG_HEADER_LEN + 4 + SEG2_TAIL_LEN {
        return None;
    }
    let end = buf.len();
    let magic = u32::from_le_bytes(buf[end - 4..].try_into().unwrap());
    let flen = u32::from_le_bytes(buf[end - 8..end - 4].try_into().unwrap());
    if magic != SEG2_FOOTER_MAGIC || flen as usize != SEG2_FOOTER_LEN {
        return None;
    }
    let fstart = end - SEG2_TAIL_LEN;
    let fbytes = &buf[fstart..fstart + SEG2_FOOTER_LEN];
    let want = u32::from_le_bytes(buf[end - 12..end - 8].try_into().unwrap());
    if crc32(fbytes) != want {
        return None;
    }
    let footer = Seg2Footer::parse(fbytes).ok()?;
    if footer.file_len() != buf.len() as u64 {
        return None;
    }
    Some(footer)
}

/// Tail-only footer read: `Ok(Some(..))` iff `path` is a sealed v2
/// segment with a fully consistent footer (body CRC is *not* checked —
/// that is deferred to the first scan). `Ok(None)` for v1 segments,
/// short/torn files, or any footer inconsistency; `Err` only for I/O.
pub fn read_seg2_footer_file(path: &std::path::Path) -> Result<Option<Seg2Footer>> {
    use std::io::{Read, Seek, SeekFrom};
    let mut f = std::fs::File::open(path)?;
    let file_len = f.metadata()?.len();
    if file_len < (SEG_HEADER_LEN + 4 + SEG2_TAIL_LEN) as u64 {
        return Ok(None);
    }
    let mut head = [0u8; SEG_HEADER_LEN];
    f.read_exact(&mut head)?;
    if u32::from_le_bytes(head[..4].try_into().unwrap()) != SEG_MAGIC
        || u16::from_le_bytes(head[4..6].try_into().unwrap()) != CODEC_VERSION_V2
    {
        return Ok(None);
    }
    f.seek(SeekFrom::End(-(SEG2_TAIL_LEN as i64)))?;
    let mut tail = [0u8; SEG2_TAIL_LEN];
    f.read_exact(&mut tail)?;
    let magic = u32::from_le_bytes(tail[SEG2_TAIL_LEN - 4..].try_into().unwrap());
    let flen = u32::from_le_bytes(tail[SEG2_TAIL_LEN - 8..SEG2_TAIL_LEN - 4].try_into().unwrap());
    if magic != SEG2_FOOTER_MAGIC || flen as usize != SEG2_FOOTER_LEN {
        return Ok(None);
    }
    let fbytes = &tail[..SEG2_FOOTER_LEN];
    let want =
        u32::from_le_bytes(tail[SEG2_TAIL_LEN - 12..SEG2_TAIL_LEN - 8].try_into().unwrap());
    if crc32(fbytes) != want {
        return Ok(None);
    }
    let footer = match Seg2Footer::parse(fbytes) {
        Ok(fo) => fo,
        Err(_) => return Ok(None),
    };
    if footer.file_len() != file_len {
        return Ok(None);
    }
    Ok(Some(footer))
}

/// One v2 segment scan: decoded records with their sealed sequence
/// numbers, the footer when it validated, and whether the body parsed
/// completely under its CRC.
pub struct Seg2Scan {
    pub records: Vec<(u64, ProvRecord)>,
    pub footer: Option<Seg2Footer>,
    /// Body fully parsed and its CRC verified.
    pub complete: bool,
    /// Diagnosis when `!complete` and the loss wasn't a clean tail cut.
    pub corrupt: Option<String>,
}

/// Columns as far as a (possibly torn) body parse got. Each dense
/// column either reaches `n` values or marks where EOF cut it.
#[derive(Default)]
struct Seg2Body {
    n: usize,
    seq: Vec<u64>,
    step: Vec<u64>,
    entry: Vec<u64>,
    dur: Vec<u64>,
    fid: Vec<u64>,
    rank: Vec<u64>,
    app: Vec<u64>,
    score: Vec<f64>,
    label: Vec<u8>,
    call_id: Vec<u64>,
    thread: Vec<u64>,
    incl: Vec<u64>,
    excl: Vec<u64>,
    depth: Vec<u64>,
    parent_bits: Vec<u8>,
    parent_delta: Vec<u64>,
    children: Vec<u64>,
    nmsg: Vec<u64>,
    msgb: Vec<u64>,
    dict: Vec<String>,
    dict_complete: bool,
    func_idx: Vec<u64>,
    label_idx: Vec<u64>,
    /// Exact body bytes consumed when everything parsed (else 0).
    consumed: usize,
}

fn col_uvarint(c: &mut Cursor, n: usize) -> Vec<u64> {
    let mut v = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        match read_uvarint(c) {
            Ok(x) => v.push(x),
            Err(_) => break,
        }
    }
    v
}

fn col_delta_zz(c: &mut Cursor, n: usize, start: u64) -> Vec<u64> {
    let mut v = Vec::with_capacity(n.min(1 << 16));
    let mut prev = start;
    for _ in 0..n {
        match read_uvarint(c) {
            Ok(z) => {
                prev = prev.wrapping_add(unzigzag(z));
                v.push(prev);
            }
            Err(_) => break,
        }
    }
    v
}

/// Decode a whole v2 file image. Bad magic is a hard error (not our
/// file); a wrong *known* version is too (the caller routes v1 files
/// through [`read_segment`]). Everything else degrades: a valid footer
/// + body CRC yields the full record set (`complete`), a torn tail
/// yields the longest decodable prefix, and a CRC-failing body under a
/// valid footer yields nothing but a diagnosis.
pub fn read_segment_v2(buf: &[u8]) -> Result<Seg2Scan> {
    if buf.len() < SEG_HEADER_LEN {
        return Ok(Seg2Scan { records: Vec::new(), footer: None, complete: false, corrupt: None });
    }
    let magic = u32::from_le_bytes(buf[..4].try_into().unwrap());
    ensure!(magic == SEG_MAGIC, "bad segment magic {magic:#010x}");
    let version = u16::from_le_bytes(buf[4..6].try_into().unwrap());
    ensure!(version == CODEC_VERSION_V2, "not a v2 segment (codec version {version})");
    let footer = read_seg2_footer(buf);
    if let Some(f) = footer {
        let body = &buf[SEG_HEADER_LEN..SEG_HEADER_LEN + f.body_len as usize];
        let at = SEG_HEADER_LEN + f.body_len as usize;
        let want = u32::from_le_bytes(buf[at..at + 4].try_into().unwrap());
        if crc32(body) != want {
            return Ok(Seg2Scan {
                records: Vec::new(),
                footer: Some(f),
                complete: false,
                corrupt: Some("body CRC mismatch under a valid footer".into()),
            });
        }
        let (records, diag, full) = decode_seg2_records(body);
        let complete = full && records.len() == f.n_records as usize && diag.is_none();
        let corrupt = if complete {
            None
        } else {
            Some(diag.unwrap_or_else(|| "body/footer record count disagreement".into()))
        };
        return Ok(Seg2Scan { records, footer: Some(f), complete, corrupt });
    }
    // No trustworthy footer: salvage the longest decodable prefix from
    // whatever body bytes survive (the tail may include a partial
    // footer; the column counts bound the parse, so trailing junk is
    // simply never reached).
    let (records, diag, full) = decode_seg2_records(&buf[SEG_HEADER_LEN..]);
    Ok(Seg2Scan { records, footer: None, complete: false, corrupt: diag.filter(|_| !full) })
}

/// Parse + assemble records from a body region. Returns the decoded
/// prefix, an optional corruption diagnosis (structural badness, as
/// opposed to a clean tail cut), and whether every column reached its
/// full count with all references resolved.
fn decode_seg2_records(body: &[u8]) -> (Vec<(u64, ProvRecord)>, Option<String>, bool) {
    let b = match parse_seg2_body_full(body) {
        Some(b) => b,
        None => return (Vec::new(), Some("unparsable v2 body preamble".into()), false),
    };
    assemble_seg2(&b)
}

/// Full column parse with soft EOF (torn tails shorten trailing columns).
fn parse_seg2_body_full(body: &[u8]) -> Option<Seg2Body> {
    let mut c = Cursor::new(body);
    let n = c.u32().ok()? as usize;
    if n > body.len() {
        return None;
    }
    let seq0 = c.u64().ok()?;
    let mut b = Seg2Body { n, ..Default::default() };
    macro_rules! dense {
        ($field:ident, $val:expr) => {{
            b.$field = $val;
            if b.$field.len() < n {
                return Some(b);
            }
        }};
    }
    dense!(step, col_delta_zz(&mut c, n, 0));
    dense!(entry, col_delta_zz(&mut c, n, 0));
    dense!(dur, {
        let mut v = Vec::new();
        for _ in 0..n {
            match read_uvarint(&mut c) {
                Ok(z) => v.push(unzigzag(z)),
                Err(_) => break,
            }
        }
        v
    });
    dense!(fid, col_uvarint(&mut c, n));
    dense!(rank, col_uvarint(&mut c, n));
    dense!(app, col_uvarint(&mut c, n));
    dense!(seq, col_delta_zz(&mut c, n, seq0));
    dense!(score, {
        let mut v = Vec::new();
        for _ in 0..n {
            match c.f64() {
                Ok(x) => v.push(x),
                Err(_) => break,
            }
        }
        v
    });
    dense!(label, {
        let mut v = Vec::new();
        for _ in 0..n {
            match c.u8() {
                Ok(x) => v.push(x),
                Err(_) => break,
            }
        }
        v
    });
    dense!(call_id, col_delta_zz(&mut c, n, 0));
    dense!(thread, col_uvarint(&mut c, n));
    dense!(incl, col_uvarint(&mut c, n));
    dense!(excl, col_uvarint(&mut c, n));
    dense!(depth, col_uvarint(&mut c, n));
    let nbits = n.div_ceil(8);
    let avail = c.remaining().min(nbits);
    b.parent_bits = c.take_slice(avail).expect("bounded by remaining").to_vec();
    if b.parent_bits.len() < nbits {
        return Some(b);
    }
    let n_parents: usize = b.parent_bits.iter().map(|x| x.count_ones() as usize).sum();
    {
        // Per-record relative deltas (not cumulative): read raw.
        let mut v = Vec::new();
        for _ in 0..n_parents {
            match read_uvarint(&mut c) {
                Ok(z) => v.push(unzigzag(z)),
                Err(_) => break,
            }
        }
        b.parent_delta = v;
        if b.parent_delta.len() < n_parents {
            return Some(b);
        }
    }
    dense!(children, col_uvarint(&mut c, n));
    dense!(nmsg, col_uvarint(&mut c, n));
    dense!(msgb, col_uvarint(&mut c, n));
    let n_strings = match c.u32() {
        Ok(x) => x as usize,
        Err(_) => return Some(b),
    };
    if n_strings > body.len() {
        return None;
    }
    for _ in 0..n_strings {
        let len = match read_uvarint(&mut c) {
            Ok(l) => l as usize,
            Err(_) => return Some(b),
        };
        if len > c.remaining() {
            return Some(b);
        }
        let bytes = c.take_slice(len).expect("bounds checked");
        match std::str::from_utf8(bytes) {
            Ok(s) => b.dict.push(s.to_string()),
            Err(_) => return Some(b),
        }
    }
    b.dict_complete = true;
    dense!(func_idx, col_uvarint(&mut c, n));
    let n_custom = b.label.iter().filter(|&&t| t == LABEL_OTHER).count();
    {
        let mut v = Vec::new();
        for _ in 0..n_custom {
            match read_uvarint(&mut c) {
                Ok(x) => v.push(x),
                Err(_) => break,
            }
        }
        b.label_idx = v;
        if b.label_idx.len() < n_custom {
            return Some(b);
        }
    }
    b.consumed = body.len() - c.remaining();
    Some(b)
}

/// Assemble the longest valid record prefix from parsed columns.
fn assemble_seg2(b: &Seg2Body) -> (Vec<(u64, ProvRecord)>, Option<String>, bool) {
    let n = b.n;
    let dense_k = [
        b.step.len(),
        b.entry.len(),
        b.dur.len(),
        b.fid.len(),
        b.rank.len(),
        b.app.len(),
        b.seq.len(),
        b.score.len(),
        b.label.len(),
        b.call_id.len(),
        b.thread.len(),
        b.incl.len(),
        b.excl.len(),
        b.depth.len(),
        b.children.len(),
        b.nmsg.len(),
        b.msgb.len(),
        b.func_idx.len(),
    ]
    .into_iter()
    .min()
    .unwrap_or(0);
    let mut out = Vec::with_capacity(dense_k);
    let mut diag = None;
    let mut parents_used = 0usize;
    let mut customs_used = 0usize;
    let u32_of = |v: u64| -> Option<u32> { u32::try_from(v).ok() };
    for i in 0..dense_k {
        if i / 8 >= b.parent_bits.len() {
            break;
        }
        let has_parent = b.parent_bits[i / 8] & (1 << (i % 8)) != 0;
        if has_parent && parents_used >= b.parent_delta.len() {
            break;
        }
        let tag = b.label[i];
        let label = match tag {
            LABEL_NORMAL | LABEL_ANOMALY_HIGH | LABEL_ANOMALY_LOW => {
                label_of_tag(tag).expect("well-known tag").to_string()
            }
            LABEL_OTHER => {
                if customs_used >= b.label_idx.len() {
                    break;
                }
                let li = b.label_idx[customs_used] as usize;
                if li >= b.dict.len() {
                    if b.dict_complete {
                        diag = Some(format!("record {i}: label dict index {li} out of range"));
                    }
                    break;
                }
                let text = b.dict[li].clone();
                if label_tag(&text) != LABEL_OTHER {
                    diag = Some(format!(
                        "record {i}: label tag 255 with well-known label text '{text}'"
                    ));
                    break;
                }
                customs_used += 1;
                text
            }
            t => {
                diag = Some(format!("record {i}: bad label tag {t}"));
                break;
            }
        };
        let fi = b.func_idx[i] as usize;
        if fi >= b.dict.len() {
            if b.dict_complete {
                diag = Some(format!("record {i}: func dict index {fi} out of range"));
            }
            break;
        }
        let (Some(app), Some(rank), Some(fid), Some(thread), Some(depth)) = (
            u32_of(b.app[i]),
            u32_of(b.rank[i]),
            u32_of(b.fid[i]),
            u32_of(b.thread[i]),
            u32_of(b.depth[i]),
        ) else {
            diag = Some(format!("record {i}: 32-bit column value out of range"));
            break;
        };
        let (Some(n_children), Some(n_messages)) =
            (u32_of(b.children[i]), u32_of(b.nmsg[i]))
        else {
            diag = Some(format!("record {i}: 32-bit column value out of range"));
            break;
        };
        let call_id = b.call_id[i];
        let parent = if has_parent {
            let p = call_id.wrapping_add(b.parent_delta[parents_used]);
            parents_used += 1;
            Some(p)
        } else {
            None
        };
        out.push((
            b.seq[i],
            ProvRecord {
                call_id,
                app,
                rank,
                thread,
                fid,
                func: b.dict[fi].clone(),
                step: b.step[i],
                entry_us: b.entry[i],
                exit_us: b.entry[i].wrapping_add(b.dur[i]),
                inclusive_us: b.incl[i],
                exclusive_us: b.excl[i],
                depth,
                parent,
                n_children,
                n_messages,
                msg_bytes: b.msgb[i],
                label,
                score: b.score[i],
            },
        ));
    }
    let full = diag.is_none() && out.len() == n && b.dict_complete && b.consumed > 0;
    (out, diag, full)
}

/// Verdict of an incremental parse attempt at the head of a buffered
/// window over a v1 segment's record stream (see [`parse_segment_record`]).
pub enum SegRecordParse {
    /// The window doesn't hold a whole record yet — refill and retry
    /// (at EOF this means a torn tail).
    NeedMore,
    /// One valid record: `total` bytes including the CRC trailer, the
    /// record itself being the first `total - 4`.
    Record { total: usize },
    /// Structural/CRC failure — the stream is bad from here on.
    Corrupt(String),
}

/// Incrementally parse one `record + crc32` unit from the start of
/// `buf` — the chunked-recovery building block that lets segment scans
/// run in bounded memory instead of `std::fs::read`-ing whole files.
pub fn parse_segment_record(buf: &[u8]) -> SegRecordParse {
    if buf.len() < HEADER_LEN {
        return SegRecordParse::NeedMore;
    }
    let h = match read_header(buf) {
        Ok(h) => h,
        Err(e) => return SegRecordParse::Corrupt(format!("bad record header: {e}")),
    };
    if h.payload_len as usize > MAX_PAYLOAD {
        return SegRecordParse::Corrupt(format!(
            "implausible record payload length {}",
            h.payload_len
        ));
    }
    let total = h.record_len() + 4;
    if buf.len() < total {
        return SegRecordParse::NeedMore;
    }
    let rec = &buf[..h.record_len()];
    let want = u32::from_le_bytes(buf[h.record_len()..total].try_into().unwrap());
    if crc32(rec) != want {
        return SegRecordParse::Corrupt("CRC mismatch".into());
    }
    if let Err(e) = validate(rec) {
        return SegRecordParse::Corrupt(format!("invalid record: {e}"));
    }
    SegRecordParse::Record { total }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(label: &str, score: f64) -> ProvRecord {
        ProvRecord {
            call_id: 42,
            app: 1,
            rank: 3,
            thread: 2,
            fid: 7,
            func: "MD_NEWTON_λ \"x\"".to_string(),
            step: 9,
            entry_us: 1000,
            exit_us: 1500,
            inclusive_us: 500,
            exclusive_us: 300,
            depth: 2,
            parent: Some(41),
            n_children: 1,
            n_messages: 2,
            msg_bytes: 4096,
            label: label.to_string(),
            score,
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        for (label, score) in [
            ("anomaly_high", 7.5),
            ("normal", 0.0),
            ("anomaly_low", -2.25),
            ("custom_label", 1e-12),
        ] {
            let mut r = rec(label, score);
            if score == 0.0 {
                r.parent = None;
                r.func = String::new(); // empty call stacks
            }
            let mut buf = Vec::new();
            encode(&r, &mut buf);
            assert_eq!(validate(&buf).unwrap(), buf.len());
            let (back, used) = decode(&buf).unwrap();
            assert_eq!(used, buf.len());
            assert_eq!(back, r);
            let h = read_header(&buf).unwrap();
            assert_eq!(h.app, r.app);
            assert_eq!(h.step, r.step);
            assert_eq!(h.score, r.score);
            assert_eq!(h.is_anomaly(), r.is_anomaly());
            assert_eq!(score_of(&buf), r.score);
            assert_eq!(entry_us_of(&buf), r.entry_us);
            assert_eq!(label_tag_of(&buf), label_tag(&r.label));
        }
    }

    #[test]
    fn self_delimiting_in_a_batch() {
        let a = rec("normal", 1.0);
        let b = rec("anomaly_high", 9.0);
        let mut buf = Vec::new();
        encode(&a, &mut buf);
        let split = buf.len();
        encode(&b, &mut buf);
        let (ra, ua) = decode(&buf).unwrap();
        assert_eq!(ua, split);
        let (rb, ub) = decode(&buf[ua..]).unwrap();
        assert_eq!(ua + ub, buf.len());
        assert_eq!(ra, a);
        assert_eq!(rb, b);
    }

    #[test]
    fn truncation_and_corruption_rejected() {
        let mut buf = Vec::new();
        encode(&rec("normal", 1.0), &mut buf);
        assert!(decode(&buf[..HEADER_LEN - 1]).is_err());
        assert!(decode(&buf[..buf.len() - 1]).is_err());
        assert!(validate(&buf[..buf.len() - 1]).is_err());
        // A lying payload length is refused before any allocation.
        let mut lying = buf.clone();
        lying[45..49].copy_from_slice(&(MAX_PAYLOAD as u32 + 1).to_le_bytes());
        assert!(validate(&lying).is_err());
        assert!(decode(&lying).is_err());
        // Bad label tag.
        let mut bad_tag = buf.clone();
        bad_tag[44] = 7;
        assert!(validate(&bad_tag).is_err());
    }

    #[test]
    fn forged_other_tag_with_well_known_label_rejected() {
        // Hand-roll what encode() never produces: tag 255 whose payload
        // label text is a well-known label. The header would claim
        // anomaly while the payload says "normal" — refused outright.
        let mut r = rec("placeholder_custom", 1.0);
        r.label = "zzz".to_string(); // custom → tag 255, label in payload
        let mut buf = Vec::new();
        encode(&r, &mut buf);
        // Patch the payload label text "zzz" → "normal" (adjusting the
        // length prefix that precedes it).
        let zzz = buf.len() - 3;
        buf.truncate(zzz - 4);
        put_bytes(&mut buf, b"normal");
        let plen = (buf.len() - HEADER_LEN) as u32;
        buf[45..49].copy_from_slice(&plen.to_le_bytes());
        assert_eq!(label_tag_of(&buf), LABEL_OTHER);
        assert!(validate(&buf).is_err());
        assert!(decode(&buf).is_err());
    }

    #[test]
    fn segment_roundtrip_with_crc_and_torn_tail() {
        let recs: Vec<ProvRecord> = (0..5)
            .map(|i| rec(if i % 2 == 0 { "normal" } else { "anomaly_low" }, i as f64))
            .collect();
        let mut file: Vec<u8> = seg_file_header().to_vec();
        let mut encoded = Vec::new();
        for r in &recs {
            let start = encoded.len();
            encode(r, &mut encoded);
            let one = &encoded[start..];
            file.extend_from_slice(one);
            file.extend_from_slice(&crc32(one).to_le_bytes());
        }
        let scan = read_segment(&file).unwrap();
        assert_eq!(scan.records.len(), 5);
        assert_eq!(scan.torn_bytes, 0);
        assert!(scan.corrupt.is_none());
        for (b, want) in scan.records.iter().zip(&recs) {
            assert_eq!(&decode(b).unwrap().0, want);
        }
        // Torn tail: drop the last 3 bytes — earlier records survive.
        let scan = read_segment(&file[..file.len() - 3]).unwrap();
        assert_eq!(scan.records.len(), 4);
        assert!(scan.torn_bytes > 0);
        assert!(scan.corrupt.is_none(), "a clean tail cut is not corruption");
        // Flipped byte inside a record: CRC stops the scan there, keeping
        // the records before it (recovery degrades, it doesn't die).
        let mut corrupt = file.clone();
        corrupt[SEG_HEADER_LEN + 20] ^= 0xFF;
        let scan = read_segment(&corrupt).unwrap();
        assert_eq!(scan.records.len(), 0);
        assert!(scan.corrupt.is_some());
        // A short/empty file (crash before the header flushed) is a torn
        // tail, not an error — restart recovery must keep going.
        let scan = read_segment(&[]).unwrap();
        assert!(scan.records.is_empty() && scan.torn_bytes == 0 && scan.corrupt.is_none());
        let scan = read_segment(&file[..3]).unwrap();
        assert!(scan.records.is_empty() && scan.torn_bytes == 3);
        // Wrong magic/version is a hard error (not our file).
        let mut bad = file.clone();
        bad[0] ^= 0xFF;
        assert!(read_segment(&bad).is_err());
        let mut badv = file;
        badv[4] = 0xEE;
        assert!(read_segment(&badv).is_err());
    }

    #[test]
    fn crc32_known_vector() {
        // IEEE CRC-32 of "123456789".
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn header_predicates_match_full_matches() {
        let r = rec("anomaly_high", 7.5);
        let mut buf = Vec::new();
        encode(&r, &mut buf);
        let h = read_header(&buf).unwrap();
        let qs = [
            ProvQuery::default(),
            ProvQuery { app: Some(1), ..Default::default() },
            ProvQuery { app: Some(2), ..Default::default() },
            ProvQuery { rank: Some((1, 3)), step: Some(9), ..Default::default() },
            ProvQuery { rank: Some((1, 4)), ..Default::default() },
            ProvQuery { fid: Some((1, 7)), ..Default::default() },
            ProvQuery { step_range: Some((8, 10)), ..Default::default() },
            ProvQuery { step_range: Some((10, 11)), ..Default::default() },
            ProvQuery { ts_range: Some((1400, 1600)), ..Default::default() },
            ProvQuery { ts_range: Some((1501, 1600)), ..Default::default() },
            ProvQuery { anomalies_only: true, ..Default::default() },
            ProvQuery { min_score: Some(7.5), ..Default::default() },
            ProvQuery { min_score: Some(7.6), ..Default::default() },
            ProvQuery { label: Some("anomaly_high".into()), ..Default::default() },
            ProvQuery { label: Some("normal".into()), ..Default::default() },
            ProvQuery { label: Some("weird".into()), ..Default::default() },
        ];
        for q in &qs {
            assert_eq!(
                matches_header(q, &h).expect("known-label record is always decidable"),
                q.matches(&r),
                "query {q:?}"
            );
        }
        // A custom-label record vs a custom query label is undecidable
        // from the header; everything else still decides.
        let custom = rec("weird", 1.0);
        let mut cbuf = Vec::new();
        encode(&custom, &mut cbuf);
        let ch = read_header(&cbuf).unwrap();
        assert_eq!(
            matches_header(
                &ProvQuery { label: Some("weird".into()), ..Default::default() },
                &ch
            ),
            None
        );
        assert_eq!(
            matches_header(
                &ProvQuery { label: Some("normal".into()), ..Default::default() },
                &ch
            ),
            Some(false)
        );
        // Custom labels are anomalies (label != "normal").
        assert_eq!(
            matches_header(&ProvQuery { anomalies_only: true, ..Default::default() }, &ch),
            Some(true)
        );
    }

    #[test]
    fn uvarint_and_zigzag_edges() {
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX, u64::MAX - 1] {
            let mut buf = Vec::new();
            write_uvarint(&mut buf, v);
            let mut c = Cursor::new(&buf);
            assert_eq!(read_uvarint(&mut c).unwrap(), v);
            assert_eq!(c.remaining(), 0);
            assert_eq!(unzigzag(zigzag(v)), v);
        }
        // Deltas of extreme magnitude survive the wrapping round trip.
        for (a, b) in [(0u64, u64::MAX), (u64::MAX, 0), (5, 3), (3, 5)] {
            let d = b.wrapping_sub(a);
            assert_eq!(a.wrapping_add(unzigzag(zigzag(d))), b);
        }
        // An 11-byte continuation run is refused, not a shift panic.
        let mut c = Cursor::new(&[0xFF; 11]);
        assert!(read_uvarint(&mut c).is_err());
    }

    /// A varied record set: custom + well-known labels, parents present
    /// and absent, shared and unique function names, gapped seqs.
    fn seg2_fixture() -> Vec<(u64, ProvRecord)> {
        (0..40u64)
            .map(|i| {
                let mut r = rec(
                    match i % 4 {
                        0 => "normal",
                        1 => "anomaly_high",
                        2 => "anomaly_low",
                        _ => "weird_label",
                    },
                    i as f64 / 3.0,
                );
                r.call_id = 1000 + i * 3;
                r.rank = (i % 3) as u32;
                r.fid = (i % 5) as u32;
                r.func = format!("F{}", i % 5);
                r.step = i / 8;
                r.entry_us = 10_000 + i * 500;
                r.exit_us = r.entry_us + 50 + i;
                r.parent = if i % 3 == 0 { None } else { Some(1000 + i * 3 - 3) };
                (100 + i * 7, r) // gapped seqs, as live sealing produces
            })
            .collect()
    }

    fn seal_fixture(recs: &[(u64, ProvRecord)]) -> (Vec<u8>, Seg2Footer, Vec<Vec<u8>>) {
        let encoded: Vec<Vec<u8>> = recs
            .iter()
            .map(|(_, r)| {
                let mut b = Vec::new();
                encode(r, &mut b);
                b
            })
            .collect();
        let pairs: Vec<(u64, &[u8])> =
            recs.iter().zip(&encoded).map(|((s, _), b)| (*s, b.as_slice())).collect();
        let (file, footer) = seal_segment_v2(&pairs).unwrap();
        (file, footer, encoded)
    }

    #[test]
    fn seg2_seal_read_bit_identical_and_smaller() {
        let recs = seg2_fixture();
        let (file, footer, encoded) = seal_fixture(&recs);
        assert_eq!(footer.n_records as usize, recs.len());
        assert_eq!(
            footer.n_anomalies as usize,
            recs.iter().filter(|(_, r)| r.is_anomaly()).count()
        );
        assert_eq!(footer.file_len(), file.len() as u64);
        let scan = read_segment_v2(&file).unwrap();
        assert!(scan.complete, "corrupt: {:?}", scan.corrupt);
        assert_eq!(scan.footer, Some(footer));
        assert_eq!(scan.records.len(), recs.len());
        for ((seq, back), ((want_seq, want), enc)) in
            scan.records.iter().zip(recs.iter().zip(&encoded))
        {
            assert_eq!(seq, want_seq);
            assert_eq!(back, want);
            // Canonical re-encode: byte-identical to the v1 source.
            let mut re = Vec::new();
            encode(back, &mut re);
            assert_eq!(&re, enc);
        }
        // Packing beats the v1 row format (records + CRC trailers).
        let v1_size: usize =
            SEG_HEADER_LEN + encoded.iter().map(|b| b.len() + 4).sum::<usize>();
        assert!(
            (file.len() as f64) < v1_size as f64 / 1.5,
            "v2 {} vs v1 {} bytes — packing below the 1.5x bar",
            file.len(),
            v1_size
        );
    }

    #[test]
    fn seg2_zone_map_is_sound_and_prunes() {
        let recs = seg2_fixture();
        let (file, footer, _) = seal_fixture(&recs);
        let scan = read_segment_v2(&file).unwrap();
        let queries = [
            ProvQuery::default(),
            ProvQuery { app: Some(1), ..Default::default() },
            ProvQuery { app: Some(9), ..Default::default() },
            ProvQuery { rank: Some((1, 2)), ..Default::default() },
            ProvQuery { rank: Some((1, 7)), ..Default::default() },
            ProvQuery { fid: Some((1, 4)), ..Default::default() },
            ProvQuery { fid: Some((1, 11)), ..Default::default() },
            ProvQuery { step: Some(3), ..Default::default() },
            ProvQuery { step: Some(99), ..Default::default() },
            ProvQuery { step_range: Some((2, 3)), ..Default::default() },
            ProvQuery { step_range: Some((50, 60)), ..Default::default() },
            ProvQuery { ts_range: Some((0, 9_999)), ..Default::default() },
            ProvQuery { ts_range: Some((15_000, 16_000)), ..Default::default() },
            ProvQuery { anomalies_only: true, ..Default::default() },
            ProvQuery { min_score: Some(5.0), ..Default::default() },
            ProvQuery { min_score: Some(99.0), ..Default::default() },
            ProvQuery { label: Some("weird_label".into()), ..Default::default() },
            ProvQuery { label: Some("normal".into()), ..Default::default() },
        ];
        let mut pruned = 0;
        for q in &queries {
            let any = scan.records.iter().any(|(_, r)| q.matches(r));
            if !footer.zone.may_match(q) {
                pruned += 1;
                assert!(!any, "zone pruned a segment holding a match for {q:?}");
            }
        }
        assert!(pruned >= 4, "zone map pruned only {pruned} of the impossible queries");
        // A segment of pure normals is prunable for anomalies_only.
        let normals: Vec<(u64, ProvRecord)> =
            (0..4).map(|i| (i, rec("normal", 0.5))).collect();
        let (_, nf, _) = seal_fixture(&normals);
        assert!(!nf.zone.may_match(&ProvQuery { anomalies_only: true, ..Default::default() }));
    }

    #[test]
    fn seg2_footer_reads_from_file_tail() {
        let recs = seg2_fixture();
        let (file, footer, _) = seal_fixture(&recs);
        let dir = std::env::temp_dir().join(format!("chimbuko_seg2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("prov_app0_rank0_seg0000.provseg");
        std::fs::write(&path, &file).unwrap();
        assert_eq!(read_seg2_footer_file(&path).unwrap(), Some(footer));
        // A v1 segment file is not sealed.
        let v1 = dir.join("prov_app0_rank0.provseg");
        std::fs::write(&v1, seg_file_header()).unwrap();
        assert_eq!(read_seg2_footer_file(&v1).unwrap(), None);
        // A torn tail (footer cut) is not sealed either.
        std::fs::write(&path, &file[..file.len() - 5]).unwrap();
        assert_eq!(read_seg2_footer_file(&path).unwrap(), None);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn seg2_torn_tail_salvages_a_prefix() {
        let recs = seg2_fixture();
        let (file, _, _) = seal_fixture(&recs);
        // Tear inside the trailing magic: the body (and its CRC) are
        // intact, so every record comes back.
        let scan = read_segment_v2(&file[..file.len() - 3]).unwrap();
        assert!(scan.footer.is_none() && !scan.complete && scan.corrupt.is_none());
        assert_eq!(scan.records.len(), recs.len());
        for ((seq, back), (want_seq, want)) in scan.records.iter().zip(&recs) {
            assert_eq!((seq, back), (want_seq, want));
        }
        // Progressive tears never yield junk: always a bit-exact prefix.
        let mut seen_partial = false;
        for cut in [SEG2_TAIL_LEN + 10, file.len() / 2, file.len() / 4, file.len() - 40] {
            let scan = read_segment_v2(&file[..file.len() - cut]).unwrap();
            assert!(scan.records.len() <= recs.len());
            if !scan.records.is_empty() && scan.records.len() < recs.len() {
                seen_partial = true;
            }
            for ((seq, back), (want_seq, want)) in scan.records.iter().zip(&recs) {
                assert_eq!((seq, back), (want_seq, want));
            }
        }
        assert!(seen_partial, "no tear produced a partial salvage — widen the cuts");
        // A flipped body byte under a valid footer is corruption: no
        // records, a diagnosis, and the footer still readable.
        let mut flipped = file.clone();
        flipped[SEG_HEADER_LEN + 30] ^= 0xFF;
        let scan = read_segment_v2(&flipped).unwrap();
        assert!(scan.records.is_empty() && !scan.complete);
        assert!(scan.corrupt.unwrap().contains("CRC"));
        assert!(scan.footer.is_some());
        // Wrong magic / non-v2 version are hard errors.
        let mut bad = file.clone();
        bad[0] ^= 0xFF;
        assert!(read_segment_v2(&bad).is_err());
        let mut v1 = file;
        v1[4] = 1;
        v1[5] = 0;
        assert!(read_segment_v2(&v1).is_err());
    }

    #[test]
    fn incremental_record_parse_matches_read_segment() {
        let recs = seg2_fixture();
        let mut stream = Vec::new();
        for (_, r) in &recs {
            let start = stream.len();
            encode(r, &mut stream);
            let crc = crc32(&stream[start..]);
            stream.extend_from_slice(&crc.to_le_bytes());
        }
        let mut pos = 0;
        let mut n = 0;
        loop {
            match parse_segment_record(&stream[pos..]) {
                SegRecordParse::Record { total } => {
                    let (r, _) = decode(&stream[pos..pos + total - 4]).unwrap();
                    assert_eq!(&r, &recs[n].1);
                    pos += total;
                    n += 1;
                }
                SegRecordParse::NeedMore => break,
                SegRecordParse::Corrupt(e) => panic!("corrupt: {e}"),
            }
        }
        assert_eq!((n, pos), (recs.len(), stream.len()));
        // A short window asks for more; a flipped byte is corrupt.
        assert!(matches!(parse_segment_record(&stream[..10]), SegRecordParse::NeedMore));
        let mut bad = stream.clone();
        bad[20] ^= 0xFF;
        assert!(matches!(parse_segment_record(&bad), SegRecordParse::Corrupt(_)));
    }
}
