//! One provenance record: a kept execution with everything the paper lists
//! (§V) — rank, thread, entry/exit, runtime, children and message counts,
//! label — plus the anomaly score and the function name resolved from the
//! registry.
//!
//! Two serializations exist: the JSONL form here (the human/edge format —
//! `/api/provenance`, offline dumps, the `--log-format jsonl` escape
//! hatch) and the binary form in [`codec`](super::codec) (the wire,
//! shard-resident, and `.provseg` segment-log format). The property tests
//! in `tests/prov_roundtrip.rs` pin the two as mutually lossless.

use crate::ad::{Label, Labeled};
use crate::util::json::{parse, Json};

/// JSON-serializable provenance record.
#[derive(Clone, Debug, PartialEq)]
pub struct ProvRecord {
    pub call_id: u64,
    pub app: u32,
    pub rank: u32,
    pub thread: u32,
    pub fid: u32,
    pub func: String,
    pub step: u64,
    pub entry_us: u64,
    pub exit_us: u64,
    pub inclusive_us: u64,
    pub exclusive_us: u64,
    pub depth: u32,
    pub parent: Option<u64>,
    pub n_children: u32,
    pub n_messages: u32,
    pub msg_bytes: u64,
    /// "normal" | "anomaly_high" | "anomaly_low".
    pub label: String,
    /// σ-distance from the mean at labelling time.
    pub score: f64,
}

impl ProvRecord {
    /// Build from a labelled execution, resolving the function name.
    pub fn from_labeled(l: &Labeled, func_name: &str) -> ProvRecord {
        ProvRecord {
            call_id: l.rec.call_id,
            app: l.rec.app,
            rank: l.rec.rank,
            thread: l.rec.thread,
            fid: l.rec.fid,
            func: func_name.to_string(),
            step: l.rec.step,
            entry_us: l.rec.entry_ts,
            exit_us: l.rec.exit_ts,
            inclusive_us: l.rec.inclusive_us(),
            exclusive_us: l.rec.exclusive_us,
            depth: l.rec.depth,
            parent: l.rec.parent,
            n_children: l.rec.n_children,
            n_messages: l.rec.n_messages,
            msg_bytes: l.rec.msg_bytes,
            label: l.label.as_str().to_string(),
            score: l.score,
        }
    }

    pub fn is_anomaly(&self) -> bool {
        self.label != Label::Normal.as_str()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("call_id", Json::num(self.call_id as f64)),
            ("app", Json::num(self.app as f64)),
            ("rank", Json::num(self.rank as f64)),
            ("thread", Json::num(self.thread as f64)),
            ("fid", Json::num(self.fid as f64)),
            ("func", Json::str(self.func.as_str())),
            ("step", Json::num(self.step as f64)),
            ("entry_us", Json::num(self.entry_us as f64)),
            ("exit_us", Json::num(self.exit_us as f64)),
            ("inclusive_us", Json::num(self.inclusive_us as f64)),
            ("exclusive_us", Json::num(self.exclusive_us as f64)),
            ("depth", Json::num(self.depth as f64)),
            (
                "parent",
                match self.parent {
                    Some(p) => Json::num(p as f64),
                    None => Json::Null,
                },
            ),
            ("n_children", Json::num(self.n_children as f64)),
            ("n_messages", Json::num(self.n_messages as f64)),
            ("msg_bytes", Json::num(self.msg_bytes as f64)),
            ("label", Json::str(self.label.as_str())),
            ("score", Json::num(self.score)),
        ])
    }

    /// Parse back from JSON (offline replay).
    pub fn from_json(j: &Json) -> anyhow::Result<ProvRecord> {
        let get_u64 = |k: &str| -> anyhow::Result<u64> {
            j.get(k)
                .and_then(|v| v.as_u64())
                .ok_or_else(|| anyhow::anyhow!("missing field {k}"))
        };
        let get_str = |k: &str| -> anyhow::Result<String> {
            Ok(j.get(k)
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow::anyhow!("missing field {k}"))?
                .to_string())
        };
        Ok(ProvRecord {
            call_id: get_u64("call_id")?,
            app: get_u64("app")? as u32,
            rank: get_u64("rank")? as u32,
            thread: get_u64("thread")? as u32,
            fid: get_u64("fid")? as u32,
            func: get_str("func")?,
            step: get_u64("step")?,
            entry_us: get_u64("entry_us")?,
            exit_us: get_u64("exit_us")?,
            inclusive_us: get_u64("inclusive_us")?,
            exclusive_us: get_u64("exclusive_us")?,
            depth: get_u64("depth")? as u32,
            parent: match j.get("parent") {
                Some(Json::Null) | None => None,
                Some(v) => v.as_u64(),
            },
            n_children: get_u64("n_children")? as u32,
            n_messages: get_u64("n_messages")? as u32,
            msg_bytes: get_u64("msg_bytes")?,
            label: get_str("label")?,
            score: j.get("score").and_then(|v| v.as_f64()).unwrap_or(0.0),
        })
    }

    /// Parse one JSONL line.
    pub fn from_jsonl_line(line: &str) -> anyhow::Result<ProvRecord> {
        Self::from_json(&parse(line)?)
    }

    /// Append the compact JSON form to `buf` — byte-identical to
    /// `to_json().to_string()` but without building the value tree
    /// (provenance writing is on the per-step hot path; see §Perf).
    pub fn write_jsonl(&self, buf: &mut String) {
        use std::fmt::Write;
        buf.push_str("{\"call_id\":");
        let _ = write!(buf, "{}", self.call_id);
        let _ = write!(buf, ",\"app\":{}", self.app);
        let _ = write!(buf, ",\"rank\":{}", self.rank);
        let _ = write!(buf, ",\"thread\":{}", self.thread);
        let _ = write!(buf, ",\"fid\":{}", self.fid);
        // Function names are from the registry (no JSON escapes needed),
        // but escape defensively to keep byte-parity with to_json().
        buf.push_str(",\"func\":");
        escape_str(&self.func, buf);
        let _ = write!(buf, ",\"step\":{}", self.step);
        let _ = write!(buf, ",\"entry_us\":{}", self.entry_us);
        let _ = write!(buf, ",\"exit_us\":{}", self.exit_us);
        let _ = write!(buf, ",\"inclusive_us\":{}", self.inclusive_us);
        let _ = write!(buf, ",\"exclusive_us\":{}", self.exclusive_us);
        let _ = write!(buf, ",\"depth\":{}", self.depth);
        match self.parent {
            Some(p) => {
                let _ = write!(buf, ",\"parent\":{p}");
            }
            None => buf.push_str(",\"parent\":null"),
        }
        let _ = write!(buf, ",\"n_children\":{}", self.n_children);
        let _ = write!(buf, ",\"n_messages\":{}", self.n_messages);
        let _ = write!(buf, ",\"msg_bytes\":{}", self.msg_bytes);
        buf.push_str(",\"label\":");
        escape_str(&self.label, buf);
        buf.push_str(",\"score\":");
        // Match util::json's number formatting (integers without fraction).
        if self.score.is_finite() {
            if self.score == self.score.trunc() && self.score.abs() < 9.0e15 {
                let _ = write!(buf, "{}", self.score as i64);
            } else {
                let _ = write!(buf, "{}", self.score);
            }
        } else {
            buf.push_str("null");
        }
        buf.push('}');
    }
}

fn escape_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{08}' => out.push_str("\\b"),
            '\u{0C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                use std::fmt::Write;
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ad::{ExecRecord, Labeled};

    fn labeled(label: Label) -> Labeled {
        Labeled {
            rec: ExecRecord {
                call_id: 42,
                app: 0,
                rank: 3,
                thread: 0,
                fid: 7,
                step: 9,
                entry_ts: 1000,
                exit_ts: 1500,
                depth: 2,
                parent: Some(41),
                n_children: 1,
                n_messages: 2,
                msg_bytes: 4096,
                exclusive_us: 300,
            },
            label,
            score: 7.5,
        }
    }

    #[test]
    fn json_roundtrip() {
        let r = ProvRecord::from_labeled(&labeled(Label::AnomalyHigh), "MD_NEWTON");
        let line = r.to_json().to_string();
        let back = ProvRecord::from_jsonl_line(&line).unwrap();
        assert_eq!(back, r);
        assert!(back.is_anomaly());
        assert_eq!(back.inclusive_us, 500);
        assert_eq!(back.func, "MD_NEWTON");
    }

    #[test]
    fn normal_label_roundtrip_and_null_parent() {
        let mut l = labeled(Label::Normal);
        l.rec.parent = None;
        let r = ProvRecord::from_labeled(&l, "F");
        let back = ProvRecord::from_jsonl_line(&r.to_json().to_string()).unwrap();
        assert!(!back.is_anomaly());
        assert_eq!(back.parent, None);
    }

    #[test]
    fn malformed_line_rejected() {
        assert!(ProvRecord::from_jsonl_line("{}").is_err());
        assert!(ProvRecord::from_jsonl_line("not json").is_err());
    }

    #[test]
    fn fast_jsonl_is_byte_identical_to_json_tree() {
        for (label, score) in [
            (Label::AnomalyHigh, 7.5),
            (Label::Normal, 0.0),
            (Label::AnomalyLow, 12.0),
            (Label::AnomalyHigh, 6.25),
        ] {
            let mut l = labeled(label);
            l.score = score;
            if score > 10.0 {
                l.rec.parent = None;
            }
            let r = ProvRecord::from_labeled(&l, "MD_NEWTON \"x\"\n");
            let mut fast = String::new();
            r.write_jsonl(&mut fast);
            assert_eq!(fast, r.to_json().to_string());
            // And it parses back.
            assert_eq!(ProvRecord::from_jsonl_line(&fast).unwrap(), r);
        }
    }
}
