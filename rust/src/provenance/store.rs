//! Provenance store: per-rank JSONL writers + run metadata + an in-memory
//! index serving the visualization queries. Byte accounting here is the
//! *reduced* size axis of Fig 9.
//!
//! The paper stores on-node AD output "in predefined file paths directly"
//! and has the viz server fetch them on demand — same shape here: each
//! (app, rank) appends to its own JSONL file; queries run off the index.

use super::record::ProvRecord;
use crate::ad::Labeled;
use crate::trace::FuncRegistry;
use crate::util::json::{parse, Json};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::{Path, PathBuf};

/// Run-level static provenance (paper: architecture, instrumentation
/// configuration, filtering, …).
#[derive(Clone, Debug)]
pub struct RunMetadata {
    /// Free-form run name.
    pub run_id: String,
    /// The full pipeline config as JSON.
    pub config: Json,
    /// Host/platform description.
    pub platform: String,
    /// Per-app function tables.
    pub registries: Vec<Json>,
}

impl RunMetadata {
    pub fn new(run_id: &str, config: Json, registries: &[FuncRegistry]) -> Self {
        RunMetadata {
            run_id: run_id.to_string(),
            config,
            platform: format!(
                "{} {} (simulated workflow substrate)",
                std::env::consts::OS,
                std::env::consts::ARCH
            ),
            registries: registries.iter().map(|r| r.to_json()).collect(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("run_id", Json::str(self.run_id.as_str())),
            ("platform", Json::str(self.platform.as_str())),
            ("config", self.config.clone()),
            ("registries", Json::Arr(self.registries.clone())),
        ])
    }
}

/// Disk-backed (optional) provenance database with in-memory indexes.
pub struct ProvDb {
    dir: Option<PathBuf>,
    writers: HashMap<(u32, u32), BufWriter<File>>,
    bytes_written: u64,
    /// All records, append order.
    records: Vec<ProvRecord>,
    /// Index: (app, rank) → record positions.
    by_rank: HashMap<(u32, u32), Vec<usize>>,
    /// Index: (app, fid) → record positions.
    by_func: HashMap<(u32, u32), Vec<usize>>,
    n_anomalies: u64,
}

impl ProvDb {
    /// On-disk store rooted at `dir`.
    pub fn create(dir: &Path) -> Result<ProvDb> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating provenance dir {}", dir.display()))?;
        Ok(ProvDb {
            dir: Some(dir.to_path_buf()),
            writers: HashMap::new(),
            bytes_written: 0,
            records: Vec::new(),
            by_rank: HashMap::new(),
            by_func: HashMap::new(),
            n_anomalies: 0,
        })
    }

    /// In-memory only (benchmarks, size modelling).
    pub fn in_memory() -> ProvDb {
        ProvDb {
            dir: None,
            writers: HashMap::new(),
            bytes_written: 0,
            records: Vec::new(),
            by_rank: HashMap::new(),
            by_func: HashMap::new(),
            n_anomalies: 0,
        }
    }

    /// Write run metadata (once, at run start).
    pub fn write_metadata(&mut self, meta: &RunMetadata) -> Result<()> {
        let text = meta.to_json().to_pretty();
        self.bytes_written += text.len() as u64;
        if let Some(dir) = &self.dir {
            std::fs::write(dir.join("metadata.json"), &text).context("writing metadata")?;
        }
        Ok(())
    }

    /// Append kept records from one AD step, resolving names via `reg`.
    pub fn append_step(&mut self, kept: &[Labeled], reg: &FuncRegistry) -> Result<()> {
        for l in kept {
            let rec = ProvRecord::from_labeled(l, reg.name(l.rec.fid));
            self.append_record(rec)?;
        }
        Ok(())
    }

    /// Append one record.
    pub fn append_record(&mut self, rec: ProvRecord) -> Result<()> {
        // Direct serialization (no Json tree) — hot path, see §Perf.
        let mut line = String::with_capacity(360);
        rec.write_jsonl(&mut line);
        self.bytes_written += line.len() as u64 + 1;
        if let Some(dir) = &self.dir {
            let key = (rec.app, rec.rank);
            let w = match self.writers.get_mut(&key) {
                Some(w) => w,
                None => {
                    let path = dir.join(format!("prov_app{}_rank{}.jsonl", rec.app, rec.rank));
                    let f = File::options()
                        .create(true)
                        .append(true)
                        .open(&path)
                        .with_context(|| format!("opening {}", path.display()))?;
                    self.writers.entry(key).or_insert_with(|| BufWriter::new(f))
                }
            };
            w.write_all(line.as_bytes())?;
            w.write_all(b"\n")?;
        }
        self.index(rec);
        Ok(())
    }

    fn index(&mut self, rec: ProvRecord) {
        let pos = self.records.len();
        self.by_rank.entry((rec.app, rec.rank)).or_default().push(pos);
        self.by_func.entry((rec.app, rec.fid)).or_default().push(pos);
        if rec.is_anomaly() {
            self.n_anomalies += 1;
        }
        self.records.push(rec);
    }

    /// Flush all writers.
    pub fn flush(&mut self) -> Result<()> {
        for w in self.writers.values_mut() {
            w.flush()?;
        }
        Ok(())
    }

    /// Total JSON bytes produced (the Fig 9 "reduced" size).
    pub fn bytes_written(&self) -> u64 {
        self.bytes_written
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn anomaly_count(&self) -> u64 {
        self.n_anomalies
    }

    /// Load a store back from disk (offline replay / `serve`).
    pub fn load(dir: &Path) -> Result<ProvDb> {
        let mut db = ProvDb::in_memory();
        db.dir = None;
        let entries = std::fs::read_dir(dir)
            .with_context(|| format!("reading provenance dir {}", dir.display()))?;
        let mut paths: Vec<PathBuf> = entries
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .map(|n| n.starts_with("prov_") && n.ends_with(".jsonl"))
                    .unwrap_or(false)
            })
            .collect();
        paths.sort();
        for path in paths {
            let f = File::open(&path).with_context(|| format!("opening {}", path.display()))?;
            for line in BufReader::new(f).lines() {
                let line = line?;
                if line.trim().is_empty() {
                    continue;
                }
                let rec = ProvRecord::from_jsonl_line(&line)
                    .with_context(|| format!("parsing record in {}", path.display()))?;
                db.bytes_written += line.len() as u64 + 1;
                db.index(rec);
            }
        }
        Ok(db)
    }

    /// Load run metadata JSON if present.
    pub fn load_metadata(dir: &Path) -> Result<Json> {
        let text = std::fs::read_to_string(dir.join("metadata.json"))?;
        Ok(parse(&text)?)
    }

    /// Run a query against the index.
    pub fn query(&self, q: &ProvQuery) -> Vec<&ProvRecord> {
        // Start from the most selective available index.
        let candidates: Box<dyn Iterator<Item = &ProvRecord>> = match (q.rank, q.fid) {
            (Some((app, rank)), _) => match self.by_rank.get(&(app, rank)) {
                Some(ix) => Box::new(ix.iter().map(|&i| &self.records[i])),
                None => Box::new(std::iter::empty()),
            },
            (None, Some((app, fid))) => match self.by_func.get(&(app, fid)) {
                Some(ix) => Box::new(ix.iter().map(|&i| &self.records[i])),
                None => Box::new(std::iter::empty()),
            },
            (None, None) => Box::new(self.records.iter()),
        };
        let mut out: Vec<&ProvRecord> = candidates
            .filter(|r| q.fid.map(|(a, f)| r.app == a && r.fid == f).unwrap_or(true))
            .filter(|r| q.step.map(|s| r.step == s).unwrap_or(true))
            .filter(|r| !q.anomalies_only || r.is_anomaly())
            .filter(|r| {
                q.ts_range
                    .map(|(lo, hi)| r.exit_us >= lo && r.entry_us <= hi)
                    .unwrap_or(true)
            })
            .collect();
        if q.order_by_score {
            out.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
        } else {
            out.sort_by_key(|r| r.entry_us);
        }
        if let Some(n) = q.limit {
            out.truncate(n);
        }
        out
    }

    /// All records of a rank for a step, entry-ordered — the call-stack
    /// view's input (Fig 6).
    pub fn call_stack(&self, app: u32, rank: u32, step: u64) -> Vec<&ProvRecord> {
        self.query(&ProvQuery {
            rank: Some((app, rank)),
            step: Some(step),
            ..ProvQuery::default()
        })
    }
}

/// Declarative query over the provenance index.
#[derive(Clone, Debug, Default)]
pub struct ProvQuery {
    /// Filter by (app, rank).
    pub rank: Option<(u32, u32)>,
    /// Filter by (app, fid).
    pub fid: Option<(u32, u32)>,
    /// Filter by step.
    pub step: Option<u64>,
    /// Overlap with a virtual-time range (µs).
    pub ts_range: Option<(u64, u64)>,
    /// Anomalies only.
    pub anomalies_only: bool,
    /// Sort by score descending instead of entry time.
    pub order_by_score: bool,
    /// Truncate results.
    pub limit: Option<usize>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ad::{ExecRecord, Label, Labeled};

    fn labeled(fid: u32, rank: u32, step: u64, dur: u64, label: Label, id: u64) -> Labeled {
        Labeled {
            rec: ExecRecord {
                call_id: id,
                app: 0,
                rank,
                thread: 0,
                fid,
                step,
                entry_ts: id * 100,
                exit_ts: id * 100 + dur,
                depth: 0,
                parent: None,
                n_children: 0,
                n_messages: 0,
                msg_bytes: 0,
                exclusive_us: dur,
            },
            label,
            score: dur as f64 / 100.0,
        }
    }

    fn reg() -> FuncRegistry {
        let mut r = FuncRegistry::new();
        r.register("F0", false);
        r.register("F1", false);
        r
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("chimbuko-prov-{tag}-{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    #[test]
    fn write_load_roundtrip() {
        let dir = tmpdir("rt");
        let mut db = ProvDb::create(&dir).unwrap();
        let reg = reg();
        db.write_metadata(&RunMetadata::new(
            "test-run",
            Json::obj(vec![("alpha", Json::num(6.0))]),
            &[reg.clone()],
        ))
        .unwrap();
        let kept = vec![
            labeled(0, 1, 5, 100, Label::Normal, 1),
            labeled(1, 1, 5, 900, Label::AnomalyHigh, 2),
            labeled(0, 2, 6, 100, Label::Normal, 3),
        ];
        db.append_step(&kept, &reg).unwrap();
        db.flush().unwrap();
        assert_eq!(db.len(), 3);
        assert_eq!(db.anomaly_count(), 1);
        assert!(db.bytes_written() > 0);

        let loaded = ProvDb::load(&dir).unwrap();
        assert_eq!(loaded.len(), 3);
        assert_eq!(loaded.anomaly_count(), 1);
        let meta = ProvDb::load_metadata(&dir).unwrap();
        assert_eq!(meta.get("run_id").unwrap().as_str(), Some("test-run"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn queries_filter_and_order() {
        let mut db = ProvDb::in_memory();
        let reg = reg();
        let kept = vec![
            labeled(0, 1, 5, 100, Label::Normal, 1),
            labeled(1, 1, 5, 900, Label::AnomalyHigh, 2),
            labeled(1, 1, 6, 950, Label::AnomalyHigh, 3),
            labeled(0, 2, 5, 120, Label::Normal, 4),
        ];
        db.append_step(&kept, &reg).unwrap();

        let r15 = db.call_stack(0, 1, 5);
        assert_eq!(r15.len(), 2);
        assert!(r15[0].entry_us <= r15[1].entry_us);

        let anoms = db.query(&ProvQuery { anomalies_only: true, ..Default::default() });
        assert_eq!(anoms.len(), 2);

        let top = db.query(&ProvQuery {
            order_by_score: true,
            limit: Some(1),
            ..Default::default()
        });
        assert_eq!(top[0].call_id, 3);

        let by_func = db.query(&ProvQuery { fid: Some((0, 1)), ..Default::default() });
        assert_eq!(by_func.len(), 2);
        assert!(by_func.iter().all(|r| r.func == "F1"));

        let windowed = db.query(&ProvQuery {
            ts_range: Some((0, 150)),
            ..Default::default()
        });
        assert_eq!(windowed.len(), 1);
        assert_eq!(windowed[0].call_id, 1);
    }

    #[test]
    fn missing_indexes_return_empty() {
        let db = ProvDb::in_memory();
        assert!(db.call_stack(0, 99, 0).is_empty());
        assert!(db
            .query(&ProvQuery { fid: Some((0, 99)), ..Default::default() })
            .is_empty());
    }

    #[test]
    fn in_memory_counts_bytes() {
        let mut db = ProvDb::in_memory();
        db.append_step(&[labeled(0, 0, 0, 50, Label::Normal, 1)], &reg()).unwrap();
        assert!(db.bytes_written() > 100);
    }
}
