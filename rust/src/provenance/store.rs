//! Provenance store: per-rank JSONL writers + run metadata + an in-memory
//! index serving the visualization queries. Byte accounting here is the
//! *reduced* size axis of Fig 9.
//!
//! The paper stores on-node AD output "in predefined file paths directly"
//! and has the viz server fetch them on demand — same shape here: each
//! (app, rank) appends to its own JSONL file; queries run off the index.

use super::record::ProvRecord;
use crate::ad::Labeled;
use crate::trace::FuncRegistry;
use crate::util::json::{parse, Json};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

/// Run-level static provenance (paper: architecture, instrumentation
/// configuration, filtering, …).
#[derive(Clone, Debug)]
pub struct RunMetadata {
    /// Free-form run name.
    pub run_id: String,
    /// The full pipeline config as JSON.
    pub config: Json,
    /// Host/platform description.
    pub platform: String,
    /// Per-app function tables.
    pub registries: Vec<Json>,
}

impl RunMetadata {
    pub fn new(run_id: &str, config: Json, registries: &[FuncRegistry]) -> Self {
        RunMetadata {
            run_id: run_id.to_string(),
            config,
            platform: format!(
                "{} {} (simulated workflow substrate)",
                std::env::consts::OS,
                std::env::consts::ARCH
            ),
            registries: registries.iter().map(|r| r.to_json()).collect(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("run_id", Json::str(self.run_id.as_str())),
            ("platform", Json::str(self.platform.as_str())),
            ("config", self.config.clone()),
            ("registries", Json::Arr(self.registries.clone())),
        ])
    }
}

/// Disk-backed (optional) provenance database with in-memory indexes.
pub struct ProvDb {
    dir: Option<PathBuf>,
    writers: HashMap<(u32, u32), BufWriter<File>>,
    bytes_written: u64,
    /// All records, append order.
    records: Vec<ProvRecord>,
    /// Index: (app, rank) → record positions.
    by_rank: HashMap<(u32, u32), Vec<usize>>,
    /// Index: (app, fid) → record positions.
    by_func: HashMap<(u32, u32), Vec<usize>>,
    n_anomalies: u64,
}

impl ProvDb {
    /// On-disk store rooted at `dir`.
    pub fn create(dir: &Path) -> Result<ProvDb> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating provenance dir {}", dir.display()))?;
        Ok(ProvDb {
            dir: Some(dir.to_path_buf()),
            writers: HashMap::new(),
            bytes_written: 0,
            records: Vec::new(),
            by_rank: HashMap::new(),
            by_func: HashMap::new(),
            n_anomalies: 0,
        })
    }

    /// In-memory only (benchmarks, size modelling).
    pub fn in_memory() -> ProvDb {
        ProvDb {
            dir: None,
            writers: HashMap::new(),
            bytes_written: 0,
            records: Vec::new(),
            by_rank: HashMap::new(),
            by_func: HashMap::new(),
            n_anomalies: 0,
        }
    }

    /// Write run metadata (once, at run start).
    pub fn write_metadata(&mut self, meta: &RunMetadata) -> Result<()> {
        let text = meta.to_json().to_pretty();
        self.bytes_written += text.len() as u64;
        if let Some(dir) = &self.dir {
            std::fs::write(dir.join("metadata.json"), &text).context("writing metadata")?;
        }
        Ok(())
    }

    /// Append kept records from one AD step, resolving names via `reg`.
    pub fn append_step(&mut self, kept: &[Labeled], reg: &FuncRegistry) -> Result<()> {
        for l in kept {
            let rec = ProvRecord::from_labeled(l, reg.name(l.rec.fid));
            self.append_record(rec)?;
        }
        Ok(())
    }

    /// Append one record.
    pub fn append_record(&mut self, rec: ProvRecord) -> Result<()> {
        // Direct serialization (no Json tree) — hot path, see §Perf.
        let mut line = String::with_capacity(360);
        rec.write_jsonl(&mut line);
        self.bytes_written += line.len() as u64 + 1;
        if let Some(dir) = &self.dir {
            let key = (rec.app, rec.rank);
            let w = match self.writers.get_mut(&key) {
                Some(w) => w,
                None => {
                    let path = dir.join(format!("prov_app{}_rank{}.jsonl", rec.app, rec.rank));
                    let f = File::options()
                        .create(true)
                        .append(true)
                        .open(&path)
                        .with_context(|| format!("opening {}", path.display()))?;
                    self.writers.entry(key).or_insert_with(|| BufWriter::new(f))
                }
            };
            w.write_all(line.as_bytes())?;
            w.write_all(b"\n")?;
        }
        self.index(rec);
        Ok(())
    }

    fn index(&mut self, rec: ProvRecord) {
        let pos = self.records.len();
        self.by_rank.entry((rec.app, rec.rank)).or_default().push(pos);
        self.by_func.entry((rec.app, rec.fid)).or_default().push(pos);
        if rec.is_anomaly() {
            self.n_anomalies += 1;
        }
        self.records.push(rec);
    }

    /// Flush all writers.
    pub fn flush(&mut self) -> Result<()> {
        for w in self.writers.values_mut() {
            w.flush()?;
        }
        Ok(())
    }

    /// Total JSON bytes produced (the Fig 9 "reduced" size).
    pub fn bytes_written(&self) -> u64 {
        self.bytes_written
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn anomaly_count(&self) -> u64 {
        self.n_anomalies
    }

    /// Load a store back from disk (offline replay / `serve`). Reads
    /// both the classic JSONL layout and the provDB service's binary
    /// `.provseg` segment logs (see [`codec`](super::codec)), in path
    /// order, so `chimbuko replay`/`serve --dir` work on either kind of
    /// data directory. Records stream into the index one at a time; the
    /// whole log set is never materialized.
    pub fn load(dir: &Path) -> Result<ProvDb> {
        let mut db = ProvDb::in_memory();
        db.dir = None;
        scan_log_dir(dir, false, &mut |buf, disk_bytes| {
            let (rec, _) = super::codec::decode(&buf)
                .with_context(|| format!("decoding record from {}", dir.display()))?;
            db.bytes_written += disk_bytes;
            db.index(rec);
            Ok(())
        })?;
        Ok(db)
    }

    /// Load run metadata JSON if present.
    pub fn load_metadata(dir: &Path) -> Result<Json> {
        let text = std::fs::read_to_string(dir.join("metadata.json"))?;
        Ok(parse(&text)?)
    }

    /// Run a query against the index.
    pub fn query(&self, q: &ProvQuery) -> Vec<&ProvRecord> {
        // Start from the most selective available index.
        let candidates: Box<dyn Iterator<Item = &ProvRecord>> = match (q.rank, q.fid) {
            (Some((app, rank)), _) => match self.by_rank.get(&(app, rank)) {
                Some(ix) => Box::new(ix.iter().map(|&i| &self.records[i])),
                None => Box::new(std::iter::empty()),
            },
            (None, Some((app, fid))) => match self.by_func.get(&(app, fid)) {
                Some(ix) => Box::new(ix.iter().map(|&i| &self.records[i])),
                None => Box::new(std::iter::empty()),
            },
            (None, None) => Box::new(self.records.iter()),
        };
        let mut out: Vec<&ProvRecord> = candidates.filter(|r| q.matches(r)).collect();
        if q.order_by_score {
            out.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
        } else {
            out.sort_by_key(|r| r.entry_us);
        }
        if let Some(n) = q.limit {
            out.truncate(n);
        }
        out
    }

    /// All records of a rank for a step, entry-ordered — the call-stack
    /// view's input (Fig 6).
    pub fn call_stack(&self, app: u32, rank: u32, step: u64) -> Vec<&ProvRecord> {
        self.query(&ProvQuery {
            rank: Some((app, rank)),
            step: Some(step),
            ..ProvQuery::default()
        })
    }
}

/// One partition log file, parsed from its name
/// (`prov_app<A>_rank<R>[_seg<K>].<jsonl|provseg>`).
pub(crate) struct PartFile {
    /// `(app, rank)` when the name follows the partition scheme;
    /// `None` for `prov_*` files outside it (scanned last, by extension).
    pub key: Option<(u32, u32)>,
    /// Rolling-segment index (`_seg<K>`); `None` for legacy logs.
    pub seg: Option<u32>,
    pub jsonl: bool,
    pub path: PathBuf,
}

/// Parse `prov_app<A>_rank<R>[_seg<K>].<ext>` → `(app, rank, seg, jsonl)`.
pub(crate) fn parse_part_name(name: &str) -> Option<(u32, u32, Option<u32>, bool)> {
    let (stem, jsonl) = match name.strip_suffix(".jsonl") {
        Some(s) => (s, true),
        None => (name.strip_suffix(".provseg")?, false),
    };
    let rest = stem.strip_prefix("prov_app")?;
    let (app, rest) = rest.split_once("_rank")?;
    let app: u32 = app.parse().ok()?;
    let (rank, seg) = match rest.split_once("_seg") {
        Some((r, k)) => (r, Some(k.parse::<u32>().ok()?)),
        None => (rest, None),
    };
    Some((app, rank.parse().ok()?, seg, jsonl))
}

/// List a directory's partition log files in replay order: partitions
/// numerically by `(app, rank)`; within one partition JSONL (oldest —
/// pre-migration) first, then the legacy single `.provseg`, then rolling
/// `_seg<K>` files by K. `prov_*` files outside the naming scheme sort
/// last in path order. The offline loader and the provDB restart
/// recovery share this ordering, so sequence re-assignment is identical
/// wherever a directory is replayed.
pub(crate) fn list_partition_files(dir: &Path) -> Result<Vec<PartFile>> {
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("reading provenance dir {}", dir.display()))?;
    let mut files: Vec<PartFile> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter_map(|path| {
            let name = path.file_name().and_then(|n| n.to_str())?;
            if !name.starts_with("prov_")
                || !(name.ends_with(".jsonl") || name.ends_with(".provseg"))
            {
                return None;
            }
            match parse_part_name(name) {
                Some((app, rank, seg, jsonl)) => {
                    Some(PartFile { key: Some((app, rank)), seg, jsonl, path })
                }
                None => {
                    let jsonl = name.ends_with(".jsonl");
                    Some(PartFile { key: None, seg: None, jsonl, path })
                }
            }
        })
        .collect();
    files.sort_by(|a, b| {
        let kind = |f: &PartFile| -> (u8, u32) {
            match (f.jsonl, f.seg) {
                (true, _) => (0, 0),
                (false, None) => (1, 0),
                (false, Some(k)) => (2, k),
            }
        };
        (a.key.is_none(), a.key.unwrap_or((0, 0)), kind(a))
            .cmp(&(b.key.is_none(), b.key.unwrap_or((0, 0)), kind(b)))
            .then_with(|| a.path.cmp(&b.path))
    });
    Ok(files)
}

/// Scan a provenance data directory's replayable log contents — shared
/// by the offline [`ProvDb::load`] and the provDB service's restart
/// recovery, so the two loaders cannot diverge. Reads every format
/// (`prov_*.jsonl`, legacy v1 `.provseg`, sealed v2 `_seg<K>.provseg`)
/// in [`list_partition_files`] order, records in file order; damage in
/// any format (torn tails, mid-file corruption, short files) degrades
/// to logged warnings keeping everything before it. Each record streams
/// to `sink` as `(encoded record, on-disk bytes)` — JSONL line +
/// newline, v1 record + CRC trailer, or an amortized share of a packed
/// v2 segment — and v1 segment files are read in bounded [`SCAN_CHUNK`]
/// windows, so recovery memory never scales with partition size.
///
/// With `repair` set (the provDB recovery path — the caller owns the
/// directory), damaged files are made safe to append to again: a torn
/// tail is truncated to the last clean record boundary (0 when even the
/// 6-byte file header was torn), and a corrupted file is sidelined to
/// `*.corrupt` (preserved for offline salvage) while its clean prefix
/// is kept in place — damaged *v2* segments are rewritten as v1 row
/// files so the salvaged records re-home as appendable hot data.
/// Without this, records appended after a crash would sit behind the
/// damage and be dropped at the *next* restart. The offline loader
/// passes `false` (read-only).
pub(crate) fn scan_log_dir(
    dir: &Path,
    repair: bool,
    sink: &mut dyn FnMut(Vec<u8>, u64) -> Result<()>,
) -> Result<()> {
    for f in list_partition_files(dir)? {
        if f.jsonl {
            scan_jsonl_file(&f.path, repair, sink)?;
        } else {
            scan_segment_file(&f.path, repair, sink)?;
        }
    }
    Ok(())
}

/// Bytes per refill of the streaming v1 segment scanner — the bound on
/// recovery's working set per file (plus one record, max ~1 MiB).
pub(crate) const SCAN_CHUNK: usize = 256 << 10;

/// Scan one `.provseg` file (either codec version), streaming records to
/// `sink`. v1 row files are read incrementally in [`SCAN_CHUNK`] windows
/// rather than one `std::fs::read`; sealed v2 files are bounded by the
/// `segment_records` knob, so a whole-image read is already bounded.
pub(crate) fn scan_segment_file(
    path: &Path,
    repair: bool,
    sink: &mut dyn FnMut(Vec<u8>, u64) -> Result<()>,
) -> Result<()> {
    use std::io::Read;
    let mut f = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let file_len = f.metadata()?.len();
    let mut header = [0u8; super::codec::SEG_HEADER_LEN];
    let mut got = 0usize;
    while got < header.len() {
        match f.read(&mut header[got..])? {
            0 => break,
            n => got += n,
        }
    }
    if got < super::codec::SEG_HEADER_LEN {
        // A crash between file creation and the first header flush
        // leaves a short/empty file — a torn tail, not foreign data.
        if got > 0 {
            crate::log_warn!(
                "prov",
                "{}: dropping {got} torn trailing bytes (crash mid-append)",
                path.display()
            );
            if repair {
                truncate_to(path, 0);
            }
        }
        return Ok(());
    }
    let magic = u32::from_le_bytes(header[..4].try_into().unwrap());
    anyhow::ensure!(
        magic == super::codec::SEG_MAGIC,
        "reading segment {}: bad segment magic {magic:#010x}",
        path.display()
    );
    let version = u16::from_le_bytes(header[4..6].try_into().unwrap());
    match version {
        super::codec::CODEC_VERSION => scan_v1_segment(f, path, file_len, repair, sink),
        super::codec::CODEC_VERSION_V2 => scan_v2_segment(path, repair, sink),
        v => anyhow::bail!(
            "reading segment {}: unsupported segment codec version {v}",
            path.display()
        ),
    }
}

fn truncate_to(path: &Path, valid: u64) {
    let res = File::options().write(true).open(path).and_then(|f| f.set_len(valid));
    match res {
        Ok(()) => crate::log_warn!(
            "prov",
            "{}: truncated to {valid} bytes (last clean record boundary)",
            path.display()
        ),
        Err(e) => crate::log_warn!(
            "prov",
            "{}: could not truncate damaged segment: {e}",
            path.display()
        ),
    }
}

/// Incremental scan of a v1 row segment: refill a bounded window, parse
/// complete `record + crc` units off its head, repeat. Never holds more
/// than [`SCAN_CHUNK`] + one record of the file in memory.
fn scan_v1_segment(
    mut f: File,
    path: &Path,
    file_len: u64,
    repair: bool,
    sink: &mut dyn FnMut(Vec<u8>, u64) -> Result<()>,
) -> Result<()> {
    use std::io::Read;
    let mut buf: Vec<u8> = Vec::new();
    let mut start = 0usize; // parse offset into `buf`
    let mut consumed = super::codec::SEG_HEADER_LEN as u64; // clean boundary in the file
    let mut n_records = 0usize;
    let mut eof = false;
    let mut corrupt: Option<String> = None;
    loop {
        match super::codec::parse_segment_record(&buf[start..]) {
            super::codec::SegRecordParse::Record { total } => {
                sink(buf[start..start + total - 4].to_vec(), total as u64)?;
                start += total;
                consumed += total as u64;
                n_records += 1;
            }
            super::codec::SegRecordParse::NeedMore => {
                if eof {
                    break;
                }
                if start > 0 {
                    buf.drain(..start);
                    start = 0;
                }
                let got = f.by_ref().take(SCAN_CHUNK as u64).read_to_end(&mut buf)?;
                if got == 0 {
                    eof = true;
                }
            }
            super::codec::SegRecordParse::Corrupt(e) => {
                corrupt = Some(format!("{e} at byte {consumed}"));
                break;
            }
        }
    }
    let torn = file_len.saturating_sub(consumed);
    if let Some(why) = &corrupt {
        crate::log_warn!(
            "prov",
            "{}: {} — keeping {} records before the damage",
            path.display(),
            why,
            n_records
        );
    } else if torn > 0 {
        crate::log_warn!(
            "prov",
            "{}: dropping {torn} torn trailing bytes (crash mid-append)",
            path.display()
        );
    }
    if repair && torn > 0 {
        if corrupt.is_some() {
            // Corruption (CRC/structure failure mid-file) may hide
            // salvageable records past the damage: preserve the whole
            // file as *.corrupt, then cut the live segment back to its
            // clean prefix so appends resume at a valid boundary.
            // fs::copy (not rename) for the sideline — the live path
            // must never be missing if we crash here.
            let sidelined = path.with_extension("provseg.corrupt");
            let res = std::fs::copy(path, &sidelined).and_then(|_| {
                File::options().write(true).open(path).and_then(|g| g.set_len(consumed))
            });
            match res {
                Ok(()) => crate::log_warn!(
                    "prov",
                    "{}: damaged segment sidelined to {} and clean prefix \
                     ({} records) kept",
                    path.display(),
                    sidelined.display(),
                    n_records
                ),
                Err(e) => crate::log_warn!(
                    "prov",
                    "{}: could not sideline damaged segment: {e}",
                    path.display()
                ),
            }
        } else {
            // Pure torn tail: truncate to the last clean record boundary
            // so post-crash appends don't land behind the tear and
            // vanish at the next restart.
            truncate_to(path, consumed);
        }
    }
    Ok(())
}

/// Scan a sealed v2 segment: decode the columns, re-encode each record
/// into the row codec for the sink. A damaged file (torn tail, body CRC
/// failure) degrades to its salvageable prefix; with `repair` the
/// original is sidelined and the prefix rewritten as a v1 row file, so
/// the records re-home as appendable hot data and reseal at the next
/// flush.
fn scan_v2_segment(
    path: &Path,
    repair: bool,
    sink: &mut dyn FnMut(Vec<u8>, u64) -> Result<()>,
) -> Result<()> {
    let bytes = std::fs::read(path).with_context(|| format!("opening {}", path.display()))?;
    let scan = super::codec::read_segment_v2(&bytes)
        .with_context(|| format!("reading segment {}", path.display()))?;
    let n = scan.records.len();
    let mut encoded: Vec<Vec<u8>> = Vec::with_capacity(n);
    for (_, rec) in &scan.records {
        let mut b = Vec::with_capacity(192);
        super::codec::encode(rec, &mut b);
        encoded.push(b);
    }
    if !scan.complete {
        let why = scan.corrupt.as_deref().unwrap_or("torn tail");
        crate::log_warn!(
            "prov",
            "{}: damaged v2 segment ({why}) — keeping {n} records before the damage",
            path.display()
        );
        if repair {
            let sidelined = path.with_extension("provseg.corrupt");
            let tmp = path.with_extension("tmp");
            let mut clean: Vec<u8> = super::codec::seg_file_header().to_vec();
            for b in &encoded {
                clean.extend_from_slice(b);
                clean.extend_from_slice(&super::codec::crc32(b).to_le_bytes());
            }
            let res = std::fs::copy(path, &sidelined)
                .and_then(|_| std::fs::write(&tmp, &clean))
                .and_then(|()| std::fs::rename(&tmp, path));
            match res {
                Ok(()) => crate::log_warn!(
                    "prov",
                    "{}: damaged v2 segment sidelined to {} and salvaged prefix \
                     ({n} records) rewritten as a v1 row file",
                    path.display(),
                    sidelined.display()
                ),
                Err(e) => crate::log_warn!(
                    "prov",
                    "{}: could not sideline damaged v2 segment: {e}",
                    path.display()
                ),
            }
        }
    }
    let flen = bytes.len() as u64;
    for (i, b) in encoded.into_iter().enumerate() {
        // Price records at what the disk actually holds: an amortized
        // share of the packed file (shares sum exactly to the file
        // size), or the v1 row cost once a damaged file was rewritten.
        let disk = if scan.complete {
            flen * (i as u64 + 1) / n as u64 - flen * i as u64 / n as u64
        } else {
            b.len() as u64 + 4
        };
        sink(b, disk)?;
    }
    Ok(())
}

pub(crate) fn scan_jsonl_file(
    path: &Path,
    repair: bool,
    sink: &mut dyn FnMut(Vec<u8>, u64) -> Result<()>,
) -> Result<()> {
    let bytes = std::fs::read(path).with_context(|| format!("opening {}", path.display()))?;
    let mut pos = 0usize; // scan position
    let mut good_end = 0usize; // end of the last cleanly parsed line
    let mut n_records = 0usize;
    let mut damage: Option<String> = None;
    while pos < bytes.len() {
        let Some(nl) = bytes[pos..].iter().position(|&b| b == b'\n') else {
            break; // torn tail: trailing fragment without its newline
        };
        let line_bytes = &bytes[pos..pos + nl];
        let next = pos + nl + 1;
        let line = match std::str::from_utf8(line_bytes) {
            Ok(l) => l.trim(),
            Err(e) => {
                damage = Some(format!("non-UTF-8 line at byte {pos}: {e}"));
                break;
            }
        };
        if !line.is_empty() {
            match ProvRecord::from_jsonl_line(line) {
                Ok(rec) => {
                    let mut buf = Vec::with_capacity(192);
                    super::codec::encode(&rec, &mut buf);
                    sink(buf, (nl + 1) as u64)?;
                    n_records += 1;
                }
                Err(e) => {
                    damage = Some(format!("bad record at byte {pos}: {e}"));
                    break;
                }
            }
        }
        pos = next;
        good_end = next;
    }
    let leftover = bytes.len() - good_end;
    if let Some(why) = &damage {
        // Same degrade-to-warning policy as segments: a damaged line
        // (partial append merged with its successor, bit rot) keeps the
        // records before it instead of refusing the whole directory.
        crate::log_warn!(
            "prov",
            "{}: {} — keeping {} records before the damage",
            path.display(),
            why,
            n_records
        );
    } else if leftover > 0 {
        crate::log_warn!(
            "prov",
            "{}: dropping {leftover} torn trailing bytes (crash mid-append)",
            path.display()
        );
    }
    // Repair mirrors the segment policy so post-recovery appends never
    // land behind damage and vanish at the next restart: a pure torn
    // tail is truncated away; detected corruption sidelines the whole
    // file for offline salvage and rewrites the clean prefix (verbatim
    // bytes — JSONL needs no re-encode) atomically in place.
    if repair && leftover > 0 {
        if damage.is_some() {
            let sidelined = path.with_extension("jsonl.corrupt");
            let tmp = path.with_extension("tmp");
            let res = std::fs::copy(path, &sidelined)
                .and_then(|_| std::fs::write(&tmp, &bytes[..good_end]))
                .and_then(|()| std::fs::rename(&tmp, path));
            match res {
                Ok(()) => crate::log_warn!(
                    "prov",
                    "{}: damaged log sidelined to {} and clean prefix \
                     ({n_records} records) rewritten",
                    path.display(),
                    sidelined.display()
                ),
                Err(e) => crate::log_warn!(
                    "prov",
                    "{}: could not sideline damaged log: {e}",
                    path.display()
                ),
            }
        } else {
            let res = File::options()
                .write(true)
                .open(path)
                .and_then(|f| f.set_len(good_end as u64));
            match res {
                Ok(()) => crate::log_warn!(
                    "prov",
                    "{}: truncated to {good_end} bytes (last clean line boundary)",
                    path.display()
                ),
                Err(e) => crate::log_warn!(
                    "prov",
                    "{}: could not truncate torn log: {e}",
                    path.display()
                ),
            }
        }
    }
    Ok(())
}

/// Declarative query over the provenance index.
///
/// Every filter here is also understood by the networked provenance
/// database ([`crate::provdb`]), whose shard-side query engine applies
/// [`ProvQuery::matches`] — keeping local and remote semantics identical
/// by construction.
#[derive(Clone, Debug, Default)]
pub struct ProvQuery {
    /// Filter by app alone (use `rank`/`fid` for app-scoped keys).
    pub app: Option<u32>,
    /// Filter by (app, rank).
    pub rank: Option<(u32, u32)>,
    /// Filter by (app, fid).
    pub fid: Option<(u32, u32)>,
    /// Filter by step.
    pub step: Option<u64>,
    /// Filter by an inclusive step window `[lo, hi]`.
    pub step_range: Option<(u64, u64)>,
    /// Overlap with a virtual-time range (µs).
    pub ts_range: Option<(u64, u64)>,
    /// Anomalies only.
    pub anomalies_only: bool,
    /// Keep records with `score >= min_score` only.
    pub min_score: Option<f64>,
    /// Exact label match ("normal" | "anomaly_high" | "anomaly_low").
    pub label: Option<String>,
    /// Sort by score descending instead of entry time.
    pub order_by_score: bool,
    /// Truncate results.
    pub limit: Option<usize>,
}

impl ProvQuery {
    /// Does `r` satisfy every filter of this query? The single source of
    /// truth for filter semantics — the local index and the provDB shard
    /// workers both call this.
    pub fn matches(&self, r: &ProvRecord) -> bool {
        self.app.map(|a| r.app == a).unwrap_or(true)
            && self.rank.map(|(a, k)| r.app == a && r.rank == k).unwrap_or(true)
            && self.fid.map(|(a, f)| r.app == a && r.fid == f).unwrap_or(true)
            && self.step.map(|s| r.step == s).unwrap_or(true)
            && self
                .step_range
                .map(|(lo, hi)| r.step >= lo && r.step <= hi)
                .unwrap_or(true)
            && (!self.anomalies_only || r.is_anomaly())
            && self.min_score.map(|m| r.score >= m).unwrap_or(true)
            && self.label.as_deref().map(|l| r.label == l).unwrap_or(true)
            && self
                .ts_range
                .map(|(lo, hi)| r.exit_us >= lo && r.entry_us <= hi)
                .unwrap_or(true)
    }

    /// JSON form (the provDB wire protocol and `/api/provenance` carry
    /// queries in this shape). Unset filters are omitted.
    pub fn to_json(&self) -> Json {
        let pair = |(a, b): (u32, u32)| {
            Json::arr(vec![Json::num(a as f64), Json::num(b as f64)])
        };
        let range = |(lo, hi): (u64, u64)| {
            Json::arr(vec![Json::num(lo as f64), Json::num(hi as f64)])
        };
        let mut fields: Vec<(&str, Json)> = Vec::new();
        if let Some(a) = self.app {
            fields.push(("app", Json::num(a as f64)));
        }
        if let Some(k) = self.rank {
            fields.push(("rank", pair(k)));
        }
        if let Some(k) = self.fid {
            fields.push(("fid", pair(k)));
        }
        if let Some(s) = self.step {
            fields.push(("step", Json::num(s as f64)));
        }
        if let Some(r) = self.step_range {
            fields.push(("step_range", range(r)));
        }
        if let Some(r) = self.ts_range {
            fields.push(("ts_range", range(r)));
        }
        if self.anomalies_only {
            fields.push(("anomalies_only", Json::Bool(true)));
        }
        if let Some(m) = self.min_score {
            fields.push(("min_score", Json::num(m)));
        }
        if let Some(l) = &self.label {
            fields.push(("label", Json::str(l.as_str())));
        }
        if self.order_by_score {
            fields.push(("order_by_score", Json::Bool(true)));
        }
        if let Some(n) = self.limit {
            fields.push(("limit", Json::num(n as f64)));
        }
        Json::obj(fields)
    }

    /// Parse back from the JSON form; missing keys mean "no filter".
    pub fn from_json(j: &Json) -> Result<ProvQuery> {
        let pair = |k: &str| -> Option<(u32, u32)> {
            let a = j.get(k)?.as_arr()?;
            Some((a.first()?.as_u64()? as u32, a.get(1)?.as_u64()? as u32))
        };
        let range = |k: &str| -> Option<(u64, u64)> {
            let a = j.get(k)?.as_arr()?;
            Some((a.first()?.as_u64()?, a.get(1)?.as_u64()?))
        };
        Ok(ProvQuery {
            app: j.get("app").and_then(|v| v.as_u64()).map(|a| a as u32),
            rank: pair("rank"),
            fid: pair("fid"),
            step: j.get("step").and_then(|v| v.as_u64()),
            step_range: range("step_range"),
            ts_range: range("ts_range"),
            anomalies_only: j
                .get("anomalies_only")
                .and_then(|v| v.as_bool())
                .unwrap_or(false),
            min_score: j.get("min_score").and_then(|v| v.as_f64()),
            label: j.get("label").and_then(|v| v.as_str()).map(|s| s.to_string()),
            order_by_score: j
                .get("order_by_score")
                .and_then(|v| v.as_bool())
                .unwrap_or(false),
            limit: j.get("limit").and_then(|v| v.as_u64()).map(|n| n as usize),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ad::{ExecRecord, Label, Labeled};

    fn labeled(fid: u32, rank: u32, step: u64, dur: u64, label: Label, id: u64) -> Labeled {
        Labeled {
            rec: ExecRecord {
                call_id: id,
                app: 0,
                rank,
                thread: 0,
                fid,
                step,
                entry_ts: id * 100,
                exit_ts: id * 100 + dur,
                depth: 0,
                parent: None,
                n_children: 0,
                n_messages: 0,
                msg_bytes: 0,
                exclusive_us: dur,
            },
            label,
            score: dur as f64 / 100.0,
        }
    }

    fn reg() -> FuncRegistry {
        let mut r = FuncRegistry::new();
        r.register("F0", false);
        r.register("F1", false);
        r
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("chimbuko-prov-{tag}-{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    #[test]
    fn write_load_roundtrip() {
        let dir = tmpdir("rt");
        let mut db = ProvDb::create(&dir).unwrap();
        let reg = reg();
        db.write_metadata(&RunMetadata::new(
            "test-run",
            Json::obj(vec![("alpha", Json::num(6.0))]),
            &[reg.clone()],
        ))
        .unwrap();
        let kept = vec![
            labeled(0, 1, 5, 100, Label::Normal, 1),
            labeled(1, 1, 5, 900, Label::AnomalyHigh, 2),
            labeled(0, 2, 6, 100, Label::Normal, 3),
        ];
        db.append_step(&kept, &reg).unwrap();
        db.flush().unwrap();
        assert_eq!(db.len(), 3);
        assert_eq!(db.anomaly_count(), 1);
        assert!(db.bytes_written() > 0);

        let loaded = ProvDb::load(&dir).unwrap();
        assert_eq!(loaded.len(), 3);
        assert_eq!(loaded.anomaly_count(), 1);
        let meta = ProvDb::load_metadata(&dir).unwrap();
        assert_eq!(meta.get("run_id").unwrap().as_str(), Some("test-run"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn queries_filter_and_order() {
        let mut db = ProvDb::in_memory();
        let reg = reg();
        let kept = vec![
            labeled(0, 1, 5, 100, Label::Normal, 1),
            labeled(1, 1, 5, 900, Label::AnomalyHigh, 2),
            labeled(1, 1, 6, 950, Label::AnomalyHigh, 3),
            labeled(0, 2, 5, 120, Label::Normal, 4),
        ];
        db.append_step(&kept, &reg).unwrap();

        let r15 = db.call_stack(0, 1, 5);
        assert_eq!(r15.len(), 2);
        assert!(r15[0].entry_us <= r15[1].entry_us);

        let anoms = db.query(&ProvQuery { anomalies_only: true, ..Default::default() });
        assert_eq!(anoms.len(), 2);

        let top = db.query(&ProvQuery {
            order_by_score: true,
            limit: Some(1),
            ..Default::default()
        });
        assert_eq!(top[0].call_id, 3);

        let by_func = db.query(&ProvQuery { fid: Some((0, 1)), ..Default::default() });
        assert_eq!(by_func.len(), 2);
        assert!(by_func.iter().all(|r| r.func == "F1"));

        let windowed = db.query(&ProvQuery {
            ts_range: Some((0, 150)),
            ..Default::default()
        });
        assert_eq!(windowed.len(), 1);
        assert_eq!(windowed[0].call_id, 1);
    }

    #[test]
    fn missing_indexes_return_empty() {
        let db = ProvDb::in_memory();
        assert!(db.call_stack(0, 99, 0).is_empty());
        assert!(db
            .query(&ProvQuery { fid: Some((0, 99)), ..Default::default() })
            .is_empty());
    }

    #[test]
    fn in_memory_counts_bytes() {
        let mut db = ProvDb::in_memory();
        db.append_step(&[labeled(0, 0, 0, 50, Label::Normal, 1)], &reg()).unwrap();
        assert!(db.bytes_written() > 100);
    }

    #[test]
    fn extended_filters_score_label_step_window() {
        let mut db = ProvDb::in_memory();
        let reg = reg();
        let kept = vec![
            labeled(0, 1, 5, 100, Label::Normal, 1),       // score 1.0
            labeled(1, 1, 6, 900, Label::AnomalyHigh, 2),  // score 9.0
            labeled(1, 2, 7, 700, Label::AnomalyHigh, 3),  // score 7.0
            labeled(0, 2, 9, 40, Label::AnomalyLow, 4),    // score 0.4
        ];
        db.append_step(&kept, &reg).unwrap();

        let high = db.query(&ProvQuery { min_score: Some(5.0), ..Default::default() });
        assert_eq!(high.len(), 2);
        assert!(high.iter().all(|r| r.score >= 5.0));

        let lows = db.query(&ProvQuery {
            label: Some("anomaly_low".to_string()),
            ..Default::default()
        });
        assert_eq!(lows.len(), 1);
        assert_eq!(lows[0].call_id, 4);

        let window = db.query(&ProvQuery { step_range: Some((6, 7)), ..Default::default() });
        assert_eq!(window.len(), 2);
        assert!(window.iter().all(|r| r.step >= 6 && r.step <= 7));

        assert_eq!(db.query(&ProvQuery { app: Some(0), ..Default::default() }).len(), 4);
        assert!(db.query(&ProvQuery { app: Some(1), ..Default::default() }).is_empty());
    }

    #[test]
    fn query_json_roundtrip() {
        let q = ProvQuery {
            app: Some(1),
            rank: Some((1, 7)),
            fid: Some((0, 3)),
            step: Some(9),
            step_range: Some((2, 11)),
            ts_range: Some((100, 900)),
            anomalies_only: true,
            min_score: Some(4.5),
            label: Some("anomaly_high".to_string()),
            order_by_score: true,
            limit: Some(25),
        };
        let back = ProvQuery::from_json(&parse(&q.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.app, q.app);
        assert_eq!(back.rank, q.rank);
        assert_eq!(back.fid, q.fid);
        assert_eq!(back.step, q.step);
        assert_eq!(back.step_range, q.step_range);
        assert_eq!(back.ts_range, q.ts_range);
        assert_eq!(back.anomalies_only, q.anomalies_only);
        assert_eq!(back.min_score, q.min_score);
        assert_eq!(back.label, q.label);
        assert_eq!(back.order_by_score, q.order_by_score);
        assert_eq!(back.limit, q.limit);

        // Default query serializes to an empty object and parses back.
        let d = ProvQuery::default();
        assert_eq!(d.to_json().to_string(), "{}");
        let back = ProvQuery::from_json(&parse("{}").unwrap()).unwrap();
        assert!(back.rank.is_none() && !back.anomalies_only && back.limit.is_none());
    }

    #[test]
    fn partition_file_names_parse_and_order_numerically() {
        assert_eq!(parse_part_name("prov_app0_rank12.jsonl"), Some((0, 12, None, true)));
        assert_eq!(parse_part_name("prov_app3_rank2.provseg"), Some((3, 2, None, false)));
        assert_eq!(
            parse_part_name("prov_app1_rank10_seg0042.provseg"),
            Some((1, 10, Some(42), false))
        );
        assert_eq!(parse_part_name("prov_weird.provseg"), None);
        assert_eq!(parse_part_name("prov_app1_rankx.provseg"), None);
        assert_eq!(parse_part_name("metadata.json"), None);

        let dir = tmpdir("order");
        std::fs::create_dir_all(&dir).unwrap();
        // Created shuffled; replay order must be numeric by (app, rank),
        // jsonl → legacy → seg<K> within a partition, misfits last.
        let names = [
            "prov_app0_rank10.provseg",
            "prov_app0_rank2_seg0001.provseg",
            "prov_app0_rank2_seg0000.provseg",
            "prov_misc.jsonl",
            "prov_app0_rank2.jsonl",
            "prov_app1_rank0.provseg",
            "prov_app0_rank2.provseg",
            "metadata.json",
        ];
        for n in names {
            std::fs::write(dir.join(n), b"").unwrap();
        }
        let got: Vec<String> = list_partition_files(&dir)
            .unwrap()
            .iter()
            .map(|f| f.path.file_name().unwrap().to_str().unwrap().to_string())
            .collect();
        assert_eq!(
            got,
            [
                "prov_app0_rank2.jsonl",
                "prov_app0_rank2.provseg",
                "prov_app0_rank2_seg0000.provseg",
                "prov_app0_rank2_seg0001.provseg",
                "prov_app0_rank10.provseg",
                "prov_app1_rank0.provseg",
                "prov_misc.jsonl",
            ]
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
