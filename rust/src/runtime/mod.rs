//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) and runs
//! them from the L3 hot path.
//!
//! `xla::PjRtClient` is `Rc`-based (not `Send`), so the runtime is a
//! **dedicated service thread** that owns the client and the compiled
//! executables; AD modules on other threads submit batches over an mpsc
//! channel and block on a reply channel. This mirrors the deployment
//! shape of on-node AD modules sharing one node-local accelerator.
//!
//! Interchange is HLO *text* — see `python/compile/aot.py` for why the
//! serialized-proto path is rejected by xla_extension 0.5.1.

mod exec;
mod service;

pub use exec::{AdBatchRequest, AdBatchResponse, Artifacts, LoadedArtifacts};
pub use service::{fold_tables_xla, RuntimeHandle, RuntimeService, XlaDetector};
