//! Artifact loading + typed executable wrappers (single-threaded; the
//! [`service`](super::service) thread owns everything here).

use crate::util::json::{parse, Json};
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Artifact manifest: baked shapes + file names (written by `aot.py`).
#[derive(Clone, Debug)]
pub struct Artifacts {
    pub dir: PathBuf,
    /// Event-batch capacity of `ad_batch`.
    pub batch: usize,
    /// Function-table capacity.
    pub funcs: usize,
    pub ad_batch_file: PathBuf,
    pub ps_merge_file: PathBuf,
}

impl Artifacts {
    /// Read and validate `manifest.json` from an artifacts directory.
    pub fn discover(dir: &Path) -> Result<Artifacts> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let j = parse(&text).context("parsing manifest.json")?;
        let batch = j
            .get("batch")
            .and_then(Json::as_u64)
            .context("manifest missing 'batch'")? as usize;
        let funcs = j
            .get("funcs")
            .and_then(Json::as_u64)
            .context("manifest missing 'funcs'")? as usize;
        let file_of = |key: &str| -> Result<PathBuf> {
            let name = j
                .get(key)
                .and_then(|o| o.get("file"))
                .and_then(Json::as_str)
                .with_context(|| format!("manifest missing {key}.file"))?;
            let p = dir.join(name);
            if !p.exists() {
                bail!("artifact {} missing — run `make artifacts`", p.display());
            }
            Ok(p)
        };
        Ok(Artifacts {
            dir: dir.to_path_buf(),
            batch,
            funcs,
            ad_batch_file: file_of("ad_batch")?,
            ps_merge_file: file_of("ps_merge")?,
        })
    }
}

/// One AD batch invocation (padded to the baked capacity by the caller's
/// side of the channel; see [`super::RuntimeHandle::ad_batch`]).
#[derive(Clone, Debug)]
pub struct AdBatchRequest {
    pub exec_us: Vec<f32>,
    pub fid: Vec<i32>,
    pub valid: Vec<f32>,
    pub n: Vec<f32>,
    pub mu: Vec<f32>,
    pub m2: Vec<f32>,
    pub alpha: f32,
    pub min_samples: f32,
}

/// AD batch result: labels/scores per event + merged stats tables.
#[derive(Clone, Debug)]
pub struct AdBatchResponse {
    /// 0 normal, 1 high, -1 low (padding slots are 0).
    pub labels: Vec<i32>,
    pub scores: Vec<f32>,
    pub n: Vec<f32>,
    pub mu: Vec<f32>,
    pub m2: Vec<f32>,
}

/// Compiled executables, living on the service thread (not `Send`).
pub struct LoadedArtifacts {
    pub meta: Artifacts,
    client: xla::PjRtClient,
    ad_batch: xla::PjRtLoadedExecutable,
    ps_merge: xla::PjRtLoadedExecutable,
}

impl LoadedArtifacts {
    /// Create the CPU PJRT client and compile both artifacts.
    pub fn load(meta: Artifacts) -> Result<LoadedArtifacts> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let ad_batch = compile(&client, &meta.ad_batch_file)?;
        let ps_merge = compile(&client, &meta.ps_merge_file)?;
        Ok(LoadedArtifacts { meta, client, ad_batch, ps_merge })
    }

    /// Execute one AD batch (shapes must match the manifest).
    pub fn run_ad_batch(&self, req: &AdBatchRequest) -> Result<AdBatchResponse> {
        let b = self.meta.batch;
        let f = self.meta.funcs;
        if req.exec_us.len() != b || req.fid.len() != b || req.valid.len() != b {
            bail!("batch inputs must have length {b}");
        }
        if req.n.len() != f || req.mu.len() != f || req.m2.len() != f {
            bail!("stats inputs must have length {f}");
        }
        let args = [
            xla::Literal::vec1(&req.exec_us),
            xla::Literal::vec1(&req.fid),
            xla::Literal::vec1(&req.valid),
            xla::Literal::vec1(&req.n),
            xla::Literal::vec1(&req.mu),
            xla::Literal::vec1(&req.m2),
            xla::Literal::scalar(req.alpha),
            xla::Literal::scalar(req.min_samples),
        ];
        let result = self.ad_batch.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()
            .context("fetching ad_batch result")?;
        let outs = result.to_tuple().context("ad_batch output tuple")?;
        if outs.len() != 5 {
            bail!("ad_batch returned {} outputs, expected 5", outs.len());
        }
        Ok(AdBatchResponse {
            labels: outs[0].to_vec::<i32>()?,
            scores: outs[1].to_vec::<f32>()?,
            n: outs[2].to_vec::<f32>()?,
            mu: outs[3].to_vec::<f32>()?,
            m2: outs[4].to_vec::<f32>()?,
        })
    }

    /// Execute the parameter-server pairwise merge.
    pub fn run_ps_merge(
        &self,
        a: (&[f32], &[f32], &[f32]),
        b: (&[f32], &[f32], &[f32]),
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let f = self.meta.funcs;
        for s in [a.0, a.1, a.2, b.0, b.1, b.2] {
            if s.len() != f {
                bail!("ps_merge inputs must have length {f}");
            }
        }
        let args = [
            xla::Literal::vec1(a.0),
            xla::Literal::vec1(a.1),
            xla::Literal::vec1(a.2),
            xla::Literal::vec1(b.0),
            xla::Literal::vec1(b.1),
            xla::Literal::vec1(b.2),
        ];
        let result = self.ps_merge.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()
            .context("fetching ps_merge result")?;
        let (n, mu, m2) = result.to_tuple3().context("ps_merge output tuple")?;
        Ok((n.to_vec::<f32>()?, mu.to_vec::<f32>()?, m2.to_vec::<f32>()?))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().context("artifact path not utf-8")?,
    )
    .with_context(|| format!("parsing HLO text {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {}", path.display()))
}
