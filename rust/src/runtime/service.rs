//! The runtime service thread + the XLA-backed detection engine.
//!
//! [`RuntimeService::spawn`] compiles the artifacts on a dedicated thread
//! and serves requests from any number of [`RuntimeHandle`] clones.
//! [`XlaDetector`] implements [`DetectEngine`](crate::ad::DetectEngine) on
//! top of a handle, so the on-node AD modules can swap between the Rust
//! and XLA backends via config (`ad.backend = rust|xla`).

use super::exec::{AdBatchRequest, AdBatchResponse, Artifacts, LoadedArtifacts};
use crate::ad::{DetectEngine, ExecRecord, Label, Labeled};
use crate::stats::{RunStats, StatsTable};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;

enum Request {
    AdBatch(AdBatchRequest, Sender<Result<AdBatchResponse>>),
    PsMerge {
        a: (Vec<f32>, Vec<f32>, Vec<f32>),
        b: (Vec<f32>, Vec<f32>, Vec<f32>),
        reply: Sender<Result<(Vec<f32>, Vec<f32>, Vec<f32>)>>,
    },
    Shutdown,
}

/// Owner of the service thread; keep it alive for the run's duration.
pub struct RuntimeService {
    tx: Sender<Request>,
    join: Option<JoinHandle<()>>,
    meta: Artifacts,
}

impl RuntimeService {
    /// Compile artifacts from `dir` on a fresh service thread.
    ///
    /// Blocks until compilation finished (so failures surface here, not on
    /// the first batch).
    pub fn spawn(dir: &std::path::Path) -> Result<RuntimeService> {
        let meta = Artifacts::discover(dir)?;
        let meta2 = meta.clone();
        let (tx, rx) = channel::<Request>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("chimbuko-xla".into())
            .spawn(move || {
                let loaded = match LoadedArtifacts::load(meta2) {
                    Ok(l) => {
                        let _ = ready_tx.send(Ok(()));
                        l
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::AdBatch(batch, reply) => {
                            let _ = reply.send(loaded.run_ad_batch(&batch));
                        }
                        Request::PsMerge { a, b, reply } => {
                            let _ = reply.send(loaded.run_ps_merge(
                                (&a.0, &a.1, &a.2),
                                (&b.0, &b.1, &b.2),
                            ));
                        }
                        Request::Shutdown => break,
                    }
                }
            })
            .context("spawning runtime service thread")?;
        ready_rx
            .recv()
            .context("runtime service thread died during compile")??;
        Ok(RuntimeService { tx, join: Some(join), meta })
    }

    /// A cloneable client handle.
    pub fn handle(&self) -> RuntimeHandle {
        RuntimeHandle { tx: self.tx.clone(), batch: self.meta.batch, funcs: self.meta.funcs }
    }

    pub fn meta(&self) -> &Artifacts {
        &self.meta
    }
}

impl Drop for RuntimeService {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Cheap, cloneable, `Send` handle to the service thread.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: Sender<Request>,
    /// Baked batch capacity.
    pub batch: usize,
    /// Baked function capacity.
    pub funcs: usize,
}

impl RuntimeHandle {
    /// Execute one AD batch (inputs must already be padded to capacity).
    pub fn ad_batch(&self, req: AdBatchRequest) -> Result<AdBatchResponse> {
        let (rtx, rrx) = channel();
        self.tx
            .send(Request::AdBatch(req, rtx))
            .map_err(|_| anyhow::anyhow!("runtime service is gone"))?;
        rrx.recv().context("runtime service dropped reply")?
    }

    /// Execute the PS pairwise merge.
    pub fn ps_merge(
        &self,
        a: (Vec<f32>, Vec<f32>, Vec<f32>),
        b: (Vec<f32>, Vec<f32>, Vec<f32>),
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let (rtx, rrx) = channel();
        self.tx
            .send(Request::PsMerge { a, b, reply: rtx })
            .map_err(|_| anyhow::anyhow!("runtime service is gone"))?;
        rrx.recv().context("runtime service dropped reply")?
    }
}

/// XLA-backed [`DetectEngine`]: per-function running stats live as dense
/// `[F]` arrays mirroring the artifact's inputs/outputs; min/max (needed
/// for the dashboard but not the detection math) are tracked Rust-side.
pub struct XlaDetector {
    handle: RuntimeHandle,
    alpha: f32,
    min_samples: f32,
    n: Vec<f32>,
    mu: Vec<f32>,
    m2: Vec<f32>,
    minmax: Vec<(f64, f64)>,
    /// Mirror of (n, mu, m2, min, max) as a [`StatsTable`] for `view()`.
    mirror: StatsTable,
    pending: StatsTable,
}

impl XlaDetector {
    pub fn new(handle: RuntimeHandle, alpha: f64, min_samples: u64) -> XlaDetector {
        let f = handle.funcs;
        XlaDetector {
            handle,
            alpha: alpha as f32,
            min_samples: min_samples as f32,
            n: vec![0.0; f],
            mu: vec![0.0; f],
            m2: vec![0.0; f],
            minmax: vec![(f64::INFINITY, f64::NEG_INFINITY); f],
            mirror: StatsTable::new(),
            pending: StatsTable::new(),
        }
    }

    fn refresh_mirror(&mut self, touched: impl Iterator<Item = u32>) {
        for fid in touched {
            let i = fid as usize;
            let (mn, mx) = self.minmax[i];
            self.mirror.replace(
                fid,
                RunStats::from_raw(
                    self.n[i] as u64,
                    self.mu[i] as f64,
                    self.m2[i] as f64,
                    mn,
                    mx,
                ),
            );
        }
    }
}

impl DetectEngine for XlaDetector {
    fn detect(&mut self, records: Vec<ExecRecord>) -> Vec<Labeled> {
        let cap = self.handle.batch;
        let f = self.handle.funcs;
        let mut out = Vec::with_capacity(records.len());
        for chunk in records.chunks(cap) {
            let mut exec_us = vec![0.0f32; cap];
            let mut fid = vec![0i32; cap];
            let mut valid = vec![0.0f32; cap];
            for (i, r) in chunk.iter().enumerate() {
                let v = r.inclusive_us() as f64;
                debug_assert!(
                    (r.fid as usize) < f,
                    "fid {} exceeds artifact capacity {f}",
                    r.fid
                );
                exec_us[i] = v as f32;
                fid[i] = (r.fid as usize).min(f - 1) as i32;
                valid[i] = 1.0;
                let mm = &mut self.minmax[fid[i] as usize];
                mm.0 = mm.0.min(v);
                mm.1 = mm.1.max(v);
                self.pending.push(r.fid, v);
            }
            let resp = self
                .handle
                .ad_batch(AdBatchRequest {
                    exec_us,
                    fid,
                    valid,
                    n: self.n.clone(),
                    mu: self.mu.clone(),
                    m2: self.m2.clone(),
                    alpha: self.alpha,
                    min_samples: self.min_samples,
                })
                .expect("xla ad_batch failed");
            self.n = resp.n;
            self.mu = resp.mu;
            self.m2 = resp.m2;
            self.refresh_mirror(chunk.iter().map(|r| r.fid));
            for (i, r) in chunk.iter().enumerate() {
                let label = match resp.labels[i] {
                    1 => Label::AnomalyHigh,
                    -1 => Label::AnomalyLow,
                    _ => Label::Normal,
                };
                out.push(Labeled {
                    rec: r.clone(),
                    label,
                    score: resp.scores[i] as f64,
                });
            }
        }
        out
    }

    fn take_pending(&mut self) -> StatsTable {
        std::mem::take(&mut self.pending)
    }

    fn adopt_global(&mut self, global: &StatsTable) {
        for (fid, st) in global.iter() {
            let i = fid as usize;
            if i >= self.n.len() {
                continue;
            }
            self.n[i] = st.count() as f32;
            self.mu[i] = st.mean() as f32;
            self.m2[i] = st.m2() as f32;
            self.minmax[i].0 = self.minmax[i].0.min(st.min());
            self.minmax[i].1 = self.minmax[i].1.max(st.max());
            self.mirror.replace(fid, *st);
        }
    }

    fn view(&self) -> &StatsTable {
        &self.mirror
    }
}

#[cfg(test)]
mod tests {
    // Integration tests that need compiled artifacts live in
    // rust/tests/xla_runtime.rs (they require `make artifacts` to have
    // run). Unit-testable parts:
    use super::*;

    #[test]
    fn artifacts_discover_rejects_missing_dir() {
        let err = Artifacts::discover(std::path::Path::new("/nonexistent-dir-xyz"));
        assert!(err.is_err());
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("make artifacts"), "msg: {msg}");
    }

    #[test]
    fn xla_detector_label_mapping() {
        // Label codes used by the artifact.
        assert_eq!(Label::Normal.as_str(), "normal");
        let codes = [(1, Label::AnomalyHigh), (-1, Label::AnomalyLow), (0, Label::Normal)];
        for (code, want) in codes {
            let got = match code {
                1 => Label::AnomalyHigh,
                -1 => Label::AnomalyLow,
                _ => Label::Normal,
            };
            assert_eq!(got, want);
        }
    }
}

/// Fold many rank deltas into one table with the ps_merge artifact —
/// used by experiment benches to exercise the L2 merge path end-to-end.
pub fn fold_tables_xla(
    handle: &RuntimeHandle,
    tables: &[HashMap<u32, RunStats>],
) -> Result<HashMap<u32, RunStats>> {
    let f = handle.funcs;
    let mut acc = (vec![0.0f32; f], vec![0.0f32; f], vec![0.0f32; f]);
    for t in tables {
        let mut b = (vec![0.0f32; f], vec![0.0f32; f], vec![0.0f32; f]);
        for (fid, st) in t {
            let i = *fid as usize;
            if i < f {
                b.0[i] = st.count() as f32;
                b.1[i] = st.mean() as f32;
                b.2[i] = st.m2() as f32;
            }
        }
        acc = handle.ps_merge(acc, b)?;
    }
    let mut out = HashMap::new();
    for i in 0..f {
        if acc.0[i] > 0.0 {
            out.insert(
                i as u32,
                RunStats::from_raw(acc.0[i] as u64, acc.1[i] as f64, acc.2[i] as f64, 0.0, 0.0),
            );
        }
    }
    Ok(out)
}
