//! Criterion-lite: a measurement harness for `cargo bench` targets
//! (`harness = false`; the offline registry has no criterion).
//!
//! Provides warmup + calibrated iteration timing with mean/σ/p50/p99,
//! throughput reporting, and paper-style table printing used by the
//! per-figure experiment benches.

use std::time::{Duration, Instant};

/// One timing measurement series.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Bench label.
    pub name: String,
    /// Per-iteration wall times, seconds.
    pub samples: Vec<f64>,
    /// Optional items-per-iteration for throughput.
    pub items_per_iter: Option<f64>,
}

impl Measurement {
    pub fn mean(&self) -> f64 {
        crate::util::mean(&self.samples)
    }

    pub fn stddev(&self) -> f64 {
        crate::util::stddev(&self.samples)
    }

    pub fn p50(&self) -> f64 {
        crate::util::percentile(&self.samples, 50.0)
    }

    pub fn p99(&self) -> f64 {
        crate::util::percentile(&self.samples, 99.0)
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// items/s at mean time, if items_per_iter was set.
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n / self.mean())
    }

    /// One human line, criterion-style.
    pub fn report_line(&self) -> String {
        let mut s = format!(
            "{:<44} mean {:>10}  p50 {:>10}  p99 {:>10}  σ {:>9}",
            self.name,
            fmt_secs(self.mean()),
            fmt_secs(self.p50()),
            fmt_secs(self.p99()),
            fmt_secs(self.stddev()),
        );
        if let Some(tp) = self.throughput() {
            s.push_str(&format!("  {:>12.0} items/s", tp));
        }
        s
    }
}

/// Format seconds with an adaptive unit.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Bench runner with warmup and a sample budget.
pub struct Bench {
    /// Warmup duration before sampling.
    pub warmup: Duration,
    /// Number of samples to record.
    pub samples: usize,
    /// Measured results, in run order.
    pub results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: Duration::from_millis(200), samples: 20, results: Vec::new() }
    }
}

impl Bench {
    pub fn new(warmup_ms: u64, samples: usize) -> Self {
        Bench {
            warmup: Duration::from_millis(warmup_ms),
            samples,
            results: Vec::new(),
        }
    }

    /// Honour `CHIMBUKO_BENCH_FAST=1` (CI smoke mode): 1 warmup ms, 3 samples.
    pub fn from_env(default_samples: usize) -> Self {
        if std::env::var("CHIMBUKO_BENCH_FAST").as_deref() == Ok("1") {
            Bench::new(1, 3)
        } else {
            Bench::new(200, default_samples)
        }
    }

    /// Time `f` (which should perform one full iteration per call).
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> &Measurement {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        self.results.push(Measurement {
            name: name.to_string(),
            samples,
            items_per_iter: None,
        });
        let m = self.results.last().unwrap();
        println!("{}", m.report_line());
        m
    }

    /// Time `f` and report items/second throughput.
    pub fn run_throughput<F: FnMut() -> u64>(&mut self, name: &str, mut f: F) -> &Measurement {
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.samples);
        let mut items = 0u64;
        for _ in 0..self.samples {
            let t = Instant::now();
            items = f();
            samples.push(t.elapsed().as_secs_f64());
        }
        self.results.push(Measurement {
            name: name.to_string(),
            samples,
            items_per_iter: Some(items as f64),
        });
        let m = self.results.last().unwrap();
        println!("{}", m.report_line());
        m
    }
}

/// Paper-style table printer: fixed-width columns, Markdown-ish separators.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render to a string (also used in EXPERIMENTS.md).
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let hdr: Vec<String> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| format!("{:>w$}", h, w = widths[i]))
            .collect();
        out.push_str(&format!("| {} |\n", hdr.join(" | ")));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&format!("|-{}-|\n", sep.join("-|-")));
        for row in &self.rows {
            let cells: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect();
            out.push_str(&format!("| {} |\n", cells.join(" | ")));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measurement_stats() {
        let m = Measurement {
            name: "x".into(),
            samples: vec![1.0, 2.0, 3.0],
            items_per_iter: Some(6.0),
        };
        assert!((m.mean() - 2.0).abs() < 1e-12);
        assert_eq!(m.p50(), 2.0);
        assert_eq!(m.min(), 1.0);
        assert!((m.throughput().unwrap() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn bench_runs_and_records() {
        let mut b = Bench::new(0, 3);
        b.run("noop", || {});
        assert_eq!(b.results.len(), 1);
        assert_eq!(b.results[0].samples.len(), 3);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Table I", &["# MPI", "overhead"]);
        t.row(vec!["80".into(), "1.85".into()]);
        t.row(vec!["2560".into(), "18.27".into()]);
        let r = t.render();
        assert!(r.contains("== Table I =="));
        assert!(r.contains(" 2560 |"));
        assert!(r.contains("18.27"));
    }

    #[test]
    fn fmt_secs_units() {
        assert_eq!(fmt_secs(2.0), "2.000s");
        assert_eq!(fmt_secs(0.0025), "2.50ms");
        assert_eq!(fmt_secs(2.5e-6), "2.50µs");
        assert_eq!(fmt_secs(5e-9), "5.0ns");
    }
}
