//! TCP transport for the provenance database — AD ranks write to it,
//! the visualization server queries it (the paper's Sonata/Mochi
//! deployment shape: a dedicated provenance service decoupled from the
//! analysis ranks).
//!
//! Wire protocol (length-prefixed messages, little-endian; shared framing
//! in [`util::wire`](crate::util::wire)):
//!
//! ```text
//! request  := u32 len, u8 kind, payload
//!   kind 1 (hello):         (empty)
//!   kind 2 (write jsonl):   n u32, n × (u32 len, JSONL record bytes)
//!   kind 3 (query jsonl):   u32 len, ProvQuery JSON bytes
//!   kind 4 (cs jsonl):      app u32, rank u32, step u64
//!   kind 5 (meta set):      u32 len, metadata JSON bytes
//!   kind 6 (meta get):      (empty)
//!   kind 7 (stats):         (empty)
//!   kind 8 (flush):         (empty)
//!   kind 9 (write bin):     codec u16, n u32, n × binary record
//!   kind 10 (query bin):    u32 len, ProvQuery JSON bytes
//!   kind 11 (cs bin):       app u32, rank u32, step u64
//! reply (hello)      := u32 n_shards, u16 codec_version
//! reply (write)      := u32 n_accepted
//! reply (query/cs 3/4) := u32 n, n × (u32 len, JSONL record bytes)
//! reply (query/cs 10/11) := codec u16, u32 n, n × binary record
//! reply (meta set)   := u8 1
//! reply (meta get)   := u8 present, [u32 len, JSON bytes]
//! reply (stats)      := u64 records, u64 resident, u64 log, u64 anoms,
//!                       u64 evicted, u64 log_errors
//! reply (flush)      := u8 1
//! ```
//!
//! Kinds 9–11 are the default pipeline: records travel in the
//! [`provenance::codec`](crate::provenance::codec) binary layout —
//! byte-identical to the shard-resident form and the `.provseg` segment
//! log — so the ingest path allocates no `Json` tree anywhere and query
//! replies copy stored bytes straight onto the wire. Kinds 2–4 keep the
//! JSONL encoding as a migration/escape hatch (`RecordFormat::Jsonl`
//! clients). Binary batches are tagged with
//! [`codec::CODEC_VERSION`](crate::provenance::codec::CODEC_VERSION);
//! a mismatch refuses the frame.
//!
//! Every count and length in a frame is untrusted: batch counts cap the
//! pre-allocation, per-record payload lengths are bounded by
//! [`codec::MAX_PAYLOAD`](crate::provenance::codec::MAX_PAYLOAD) and
//! validated against the actual frame bytes *before* any allocation. A
//! malformed record drops the connection without ingesting anything (the
//! wire is a trust boundary), mirroring `ps::net`'s misgrouped-frame
//! policy.
//!
//! [`ProvClient::append`] batches client-side: records encode into a
//! reused buffer and ship `batch` at a time, so AD ranks never block per
//! record. One connection reads its own writes (server-side, a
//! connection's ingests and queries traverse each shard queue in order);
//! cross-client visibility needs [`ProvClient::flush`], which is a
//! shard-drain barrier.

use super::store::{ProvDbStats, ProvStore};
use crate::ad::Labeled;
use crate::provenance::codec::{self, RecordFormat};
use crate::provenance::{ProvQuery, ProvRecord};
use crate::trace::FuncRegistry;
use crate::util::json::{parse, Json};
use crate::util::net::{serve_tcp, TcpServerHandle};
use crate::util::wire::{put_str, read_msg, write_msg, Cursor};
use anyhow::{bail, Context, Result};
use std::net::TcpStream;
use std::sync::Mutex;

const KIND_HELLO: u8 = 1;
const KIND_WRITE: u8 = 2;
const KIND_QUERY: u8 = 3;
const KIND_CALLSTACK: u8 = 4;
const KIND_META_SET: u8 = 5;
const KIND_META_GET: u8 = 6;
const KIND_STATS: u8 = 7;
const KIND_FLUSH: u8 = 8;
const KIND_WRITE_BIN: u8 = 9;
const KIND_QUERY_BIN: u8 = 10;
const KIND_CALLSTACK_BIN: u8 = 11;

/// Default client-side write batch (records per wire round-trip).
pub const DEFAULT_BATCH: usize = 64;

/// Untrusted-count cap: the largest record-count pre-allocation a frame
/// header can cause (pushes still validate against the payload).
const MAX_PREALLOC: usize = 4096;

/// Largest capacity the per-connection reused reply buffer keeps after a
/// request: one `limit=0` full dump must not pin the store's size in
/// memory for the connection's (long — the viz server reconnects lazily)
/// lifetime.
const MAX_REPLY_RETAIN: usize = 4 << 20;

/// TCP front-end for a provenance database; forwards to a [`ProvStore`].
/// The accept loop is the shared [`serve_tcp`] substrate (one handler
/// thread per connection, all sharing the store's shard constellation).
pub struct ProvDbTcpServer {
    inner: TcpServerHandle,
}

impl ProvDbTcpServer {
    /// Bind and serve; each connection is one writer or reader.
    pub fn start(addr: &str, store: ProvStore) -> Result<ProvDbTcpServer> {
        // The handler is shared across connection threads; clone the
        // store out from under a mutex per connection (ProvStore is
        // Send, and this keeps no Sync requirement on its internals).
        let store = Mutex::new(store);
        let inner = serve_tcp("chimbuko-provdb-tcp", addr, move |stream| {
            let s = store.lock().expect("provdb store lock").clone();
            let _ = serve_conn(stream, s);
        })?;
        Ok(ProvDbTcpServer { inner })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.inner.addr()
    }

    pub fn stop(&mut self) {
        self.inner.stop();
    }
}

/// JSONL reply form (legacy kinds 3/4).
fn put_records_jsonl(reply: &mut Vec<u8>, recs: &[ProvRecord]) {
    reply.extend_from_slice(&(recs.len() as u32).to_le_bytes());
    let mut line = String::with_capacity(360);
    for r in recs {
        line.clear();
        r.write_jsonl(&mut line);
        put_str(reply, &line);
    }
}

/// Binary reply form (kinds 10/11): stored bytes, copied verbatim.
fn put_records_bin(reply: &mut Vec<u8>, recs: &[Vec<u8>]) {
    reply.extend_from_slice(&codec::CODEC_VERSION.to_le_bytes());
    reply.extend_from_slice(&(recs.len() as u32).to_le_bytes());
    for r in recs {
        reply.extend_from_slice(r);
    }
}

fn serve_conn(mut stream: TcpStream, store: ProvStore) -> Result<()> {
    // Reused across requests on this connection: binary query replies
    // concatenate stored record bytes into this scratch buffer.
    let mut reply = Vec::new();
    loop {
        let Some(msg) = read_msg(&mut stream)? else {
            return Ok(()); // clean disconnect
        };
        let mut c = Cursor::new(&msg);
        let kind = c.u8()?;
        match kind {
            KIND_HELLO => {
                let mut hello = Vec::with_capacity(6);
                hello.extend_from_slice(&(store.shard_count() as u32).to_le_bytes());
                hello.extend_from_slice(&codec::CODEC_VERSION.to_le_bytes());
                write_msg(&mut stream, &hello)?;
            }
            KIND_WRITE => {
                let n = c.u32()? as usize;
                // The count is wire-supplied (untrusted): cap the
                // pre-allocation so a lying header cannot abort the
                // process; pushes still validate against the payload.
                let mut recs = Vec::with_capacity(n.min(MAX_PREALLOC));
                for _ in 0..n {
                    let line = c.str()?;
                    // Trust boundary: refuse the whole frame on a
                    // malformed record instead of ingesting a prefix.
                    recs.push(
                        ProvRecord::from_jsonl_line(&line)
                            .context("malformed provenance record on the wire")?,
                    );
                }
                let accepted = store.ingest(recs);
                write_msg(&mut stream, &(accepted as u32).to_le_bytes())?;
            }
            KIND_WRITE_BIN => {
                let ver = c.u16()?;
                if ver != codec::CODEC_VERSION {
                    bail!("unsupported provenance codec version {ver} on the wire");
                }
                let n = c.u32()? as usize;
                // Untrusted count: cap the pre-allocation. Each record is
                // structurally validated (incl. the MAX_PAYLOAD cap on
                // its length field) before its bytes are copied out.
                let mut recs = Vec::with_capacity(n.min(MAX_PREALLOC));
                for _ in 0..n {
                    let len = codec::validate(c.peek())
                        .context("malformed binary provenance record on the wire")?;
                    recs.push(c.take_slice(len)?.to_vec());
                }
                let accepted = store.ingest_encoded(recs);
                write_msg(&mut stream, &(accepted as u32).to_le_bytes())?;
            }
            KIND_QUERY => {
                let text = c.str()?;
                let q = ProvQuery::from_json(&parse(&text)?)?;
                let recs = store.query(&q);
                reply.clear();
                put_records_jsonl(&mut reply, &recs);
                write_msg(&mut stream, &reply)?;
            }
            KIND_QUERY_BIN => {
                let text = c.str()?;
                let q = ProvQuery::from_json(&parse(&text)?)?;
                let recs = store.query_encoded(&q);
                reply.clear();
                put_records_bin(&mut reply, &recs);
                write_msg(&mut stream, &reply)?;
            }
            KIND_CALLSTACK => {
                let app = c.u32()?;
                let rank = c.u32()?;
                let step = c.u64()?;
                let recs = store.call_stack(app, rank, step);
                reply.clear();
                put_records_jsonl(&mut reply, &recs);
                write_msg(&mut stream, &reply)?;
            }
            KIND_CALLSTACK_BIN => {
                let app = c.u32()?;
                let rank = c.u32()?;
                let step = c.u64()?;
                let recs = store.query_encoded(&ProvStore::call_stack_query(app, rank, step));
                reply.clear();
                put_records_bin(&mut reply, &recs);
                write_msg(&mut stream, &reply)?;
            }
            KIND_META_SET => {
                let text = c.str()?;
                store.set_metadata(parse(&text)?)?;
                write_msg(&mut stream, &[1u8])?;
            }
            KIND_META_GET => {
                let mut out = Vec::new();
                match store.metadata() {
                    Some(m) => {
                        out.push(1u8);
                        put_str(&mut out, &m.to_string());
                    }
                    None => out.push(0u8),
                }
                write_msg(&mut stream, &out)?;
            }
            KIND_STATS => {
                let s = store.stats();
                let mut out = Vec::with_capacity(48);
                out.extend_from_slice(&s.records.to_le_bytes());
                out.extend_from_slice(&s.resident_bytes.to_le_bytes());
                out.extend_from_slice(&s.log_bytes.to_le_bytes());
                out.extend_from_slice(&s.anomalies.to_le_bytes());
                out.extend_from_slice(&s.evicted.to_le_bytes());
                out.extend_from_slice(&s.log_errors.to_le_bytes());
                write_msg(&mut stream, &out)?;
            }
            KIND_FLUSH => {
                store.flush();
                write_msg(&mut stream, &[1u8])?;
            }
            k => bail!("unknown request kind {k}"),
        }
        if reply.capacity() > MAX_REPLY_RETAIN {
            reply = Vec::new();
        }
    }
}

/// TCP client for the provenance database; same query surface as the
/// local [`ProvDb`](crate::provenance::ProvDb), plus batched writes.
///
/// Records encode into a reused pending buffer as they are appended (the
/// binary default — no intermediate `Json` or per-record `String`), and
/// ship `batch` at a time. [`RecordFormat::Jsonl`] keeps the legacy text
/// encoding for migration and A/B measurement (the fig9 codec sweep).
pub struct ProvClient {
    stream: TcpStream,
    /// Server shard count, learned from the hello handshake.
    n_shards: usize,
    /// Encoded records awaiting the next batch send (reused).
    pending: Vec<u8>,
    pending_n: usize,
    /// Reused frame-assembly buffer.
    msg: Vec<u8>,
    batch: usize,
    wire: RecordFormat,
}

impl ProvClient {
    /// Connect with the default write batch size (binary wire).
    pub fn connect(addr: &str) -> Result<ProvClient> {
        Self::connect_with_batch(addr, DEFAULT_BATCH)
    }

    /// Connect; `batch` records buffer client-side per write round-trip.
    pub fn connect_with_batch(addr: &str, batch: usize) -> Result<ProvClient> {
        Self::connect_with(addr, batch, RecordFormat::Binary)
    }

    /// Connect with an explicit wire record format.
    pub fn connect_with(addr: &str, batch: usize, wire: RecordFormat) -> Result<ProvClient> {
        let mut stream =
            TcpStream::connect(addr).with_context(|| format!("connecting to provdb {addr}"))?;
        stream.set_nodelay(true).ok();
        write_msg(&mut stream, &[KIND_HELLO])?;
        let hello = read_msg(&mut stream)?.context("provdb closed during hello")?;
        let mut c = Cursor::new(&hello);
        let n_shards = c.u32()? as usize;
        if n_shards == 0 {
            bail!("provdb server reported zero shards");
        }
        if wire == RecordFormat::Binary {
            let ver = c.u16().context("provdb server predates the binary codec")?;
            if ver != codec::CODEC_VERSION {
                bail!(
                    "provdb codec version mismatch: server {ver}, client {}",
                    codec::CODEC_VERSION
                );
            }
        }
        Ok(ProvClient {
            stream,
            n_shards,
            pending: Vec::new(),
            pending_n: 0,
            msg: Vec::new(),
            batch: batch.max(1),
            wire,
        })
    }

    /// Server shard count from the handshake.
    pub fn shard_count(&self) -> usize {
        self.n_shards
    }

    /// Buffer one record; ships a batch once `batch` records accumulate,
    /// so the caller never blocks per record.
    pub fn append(&mut self, rec: &ProvRecord) -> Result<()> {
        match self.wire {
            RecordFormat::Binary => codec::encode(rec, &mut self.pending),
            RecordFormat::Jsonl => {
                let mut line = String::with_capacity(360);
                rec.write_jsonl(&mut line);
                put_str(&mut self.pending, &line);
            }
        }
        self.pending_n += 1;
        if self.pending_n >= self.batch {
            self.send_batch()?;
        }
        Ok(())
    }

    /// Append kept records from one AD step, resolving names via `reg` —
    /// the remote mirror of [`ProvDb::append_step`](crate::provenance::ProvDb::append_step).
    /// Each record encodes straight into the pending batch buffer.
    pub fn append_step(&mut self, kept: &[Labeled], reg: &FuncRegistry) -> Result<()> {
        for l in kept {
            let rec = ProvRecord::from_labeled(l, reg.name(l.rec.fid));
            self.append(&rec)?;
        }
        Ok(())
    }

    fn send_batch(&mut self) -> Result<()> {
        if self.pending_n == 0 {
            return Ok(());
        }
        self.msg.clear();
        match self.wire {
            RecordFormat::Binary => {
                self.msg.push(KIND_WRITE_BIN);
                self.msg.extend_from_slice(&codec::CODEC_VERSION.to_le_bytes());
            }
            RecordFormat::Jsonl => self.msg.push(KIND_WRITE),
        }
        self.msg.extend_from_slice(&(self.pending_n as u32).to_le_bytes());
        self.msg.extend_from_slice(&self.pending);
        write_msg(&mut self.stream, &self.msg)?;
        let reply = read_msg(&mut self.stream)?.context("provdb closed on write")?;
        let mut c = Cursor::new(&reply);
        let acked = c.u32()? as usize;
        if acked != self.pending_n {
            bail!("provdb acked {acked} of {} records", self.pending_n);
        }
        self.pending.clear();
        self.pending_n = 0;
        Ok(())
    }

    /// Ship any buffered records, then barrier server-side: every shard
    /// queue drains and the append log is flushed/compacted before this
    /// returns, making the writes visible to every other client.
    pub fn flush(&mut self) -> Result<()> {
        self.send_batch()?;
        write_msg(&mut self.stream, &[KIND_FLUSH])?;
        read_msg(&mut self.stream)?.context("provdb closed on flush")?;
        Ok(())
    }

    fn read_records(&mut self) -> Result<Vec<ProvRecord>> {
        let reply = read_msg(&mut self.stream)?.context("provdb closed on query")?;
        let mut c = Cursor::new(&reply);
        match self.wire {
            RecordFormat::Binary => {
                let ver = c.u16()?;
                if ver != codec::CODEC_VERSION {
                    bail!("provdb reply codec version {ver} unsupported");
                }
                let n = c.u32()? as usize;
                // Count is peer-supplied: cap the pre-allocation; decode
                // validates each record against the actual bytes.
                let mut out = Vec::with_capacity(n.min(MAX_PREALLOC));
                for _ in 0..n {
                    let (rec, used) = codec::decode(c.peek())?;
                    c.take_slice(used)?;
                    out.push(rec);
                }
                Ok(out)
            }
            RecordFormat::Jsonl => {
                let n = c.u32()? as usize;
                let mut out = Vec::with_capacity(n.min(MAX_PREALLOC));
                for _ in 0..n {
                    let line = c.str()?;
                    out.push(ProvRecord::from_jsonl_line(&line)?);
                }
                Ok(out)
            }
        }
    }

    /// Run a query server-side (buffered writes ship first, so a client
    /// always reads its own writes).
    pub fn query(&mut self, q: &ProvQuery) -> Result<Vec<ProvRecord>> {
        self.send_batch()?;
        let kind = match self.wire {
            RecordFormat::Binary => KIND_QUERY_BIN,
            RecordFormat::Jsonl => KIND_QUERY,
        };
        let mut msg = vec![kind];
        put_str(&mut msg, &q.to_json().to_string());
        write_msg(&mut self.stream, &msg)?;
        self.read_records()
    }

    /// Call-stack reconstruction for `(app, rank, step)`, entry-ordered.
    pub fn call_stack(&mut self, app: u32, rank: u32, step: u64) -> Result<Vec<ProvRecord>> {
        self.send_batch()?;
        let kind = match self.wire {
            RecordFormat::Binary => KIND_CALLSTACK_BIN,
            RecordFormat::Jsonl => KIND_CALLSTACK,
        };
        let mut msg = vec![kind];
        msg.extend_from_slice(&app.to_le_bytes());
        msg.extend_from_slice(&rank.to_le_bytes());
        msg.extend_from_slice(&step.to_le_bytes());
        write_msg(&mut self.stream, &msg)?;
        self.read_records()
    }

    /// Store run metadata on the server.
    pub fn set_metadata(&mut self, meta: &Json) -> Result<()> {
        let mut msg = vec![KIND_META_SET];
        put_str(&mut msg, &meta.to_string());
        write_msg(&mut self.stream, &msg)?;
        read_msg(&mut self.stream)?.context("provdb closed on metadata")?;
        Ok(())
    }

    /// Retrieve run metadata, if the server holds any.
    pub fn metadata(&mut self) -> Result<Option<Json>> {
        write_msg(&mut self.stream, &[KIND_META_GET])?;
        let reply = read_msg(&mut self.stream)?.context("provdb closed on metadata")?;
        let mut c = Cursor::new(&reply);
        if c.u8()? == 0 {
            return Ok(None);
        }
        Ok(Some(parse(&c.str()?)?))
    }

    /// Aggregate store counters.
    pub fn stats(&mut self) -> Result<ProvDbStats> {
        self.send_batch()?;
        write_msg(&mut self.stream, &[KIND_STATS])?;
        let reply = read_msg(&mut self.stream)?.context("provdb closed on stats")?;
        let mut c = Cursor::new(&reply);
        Ok(ProvDbStats {
            records: c.u64()?,
            resident_bytes: c.u64()?,
            log_bytes: c.u64()?,
            anomalies: c.u64()?,
            evicted: c.u64()?,
            // Absent on pre-binary servers: default to 0.
            log_errors: c.u64().unwrap_or(0),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::store::{spawn_store, Retention};
    use super::*;
    use std::io::Write;

    fn rec(rank: u32, step: u64, score: f64, id: u64) -> ProvRecord {
        ProvRecord {
            call_id: id,
            app: 0,
            rank,
            thread: 0,
            fid: 1,
            func: "F1".to_string(),
            step,
            entry_us: id * 10,
            exit_us: id * 10 + 5,
            inclusive_us: 5,
            exclusive_us: 5,
            depth: 0,
            parent: None,
            n_children: 0,
            n_messages: 0,
            msg_bytes: 0,
            label: if score >= 6.0 { "anomaly_high".into() } else { "normal".into() },
            score,
        }
    }

    #[test]
    fn write_flush_query_roundtrip() {
        let (store, handle) = spawn_store(None, 2, Retention::default()).unwrap();
        let mut srv = ProvDbTcpServer::start("127.0.0.1:0", store.clone()).unwrap();
        let addr = srv.addr().to_string();
        let mut cl = ProvClient::connect_with_batch(&addr, 4).unwrap();
        assert_eq!(cl.shard_count(), 2);
        for i in 0..10u64 {
            cl.append(&rec((i % 3) as u32, i / 2, i as f64, i)).unwrap();
        }
        // 10 records at batch 4: two batches shipped, two still pending.
        let all = cl.query(&ProvQuery::default()).unwrap();
        assert_eq!(all.len(), 10, "query must ship pending writes first");
        let anoms = cl
            .query(&ProvQuery { anomalies_only: true, ..Default::default() })
            .unwrap();
        assert_eq!(anoms.len(), 4); // scores 6..=9
        let stack = cl.call_stack(0, 0, 0).unwrap();
        assert!(stack.iter().all(|r| r.rank == 0 && r.step == 0));
        cl.flush().unwrap();
        // A second client sees the flushed records.
        let mut cl2 = ProvClient::connect(&addr).unwrap();
        assert_eq!(cl2.query(&ProvQuery::default()).unwrap().len(), 10);
        let stats = cl2.stats().unwrap();
        assert_eq!(stats.records, 10);
        assert_eq!(stats.anomalies, 4);
        assert_eq!(stats.log_errors, 0);
        srv.stop();
        handle.join();
    }

    #[test]
    fn jsonl_wire_clients_interoperate_with_binary() {
        let (store, handle) = spawn_store(None, 2, Retention::default()).unwrap();
        let srv = ProvDbTcpServer::start("127.0.0.1:0", store.clone()).unwrap();
        let addr = srv.addr().to_string();
        // Legacy JSONL wire writer…
        let mut legacy = ProvClient::connect_with(&addr, 3, RecordFormat::Jsonl).unwrap();
        for i in 0..7u64 {
            legacy.append(&rec(0, i, i as f64, i)).unwrap();
        }
        legacy.flush().unwrap();
        // …is fully visible to a binary client, record-for-record…
        let mut bin = ProvClient::connect(&addr).unwrap();
        let from_bin = bin.query(&ProvQuery::default()).unwrap();
        assert_eq!(from_bin.len(), 7);
        // …and the legacy client reads binary-written records back too.
        bin.append(&rec(1, 9, 9.0, 100)).unwrap();
        bin.flush().unwrap();
        let from_legacy = legacy.query(&ProvQuery::default()).unwrap();
        assert_eq!(from_legacy.len(), 8);
        let from_bin = bin.query(&ProvQuery::default()).unwrap();
        assert_eq!(from_legacy, from_bin, "wire format must not change results");
        drop(srv);
        handle.join();
    }

    #[test]
    fn metadata_over_the_wire() {
        let (store, handle) = spawn_store(None, 1, Retention::default()).unwrap();
        let srv = ProvDbTcpServer::start("127.0.0.1:0", store.clone()).unwrap();
        let addr = srv.addr().to_string();
        let mut cl = ProvClient::connect(&addr).unwrap();
        assert!(cl.metadata().unwrap().is_none());
        cl.set_metadata(&Json::obj(vec![("run_id", Json::str("wire"))])).unwrap();
        let m = cl.metadata().unwrap().unwrap();
        assert_eq!(m.get("run_id").unwrap().as_str(), Some("wire"));
        drop(srv);
        handle.join();
    }

    #[test]
    fn malformed_record_drops_connection_not_server() {
        let (store, handle) = spawn_store(None, 2, Retention::default()).unwrap();
        let srv = ProvDbTcpServer::start("127.0.0.1:0", store.clone()).unwrap();
        let addr = srv.addr().to_string();
        // Hand-roll a JSONL write frame with junk instead of a record.
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut msg = vec![KIND_WRITE];
        msg.extend_from_slice(&1u32.to_le_bytes());
        put_str(&mut msg, "not json at all");
        write_msg(&mut s, &msg).unwrap();
        assert!(read_msg(&mut s).unwrap().is_none(), "conn must drop, no reply");
        drop(s);
        // Binary frame with garbage record bytes drops too.
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut msg = vec![KIND_WRITE_BIN];
        msg.extend_from_slice(&codec::CODEC_VERSION.to_le_bytes());
        msg.extend_from_slice(&1u32.to_le_bytes());
        msg.extend_from_slice(&[0xAB; 16]); // far short of a header
        write_msg(&mut s, &msg).unwrap();
        assert!(read_msg(&mut s).unwrap().is_none());
        drop(s);
        // A lying batch count with no bytes behind it: refused without a
        // giant allocation, connection drops.
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut msg = vec![KIND_WRITE_BIN];
        msg.extend_from_slice(&codec::CODEC_VERSION.to_le_bytes());
        msg.extend_from_slice(&u32::MAX.to_le_bytes());
        write_msg(&mut s, &msg).unwrap();
        assert!(read_msg(&mut s).unwrap().is_none());
        drop(s);
        // A record whose header claims an implausible payload length is
        // refused before any allocation.
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut msg = vec![KIND_WRITE_BIN];
        msg.extend_from_slice(&codec::CODEC_VERSION.to_le_bytes());
        msg.extend_from_slice(&1u32.to_le_bytes());
        let good = rec(0, 0, 1.0, 1);
        let start = msg.len();
        codec::encode(&good, &mut msg);
        msg[start + 45..start + 49]
            .copy_from_slice(&(codec::MAX_PAYLOAD as u32 + 7).to_le_bytes());
        write_msg(&mut s, &msg).unwrap();
        assert!(read_msg(&mut s).unwrap().is_none());
        drop(s);
        // A wrong codec version is refused.
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut msg = vec![KIND_WRITE_BIN];
        msg.extend_from_slice(&0xEEEEu16.to_le_bytes());
        msg.extend_from_slice(&0u32.to_le_bytes());
        write_msg(&mut s, &msg).unwrap();
        assert!(read_msg(&mut s).unwrap().is_none());
        drop(s);
        // Nothing was ingested; the server still serves good clients.
        let mut cl = ProvClient::connect(&addr).unwrap();
        assert!(cl.query(&ProvQuery::default()).unwrap().is_empty());
        cl.append(&rec(0, 0, 1.0, 1)).unwrap();
        assert_eq!(cl.query(&ProvQuery::default()).unwrap().len(), 1);
        // Junk frame kind also drops cleanly.
        let mut s2 = TcpStream::connect(&addr).unwrap();
        s2.write_all(&3u32.to_le_bytes()).unwrap();
        s2.write_all(&[0xFF, 0xFF, 0xFF]).unwrap();
        s2.flush().unwrap();
        assert!(read_msg(&mut s2).unwrap().is_none());
        drop(srv);
        handle.join();
    }

    #[test]
    fn concurrent_writers_converge() {
        let (store, handle) = spawn_store(None, 4, Retention::default()).unwrap();
        let srv = ProvDbTcpServer::start("127.0.0.1:0", store.clone()).unwrap();
        let addr = srv.addr().to_string();
        let mut joins = Vec::new();
        for rank in 0..6u32 {
            let addr = addr.clone();
            joins.push(std::thread::spawn(move || {
                let mut cl = ProvClient::connect_with_batch(&addr, 8).unwrap();
                for i in 0..40u64 {
                    cl.append(&rec(rank, i, 1.0, rank as u64 * 1000 + i)).unwrap();
                }
                cl.flush().unwrap();
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let mut cl = ProvClient::connect(&addr).unwrap();
        assert_eq!(cl.stats().unwrap().records, 240);
        for rank in 0..6u32 {
            let mine = cl
                .query(&ProvQuery { rank: Some((0, rank)), ..Default::default() })
                .unwrap();
            assert_eq!(mine.len(), 40, "rank {rank}");
        }
        drop(srv);
        handle.join();
    }
}
