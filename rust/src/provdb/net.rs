//! TCP transport for the provenance database — AD ranks write to it,
//! the visualization server queries it (the paper's Sonata/Mochi
//! deployment shape: a dedicated provenance service decoupled from the
//! analysis ranks).
//!
//! Wire protocol (length-prefixed frames, little-endian; shared framing
//! in [`util::wire`](crate::util::wire); the server echoes the request's
//! stream id on its reply):
//!
//! ```text
//! request  := u32 len, u32 stream, u8 kind, payload
//!   kind 1 (hello):         (empty)
//!   kind 2 (write jsonl):   n u32, n × (u32 len, JSONL record bytes)
//!   kind 3 (query jsonl):   u32 len, ProvQuery JSON bytes
//!   kind 4 (cs jsonl):      app u32, rank u32, step u64
//!   kind 5 (meta set):      u32 len, metadata JSON bytes
//!   kind 6 (meta get):      (empty)
//!   kind 7 (stats):         (empty)
//!   kind 8 (flush):         (empty)
//!   kind 9 (write bin):     codec u16, n u32, n × binary record
//!   kind 10 (query bin):    u32 len, ProvQuery JSON bytes
//!   kind 11 (cs bin):       app u32, rank u32, step u64
//!   kind 12 (probe install): probe wire encoding (see probe::Probe)
//!   kind 13 (probe remove): u32 len, name bytes
//!   kind 14 (probe list):   (empty)
//!   kind 15 (probe query):  u32 len, name bytes
//! reply (hello)      := u32 n_shards, u16 codec_version
//! reply (write)      := u32 n_accepted
//! reply (query/cs 3/4) := u32 n, n × (u32 len, JSONL record bytes)
//! reply (query/cs 10/11) := codec u16, u32 n, n × binary record
//! reply (meta set)   := u8 1
//! reply (meta get)   := u8 present, [u32 len, JSON bytes]
//! reply (stats)      := u64 records, u64 resident, u64 log, u64 anoms,
//!                       u64 evicted, u64 log_errors, u64 shed,
//!                       u64 net_queue_depth, u64 segments_total,
//!                       u64 segments_skipped, u64 zone_map_bytes
//! reply (flush)      := u8 1
//! reply (probe install) := u8 1
//! reply (probe remove)  := u8 existed
//! reply (probe list)    := u32 n, n × (name str, source str, u64 matches,
//!                          u64 shed, u64 pushed_records, u64 pushed_bytes)
//! reply (probe query)   := codec u16, u32 n, n × binary record
//! ```
//!
//! Kinds 12–15 turn installed probes (compiled predicate programs, see
//! [`probe`](crate::probe)) into **server-side filtered subscriptions**:
//! a probe query evaluates the named probe's verified bytecode against
//! every stored record inside the shards and ships only the admitted
//! records — non-matching records never cross the wire, which the
//! per-probe `pushed_records`/`pushed_bytes` counters in the list reply
//! make auditable. Installs are untrusted: the program is re-verified
//! server-side and a malformed or over-budget probe drops the connection
//! like any other hostile frame.
//!
//! The server runs on the shared poll(2) reactor
//! ([`serve_frames`](crate::util::net::serve_frames)): a fixed pool of
//! event-loop threads regardless of connection count, with bounded
//! per-connection reply backlogs. A connection that stops draining its
//! replies has further requests shed with a `Busy` control frame instead
//! of queueing unboundedly; the shed count and the live reply backlog
//! ride the stats reply (`shed`, `net_queue_depth`) so operators see
//! overload from the same surface as store health. See `docs/net.md`.
//!
//! Kinds 9–11 are the default pipeline: records travel in the
//! [`provenance::codec`](crate::provenance::codec) binary layout —
//! byte-identical to the shard-resident form and the `.provseg` segment
//! log — so the ingest path allocates no `Json` tree anywhere and query
//! replies copy stored bytes straight onto the wire. Kinds 2–4 keep the
//! JSONL encoding as a migration/escape hatch (`RecordFormat::Jsonl`
//! clients). Binary batches are tagged with
//! [`codec::CODEC_VERSION`](crate::provenance::codec::CODEC_VERSION);
//! a mismatch refuses the frame.
//!
//! Every count and length in a frame is untrusted: batch counts cap the
//! pre-allocation, per-record payload lengths are bounded by
//! [`codec::MAX_PAYLOAD`](crate::provenance::codec::MAX_PAYLOAD) and
//! validated against the actual frame bytes *before* any allocation. A
//! malformed record drops the connection without ingesting anything (the
//! wire is a trust boundary), mirroring `ps::net`'s misgrouped-frame
//! policy.
//!
//! [`ProvClient::append`] batches client-side: records encode into a
//! reused buffer and ship `batch` at a time, so AD ranks never block per
//! record. One connection reads its own writes (server-side, a
//! connection's ingests and queries traverse each shard queue in order);
//! cross-client visibility needs [`ProvClient::flush`], which is a
//! shard-drain barrier.

use super::store::{ProvDbStats, ProvStore};
use crate::ad::Labeled;
use crate::probe::{Probe, ProbeTable};
use crate::provenance::codec::{self, RecordFormat};
use crate::provenance::{ProvQuery, ProvRecord};
use crate::trace::FuncRegistry;
use crate::util::json::{parse, Json};
use crate::util::net::{serve_frames, FrameHandler, FrameSink, NetStats, ReactorOpts, TcpServerHandle};
use crate::util::wire::{put_str, read_msg, write_msg, Cursor};
use anyhow::{bail, Context, Result};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};

const KIND_HELLO: u8 = 1;
const KIND_WRITE: u8 = 2;
const KIND_QUERY: u8 = 3;
const KIND_CALLSTACK: u8 = 4;
const KIND_META_SET: u8 = 5;
const KIND_META_GET: u8 = 6;
const KIND_STATS: u8 = 7;
const KIND_FLUSH: u8 = 8;
const KIND_WRITE_BIN: u8 = 9;
const KIND_QUERY_BIN: u8 = 10;
const KIND_CALLSTACK_BIN: u8 = 11;
const KIND_PROBE_INSTALL: u8 = 12;
const KIND_PROBE_REMOVE: u8 = 13;
const KIND_PROBE_LIST: u8 = 14;
const KIND_PROBE_QUERY: u8 = 15;

/// Default client-side write batch (records per wire round-trip).
pub const DEFAULT_BATCH: usize = 64;

/// Untrusted-count cap: the largest record-count pre-allocation a frame
/// header can cause (pushes still validate against the payload).
const MAX_PREALLOC: usize = 4096;

/// Largest capacity the per-connection reused reply buffer keeps after a
/// request: one `limit=0` full dump must not pin the store's size in
/// memory for the connection's (long — the viz server reconnects lazily)
/// lifetime.
const MAX_REPLY_RETAIN: usize = 4 << 20;

/// TCP front-end for a provenance database; forwards to a [`ProvStore`].
/// Connections are multiplexed over the shared poll(2) reactor
/// ([`serve_frames`]): a fixed event-loop pool serves every connection,
/// each with its own [`ProvHandler`] protocol state.
pub struct ProvDbTcpServer {
    inner: TcpServerHandle,
    /// Probes installed over the wire, shared by every connection (and
    /// by the aggregator-trigger path when co-hosted in-process).
    probes: Arc<ProbeTable>,
}

impl ProvDbTcpServer {
    /// Bind and serve with default reactor sizing.
    pub fn start(addr: &str, store: ProvStore) -> Result<ProvDbTcpServer> {
        Self::start_with_opts(addr, store, ReactorOpts::default())
    }

    /// Bind and serve with explicit reactor/backpressure bounds.
    pub fn start_with_opts(
        addr: &str,
        store: ProvStore,
        opts: ReactorOpts,
    ) -> Result<ProvDbTcpServer> {
        // The factory is shared across event loops; clone the store out
        // from under a mutex per connection (ProvStore is Send, and this
        // keeps no Sync requirement on its internals).
        let store = Mutex::new(store);
        let stats = NetStats::new();
        let hstats = stats.clone();
        let probes = Arc::new(ProbeTable::new());
        let hprobes = Arc::clone(&probes);
        let inner = serve_frames("chimbuko-provdb-tcp", addr, opts, stats, move || {
            ProvHandler {
                store: store.lock().expect("provdb store lock").clone(),
                stats: hstats.clone(),
                probes: Arc::clone(&hprobes),
                reply: Vec::new(),
            }
        })?;
        Ok(ProvDbTcpServer { inner, probes })
    }

    /// The server's installed-probe table (shared with every connection).
    pub fn probes(&self) -> Arc<ProbeTable> {
        Arc::clone(&self.probes)
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.inner.addr()
    }

    /// Transport counters (accepted/shed/backlog...) for this server.
    pub fn net_stats(&self) -> Arc<NetStats> {
        self.inner.stats().clone()
    }

    pub fn stop(&mut self) {
        self.inner.stop();
    }
}

/// JSONL reply form (legacy kinds 3/4).
fn put_records_jsonl(reply: &mut Vec<u8>, recs: &[ProvRecord]) {
    reply.extend_from_slice(&(recs.len() as u32).to_le_bytes());
    let mut line = String::with_capacity(360);
    for r in recs {
        line.clear();
        r.write_jsonl(&mut line);
        put_str(reply, &line);
    }
}

/// Binary reply form (kinds 10/11): stored bytes, copied verbatim.
fn put_records_bin(reply: &mut Vec<u8>, recs: &[Vec<u8>]) {
    reply.extend_from_slice(&codec::CODEC_VERSION.to_le_bytes());
    reply.extend_from_slice(&(recs.len() as u32).to_le_bytes());
    for r in recs {
        reply.extend_from_slice(r);
    }
}

/// Per-connection protocol state for the reactor: one [`ProvStore`]
/// clone (its shard channels are FIFO per clone, preserving the
/// read-your-writes ordering the thread-per-connection server had) plus
/// the reused reply scratch buffer.
struct ProvHandler {
    store: ProvStore,
    /// Server-wide transport counters; the stats reply stamps its shed
    /// and backlog numbers from here.
    stats: Arc<NetStats>,
    /// Installed probes, shared across connections.
    probes: Arc<ProbeTable>,
    /// Reused across requests on this connection: binary query replies
    /// concatenate stored record bytes into this scratch buffer.
    reply: Vec<u8>,
}

impl ProvHandler {
    fn handle(&mut self, stream: u32, msg: &[u8], out: &mut FrameSink) -> Result<()> {
        let mut c = Cursor::new(msg);
        let kind = c.u8()?;
        match kind {
            KIND_HELLO => {
                let mut hello = Vec::with_capacity(6);
                hello.extend_from_slice(&(self.store.shard_count() as u32).to_le_bytes());
                hello.extend_from_slice(&codec::CODEC_VERSION.to_le_bytes());
                out.send(stream, &hello);
            }
            KIND_WRITE => {
                let n = c.u32()? as usize;
                // The count is wire-supplied (untrusted): cap the
                // pre-allocation so a lying header cannot abort the
                // process; pushes still validate against the payload.
                let mut recs = Vec::with_capacity(n.min(MAX_PREALLOC));
                for _ in 0..n {
                    let line = c.str()?;
                    // Trust boundary: refuse the whole frame on a
                    // malformed record instead of ingesting a prefix.
                    recs.push(
                        ProvRecord::from_jsonl_line(&line)
                            .context("malformed provenance record on the wire")?,
                    );
                }
                let accepted = self.store.ingest(recs);
                out.send(stream, &(accepted as u32).to_le_bytes());
            }
            KIND_WRITE_BIN => {
                let ver = c.u16()?;
                if ver != codec::CODEC_VERSION {
                    bail!("unsupported provenance codec version {ver} on the wire");
                }
                let n = c.u32()? as usize;
                // Untrusted count: cap the pre-allocation. Each record is
                // structurally validated (incl. the MAX_PAYLOAD cap on
                // its length field) before its bytes are copied out.
                let mut recs = Vec::with_capacity(n.min(MAX_PREALLOC));
                for _ in 0..n {
                    let len = codec::validate(c.peek())
                        .context("malformed binary provenance record on the wire")?;
                    recs.push(c.take_slice(len)?.to_vec());
                }
                let accepted = self.store.ingest_encoded(recs);
                out.send(stream, &(accepted as u32).to_le_bytes());
            }
            KIND_QUERY => {
                let text = c.str()?;
                let q = ProvQuery::from_json(&parse(&text)?)?;
                let recs = self.store.query(&q);
                self.reply.clear();
                put_records_jsonl(&mut self.reply, &recs);
                out.send(stream, &self.reply);
            }
            KIND_QUERY_BIN => {
                let text = c.str()?;
                let q = ProvQuery::from_json(&parse(&text)?)?;
                let recs = self.store.query_encoded(&q);
                self.reply.clear();
                put_records_bin(&mut self.reply, &recs);
                out.send(stream, &self.reply);
            }
            KIND_CALLSTACK => {
                let app = c.u32()?;
                let rank = c.u32()?;
                let step = c.u64()?;
                let recs = self.store.call_stack(app, rank, step);
                self.reply.clear();
                put_records_jsonl(&mut self.reply, &recs);
                out.send(stream, &self.reply);
            }
            KIND_CALLSTACK_BIN => {
                let app = c.u32()?;
                let rank = c.u32()?;
                let step = c.u64()?;
                let recs = self
                    .store
                    .query_encoded(&ProvStore::call_stack_query(app, rank, step));
                self.reply.clear();
                put_records_bin(&mut self.reply, &recs);
                out.send(stream, &self.reply);
            }
            KIND_META_SET => {
                let text = c.str()?;
                self.store.set_metadata(parse(&text)?)?;
                out.send(stream, &[1u8]);
            }
            KIND_META_GET => {
                let mut meta = Vec::new();
                match self.store.metadata() {
                    Some(m) => {
                        meta.push(1u8);
                        put_str(&mut meta, &m.to_string());
                    }
                    None => meta.push(0u8),
                }
                out.send(stream, &meta);
            }
            KIND_STATS => {
                let s = self.store.stats();
                let mut buf = Vec::with_capacity(64);
                buf.extend_from_slice(&s.records.to_le_bytes());
                buf.extend_from_slice(&s.resident_bytes.to_le_bytes());
                buf.extend_from_slice(&s.log_bytes.to_le_bytes());
                buf.extend_from_slice(&s.anomalies.to_le_bytes());
                buf.extend_from_slice(&s.evicted.to_le_bytes());
                buf.extend_from_slice(&s.log_errors.to_le_bytes());
                // Transport counters join the store's own on the wire.
                buf.extend_from_slice(&self.stats.shed_count().to_le_bytes());
                buf.extend_from_slice(&self.stats.queue_depth().to_le_bytes());
                // Warm-tier counters ride at the tail so v1-era clients
                // (which stop reading after the queue depth) still parse.
                buf.extend_from_slice(&s.segments_total.to_le_bytes());
                buf.extend_from_slice(&s.segments_skipped.to_le_bytes());
                buf.extend_from_slice(&s.zone_map_bytes.to_le_bytes());
                out.send(stream, &buf);
            }
            KIND_FLUSH => {
                self.store.flush();
                out.send(stream, &[1u8]);
            }
            KIND_PROBE_INSTALL => {
                // Untrusted program: from_wire enforces every cap and
                // runs the verifier; a hostile install drops the
                // connection like any other malformed frame.
                let probe = Probe::from_wire(&mut c)
                    .context("malformed probe install on the wire")?;
                self.probes.install(probe)?;
                out.send(stream, &[1u8]);
            }
            KIND_PROBE_REMOVE => {
                let name = c.str()?;
                let existed = self.probes.remove(&name);
                out.send(stream, &[existed as u8]);
            }
            KIND_PROBE_LIST => {
                let probes = self.probes.list();
                self.reply.clear();
                self.reply
                    .extend_from_slice(&(probes.len() as u32).to_le_bytes());
                for ip in &probes {
                    put_str(&mut self.reply, &ip.probe.name);
                    put_str(&mut self.reply, &ip.probe.source);
                    for v in [
                        ip.matches.load(std::sync::atomic::Ordering::Relaxed),
                        ip.shed.load(std::sync::atomic::Ordering::Relaxed),
                        ip.pushed_records.load(std::sync::atomic::Ordering::Relaxed),
                        ip.pushed_bytes.load(std::sync::atomic::Ordering::Relaxed),
                    ] {
                        self.reply.extend_from_slice(&v.to_le_bytes());
                    }
                }
                out.send(stream, &self.reply);
            }
            KIND_PROBE_QUERY => {
                let name = c.str()?;
                let ip = self
                    .probes
                    .get(&name)
                    .with_context(|| format!("no installed probe named '{name}'"))?;
                let recs = self.store.probe_scan(&ip);
                let bytes: u64 = recs.iter().map(|r| r.len() as u64).sum();
                ip.note_pushed(recs.len() as u64, bytes);
                self.reply.clear();
                put_records_bin(&mut self.reply, &recs);
                out.send(stream, &self.reply);
            }
            k => bail!("unknown request kind {k}"),
        }
        if self.reply.capacity() > MAX_REPLY_RETAIN {
            self.reply = Vec::new();
        }
        Ok(())
    }
}

impl FrameHandler for ProvHandler {
    fn on_frame(&mut self, stream: u32, payload: &[u8], out: &mut FrameSink) -> bool {
        // A malformed frame drops the connection (the wire is a trust
        // boundary); the server and its other connections are unaffected.
        self.handle(stream, payload, out).is_ok()
    }
}

/// TCP client for the provenance database; same query surface as the
/// local [`ProvDb`](crate::provenance::ProvDb), plus batched writes.
///
/// Records encode into a reused pending buffer as they are appended (the
/// binary default — no intermediate `Json` or per-record `String`), and
/// ship `batch` at a time. [`RecordFormat::Jsonl`] keeps the legacy text
/// encoding for migration and A/B measurement (the fig9 codec sweep).
pub struct ProvClient {
    stream: TcpStream,
    /// Peer address, kept for the write path's one-shot reconnect.
    addr: String,
    /// Server shard count, learned from the hello handshake.
    n_shards: usize,
    /// Encoded records awaiting the next batch send (reused).
    pending: Vec<u8>,
    pending_n: usize,
    /// Reused frame-assembly buffer.
    msg: Vec<u8>,
    batch: usize,
    wire: RecordFormat,
    /// Records abandoned after a send-side failure survived the one
    /// resend (bounded-loss accounting; see `rust/docs/chaos.md`).
    inflight_lost: u64,
}

impl ProvClient {
    /// Connect with the default write batch size (binary wire).
    pub fn connect(addr: &str) -> Result<ProvClient> {
        Self::connect_with_batch(addr, DEFAULT_BATCH)
    }

    /// Connect; `batch` records buffer client-side per write round-trip.
    pub fn connect_with_batch(addr: &str, batch: usize) -> Result<ProvClient> {
        Self::connect_with(addr, batch, RecordFormat::Binary)
    }

    /// Connect with an explicit wire record format.
    pub fn connect_with(addr: &str, batch: usize, wire: RecordFormat) -> Result<ProvClient> {
        let (stream, n_shards) = Self::dial(addr, wire)?;
        Ok(ProvClient {
            stream,
            addr: addr.to_string(),
            n_shards,
            pending: Vec::new(),
            pending_n: 0,
            msg: Vec::new(),
            batch: batch.max(1),
            wire,
            inflight_lost: 0,
        })
    }

    /// Dial + hello handshake (shared by connect and the write path's
    /// reconnect, so a healed connection is fully re-verified).
    fn dial(addr: &str, wire: RecordFormat) -> Result<(TcpStream, usize)> {
        let mut stream =
            TcpStream::connect(addr).with_context(|| format!("connecting to provdb {addr}"))?;
        stream.set_nodelay(true).ok();
        write_msg(&mut stream, &[KIND_HELLO])?;
        let hello = read_msg(&mut stream)?.context("provdb closed during hello")?;
        let mut c = Cursor::new(&hello);
        let n_shards = c.u32()? as usize;
        if n_shards == 0 {
            bail!("provdb server reported zero shards");
        }
        if wire == RecordFormat::Binary {
            let ver = c.u16().context("provdb server predates the binary codec")?;
            if ver != codec::CODEC_VERSION {
                bail!(
                    "provdb codec version mismatch: server {ver}, client {}",
                    codec::CODEC_VERSION
                );
            }
        }
        Ok((stream, n_shards))
    }

    /// Server shard count from the handshake.
    pub fn shard_count(&self) -> usize {
        self.n_shards
    }

    /// Buffer one record; ships a batch once `batch` records accumulate,
    /// so the caller never blocks per record.
    pub fn append(&mut self, rec: &ProvRecord) -> Result<()> {
        match self.wire {
            RecordFormat::Binary => codec::encode(rec, &mut self.pending),
            RecordFormat::Jsonl => {
                let mut line = String::with_capacity(360);
                rec.write_jsonl(&mut line);
                put_str(&mut self.pending, &line);
            }
        }
        self.pending_n += 1;
        if self.pending_n >= self.batch {
            self.send_batch()?;
        }
        Ok(())
    }

    /// Append kept records from one AD step, resolving names via `reg` —
    /// the remote mirror of [`ProvDb::append_step`](crate::provenance::ProvDb::append_step).
    /// Each record encodes straight into the pending batch buffer.
    pub fn append_step(&mut self, kept: &[Labeled], reg: &FuncRegistry) -> Result<()> {
        for l in kept {
            let rec = ProvRecord::from_labeled(l, reg.name(l.rec.fid));
            self.append(&rec)?;
        }
        Ok(())
    }

    /// Write one assembled batch frame and read its ack count. A
    /// transport failure here means the batch's fate is unknown.
    fn ship(stream: &mut TcpStream, msg: &[u8]) -> Result<usize> {
        write_msg(stream, msg)?;
        let reply = read_msg(stream)?.context("provdb closed on write")?;
        Ok(Cursor::new(&reply).u32()? as usize)
    }

    fn send_batch(&mut self) -> Result<()> {
        if self.pending_n == 0 {
            return Ok(());
        }
        self.msg.clear();
        match self.wire {
            RecordFormat::Binary => {
                self.msg.push(KIND_WRITE_BIN);
                self.msg.extend_from_slice(&codec::CODEC_VERSION.to_le_bytes());
            }
            RecordFormat::Jsonl => self.msg.push(KIND_WRITE),
        }
        self.msg.extend_from_slice(&(self.pending_n as u32).to_le_bytes());
        self.msg.extend_from_slice(&self.pending);
        let acked = match Self::ship(&mut self.stream, &self.msg) {
            Ok(a) => a,
            Err(first) => {
                // Send-side failure (a crashed or restarted server):
                // redial — re-running the full hello handshake — and
                // resend the already-encoded batch exactly once. Ingest
                // is append-with-seq, so a healed server absorbing the
                // resend is idempotent from the run's point of view. If
                // the resend fails too, the batch is *counted* as lost
                // (never silently dropped) and abandoned, so the client
                // keeps making progress against the healed endpoint.
                let resent = Self::dial(&self.addr, self.wire).and_then(|(mut s, n)| {
                    let acked = Self::ship(&mut s, &self.msg)?;
                    Ok((s, n, acked))
                });
                match resent {
                    Ok((stream, n_shards, acked)) => {
                        crate::log_warn!(
                            "prov",
                            "provdb {} write severed mid-batch; reconnected and resent {} records",
                            self.addr,
                            self.pending_n
                        );
                        self.stream = stream;
                        self.n_shards = n_shards;
                        acked
                    }
                    Err(e) => {
                        self.inflight_lost += self.pending_n as u64;
                        crate::log_warn!(
                            "prov",
                            "provdb {} unreachable after resend: {} in-flight records lost \
                             (counted; total {})",
                            self.addr,
                            self.pending_n,
                            self.inflight_lost
                        );
                        self.pending.clear();
                        self.pending_n = 0;
                        return Err(e.context(first).context(format!(
                            "provdb {} write failed and the one resend failed too",
                            self.addr
                        )));
                    }
                }
            }
        };
        if acked != self.pending_n {
            bail!("provdb acked {acked} of {} records", self.pending_n);
        }
        self.pending.clear();
        self.pending_n = 0;
        Ok(())
    }

    /// Records abandoned after a mid-batch failure survived the one
    /// resend — the client-side half of the chaos plane's bounded-loss
    /// ledger (the transport's [`NetStats::inflight_lost`] is the
    /// server-facing half).
    pub fn inflight_lost(&self) -> u64 {
        self.inflight_lost
    }

    /// Ship any buffered records, then barrier server-side: every shard
    /// queue drains and the append log is flushed/compacted before this
    /// returns, making the writes visible to every other client.
    pub fn flush(&mut self) -> Result<()> {
        self.send_batch()?;
        write_msg(&mut self.stream, &[KIND_FLUSH])?;
        read_msg(&mut self.stream)?.context("provdb closed on flush")?;
        Ok(())
    }

    fn read_records(&mut self) -> Result<Vec<ProvRecord>> {
        let reply = read_msg(&mut self.stream)?.context("provdb closed on query")?;
        let mut c = Cursor::new(&reply);
        match self.wire {
            RecordFormat::Binary => {
                let ver = c.u16()?;
                if ver != codec::CODEC_VERSION {
                    bail!("provdb reply codec version {ver} unsupported");
                }
                let n = c.u32()? as usize;
                // Count is peer-supplied: cap the pre-allocation; decode
                // validates each record against the actual bytes.
                let mut out = Vec::with_capacity(n.min(MAX_PREALLOC));
                for _ in 0..n {
                    let (rec, used) = codec::decode(c.peek())?;
                    c.take_slice(used)?;
                    out.push(rec);
                }
                Ok(out)
            }
            RecordFormat::Jsonl => {
                let n = c.u32()? as usize;
                let mut out = Vec::with_capacity(n.min(MAX_PREALLOC));
                for _ in 0..n {
                    let line = c.str()?;
                    out.push(ProvRecord::from_jsonl_line(&line)?);
                }
                Ok(out)
            }
        }
    }

    /// Run a query server-side (buffered writes ship first, so a client
    /// always reads its own writes).
    pub fn query(&mut self, q: &ProvQuery) -> Result<Vec<ProvRecord>> {
        self.send_batch()?;
        let kind = match self.wire {
            RecordFormat::Binary => KIND_QUERY_BIN,
            RecordFormat::Jsonl => KIND_QUERY,
        };
        let mut msg = vec![kind];
        put_str(&mut msg, &q.to_json().to_string());
        write_msg(&mut self.stream, &msg)?;
        self.read_records()
    }

    /// Call-stack reconstruction for `(app, rank, step)`, entry-ordered.
    pub fn call_stack(&mut self, app: u32, rank: u32, step: u64) -> Result<Vec<ProvRecord>> {
        self.send_batch()?;
        let kind = match self.wire {
            RecordFormat::Binary => KIND_CALLSTACK_BIN,
            RecordFormat::Jsonl => KIND_CALLSTACK,
        };
        let mut msg = vec![kind];
        msg.extend_from_slice(&app.to_le_bytes());
        msg.extend_from_slice(&rank.to_le_bytes());
        msg.extend_from_slice(&step.to_le_bytes());
        write_msg(&mut self.stream, &msg)?;
        self.read_records()
    }

    /// Store run metadata on the server.
    pub fn set_metadata(&mut self, meta: &Json) -> Result<()> {
        let mut msg = vec![KIND_META_SET];
        put_str(&mut msg, &meta.to_string());
        write_msg(&mut self.stream, &msg)?;
        read_msg(&mut self.stream)?.context("provdb closed on metadata")?;
        Ok(())
    }

    /// Retrieve run metadata, if the server holds any.
    pub fn metadata(&mut self) -> Result<Option<Json>> {
        write_msg(&mut self.stream, &[KIND_META_GET])?;
        let reply = read_msg(&mut self.stream)?.context("provdb closed on metadata")?;
        let mut c = Cursor::new(&reply);
        if c.u8()? == 0 {
            return Ok(None);
        }
        Ok(Some(parse(&c.str()?)?))
    }

    /// Install (or replace) a compiled probe on the server, turning it
    /// into a server-side filtered subscription. The server re-verifies
    /// the program before accepting it.
    pub fn install_probe(&mut self, probe: &Probe) -> Result<()> {
        let mut msg = vec![KIND_PROBE_INSTALL];
        probe.to_wire(&mut msg);
        write_msg(&mut self.stream, &msg)?;
        read_msg(&mut self.stream)?.context("provdb closed on probe install")?;
        Ok(())
    }

    /// Remove an installed probe; `Ok(true)` when it existed.
    pub fn remove_probe(&mut self, name: &str) -> Result<bool> {
        let mut msg = vec![KIND_PROBE_REMOVE];
        put_str(&mut msg, name);
        write_msg(&mut self.stream, &msg)?;
        let reply = read_msg(&mut self.stream)?.context("provdb closed on probe remove")?;
        Ok(Cursor::new(&reply).u8()? != 0)
    }

    /// List installed probes with their live match/shed/push counters.
    pub fn list_probes(&mut self) -> Result<Vec<ProbeInfo>> {
        write_msg(&mut self.stream, &[KIND_PROBE_LIST])?;
        let reply = read_msg(&mut self.stream)?.context("provdb closed on probe list")?;
        let mut c = Cursor::new(&reply);
        let n = c.u32()? as usize;
        let mut out = Vec::with_capacity(n.min(MAX_PREALLOC));
        for _ in 0..n {
            out.push(ProbeInfo {
                name: c.str()?,
                source: c.str()?,
                matches: c.u64()?,
                shed: c.u64()?,
                pushed_records: c.u64()?,
                pushed_bytes: c.u64()?,
            });
        }
        Ok(out)
    }

    /// Pull the installed probe `name`'s subscription: the server
    /// evaluates the compiled predicate inside the shards and ships only
    /// admitted records (buffered writes ship first). The reply is the
    /// stored encoding — bit-identical to a `ProvQuery`-equivalent
    /// [`query`](Self::query) — always binary regardless of the
    /// client's write wire format.
    pub fn probe_query_encoded(&mut self, name: &str) -> Result<Vec<Vec<u8>>> {
        self.send_batch()?;
        let mut msg = vec![KIND_PROBE_QUERY];
        put_str(&mut msg, name);
        write_msg(&mut self.stream, &msg)?;
        let reply = read_msg(&mut self.stream)?.context("provdb closed on probe query")?;
        let mut c = Cursor::new(&reply);
        let ver = c.u16()?;
        if ver != codec::CODEC_VERSION {
            bail!("provdb reply codec version {ver} unsupported");
        }
        let n = c.u32()? as usize;
        let mut out = Vec::with_capacity(n.min(MAX_PREALLOC));
        for _ in 0..n {
            let used = codec::validate(c.peek())?;
            out.push(c.take_slice(used)?.to_vec());
        }
        Ok(out)
    }

    /// [`Self::probe_query_encoded`], decoded.
    pub fn probe_query(&mut self, name: &str) -> Result<Vec<ProvRecord>> {
        self.probe_query_encoded(name)?
            .iter()
            .map(|b| Ok(codec::decode(b)?.0))
            .collect()
    }

    /// Aggregate store counters.
    pub fn stats(&mut self) -> Result<ProvDbStats> {
        self.send_batch()?;
        write_msg(&mut self.stream, &[KIND_STATS])?;
        let reply = read_msg(&mut self.stream)?.context("provdb closed on stats")?;
        let mut c = Cursor::new(&reply);
        Ok(ProvDbStats {
            records: c.u64()?,
            resident_bytes: c.u64()?,
            log_bytes: c.u64()?,
            anomalies: c.u64()?,
            evicted: c.u64()?,
            // Trailing fields are absent on older servers: default to 0.
            log_errors: c.u64().unwrap_or(0),
            shed: c.u64().unwrap_or(0),
            net_queue_depth: c.u64().unwrap_or(0),
            segments_total: c.u64().unwrap_or(0),
            segments_skipped: c.u64().unwrap_or(0),
            zone_map_bytes: c.u64().unwrap_or(0),
        })
    }
}

/// One installed probe as reported by the list reply: identity plus the
/// live counters that prove what did (and did not) cross the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct ProbeInfo {
    pub name: String,
    pub source: String,
    /// Records the predicate matched during scans.
    pub matches: u64,
    /// Matching records dropped by the probe's sampling gate.
    pub shed: u64,
    /// Records actually shipped to subscribers.
    pub pushed_records: u64,
    /// Bytes of those records on the wire.
    pub pushed_bytes: u64,
}

impl ProbeInfo {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("source", Json::str(&self.source)),
            ("matches", Json::num(self.matches as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("pushed_records", Json::num(self.pushed_records as f64)),
            ("pushed_bytes", Json::num(self.pushed_bytes as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::super::store::{spawn_store, Retention};
    use super::*;

    fn rec(rank: u32, step: u64, score: f64, id: u64) -> ProvRecord {
        ProvRecord {
            call_id: id,
            app: 0,
            rank,
            thread: 0,
            fid: 1,
            func: "F1".to_string(),
            step,
            entry_us: id * 10,
            exit_us: id * 10 + 5,
            inclusive_us: 5,
            exclusive_us: 5,
            depth: 0,
            parent: None,
            n_children: 0,
            n_messages: 0,
            msg_bytes: 0,
            label: if score >= 6.0 { "anomaly_high".into() } else { "normal".into() },
            score,
        }
    }

    #[test]
    fn write_flush_query_roundtrip() {
        let (store, handle) = spawn_store(None, 2, Retention::default()).unwrap();
        let mut srv = ProvDbTcpServer::start("127.0.0.1:0", store.clone()).unwrap();
        let addr = srv.addr().to_string();
        let mut cl = ProvClient::connect_with_batch(&addr, 4).unwrap();
        assert_eq!(cl.shard_count(), 2);
        for i in 0..10u64 {
            cl.append(&rec((i % 3) as u32, i / 2, i as f64, i)).unwrap();
        }
        // 10 records at batch 4: two batches shipped, two still pending.
        let all = cl.query(&ProvQuery::default()).unwrap();
        assert_eq!(all.len(), 10, "query must ship pending writes first");
        let anoms = cl
            .query(&ProvQuery { anomalies_only: true, ..Default::default() })
            .unwrap();
        assert_eq!(anoms.len(), 4); // scores 6..=9
        let stack = cl.call_stack(0, 0, 0).unwrap();
        assert!(stack.iter().all(|r| r.rank == 0 && r.step == 0));
        cl.flush().unwrap();
        // A second client sees the flushed records.
        let mut cl2 = ProvClient::connect(&addr).unwrap();
        assert_eq!(cl2.query(&ProvQuery::default()).unwrap().len(), 10);
        let stats = cl2.stats().unwrap();
        assert_eq!(stats.records, 10);
        assert_eq!(stats.anomalies, 4);
        assert_eq!(stats.log_errors, 0);
        assert_eq!(stats.shed, 0, "well-behaved clients must never be shed");
        srv.stop();
        handle.join();
    }

    #[test]
    fn jsonl_wire_clients_interoperate_with_binary() {
        let (store, handle) = spawn_store(None, 2, Retention::default()).unwrap();
        let srv = ProvDbTcpServer::start("127.0.0.1:0", store.clone()).unwrap();
        let addr = srv.addr().to_string();
        // Legacy JSONL wire writer…
        let mut legacy = ProvClient::connect_with(&addr, 3, RecordFormat::Jsonl).unwrap();
        for i in 0..7u64 {
            legacy.append(&rec(0, i, i as f64, i)).unwrap();
        }
        legacy.flush().unwrap();
        // …is fully visible to a binary client, record-for-record…
        let mut bin = ProvClient::connect(&addr).unwrap();
        let from_bin = bin.query(&ProvQuery::default()).unwrap();
        assert_eq!(from_bin.len(), 7);
        // …and the legacy client reads binary-written records back too.
        bin.append(&rec(1, 9, 9.0, 100)).unwrap();
        bin.flush().unwrap();
        let from_legacy = legacy.query(&ProvQuery::default()).unwrap();
        assert_eq!(from_legacy.len(), 8);
        let from_bin = bin.query(&ProvQuery::default()).unwrap();
        assert_eq!(from_legacy, from_bin, "wire format must not change results");
        drop(srv);
        handle.join();
    }

    #[test]
    fn metadata_over_the_wire() {
        let (store, handle) = spawn_store(None, 1, Retention::default()).unwrap();
        let srv = ProvDbTcpServer::start("127.0.0.1:0", store.clone()).unwrap();
        let addr = srv.addr().to_string();
        let mut cl = ProvClient::connect(&addr).unwrap();
        assert!(cl.metadata().unwrap().is_none());
        cl.set_metadata(&Json::obj(vec![("run_id", Json::str("wire"))])).unwrap();
        let m = cl.metadata().unwrap().unwrap();
        assert_eq!(m.get("run_id").unwrap().as_str(), Some("wire"));
        drop(srv);
        handle.join();
    }

    #[test]
    fn malformed_record_drops_connection_not_server() {
        let (store, handle) = spawn_store(None, 2, Retention::default()).unwrap();
        let srv = ProvDbTcpServer::start("127.0.0.1:0", store.clone()).unwrap();
        let addr = srv.addr().to_string();
        // Hand-roll a JSONL write frame with junk instead of a record.
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut msg = vec![KIND_WRITE];
        msg.extend_from_slice(&1u32.to_le_bytes());
        put_str(&mut msg, "not json at all");
        write_msg(&mut s, &msg).unwrap();
        assert!(read_msg(&mut s).unwrap().is_none(), "conn must drop, no reply");
        drop(s);
        // Binary frame with garbage record bytes drops too.
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut msg = vec![KIND_WRITE_BIN];
        msg.extend_from_slice(&codec::CODEC_VERSION.to_le_bytes());
        msg.extend_from_slice(&1u32.to_le_bytes());
        msg.extend_from_slice(&[0xAB; 16]); // far short of a header
        write_msg(&mut s, &msg).unwrap();
        assert!(read_msg(&mut s).unwrap().is_none());
        drop(s);
        // A lying batch count with no bytes behind it: refused without a
        // giant allocation, connection drops.
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut msg = vec![KIND_WRITE_BIN];
        msg.extend_from_slice(&codec::CODEC_VERSION.to_le_bytes());
        msg.extend_from_slice(&u32::MAX.to_le_bytes());
        write_msg(&mut s, &msg).unwrap();
        assert!(read_msg(&mut s).unwrap().is_none());
        drop(s);
        // A record whose header claims an implausible payload length is
        // refused before any allocation.
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut msg = vec![KIND_WRITE_BIN];
        msg.extend_from_slice(&codec::CODEC_VERSION.to_le_bytes());
        msg.extend_from_slice(&1u32.to_le_bytes());
        let good = rec(0, 0, 1.0, 1);
        let start = msg.len();
        codec::encode(&good, &mut msg);
        msg[start + 45..start + 49]
            .copy_from_slice(&(codec::MAX_PAYLOAD as u32 + 7).to_le_bytes());
        write_msg(&mut s, &msg).unwrap();
        assert!(read_msg(&mut s).unwrap().is_none());
        drop(s);
        // A wrong codec version is refused.
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut msg = vec![KIND_WRITE_BIN];
        msg.extend_from_slice(&0xEEEEu16.to_le_bytes());
        msg.extend_from_slice(&0u32.to_le_bytes());
        write_msg(&mut s, &msg).unwrap();
        assert!(read_msg(&mut s).unwrap().is_none());
        drop(s);
        // Nothing was ingested; the server still serves good clients.
        let mut cl = ProvClient::connect(&addr).unwrap();
        assert!(cl.query(&ProvQuery::default()).unwrap().is_empty());
        cl.append(&rec(0, 0, 1.0, 1)).unwrap();
        assert_eq!(cl.query(&ProvQuery::default()).unwrap().len(), 1);
        // Junk request kind also drops cleanly.
        let mut s2 = TcpStream::connect(&addr).unwrap();
        write_msg(&mut s2, &[0xFF, 0xFF, 0xFF]).unwrap();
        assert!(read_msg(&mut s2).unwrap().is_none());
        drop(srv);
        handle.join();
    }

    #[test]
    fn flooded_connection_sheds_but_behaved_clients_are_unaffected() {
        let (store, handle) = spawn_store(None, 2, Retention::default()).unwrap();
        // Tiny per-connection reply budget so a non-draining reader trips
        // the shed path quickly; the huge server-wide bound keeps the
        // behaved client out of the blast radius.
        let opts = ReactorOpts::new(1, 32 * 1024, 1 << 30);
        let srv = ProvDbTcpServer::start_with_opts("127.0.0.1:0", store.clone(), opts).unwrap();
        let addr = srv.addr().to_string();
        let mut cl = ProvClient::connect(&addr).unwrap();
        // Seed ~256 KiB of metadata: one META_GET reply alone overflows
        // the connection's reply budget.
        let big = "m".repeat(256 * 1024);
        cl.set_metadata(&Json::obj(vec![("blob", Json::str(&big))])).unwrap();
        // The flooder requests metadata 200 times (~50 MiB of replies,
        // far past any kernel socket-buffer cushion) and never reads.
        let mut flood = TcpStream::connect(&addr).unwrap();
        for _ in 0..200 {
            if write_msg(&mut flood, &[KIND_META_GET]).is_err() {
                break;
            }
        }
        let net = srv.net_stats();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while net.shed_count() == 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert!(net.shed_count() > 0, "flooded connection never shed");
        // The behaved client's writes and reads are untouched by the
        // overload next door.
        for i in 0..20u64 {
            cl.append(&rec(0, i, i as f64, i)).unwrap();
        }
        cl.flush().unwrap();
        assert_eq!(cl.query(&ProvQuery::default()).unwrap().len(), 20);
        let stats = cl.stats().unwrap();
        assert_eq!(stats.records, 20);
        assert!(stats.shed > 0, "stats must surface the transport shed count");
        drop(flood);
        drop(srv);
        handle.join();
    }

    #[test]
    fn probe_install_list_query_remove_over_the_wire() {
        let (store, handle) = spawn_store(None, 2, Retention::default()).unwrap();
        let srv = ProvDbTcpServer::start("127.0.0.1:0", store.clone()).unwrap();
        let addr = srv.addr().to_string();
        let mut cl = ProvClient::connect(&addr).unwrap();
        for i in 0..12u64 {
            cl.append(&rec((i % 3) as u32, i, i as f64, i)).unwrap();
        }
        cl.flush().unwrap();
        let probe = Probe::compile("probe hot: fn:*.*:exit / score >= 6.0 /").unwrap();
        cl.install_probe(&probe).unwrap();
        // Visible (with zeroed counters) from another connection.
        let mut cl2 = ProvClient::connect(&addr).unwrap();
        let listed = cl2.list_probes().unwrap();
        assert_eq!(listed.len(), 1);
        assert_eq!(listed[0].name, "hot");
        assert!(listed[0].source.contains("score >= 6.0"));
        assert_eq!((listed[0].matches, listed[0].pushed_records), (0, 0));
        // Probe query ships exactly the matches, counted.
        let got = cl2.probe_query("hot").unwrap();
        assert_eq!(got.len(), 6); // scores 6..=11
        assert!(got.iter().all(|r| r.score >= 6.0));
        let listed = cl.list_probes().unwrap();
        assert_eq!(listed[0].matches, 6);
        assert_eq!(listed[0].shed, 0);
        assert_eq!(listed[0].pushed_records, 6);
        assert!(listed[0].pushed_bytes > 0);
        // Remove: gone for everyone.
        assert!(cl.remove_probe("hot").unwrap());
        assert!(!cl.remove_probe("hot").unwrap());
        assert!(cl2.list_probes().unwrap().is_empty());
        drop(srv);
        handle.join();
    }

    #[test]
    fn hostile_probe_frames_drop_connection_not_server() {
        use crate::probe::bytecode::{Program, MAX_CODE, OP_RET};
        let (store, handle) = spawn_store(None, 1, Retention::default()).unwrap();
        let srv = ProvDbTcpServer::start("127.0.0.1:0", store.clone()).unwrap();
        let addr = srv.addr().to_string();
        // A structurally valid wire probe whose program fails the
        // verifier (RET with empty stack): to_wire doesn't verify, the
        // server must.
        let mut evil = Probe::compile("fn:*.*:exit").unwrap();
        evil.program = Program { consts: vec![], code: vec![OP_RET] };
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut msg = vec![KIND_PROBE_INSTALL];
        evil.to_wire(&mut msg);
        write_msg(&mut s, &msg).unwrap();
        assert!(read_msg(&mut s).unwrap().is_none(), "unverified program must drop");
        // Over-budget code length announced in the frame.
        let mut big = Probe::compile("fn:*.*:exit").unwrap();
        big.program.code = vec![0u8; MAX_CODE + 1];
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut msg = vec![KIND_PROBE_INSTALL];
        big.to_wire(&mut msg);
        write_msg(&mut s, &msg).unwrap();
        assert!(read_msg(&mut s).unwrap().is_none());
        // Truncated install frame.
        let mut s = TcpStream::connect(&addr).unwrap();
        write_msg(&mut s, &[KIND_PROBE_INSTALL, 1, 3, 0]).unwrap();
        assert!(read_msg(&mut s).unwrap().is_none());
        // Query of a probe that does not exist.
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut msg = vec![KIND_PROBE_QUERY];
        put_str(&mut msg, "ghost");
        write_msg(&mut s, &msg).unwrap();
        assert!(read_msg(&mut s).unwrap().is_none());
        // The server is unharmed and has installed nothing.
        let mut cl = ProvClient::connect(&addr).unwrap();
        assert!(cl.list_probes().unwrap().is_empty());
        cl.install_probe(&Probe::compile("fn:*.*:exit").unwrap()).unwrap();
        assert_eq!(cl.list_probes().unwrap().len(), 1);
        drop(srv);
        handle.join();
    }

    #[test]
    fn mid_batch_sever_resends_once_then_counts_loss() {
        let (store, handle) = spawn_store(None, 1, Retention::default()).unwrap();
        let mut srv = ProvDbTcpServer::start("127.0.0.1:0", store.clone()).unwrap();
        let addr = srv.addr().to_string();
        let mut cl = ProvClient::connect_with_batch(&addr, 4).unwrap();
        for i in 0..4u64 {
            cl.append(&rec(0, i, 1.0, i)).unwrap(); // batch 1 ships cleanly
        }
        // Sever mid-run: kill the server, then heal the endpoint (a
        // restarted provdb-server child on the same port).
        srv.stop();
        let (store2, handle2) = spawn_store(None, 1, Retention::default()).unwrap();
        let mut srv2 = ProvDbTcpServer::start(&addr, store2.clone()).unwrap();
        // Batch 2 hits the dead socket, reconnects, and is resent once:
        // no counted loss, and the healed store holds exactly batch 2.
        for i in 4..8u64 {
            cl.append(&rec(0, i, 1.0, i)).unwrap();
        }
        assert_eq!(cl.inflight_lost(), 0, "a successful resend is not loss");
        cl.flush().unwrap();
        assert_eq!(cl.query(&ProvQuery::default()).unwrap().len(), 4);
        // Sever with no healing: the resend fails too, so the batch is
        // counted as lost — exactly once — and the client moves on.
        srv2.stop();
        let mut failed = false;
        for i in 8..12u64 {
            failed |= cl.append(&rec(0, i, 1.0, i)).is_err();
        }
        assert!(failed, "unreachable server must surface the write error");
        assert_eq!(cl.inflight_lost(), 4, "abandoned batch must be counted");
        handle.join();
        handle2.join();
    }

    #[test]
    fn concurrent_writers_converge() {
        let (store, handle) = spawn_store(None, 4, Retention::default()).unwrap();
        let srv = ProvDbTcpServer::start("127.0.0.1:0", store.clone()).unwrap();
        let addr = srv.addr().to_string();
        let mut joins = Vec::new();
        for rank in 0..6u32 {
            let addr = addr.clone();
            joins.push(std::thread::spawn(move || {
                let mut cl = ProvClient::connect_with_batch(&addr, 8).unwrap();
                for i in 0..40u64 {
                    cl.append(&rec(rank, i, 1.0, rank as u64 * 1000 + i)).unwrap();
                }
                cl.flush().unwrap();
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let mut cl = ProvClient::connect(&addr).unwrap();
        assert_eq!(cl.stats().unwrap().records, 240);
        for rank in 0..6u32 {
            let mine = cl
                .query(&ProvQuery { rank: Some((0, rank)), ..Default::default() })
                .unwrap();
            assert_eq!(mine.len(), 40, "rank {rank}");
        }
        drop(srv);
        handle.join();
    }
}
