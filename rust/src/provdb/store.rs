//! The sharded provenance document store behind the provDB service.
//!
//! [`spawn_store`] starts `n` shard worker threads; [`ProvStore`] is the
//! cloneable front-end that routes every record to the shard owning its
//! `(app, rank)` partition ([`prov_shard_of`]) and fans queries out. Each
//! shard owns:
//!
//! * the in-memory, queryable partitions — one per `(app, rank)`, holding
//!   records in their *encoded* binary form
//!   ([`provenance::codec`](crate::provenance::codec)): the fixed header
//!   answers every [`ProvQuery`] filter, so scans touch 49 bytes per
//!   record and decode payloads only for matches (predicate pushdown) —
//!   and bounded by the [`Retention`] policy (score-based eviction keeps
//!   the highest-score records, implementing the paper's "reduction for
//!   human-level processing" instead of growing unboundedly);
//! * the append log — per partition, by default a rolling set of binary
//!   segments: an append file of encoded rows (+CRC-32 each, ~2.5×
//!   smaller than JSONL) that seals into an immutable columnar v2
//!   segment `prov_app<A>_rank<R>_seg<K>.provseg` every
//!   [`Retention::segment_records`] records. Sealed (*warm*) segments
//!   pack delta+varint columns behind a zone-map footer, so queries can
//!   prove "nothing here matches" and skip whole segments unread;
//!   [`RecordFormat::Jsonl`] is the escape hatch that keeps the classic
//!   `*.jsonl` layout. Recovery reads *every* layout generation (JSONL,
//!   legacy single-file v1, rolling v1/v2), so old stores restarted
//!   under the binary format migrate in place, and sealed segments are
//!   re-adopted from their footers alone. A flush rewrites any partition
//!   that evicted records so the on-disk log matches the retained view,
//!   and expires records older than [`Retention::retain_window_us`].
//!
//! ## Ordering and equivalence
//!
//! The front-end stamps every ingested record with a global sequence
//! number. Query results are merged centrally and sorted by the query's
//! ordering with the sequence as tie-breaker — exactly the stable-sort
//! tie order of the local [`ProvDb`](crate::provenance::ProvDb) index
//! when records arrive in the same order, which is what the equivalence
//! property in `tests/provdb_service.rs` pins down for 1/2/4 shards.
//!
//! ## Consistency and failure policy
//!
//! Shard channels are FIFO per sender: a [`ProvStore`] clone (or a TCP
//! connection, which owns one clone) always reads its own writes.
//! Cross-client visibility needs a [`ProvStore::flush`] barrier, which
//! drains every shard queue before returning. Log I/O failures (full
//! disk, yanked directory) never take a shard thread down: the write is
//! dropped from the *log* (the record stays queryable in memory), a
//! warning is logged, and [`ProvDbStats::log_errors`] counts it.

use crate::probe::InstalledProbe;
use crate::provenance::codec::{self, RecordFormat};
use crate::provenance::{ProvQuery, ProvRecord};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;

/// Stable shard routing: which of `n_shards` owns `(app, rank)`.
///
/// The epoch-0 default of the shared [`Placement`](crate::placement)
/// abstraction — the same slot hashing as the PS's
/// [`ps::shard_of`](crate::ps::shard_of), but keyed by rank: provenance
/// is partitioned by *who produced it*, statistics by *which function*.
/// The provDB stays at epoch 0 for now (no live rebalancing); its
/// [`ProvStore`] routes through a `Placement` so the two subsystems
/// share one placement type.
pub fn prov_shard_of(app: u32, rank: u32, n_shards: usize) -> usize {
    crate::placement::Placement::default_shard_of(app, rank, n_shards)
}

/// Retention policy applied per `(app, rank)` partition, across the
/// storage tiers: hot resident rows → warm sealed segments on disk →
/// expired by the time window.
#[derive(Clone, Copy, Debug)]
pub struct Retention {
    /// Retained records per `(app, rank)` — hot *plus* warm;
    /// `usize::MAX` = unbounded. Over capacity, the lowest-score records
    /// are evicted first (oldest on score ties), so anomalies outlive
    /// their normal context records. Eviction sweeps run when the hot
    /// tier overshoots the bound by a slack (¼ of the bound, at least 64
    /// — amortized O(log n) per insert) and globally (warm segments
    /// demoted back to hot to take part) at every flush, so the bound is
    /// precise at flush barriers.
    pub max_records_per_rank: usize,
    /// Hot records per partition at which the shard seals them into a
    /// warm columnar v2 segment (`prov_app<A>_rank<R>_seg<K>.provseg`,
    /// binary log format + data dir only); `usize::MAX` = never seal
    /// (one ever-growing row file, the pre-v2 layout).
    pub segment_records: usize,
    /// Expiry window in µs over each partition's own clock (its max
    /// `entry_us` seen): at every flush, records older than
    /// `max_entry - window` are dropped — whole warm segments by zone
    /// map, without decoding, when their `max_entry` clears the cutoff.
    /// 0 = no time-based expiry.
    pub retain_window_us: u64,
}

impl Default for Retention {
    fn default() -> Self {
        Retention {
            max_records_per_rank: usize::MAX,
            segment_records: 8192,
            retain_window_us: 0,
        }
    }
}

impl Retention {
    /// Knob form used by config/CLI: 0 means unbounded.
    pub fn from_knob(max_records_per_rank: usize) -> Retention {
        Retention {
            max_records_per_rank: if max_records_per_rank == 0 {
                usize::MAX
            } else {
                max_records_per_rank
            },
            ..Default::default()
        }
    }

    /// Knob form of [`Self::segment_records`]: 0 means never seal.
    pub fn with_segment_knob(mut self, segment_records: usize) -> Retention {
        self.segment_records =
            if segment_records == 0 { usize::MAX } else { segment_records };
        self
    }

    /// Knob form of [`Self::retain_window_us`]: 0 means no expiry.
    pub fn with_window_knob(mut self, retain_window_us: u64) -> Retention {
        self.retain_window_us = retain_window_us;
        self
    }
}

/// Aggregate store counters (summed over shards).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ProvDbStats {
    /// Retained records across all partitions.
    pub records: u64,
    /// On-disk-format bytes of the retained records (the provDB-resident
    /// size; binary segment bytes by default, JSONL under the escape
    /// hatch).
    pub resident_bytes: u64,
    /// Total log bytes ever appended (plus metadata) — the Fig 9
    /// "reduced output" axis.
    pub log_bytes: u64,
    /// Retained anomaly records.
    pub anomalies: u64,
    /// Records evicted by retention so far.
    pub evicted: u64,
    /// Log I/O failures degraded to drops (full disk etc.) — each lost a
    /// record or a compaction from the *log*; the in-memory view is
    /// unaffected.
    pub log_errors: u64,
    /// Requests the TCP front-end shed with `Busy` under overload.
    /// Stamped by [`provdb::net`](crate::provdb::net) when the stats
    /// travel over the wire; always 0 for an in-process store (no
    /// transport, nothing to shed).
    pub shed: u64,
    /// Unflushed reply bytes queued on the TCP front-end when the stats
    /// were taken (0 for an in-process store).
    pub net_queue_depth: u64,
    /// Warm sealed v2 segments currently registered across partitions.
    pub segments_total: u64,
    /// Sealed segments whose zone map pruned them from a query scan
    /// without touching a record (cumulative).
    pub segments_skipped: u64,
    /// Bytes of resident zone-map index (one packed footer per warm
    /// segment) — the whole cost of segment skipping.
    pub zone_map_bytes: u64,
}

impl ProvDbStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("records", Json::num(self.records as f64)),
            ("resident_bytes", Json::num(self.resident_bytes as f64)),
            ("log_bytes", Json::num(self.log_bytes as f64)),
            ("anomalies", Json::num(self.anomalies as f64)),
            ("evicted", Json::num(self.evicted as f64)),
            ("log_errors", Json::num(self.log_errors as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("net_queue_depth", Json::num(self.net_queue_depth as f64)),
            ("segments_total", Json::num(self.segments_total as f64)),
            ("segments_skipped", Json::num(self.segments_skipped as f64)),
            ("zone_map_bytes", Json::num(self.zone_map_bytes as f64)),
        ])
    }
}

/// Message to one shard worker. Records travel pre-encoded
/// (`codec`-validated) so the ingest path never rebuilds them.
enum ShardReq {
    /// Sequence-stamped encoded records, all owned by this shard, each
    /// with its on-disk byte size when already known (recovery replay
    /// carries the *scanned* size — a JSONL-resident record must not be
    /// charged binary bytes — while live ingest passes `None` and the
    /// shard prices it by its own log format). `log: false` for recovery
    /// replay (already in the append log).
    Ingest { batch: Vec<(u64, Option<u64>, Vec<u8>)>, log: bool },
    /// Run the query over this shard's partitions; reply with encoded
    /// matches (unsorted — the front-end merges and orders).
    Query { q: ProvQuery, reply: Sender<Vec<(u64, Vec<u8>)>> },
    /// Evaluate an installed probe (predicate + sampling gate, counters
    /// bumped) over this shard's partitions; reply with the admitted
    /// encoded records (unsorted — the front-end merges and orders).
    ProbeScan { probe: Arc<InstalledProbe>, reply: Sender<Vec<(u64, Vec<u8>)>> },
    /// Flush writers; compact logs of partitions that evicted records.
    Flush { reply: Sender<()> },
    Stats { reply: Sender<ProvDbStats> },
    /// Recovery: adopt a sealed v2 segment as a warm tier member —
    /// counters absorb its footer, records stay on disk until queried.
    RegisterSegment { key: (u32, u32), meta: SegmentMeta },
    /// Recovery: set where the partition's rolling segment counter
    /// resumes (the next seal target / append file index).
    SetActive { key: (u32, u32), active_k: u32 },
    Shutdown,
}

/// Cloneable front-end to a spawned shard constellation.
#[derive(Clone)]
pub struct ProvStore {
    shards: Vec<Sender<ShardReq>>,
    /// `(app, rank)` → shard routing table (epoch 0: the provDB has no
    /// live rebalancing yet, but shares the PS's placement abstraction).
    placement: crate::placement::Placement,
    seq: Arc<AtomicU64>,
    meta: Arc<RwLock<Option<Json>>>,
    meta_bytes: Arc<AtomicU64>,
    dir: Option<PathBuf>,
}

impl ProvStore {
    /// Number of shard workers.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Ingest a batch: encode, stamp sequence numbers, group by owning
    /// shard, send one message per touched shard. Returns the number
    /// accepted.
    pub fn ingest(&self, records: Vec<ProvRecord>) -> usize {
        let mut encoded = Vec::with_capacity(records.len());
        for r in &records {
            let mut buf = Vec::with_capacity(192);
            codec::encode(r, &mut buf);
            encoded.push((buf, None));
        }
        self.route(encoded, true)
    }

    /// Ingest pre-encoded records — the binary wire path hands frames
    /// straight through. Callers must have run [`codec::validate`] on
    /// each buffer (the TCP server does, at its trust boundary).
    pub fn ingest_encoded(&self, records: Vec<Vec<u8>>) -> usize {
        self.route(records.into_iter().map(|b| (b, None)).collect(), true)
    }

    fn route(&self, records: Vec<(Vec<u8>, Option<u64>)>, log: bool) -> usize {
        if records.is_empty() {
            return 0;
        }
        let mut n = 0usize;
        let mut parts: Vec<Vec<(u64, Option<u64>, Vec<u8>)>> =
            vec![Vec::new(); self.shards.len()];
        for (buf, disk_bytes) in records {
            // Routing needs only the fixed header; skip (defensively)
            // anything that cannot even carry one.
            let Ok(h) = codec::read_header(&buf) else { continue };
            let seq = self.seq.fetch_add(1, Ordering::Relaxed);
            let shard = self.placement.shard_of(h.app, h.rank);
            parts[shard].push((seq, disk_bytes, buf));
            n += 1;
        }
        for (i, part) in parts.into_iter().enumerate() {
            if !part.is_empty() {
                let _ = self.shards[i].send(ShardReq::Ingest { batch: part, log });
            }
        }
        n
    }

    /// Run a query, decoding the matches — the local-caller surface.
    pub fn query(&self, q: &ProvQuery) -> Vec<ProvRecord> {
        self.query_encoded(q)
            .iter()
            .map(|b| codec::decode(b).expect("stored provenance record decodes").0)
            .collect()
    }

    /// Run a query returning *encoded* matches, merged, ordered
    /// (sequence-stable) and truncated — the TCP reply path copies these
    /// bytes straight onto the wire without re-encoding. Single-shard
    /// when filtered by `(app, rank)`, fan-out otherwise.
    pub fn query_encoded(&self, q: &ProvQuery) -> Vec<Vec<u8>> {
        let targets: Vec<usize> = match q.rank {
            Some((app, rank)) => vec![self.placement.shard_of(app, rank)],
            None => (0..self.shards.len()).collect(),
        };
        let (tx, rx) = channel();
        let mut expected = 0usize;
        for &i in &targets {
            if self.shards[i]
                .send(ShardReq::Query { q: q.clone(), reply: tx.clone() })
                .is_ok()
            {
                expected += 1;
            }
        }
        drop(tx);
        let mut out: Vec<(u64, Vec<u8>)> = Vec::new();
        for _ in 0..expected {
            match rx.recv() {
                Ok(mut part) => out.append(&mut part),
                Err(_) => break,
            }
        }
        sort_results(q, &mut out);
        if let Some(n) = q.limit {
            out.truncate(n);
        }
        out.into_iter().map(|(_, b)| b).collect()
    }

    /// Evaluate an installed probe over every shard — the server side of
    /// a probe subscription. Each shard runs the compiled predicate (and
    /// the probe's sampling gate, bumping its counters) against its
    /// encoded records; the front-end merges and orders exactly like an
    /// unfiltered [`Self::query_encoded`], so a probe equivalent to a
    /// `ProvQuery` filter returns bit-identical bytes.
    pub fn probe_scan(&self, probe: &Arc<InstalledProbe>) -> Vec<Vec<u8>> {
        let (tx, rx) = channel();
        let mut expected = 0usize;
        for s in &self.shards {
            if s.send(ShardReq::ProbeScan { probe: Arc::clone(probe), reply: tx.clone() })
                .is_ok()
            {
                expected += 1;
            }
        }
        drop(tx);
        let mut out: Vec<(u64, Vec<u8>)> = Vec::new();
        for _ in 0..expected {
            match rx.recv() {
                Ok(mut part) => out.append(&mut part),
                Err(_) => break,
            }
        }
        sort_results(&ProvQuery::default(), &mut out);
        out.into_iter().map(|(_, b)| b).collect()
    }

    /// All records of `(app, rank)` for `step`, entry-ordered — the
    /// call-stack reconstruction query (Fig 6).
    pub fn call_stack(&self, app: u32, rank: u32, step: u64) -> Vec<ProvRecord> {
        self.query(&Self::call_stack_query(app, rank, step))
    }

    /// The call-stack view's query shape (shared with the TCP server's
    /// binary reply path).
    pub fn call_stack_query(app: u32, rank: u32, step: u64) -> ProvQuery {
        ProvQuery {
            rank: Some((app, rank)),
            step: Some(step),
            ..ProvQuery::default()
        }
    }

    /// Store run metadata (served back via [`Self::metadata`]; persisted
    /// to `metadata.json` when the store has a data directory — JSON is
    /// the edge format for metadata).
    pub fn set_metadata(&self, meta: Json) -> Result<()> {
        let text = meta.to_pretty();
        self.meta_bytes.store(text.len() as u64, Ordering::Relaxed);
        if let Some(dir) = &self.dir {
            std::fs::write(dir.join("metadata.json"), &text)
                .context("writing provdb metadata")?;
        }
        *self.meta.write().expect("provdb metadata lock") = Some(meta);
        Ok(())
    }

    /// Run metadata, if any was stored.
    pub fn metadata(&self) -> Option<Json> {
        self.meta.read().expect("provdb metadata lock").clone()
    }

    /// Barrier: drain every shard queue, flush writers, compact logs of
    /// partitions that evicted records since the last flush.
    pub fn flush(&self) {
        let (tx, rx) = channel();
        let mut expected = 0usize;
        for s in &self.shards {
            if s.send(ShardReq::Flush { reply: tx.clone() }).is_ok() {
                expected += 1;
            }
        }
        drop(tx);
        for _ in 0..expected {
            if rx.recv().is_err() {
                break;
            }
        }
    }

    /// Aggregate counters over all shards (consistent after a flush).
    pub fn stats(&self) -> ProvDbStats {
        let (tx, rx) = channel();
        let mut expected = 0usize;
        for s in &self.shards {
            if s.send(ShardReq::Stats { reply: tx.clone() }).is_ok() {
                expected += 1;
            }
        }
        drop(tx);
        let mut out = ProvDbStats::default();
        for _ in 0..expected {
            match rx.recv() {
                Ok(s) => {
                    out.records += s.records;
                    out.resident_bytes += s.resident_bytes;
                    out.log_bytes += s.log_bytes;
                    out.anomalies += s.anomalies;
                    out.evicted += s.evicted;
                    out.log_errors += s.log_errors;
                    out.segments_total += s.segments_total;
                    out.segments_skipped += s.segments_skipped;
                    out.zone_map_bytes += s.zone_map_bytes;
                }
                Err(_) => break,
            }
        }
        out.log_bytes += self.meta_bytes.load(Ordering::Relaxed);
        out
    }
}

/// Order merged shard results exactly like the local index: the query's
/// primary key, sequence (= arrival order) on ties. Sort keys are read
/// at fixed offsets from the encoded headers — no decode per comparison.
fn sort_results(q: &ProvQuery, out: &mut [(u64, Vec<u8>)]) {
    if q.order_by_score {
        out.sort_by(|a, b| {
            codec::score_of(&b.1)
                .partial_cmp(&codec::score_of(&a.1))
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.0.cmp(&b.0))
        });
    } else {
        out.sort_by(|a, b| {
            codec::entry_us_of(&a.1)
                .cmp(&codec::entry_us_of(&b.1))
                .then(a.0.cmp(&b.0))
        });
    }
}

/// Joinable handle to the shard constellation.
pub struct ProvStoreHandle {
    shards: Vec<Sender<ShardReq>>,
    joins: Vec<JoinHandle<()>>,
}

impl ProvStoreHandle {
    /// Stop every shard (each flushes its log first) and join.
    /// Panics if a shard worker panicked.
    pub fn join(self) {
        for tx in &self.shards {
            let _ = tx.send(ShardReq::Shutdown);
        }
        for j in self.joins {
            j.join().expect("provdb shard panicked");
        }
    }
}

/// Spawn a sharded provenance store with the default binary segment log.
///
/// * `dir` — data directory for the append log + metadata (`None` =
///   memory only);
/// * `n_shards` — shard worker threads (1 = single-consumer layout);
/// * `retention` — per-partition bound (see [`Retention`]).
pub fn spawn_store(
    dir: Option<&Path>,
    n_shards: usize,
    retention: Retention,
) -> Result<(ProvStore, ProvStoreHandle)> {
    spawn_store_fmt(dir, n_shards, retention, RecordFormat::Binary)
}

/// [`spawn_store`] with an explicit log format ([`RecordFormat::Jsonl`]
/// is the `--log-format jsonl` escape hatch).
pub fn spawn_store_fmt(
    dir: Option<&Path>,
    n_shards: usize,
    retention: Retention,
    format: RecordFormat,
) -> Result<(ProvStore, ProvStoreHandle)> {
    if let Some(d) = dir {
        std::fs::create_dir_all(d)
            .with_context(|| format!("creating provdb dir {}", d.display()))?;
    }
    let n = n_shards.max(1);
    anyhow::ensure!(
        n <= crate::placement::SLOTS,
        "at most {} provdb shards supported ({n} requested): placement routes \
         through that many fixed slots",
        crate::placement::SLOTS
    );
    let mut shard_txs: Vec<Sender<ShardReq>> = Vec::with_capacity(n);
    let mut joins = Vec::with_capacity(n);
    for i in 0..n {
        let (tx, rx): (Sender<ShardReq>, Receiver<ShardReq>) = channel();
        let shard_dir = dir.map(|d| d.to_path_buf());
        let join = std::thread::Builder::new()
            .name(format!("chimbuko-provdb-{i}"))
            .spawn(move || run_shard(shard_dir, retention, format, rx))
            .context("spawning provdb shard")?;
        shard_txs.push(tx);
        joins.push(join);
    }
    let store = ProvStore {
        shards: shard_txs.clone(),
        placement: crate::placement::Placement::new(n),
        seq: Arc::new(AtomicU64::new(0)),
        meta: Arc::new(RwLock::new(None)),
        meta_bytes: Arc::new(AtomicU64::new(0)),
        dir: dir.map(|d| d.to_path_buf()),
    };
    // Recover an existing data directory: restarting a provdb-server on
    // its dir must see (and never clobber) the previous run's records.
    if let Some(d) = dir {
        recover_logs(d, &store)
            .with_context(|| format!("recovering provdb logs in {}", d.display()))?;
    }
    Ok((store, ProvStoreHandle { shards: shard_txs, joins }))
}

/// Replay an existing data directory into the shards (without
/// re-appending to the log) and reload stored run metadata. Files are
/// visited in the shared [`list_partition_files`](crate::provenance)
/// order also used by [`ProvDb::load`](crate::provenance::ProvDb::load)
/// — partitions numerically, `.jsonl` → legacy `.provseg` → `_seg<K>`
/// within one — so the service and the offline loader read directories
/// identically and sequence re-assignment is deterministic.
///
/// Tiering on restart: a `_seg<K>` file with a valid v2 footer is
/// *adopted* as a warm segment — only its 105-byte footer is read (zone
/// map + counts); the records stay on disk until a query needs them.
/// Everything else (JSONL, legacy/active row files, damaged v2 segments
/// repaired by the scan) streams through the chunked reader into the hot
/// tier. Warm segments reserve a contiguous sequence block in file
/// order, so the merged (hot ∪ warm) arrival order equals the replay
/// order of a store that held everything resident.
fn recover_logs(dir: &Path, store: &ProvStore) -> Result<()> {
    if let Ok(text) = std::fs::read_to_string(dir.join("metadata.json")) {
        let meta = crate::util::json::parse(&text).context("parsing provdb metadata.json")?;
        store.meta_bytes.store(text.len() as u64, Ordering::Relaxed);
        *store.meta.write().expect("provdb metadata lock") = Some(meta);
    }
    let files = crate::provenance::list_partition_files(dir)?;
    // Footer pre-pass: which rolling segments are sealed, and where each
    // partition's segment counter resumes. Runs (and the SetActive sends
    // below) before any replay so a seal triggered later can never
    // target an index that is still on disk.
    let mut footers: HashMap<PathBuf, codec::Seg2Footer> = HashMap::new();
    let mut active: HashMap<(u32, u32), u32> = HashMap::new();
    for f in &files {
        if let (Some(key), Some(k), false) = (f.key, f.seg, f.jsonl) {
            match codec::read_seg2_footer_file(&f.path)? {
                Some(footer) => {
                    footers.insert(f.path.clone(), footer);
                    active.insert(key, k + 1);
                }
                // Unsealed/damaged highest segment stays the append
                // target once the scan below has repaired it.
                None => {
                    active.insert(key, k);
                }
            }
        }
    }
    for (&key, &active_k) in &active {
        let shard = store.placement.shard_of(key.0, key.1);
        let _ = store.shards[shard].send(ShardReq::SetActive { key, active_k });
    }
    // Stream in bounded chunks: a large data directory never has to fit
    // in the front-end's memory (sequence stamping is per-record inside
    // route(), so chunking preserves replay order exactly).
    const CHUNK: usize = 4096;
    let mut chunk: Vec<(Vec<u8>, Option<u64>)> = Vec::with_capacity(CHUNK);
    for f in &files {
        if let (Some(key), Some(footer)) = (f.key, footers.get(&f.path)) {
            // Keep sequence assignment aligned with file order: drain
            // pending hot records before this segment reserves its block.
            if !chunk.is_empty() {
                store.route(std::mem::take(&mut chunk), false);
            }
            let n = footer.n_records as u64;
            let seq0 = store.seq.fetch_add(n, Ordering::Relaxed);
            let disk_bytes = std::fs::metadata(&f.path)
                .with_context(|| format!("sizing {}", f.path.display()))?
                .len();
            let meta = SegmentMeta {
                path: f.path.clone(),
                footer: *footer,
                disk_bytes,
                seq0,
                stored_seqs: false,
            };
            let shard = store.placement.shard_of(key.0, key.1);
            let _ = store.shards[shard].send(ShardReq::RegisterSegment { key, meta });
            continue;
        }
        let sink: &mut dyn FnMut(Vec<u8>, u64) -> Result<()> = &mut |buf, disk_bytes| {
            chunk.push((buf, Some(disk_bytes)));
            if chunk.len() >= CHUNK {
                store.route(std::mem::take(&mut chunk), false);
            }
            Ok(())
        };
        if f.jsonl {
            crate::provenance::scan_jsonl_file(&f.path, true, sink)?;
        } else {
            crate::provenance::scan_segment_file(&f.path, true, sink)?;
        }
    }
    store.route(chunk, false);
    Ok(())
}

/// One retained record: its global sequence stamp, encoded bytes, and
/// the on-disk size charged to the byte accounting (format-dependent).
struct Entry {
    seq: u64,
    disk_bytes: u64,
    buf: Vec<u8>,
}

/// One warm tier member: a sealed columnar v2 segment on disk. Only its
/// footer lives in memory; queries consult the zone map first and decode
/// the file only when the zones admit a possible match.
struct SegmentMeta {
    path: PathBuf,
    footer: codec::Seg2Footer,
    /// Whole-file size (what the resident accounting charges).
    disk_bytes: u64,
    /// Sequence of the segment's first record when the stored column is
    /// superseded (see [`Self::stored_seqs`]).
    seq0: u64,
    /// Live-sealed segments carry the true (gapped) sequence stamps in
    /// their seq column; recovery-adopted ones are re-stamped as the
    /// contiguous block `seq0 + index` reserved in replay order.
    stored_seqs: bool,
}

/// One `(app, rank)` partition of a shard.
#[derive(Default)]
struct Partition {
    /// Hot tier: arrival-ordered retained records (encoded rows).
    entries: Vec<Entry>,
    /// Evicted/log-dropped since the last log compaction.
    dirty: bool,
    /// Warm tier: sealed segments, oldest first.
    warm: Vec<SegmentMeta>,
    /// Rolling segment counter: the next seal writes `_seg<active_k>`
    /// (which is also the append file once the partition has rolled).
    active_k: u32,
    /// Largest `entry_us` ever ingested — the partition-local clock the
    /// expiry window measures against.
    max_entry: u64,
}

/// Shard worker state: the `prov_shard_of == i` partitions plus their
/// slice of the append log.
struct ShardState {
    dir: Option<PathBuf>,
    format: RecordFormat,
    retention: Retention,
    parts: HashMap<(u32, u32), Partition>,
    writers: HashMap<(u32, u32), BufWriter<File>>,
    log_bytes: u64,
    resident_bytes: u64,
    anomalies: u64,
    evicted: u64,
    log_errors: u64,
    /// Sealed segments pruned by zone map across all queries so far.
    segments_skipped: u64,
}

/// Path of a partition's rolling segment `K`.
fn seg_path(dir: &Path, key: (u32, u32), k: u32) -> PathBuf {
    dir.join(format!("prov_app{}_rank{}_seg{k:04}.provseg", key.0, key.1))
}

/// Path of a partition's current append file: the legacy single-file
/// name until the partition seals its first segment, `_seg<K>` after.
fn log_path(dir: &Path, key: (u32, u32), format: RecordFormat, active_k: u32) -> PathBuf {
    match format {
        RecordFormat::Jsonl => dir.join(format!("prov_app{}_rank{}.jsonl", key.0, key.1)),
        RecordFormat::Binary if active_k == 0 => {
            dir.join(format!("prov_app{}_rank{}.provseg", key.0, key.1))
        }
        RecordFormat::Binary => seg_path(dir, key, active_k),
    }
}

/// Decode a warm sealed segment into `(seq, decoded record, canonical
/// row bytes)` triples — the one reader behind warm queries, probe
/// scans, and demotion back to hot. Canonical re-encoding makes warm
/// query results bit-identical to the hot path. Errors on I/O failure,
/// an unreadable image, or a file that lost records since it was sealed.
fn scan_warm(meta: &SegmentMeta) -> Result<Vec<(u64, ProvRecord, Vec<u8>)>> {
    let bytes =
        std::fs::read(&meta.path).with_context(|| format!("opening {}", meta.path.display()))?;
    let scan = codec::read_segment_v2(&bytes)
        .with_context(|| format!("reading segment {}", meta.path.display()))?;
    anyhow::ensure!(
        scan.complete && scan.records.len() == meta.footer.n_records as usize,
        "sealed segment {} no longer decodes completely ({} of {} records{})",
        meta.path.display(),
        scan.records.len(),
        meta.footer.n_records,
        scan.corrupt.as_deref().map(|c| format!(": {c}")).unwrap_or_default()
    );
    let mut out = Vec::with_capacity(scan.records.len());
    for (i, (stored_seq, rec)) in scan.records.into_iter().enumerate() {
        let seq = if meta.stored_seqs { stored_seq } else { meta.seq0 + i as u64 };
        let mut buf = Vec::with_capacity(192);
        codec::encode(&rec, &mut buf);
        out.push((seq, rec, buf));
    }
    Ok(out)
}

/// Open (or create) a partition's append log; a fresh binary segment
/// gets its file header.
fn open_log(path: &Path, format: RecordFormat) -> std::io::Result<BufWriter<File>> {
    let f = File::options().create(true).append(true).open(path)?;
    let fresh = f.metadata()?.len() == 0;
    let mut w = BufWriter::new(f);
    if fresh && format == RecordFormat::Binary {
        w.write_all(&codec::seg_file_header())?;
    }
    Ok(w)
}

/// Batch-eviction trigger: let a partition overshoot its bound by this
/// slack before paying one O(n log n) eviction sweep, so retention costs
/// amortized O(log n) per insert instead of an O(n) victim scan each.
/// Flush always evicts down to the exact bound.
fn retention_trigger(max: usize) -> usize {
    max.saturating_add((max / 4).max(64))
}

/// Remove every log file of `key` except the paths in `keep` — the
/// cleanup step after sealing or compacting, when one file (plus the
/// warm set) owns all of the partition's records and anything else
/// would duplicate them on reload. `NotFound` is success (already
/// gone); returns whether everything superseded is really gone.
fn remove_superseded(dir: &Path, key: (u32, u32), keep: &[PathBuf]) -> bool {
    let files = match crate::provenance::list_partition_files(dir) {
        Ok(files) => files,
        Err(e) => {
            crate::log_warn!(
                "provdb",
                "listing {} for cleanup: {e} — superseded logs may remain",
                dir.display()
            );
            return false;
        }
    };
    let mut all_removed = true;
    for f in files {
        if f.key != Some(key) || keep.contains(&f.path) {
            continue;
        }
        match std::fs::remove_file(&f.path) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => {
                all_removed = false;
                crate::log_warn!(
                    "provdb",
                    "removing superseded {}: {e} — records would duplicate \
                     on reload; retrying at the next flush",
                    f.path.display()
                );
            }
        }
    }
    all_removed
}

/// Evict down to `max` records: lowest score first, oldest on score ties
/// — high-score anomalies outlive their context. Scores come from the
/// fixed header offsets; no decode. Returns
/// `(evicted, freed_bytes, freed_anomalies)`.
fn evict_partition(part: &mut Partition, max: usize) -> (u64, u64, u64) {
    if part.entries.len() <= max {
        return (0, 0, 0);
    }
    let k = part.entries.len() - max;
    let mut order: Vec<usize> = (0..part.entries.len()).collect();
    order.sort_by(|&a, &b| {
        codec::score_of(&part.entries[a].buf)
            .partial_cmp(&codec::score_of(&part.entries[b].buf))
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(part.entries[a].seq.cmp(&part.entries[b].seq))
    });
    let drop: std::collections::HashSet<u64> =
        order[..k].iter().map(|&i| part.entries[i].seq).collect();
    let mut freed_bytes = 0u64;
    let mut freed_anoms = 0u64;
    part.entries.retain(|e| {
        if drop.contains(&e.seq) {
            freed_bytes += e.disk_bytes;
            if codec::label_tag_of(&e.buf) != codec::LABEL_NORMAL {
                freed_anoms += 1;
            }
            false
        } else {
            true
        }
    });
    part.dirty = true;
    (k as u64, freed_bytes, freed_anoms)
}

impl ShardState {
    fn ingest(&mut self, batch: Vec<(u64, Option<u64>, Vec<u8>)>, log: bool) {
        let max_per_rank = self.retention.max_records_per_rank;
        let trigger = retention_trigger(max_per_rank);
        let mut line = String::new(); // reused across the batch (JSONL mode)
        for (seq, known_disk_bytes, buf) in batch {
            // Pre-priced records come only from recovery replay, which
            // never re-appends (the JSONL-format line below would be
            // stale otherwise).
            debug_assert!(known_disk_bytes.is_none() || !log);
            let Ok(h) = codec::read_header(&buf) else { continue };
            let key = (h.app, h.rank);
            // Recovery replay carries the record's actual on-disk size
            // (it may sit in the *other* format's file — migration);
            // live ingest prices by this shard's log format.
            let disk_bytes = match (known_disk_bytes, self.format) {
                (Some(d), _) => d,
                (None, RecordFormat::Binary) => buf.len() as u64 + 4, // + CRC trailer
                (None, RecordFormat::Jsonl) => {
                    let Ok((rec, _)) = codec::decode(&buf) else { continue };
                    line.clear();
                    rec.write_jsonl(&mut line);
                    line.len() as u64 + 1 // + newline
                }
            };
            let log_ok = if log { self.append_log(key, &buf, &line) } else { true };
            self.log_bytes += disk_bytes;
            self.resident_bytes += disk_bytes;
            if h.is_anomaly() {
                self.anomalies += 1;
            }
            let part = self.parts.entry(key).or_default();
            part.max_entry = part.max_entry.max(h.entry_us);
            part.entries.push(Entry { seq, disk_bytes, buf });
            if !log_ok {
                // The on-disk log is now missing this record and may end
                // in partial bytes; marking the partition dirty makes
                // the next flush-compaction rewrite the file atomically
                // from the retained entries — the drop heals itself once
                // the disk recovers.
                part.dirty = true;
            }
            if part.entries.len() > trigger {
                let (ev, fb, fa) = evict_partition(part, max_per_rank);
                self.evicted += ev;
                self.resident_bytes -= fb;
                self.anomalies -= fa;
            }
            let hot = part.entries.len();
            // Seal only on live ingest: recovery replay must never write
            // a segment index that a later file in the replay still owns
            // (`log` is false exactly there).
            if log && hot >= self.retention.segment_records {
                self.seal_partition(key);
            }
        }
    }

    /// Seal a partition's hot tier into a warm columnar v2 segment:
    /// pack + zone-map the rows, write `_seg<active_k>` (tmp → rename),
    /// adopt it as warm, clear the hot tier, and remove every superseded
    /// non-warm file (the legacy logs / old append file whose records the
    /// new segment now owns). Binary-format, dir-backed stores only. A
    /// failed seal leaves the partition exactly as it was (retried at the
    /// next trigger).
    fn seal_partition(&mut self, key: (u32, u32)) {
        let Some(dir) = self.dir.clone() else { return };
        if self.format != RecordFormat::Binary {
            return;
        }
        let Some(part) = self.parts.get_mut(&key) else { return };
        if part.entries.is_empty() {
            return;
        }
        let rows: Vec<(u64, &[u8])> =
            part.entries.iter().map(|e| (e.seq, e.buf.as_slice())).collect();
        let (bytes, footer) = match codec::seal_segment_v2(&rows) {
            Ok(sealed) => sealed,
            Err(e) => {
                self.log_errors += 1;
                crate::log_warn!(
                    "provdb",
                    "sealing app{} rank{}: {e:#} — partition stays hot",
                    key.0,
                    key.1
                );
                return;
            }
        };
        let path = seg_path(&dir, key, part.active_k);
        let tmp = path.with_extension("tmp");
        let res =
            std::fs::write(&tmp, &bytes).and_then(|()| std::fs::rename(&tmp, &path));
        if let Err(e) = res {
            self.log_errors += 1;
            std::fs::remove_file(&tmp).ok();
            crate::log_warn!(
                "provdb",
                "sealing {}: {e} — partition stays hot",
                path.display()
            );
            return;
        }
        // Chaos hook: tear the tail off the freshly sealed file, as a
        // crash between the data write and the footer landing would. The
        // in-memory adoption below proceeds normally (the footer is
        // already in hand) — the damage only surfaces at the *next*
        // recovery, which must fall back to the streaming scan and
        // sideline/truncate the segment (rust/docs/chaos.md).
        let tear = crate::util::fault::torn_tail();
        if tear > 0 {
            let keep = (bytes.len() as u64).saturating_sub(tear).max(1);
            let res = std::fs::File::options()
                .write(true)
                .open(&path)
                .and_then(|f| f.set_len(keep));
            if let Err(e) = res {
                crate::log_warn!("provdb", "chaos tear {}: {e}", path.display());
            } else {
                crate::log_debug!(
                    "provdb",
                    "chaos: tore {tear} tail bytes off {}",
                    path.display()
                );
            }
        }
        self.writers.remove(&key);
        let freed: u64 = part.entries.iter().map(|e| e.disk_bytes).sum();
        self.resident_bytes = self.resident_bytes - freed + bytes.len() as u64;
        let seq0 = part.entries.first().map_or(0, |e| e.seq);
        part.entries.clear();
        part.dirty = false;
        part.warm.push(SegmentMeta {
            path,
            footer,
            disk_bytes: bytes.len() as u64,
            seq0,
            stored_seqs: true,
        });
        part.active_k += 1;
        let keep: Vec<PathBuf> = part.warm.iter().map(|m| m.path.clone()).collect();
        if !remove_superseded(&dir, key, &keep) {
            self.log_errors += 1;
            // Leftover files would duplicate records on reload; dirty
            // compaction retries the removal at the next flush.
            if let Some(part) = self.parts.get_mut(&key) {
                part.dirty = true;
            }
        }
    }

    /// Seal every partition whose hot tier reached the bound — recovery
    /// replay defers sealing to here (the first flush), and a partition
    /// that hovers just under the trigger between ingest batches still
    /// rolls at barriers.
    fn seal_ready(&mut self) {
        if self.dir.is_none() || self.format != RecordFormat::Binary {
            return;
        }
        let ready: Vec<(u32, u32)> = self
            .parts
            .iter()
            .filter(|(_, p)| p.entries.len() >= self.retention.segment_records)
            .map(|(k, _)| *k)
            .collect();
        for key in ready {
            self.seal_partition(key);
        }
    }

    /// Expire records older than the partition-local time window (flush
    /// time, before retention): whole warm segments are dropped by zone
    /// map alone when `max_entry` clears the cutoff; a straddling
    /// segment is demoted to hot and filtered; hot rows are filtered in
    /// place. Expired records count into `evicted`.
    fn enforce_window(&mut self) {
        let window = self.retention.retain_window_us;
        if window == 0 {
            return;
        }
        let keys: Vec<(u32, u32)> = self.parts.keys().copied().collect();
        for key in keys {
            let part = self.parts.get_mut(&key).expect("listed partition exists");
            let cutoff = part.max_entry.saturating_sub(window);
            if cutoff == 0 {
                continue;
            }
            let straddlers: Vec<SegmentMeta> = {
                let mut kept = Vec::new();
                let mut straddle = Vec::new();
                for meta in part.warm.drain(..) {
                    if meta.footer.zone.max_entry < cutoff {
                        // Every record in the segment is expired: drop
                        // the whole file without decoding it.
                        self.evicted += meta.footer.n_records as u64;
                        self.anomalies -= meta.footer.n_anomalies as u64;
                        self.resident_bytes -= meta.disk_bytes;
                        part.dirty = true;
                        if let Err(e) = std::fs::remove_file(&meta.path) {
                            self.log_errors += 1;
                            crate::log_warn!(
                                "provdb",
                                "removing expired {}: {e}",
                                meta.path.display()
                            );
                        }
                    } else if meta.footer.zone.min_entry < cutoff {
                        straddle.push(meta);
                    } else {
                        kept.push(meta);
                    }
                }
                part.warm = kept;
                straddle
            };
            for meta in straddlers {
                self.demote_segment(key, meta);
            }
            let part = self.parts.get_mut(&key).expect("listed partition exists");
            let mut expired = 0u64;
            let mut freed_bytes = 0u64;
            let mut freed_anoms = 0u64;
            part.entries.retain(|e| {
                if codec::entry_us_of(&e.buf) < cutoff {
                    expired += 1;
                    freed_bytes += e.disk_bytes;
                    if codec::label_tag_of(&e.buf) != codec::LABEL_NORMAL {
                        freed_anoms += 1;
                    }
                    false
                } else {
                    true
                }
            });
            if expired > 0 {
                part.dirty = true;
                self.evicted += expired;
                self.resident_bytes -= freed_bytes;
                self.anomalies -= freed_anoms;
            }
        }
    }

    /// Demote one warm segment back into the hot tier (decoded, re-priced
    /// as rows, merged in sequence order) and delete its file. An
    /// unreadable segment is sidelined to `*.corrupt` and its records are
    /// surfaced as a counted loss — never a panic.
    fn demote_segment(&mut self, key: (u32, u32), meta: SegmentMeta) {
        let part = self.parts.get_mut(&key).expect("demoting into a live partition");
        part.dirty = true;
        self.resident_bytes -= meta.disk_bytes;
        match scan_warm(&meta) {
            Ok(rows) => {
                for (seq, _, buf) in rows {
                    let disk_bytes = buf.len() as u64 + 4; // + CRC trailer
                    self.resident_bytes += disk_bytes;
                    part.entries.push(Entry { seq, disk_bytes, buf });
                }
                part.entries.sort_by_key(|e| e.seq);
                if let Err(e) = std::fs::remove_file(&meta.path) {
                    self.log_errors += 1;
                    crate::log_warn!(
                        "provdb",
                        "removing demoted {}: {e}",
                        meta.path.display()
                    );
                }
            }
            Err(e) => {
                self.log_errors += 1;
                self.anomalies -= meta.footer.n_anomalies as u64;
                let sidelined = meta.path.with_extension("provseg.corrupt");
                std::fs::rename(&meta.path, &sidelined).ok();
                crate::log_warn!(
                    "provdb",
                    "demoting {}: {e:#} — segment sidelined to {}, {} records lost",
                    meta.path.display(),
                    sidelined.display(),
                    meta.footer.n_records
                );
            }
        }
    }

    /// Enforce the exact retention bound on every partition (the ingest
    /// path lets the hot tier overshoot by a slack between sweeps). The
    /// bound is global across tiers: a partition whose hot + warm total
    /// exceeds it demotes all warm segments back to hot first, so
    /// eviction ranks every retained record by score — exactly the
    /// single-tier policy.
    fn enforce_retention(&mut self) {
        let max = self.retention.max_records_per_rank;
        if max == usize::MAX {
            return;
        }
        let keys: Vec<(u32, u32)> = self.parts.keys().copied().collect();
        for key in keys {
            let part = self.parts.get_mut(&key).expect("listed partition exists");
            let warm_records: usize =
                part.warm.iter().map(|m| m.footer.n_records as usize).sum();
            if part.entries.len() + warm_records <= max {
                continue;
            }
            for meta in std::mem::take(&mut self.parts.get_mut(&key).unwrap().warm) {
                self.demote_segment(key, meta);
            }
            let part = self.parts.get_mut(&key).expect("listed partition exists");
            let (ev, fb, fa) = evict_partition(part, max);
            self.evicted += ev;
            self.resident_bytes -= fb;
            self.anomalies -= fa;
        }
    }

    /// Append one record to the partition's log. I/O failure is a
    /// counted, logged drop — never a panic (a full disk must not take
    /// the shard thread down); the record stays queryable in memory, and
    /// the caller marks the partition dirty so the next flush-compaction
    /// rewrites the file (restoring the dropped record and wiping any
    /// partially-written bytes). Returns whether the append succeeded.
    fn append_log(&mut self, key: (u32, u32), rec: &[u8], line: &str) -> bool {
        let Some(dir) = &self.dir else {
            return true; // memory-only store: nothing to log
        };
        if !self.writers.contains_key(&key) {
            let active_k = self.parts.get(&key).map_or(0, |p| p.active_k);
            let path = log_path(dir, key, self.format, active_k);
            match open_log(&path, self.format) {
                Ok(w) => {
                    self.writers.insert(key, w);
                }
                Err(e) => {
                    self.log_errors += 1;
                    crate::log_warn!(
                        "provdb",
                        "opening {}: {e} — record dropped from log (kept in memory)",
                        path.display()
                    );
                    return false;
                }
            }
        }
        let w = self.writers.get_mut(&key).expect("writer just ensured");
        let res = match self.format {
            RecordFormat::Binary => w
                .write_all(rec)
                .and_then(|()| w.write_all(&codec::crc32(rec).to_le_bytes())),
            RecordFormat::Jsonl => {
                w.write_all(line.as_bytes()).and_then(|()| w.write_all(b"\n"))
            }
        };
        if let Err(e) = res {
            self.log_errors += 1;
            // Drop the writer: part of the record may already be in the
            // file (or the BufWriter); the dirty-compaction rewrite the
            // caller schedules is what makes the file whole again.
            self.writers.remove(&key);
            crate::log_warn!(
                "provdb",
                "appending to log for app{} rank{}: {e} — record dropped from log",
                key.0,
                key.1
            );
            return false;
        }
        true
    }

    fn query(&mut self, q: &ProvQuery) -> Vec<(u64, Vec<u8>)> {
        let mut out = Vec::new();
        let mut skipped = 0u64;
        let mut errors = 0u64;
        let parts: Vec<&Partition> = match q.rank {
            Some(key) => self.parts.get(&key).into_iter().collect(),
            None => self.parts.values().collect(),
        };
        for part in parts {
            // Warm tier first: the zone map proves "nothing here can
            // match" from the 105-byte footer alone — a pruned segment
            // costs zero reads and zero decodes.
            for meta in &part.warm {
                if !meta.footer.zone.may_match(q) {
                    skipped += 1;
                    continue;
                }
                match scan_warm(meta) {
                    Ok(rows) => {
                        for (seq, rec, buf) in rows {
                            if q.matches(&rec) {
                                out.push((seq, buf));
                            }
                        }
                    }
                    Err(e) => {
                        errors += 1;
                        crate::log_warn!("provdb", "warm scan failed: {e:#}");
                    }
                }
            }
            for e in &part.entries {
                let Ok(h) = codec::read_header(&e.buf) else { continue };
                // Predicate pushdown: the fixed header decides every
                // filter except a custom-label × custom-label compare;
                // that last case reads the label bytes at their fixed
                // payload offset (probe VM string access) — the record
                // is never decoded just to settle it.
                let keep = match codec::matches_header(q, &h) {
                    Some(v) => v,
                    None => q
                        .label
                        .as_deref()
                        .is_some_and(|l| crate::probe::vm::label_eq(&e.buf, l)),
                };
                if keep {
                    out.push((e.seq, e.buf.clone()));
                }
            }
        }
        self.segments_skipped += skipped;
        self.log_errors += errors;
        out
    }

    /// Evaluate an installed probe over every partition of this shard —
    /// warm segments included. Probe bytecode runs over encoded rows and
    /// cannot consult zone maps (a predicate VM sees one record at a
    /// time), so warm segments are always decoded here; canonical
    /// re-encoding keeps admitted bytes identical to the hot path.
    fn probe_scan(&mut self, probe: &InstalledProbe) -> Vec<(u64, Vec<u8>)> {
        let mut out = Vec::new();
        let mut errors = 0u64;
        for part in self.parts.values() {
            for meta in &part.warm {
                match scan_warm(meta) {
                    Ok(rows) => {
                        for (seq, _, buf) in rows {
                            if probe.admit(&buf) {
                                out.push((seq, buf));
                            }
                        }
                    }
                    Err(e) => {
                        errors += 1;
                        crate::log_warn!("provdb", "warm probe scan failed: {e:#}");
                    }
                }
            }
            for e in &part.entries {
                if probe.admit(&e.buf) {
                    out.push((e.seq, e.buf.clone()));
                }
            }
        }
        self.log_errors += errors;
        out
    }

    /// Flush-time tier maintenance, in dependency order: expire the time
    /// window, enforce the global retention bound exactly (demoting warm
    /// segments so eviction ranks every record), seal hot tiers that
    /// reached the rolling bound, then rewrite the append file of every
    /// partition still marked dirty so a reload sees exactly the
    /// retained view. Compaction writes the *current* format to the
    /// current append path and removes every superseded file for the
    /// partition (the in-place migration step for JSONL dirs restarted
    /// under the binary format, and for legacy single-file dirs rolling
    /// into v2 segments).
    fn flush(&mut self) {
        self.enforce_window();
        self.enforce_retention();
        self.seal_ready();
        if let Some(dir) = self.dir.clone() {
            let dirty: Vec<(u32, u32)> = self
                .parts
                .iter()
                .filter(|(_, p)| p.dirty)
                .map(|(k, _)| *k)
                .collect();
            for key in dirty {
                self.writers.remove(&key);
                let part = self.parts.get_mut(&key).expect("dirty partition exists");
                // Build the compacted file and each entry's size in it —
                // applied below on success, so migrated partitions stop
                // carrying the other format's byte prices.
                let mut sizes: Vec<u64> = Vec::with_capacity(part.entries.len());
                let bytes = match self.format {
                    RecordFormat::Binary => {
                        let mut bytes: Vec<u8> = codec::seg_file_header().to_vec();
                        for e in &part.entries {
                            bytes.extend_from_slice(&e.buf);
                            bytes.extend_from_slice(&codec::crc32(&e.buf).to_le_bytes());
                            sizes.push(e.buf.len() as u64 + 4);
                        }
                        bytes
                    }
                    RecordFormat::Jsonl => {
                        let mut text = String::with_capacity(part.entries.len() * 360);
                        for e in &part.entries {
                            let before = text.len();
                            if let Ok((rec, _)) = codec::decode(&e.buf) {
                                rec.write_jsonl(&mut text);
                                text.push('\n');
                            }
                            sizes.push((text.len() - before) as u64);
                        }
                        text.into_bytes()
                    }
                };
                let path = log_path(&dir, key, self.format, part.active_k);
                let mut keep: Vec<PathBuf> =
                    part.warm.iter().map(|m| m.path.clone()).collect();
                keep.push(path.clone());
                // Write-tmp → atomic rename → only then drop superseded
                // files: a failed write (ENOSPC — the very case the log
                // hardening targets) or a crash mid-compaction must
                // never destroy the partition's only on-disk copy.
                let tmp = path.with_extension("tmp");
                let res = std::fs::write(&tmp, &bytes)
                    .and_then(|()| std::fs::rename(&tmp, &path));
                match res {
                    Ok(()) => {
                        // Removal can fail (or a crash can land between
                        // the rename and here); the partition then
                        // reloads with duplicates, so surface it and
                        // retry via dirty.
                        let removed = remove_superseded(&dir, key, &keep);
                        if !removed {
                            self.log_errors += 1;
                        }
                        let part = self.parts.get_mut(&key).expect("dirty partition exists");
                        part.dirty = !removed;
                        for (e, nb) in part.entries.iter_mut().zip(&sizes) {
                            self.resident_bytes = self.resident_bytes - e.disk_bytes + nb;
                            e.disk_bytes = *nb;
                        }
                    }
                    Err(e) => {
                        self.log_errors += 1;
                        std::fs::remove_file(&tmp).ok();
                        crate::log_warn!(
                            "provdb",
                            "compacting {}: {e} — will retry at the next flush",
                            path.display()
                        );
                    }
                }
            }
        }
        for w in self.writers.values_mut() {
            if w.flush().is_err() {
                self.log_errors += 1;
            }
        }
    }

    fn stats(&self) -> ProvDbStats {
        let warm_records: u64 = self
            .parts
            .values()
            .flat_map(|p| p.warm.iter())
            .map(|m| m.footer.n_records as u64)
            .sum();
        let segments_total: u64 =
            self.parts.values().map(|p| p.warm.len() as u64).sum();
        ProvDbStats {
            records: self.parts.values().map(|p| p.entries.len() as u64).sum::<u64>()
                + warm_records,
            resident_bytes: self.resident_bytes,
            log_bytes: self.log_bytes,
            anomalies: self.anomalies,
            evicted: self.evicted,
            log_errors: self.log_errors,
            // Transport counters live on the TCP front-end, not here.
            shed: 0,
            net_queue_depth: 0,
            segments_total,
            segments_skipped: self.segments_skipped,
            zone_map_bytes: segments_total * codec::SEG2_FOOTER_LEN as u64,
        }
    }
}

fn run_shard(
    dir: Option<PathBuf>,
    retention: Retention,
    format: RecordFormat,
    rx: Receiver<ShardReq>,
) {
    let mut shard = ShardState {
        dir,
        format,
        retention,
        parts: HashMap::new(),
        writers: HashMap::new(),
        log_bytes: 0,
        resident_bytes: 0,
        anomalies: 0,
        evicted: 0,
        log_errors: 0,
        segments_skipped: 0,
    };
    while let Ok(req) = rx.recv() {
        match req {
            ShardReq::Ingest { batch, log } => shard.ingest(batch, log),
            ShardReq::Query { q, reply } => {
                let _ = reply.send(shard.query(&q));
            }
            ShardReq::ProbeScan { probe, reply } => {
                let _ = reply.send(shard.probe_scan(&probe));
            }
            ShardReq::Flush { reply } => {
                shard.flush();
                let _ = reply.send(());
            }
            ShardReq::Stats { reply } => {
                let _ = reply.send(shard.stats());
            }
            ShardReq::RegisterSegment { key, meta } => {
                shard.resident_bytes += meta.disk_bytes;
                shard.log_bytes += meta.disk_bytes;
                shard.anomalies += meta.footer.n_anomalies as u64;
                let part = shard.parts.entry(key).or_default();
                if meta.footer.n_records > 0 {
                    part.max_entry = part.max_entry.max(meta.footer.zone.max_entry);
                }
                part.warm.push(meta);
            }
            ShardReq::SetActive { key, active_k } => {
                shard.parts.entry(key).or_default().active_k = active_k;
            }
            ShardReq::Shutdown => break,
        }
    }
    shard.flush();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(app: u32, rank: u32, step: u64, score: f64, id: u64) -> ProvRecord {
        let entry = id * 100;
        ProvRecord {
            call_id: id,
            app,
            rank,
            thread: 0,
            fid: (id % 5) as u32,
            func: format!("F{}", id % 5),
            step,
            entry_us: entry,
            exit_us: entry + 50,
            inclusive_us: 50,
            exclusive_us: 30,
            depth: 0,
            parent: None,
            n_children: 0,
            n_messages: 0,
            msg_bytes: 0,
            label: if score >= 6.0 { "anomaly_high".into() } else { "normal".into() },
            score,
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("chimbuko-provdb-{tag}-{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    #[test]
    fn shard_routing_is_stable_and_in_range() {
        for n in [1usize, 2, 4, 7] {
            for app in 0..3u32 {
                for rank in 0..64u32 {
                    let s = prov_shard_of(app, rank, n);
                    assert!(s < n);
                    assert_eq!(s, prov_shard_of(app, rank, n));
                }
            }
        }
        assert_eq!(prov_shard_of(5, 1234, 1), 0);
    }

    #[test]
    fn ingest_query_across_shards() {
        let (store, handle) = spawn_store(None, 4, Retention::default()).unwrap();
        let mut recs = Vec::new();
        for rank in 0..8u32 {
            for i in 0..10u64 {
                recs.push(rec(0, rank, i / 4, (i % 7) as f64, rank as u64 * 100 + i));
            }
        }
        store.ingest(recs);
        store.flush();
        let all = store.query(&ProvQuery::default());
        assert_eq!(all.len(), 80);
        // entry-ordered with sequence tie-break.
        for w in all.windows(2) {
            assert!(w[0].entry_us <= w[1].entry_us);
        }
        let one_rank = store.query(&ProvQuery { rank: Some((0, 3)), ..Default::default() });
        assert_eq!(one_rank.len(), 10);
        assert!(one_rank.iter().all(|r| r.rank == 3));
        let stack = store.call_stack(0, 3, 0);
        assert_eq!(stack.len(), 4);
        let top = store.query(&ProvQuery {
            order_by_score: true,
            limit: Some(3),
            ..Default::default()
        });
        assert_eq!(top.len(), 3);
        assert!(top[0].score >= top[1].score && top[1].score >= top[2].score);
        let stats = store.stats();
        assert_eq!(stats.records, 80);
        assert_eq!(stats.evicted, 0);
        assert_eq!(stats.log_errors, 0);
        assert_eq!(stats.resident_bytes, stats.log_bytes);
        handle.join();
    }

    #[test]
    fn retention_evicts_lowest_scores_first() {
        let (store, handle) =
            spawn_store(None, 2, Retention { max_records_per_rank: 5, ..Default::default() })
                .unwrap();
        // 20 records on one rank with distinct scores 0..19.
        let recs: Vec<ProvRecord> =
            (0..20u64).map(|i| rec(0, 1, i, i as f64, i)).collect();
        store.ingest(recs);
        store.flush();
        let kept = store.query(&ProvQuery { rank: Some((0, 1)), ..Default::default() });
        assert_eq!(kept.len(), 5);
        // The five highest scores survive.
        let mut scores: Vec<f64> = kept.iter().map(|r| r.score).collect();
        scores.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(scores, vec![15.0, 16.0, 17.0, 18.0, 19.0]);
        let stats = store.stats();
        assert_eq!(stats.records, 5);
        assert_eq!(stats.evicted, 15);
        assert!(stats.resident_bytes < stats.log_bytes);
        handle.join();
    }

    #[test]
    fn log_is_provdb_compatible_and_compacts() {
        use crate::provenance::ProvDb;
        for format in [RecordFormat::Binary, RecordFormat::Jsonl] {
            let dir = tmpdir(&format!("log-{}", format.name()));
            let (store, handle) = spawn_store_fmt(
                Some(dir.as_path()),
                2,
                Retention { max_records_per_rank: 3, ..Default::default() },
                format,
            )
            .unwrap();
            let recs: Vec<ProvRecord> =
                (0..9u64).map(|i| rec(0, 2, i, i as f64, i)).collect();
            store.ingest(recs);
            store
                .set_metadata(Json::obj(vec![("run_id", Json::str("provdb-test"))]))
                .unwrap();
            store.flush();
            // The compacted log reloads through the classic loader
            // (which reads both formats) and holds exactly the retained
            // view.
            let db = ProvDb::load(&dir).unwrap();
            assert_eq!(db.len(), 3, "{}", format.name());
            let meta = ProvDb::load_metadata(&dir).unwrap();
            assert_eq!(meta.get("run_id").unwrap().as_str(), Some("provdb-test"));
            let retained = store.query(&ProvQuery::default());
            let reloaded = db.query(&ProvQuery::default());
            assert_eq!(retained.len(), reloaded.len());
            for (a, b) in retained.iter().zip(reloaded.iter()) {
                assert_eq!(&a, b);
            }
            handle.join();
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn binary_log_is_smaller_per_record_than_jsonl() {
        let recs: Vec<ProvRecord> = (0..50u64).map(|i| rec(0, 1, i, i as f64, i)).collect();
        let mut sizes = Vec::new();
        for format in [RecordFormat::Binary, RecordFormat::Jsonl] {
            let (store, handle) =
                spawn_store_fmt(None, 1, Retention::default(), format).unwrap();
            store.ingest(recs.clone());
            store.flush();
            sizes.push(store.stats().log_bytes);
            handle.join();
        }
        assert!(
            sizes[0] < sizes[1],
            "binary log ({}) must be strictly smaller than JSONL ({})",
            sizes[0],
            sizes[1]
        );
    }

    #[test]
    fn restart_recovers_existing_logs() {
        let dir = tmpdir("recover");
        {
            let (store, handle) =
                spawn_store(Some(dir.as_path()), 2, Retention::default()).unwrap();
            let recs: Vec<ProvRecord> =
                (0..6u64).map(|i| rec(0, 1, i, i as f64, i)).collect();
            store.ingest(recs);
            store
                .set_metadata(Json::obj(vec![("run_id", Json::str("r1"))]))
                .unwrap();
            store.flush();
            handle.join();
        }
        // Restart on the same dir (different shard count): the previous
        // run's records and metadata are queryable, not clobbered.
        let (store, handle) =
            spawn_store(Some(dir.as_path()), 4, Retention::default()).unwrap();
        assert_eq!(store.query(&ProvQuery::default()).len(), 6);
        assert_eq!(
            store.metadata().unwrap().get("run_id").unwrap().as_str(),
            Some("r1")
        );
        let before = store.stats();
        assert_eq!(before.records, 6);
        assert!(before.log_bytes > 0);
        // New ingest appends; old data survives flush + reload.
        store.ingest(vec![rec(0, 1, 9, 99.0, 100)]);
        store.flush();
        assert_eq!(store.stats().records, 7);
        let db = crate::provenance::ProvDb::load(&dir).unwrap();
        assert_eq!(db.len(), 7);
        handle.join();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn jsonl_dir_migrates_in_place_under_binary_format() {
        let dir = tmpdir("migrate");
        let jsonl_log_bytes;
        {
            let (store, handle) = spawn_store_fmt(
                Some(dir.as_path()),
                2,
                Retention::default(),
                RecordFormat::Jsonl,
            )
            .unwrap();
            store.ingest((0..8u64).map(|i| rec(0, 1, i, i as f64, i)).collect());
            store.flush();
            jsonl_log_bytes = store.stats().log_bytes;
            handle.join();
        }
        // Restart under the binary format: JSONL records replay, new
        // appends go to the segment file, and both survive a reload.
        let (store, handle) =
            spawn_store(Some(dir.as_path()), 1, Retention::default()).unwrap();
        assert_eq!(store.query(&ProvQuery::default()).len(), 8);
        // Replayed records keep their true (JSONL) on-disk byte prices —
        // they still live in the .jsonl file, not in binary form.
        assert_eq!(store.stats().log_bytes, jsonl_log_bytes);
        store.ingest(vec![rec(0, 1, 9, 99.0, 100)]);
        store.flush();
        assert_eq!(store.query(&ProvQuery::default()).len(), 9);
        handle.join();
        // The dir now holds both the old .jsonl and the new .provseg for
        // the partition; the classic loader reads them in path order.
        assert!(dir.join("prov_app0_rank1.jsonl").exists());
        assert!(dir.join("prov_app0_rank1.provseg").exists());
        let db = crate::provenance::ProvDb::load(&dir).unwrap();
        assert_eq!(db.len(), 9);
        // A third restart sees all nine too.
        let (store, handle) =
            spawn_store(Some(dir.as_path()), 2, Retention::default()).unwrap();
        assert_eq!(store.query(&ProvQuery::default()).len(), 9);
        handle.join();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_segment_tail_is_repaired_on_recovery() {
        let dir = tmpdir("torn");
        {
            let (store, handle) =
                spawn_store(Some(dir.as_path()), 1, Retention::default()).unwrap();
            store.ingest((0..4u64).map(|i| rec(0, 0, i, i as f64, i)).collect());
            store.flush();
            handle.join();
        }
        // Crash mid-append: a partial record left at the tail.
        let path = dir.join("prov_app0_rank0.provseg");
        let mut bytes = std::fs::read(&path).unwrap();
        let clean_len = bytes.len() as u64;
        bytes.extend_from_slice(&[0xAB; 17]);
        std::fs::write(&path, &bytes).unwrap();
        // Restart: the 4 good records survive and the tear is truncated
        // away, so the log reopens at a clean record boundary…
        {
            let (store, handle) =
                spawn_store(Some(dir.as_path()), 1, Retention::default()).unwrap();
            assert_eq!(store.query(&ProvQuery::default()).len(), 4);
            assert_eq!(std::fs::metadata(&path).unwrap().len(), clean_len);
            store.ingest(vec![rec(0, 0, 9, 9.0, 50)]);
            store.flush();
            handle.join();
        }
        // …and records appended after the crash survive the NEXT restart
        // (without the repair they would sit behind the tear and vanish).
        let (store, handle) = spawn_store(Some(dir.as_path()), 2, Retention::default()).unwrap();
        assert_eq!(store.query(&ProvQuery::default()).len(), 5);
        handle.join();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_segment_is_sidelined_and_clean_prefix_rewritten() {
        let dir = tmpdir("corrupt");
        {
            let (store, handle) =
                spawn_store(Some(dir.as_path()), 1, Retention::default()).unwrap();
            store.ingest((0..4u64).map(|i| rec(0, 0, i, i as f64, i)).collect());
            store.flush();
            handle.join();
        }
        // Flip a byte inside the third record (all four encode to the
        // same length here): CRC fails there, records 1-2 stay valid.
        let path = dir.join("prov_app0_rank0.provseg");
        let mut bytes = std::fs::read(&path).unwrap();
        let rec_len = (bytes.len() - 6) / 4;
        bytes[6 + 2 * rec_len + 10] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        // Restart: the two records before the damage survive; the bad
        // file is preserved for salvage and the live segment rewritten
        // clean, so post-recovery appends survive the next restart.
        {
            let (store, handle) =
                spawn_store(Some(dir.as_path()), 1, Retention::default()).unwrap();
            assert_eq!(store.query(&ProvQuery::default()).len(), 2);
            assert!(dir.join("prov_app0_rank0.provseg.corrupt").exists());
            store.ingest(vec![rec(0, 0, 9, 9.0, 50)]);
            store.flush();
            handle.join();
        }
        let (store, handle) =
            spawn_store(Some(dir.as_path()), 2, Retention::default()).unwrap();
        assert_eq!(store.query(&ProvQuery::default()).len(), 3);
        handle.join();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn damaged_jsonl_line_degrades_instead_of_failing_recovery() {
        let dir = tmpdir("badline");
        {
            let (store, handle) = spawn_store_fmt(
                Some(dir.as_path()),
                1,
                Retention::default(),
                RecordFormat::Jsonl,
            )
            .unwrap();
            store.ingest((0..5u64).map(|i| rec(0, 0, i, i as f64, i)).collect());
            store.flush();
            handle.join();
        }
        // Mangle the third line (a partial append merged with its
        // successor looks exactly like this).
        let path = dir.join("prov_app0_rank0.jsonl");
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines: Vec<&str> = text.lines().collect();
        lines[2] = "{\"call_id\": 2, \"app\"";
        std::fs::write(&path, lines.join("\n") + "\n").unwrap();
        // Recovery keeps the records before the damage and still starts
        // (the old loader refused the whole directory here); the damaged
        // file is sidelined and the live log rewritten clean, so records
        // appended after the recovery survive the NEXT restart too.
        {
            let (store, handle) = spawn_store_fmt(
                Some(dir.as_path()),
                1,
                Retention::default(),
                RecordFormat::Jsonl,
            )
            .unwrap();
            assert_eq!(store.query(&ProvQuery::default()).len(), 2);
            assert!(dir.join("prov_app0_rank0.jsonl.corrupt").exists());
            store.ingest(vec![rec(0, 0, 9, 9.0, 50)]);
            store.flush();
            handle.join();
        }
        let (store, handle) =
            spawn_store_fmt(Some(dir.as_path()), 1, Retention::default(), RecordFormat::Jsonl)
                .unwrap();
        assert_eq!(store.query(&ProvQuery::default()).len(), 3);
        handle.join();
        let db = crate::provenance::ProvDb::load(&dir).unwrap();
        assert_eq!(db.len(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn log_io_error_degrades_to_counted_drop() {
        let dir = tmpdir("ioerr");
        let (store, handle) = spawn_store(Some(dir.as_path()), 1, Retention::default()).unwrap();
        // Yank the directory out from under the store: every append's
        // log write now fails (ENOENT) — the shard must keep running.
        std::fs::remove_dir_all(&dir).unwrap();
        let recs: Vec<ProvRecord> = (0..3u64).map(|i| rec(0, 0, i, i as f64, i)).collect();
        store.ingest(recs);
        store.flush();
        // Records are still queryable from memory; the drops are counted.
        assert_eq!(store.query(&ProvQuery::default()).len(), 3);
        let stats = store.stats();
        assert_eq!(stats.records, 3);
        assert!(stats.log_errors >= 3, "log_errors {}", stats.log_errors);
        // Shutdown must not panic (the old code `expect()`ed here).
        handle.join();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn custom_label_query_decided_without_decode() {
        // Satellite regression: the custom-label × custom-label case
        // (the one filter `codec::matches_header` cannot settle) is
        // resolved by the probe VM's fixed-offset label compare — the
        // results must match a full-decode evaluation exactly.
        let (store, handle) = spawn_store(None, 2, Retention::default()).unwrap();
        let mut recs = Vec::new();
        for (i, label) in ["weird", "weird_2", "normal", "ünï-label", "weird"]
            .iter()
            .enumerate()
        {
            let mut r = rec(0, i as u32 % 2, 0, 1.0, i as u64);
            r.label = label.to_string();
            recs.push(r);
        }
        store.ingest(recs.clone());
        store.flush();
        for want in ["weird", "weird_2", "ünï-label", "nosuch", "normal"] {
            let q = ProvQuery { label: Some(want.to_string()), ..Default::default() };
            let got = store.query(&q);
            let expect: Vec<&ProvRecord> =
                recs.iter().filter(|r| q.matches(r)).collect();
            assert_eq!(got.len(), expect.len(), "label {want}");
            assert!(got.iter().all(|r| r.label == want), "label {want}");
        }
        handle.join();
    }

    #[test]
    fn probe_scan_matches_equivalent_query_bytes() {
        use crate::probe::{InstalledProbe, Probe};
        let (store, handle) = spawn_store(None, 4, Retention::default()).unwrap();
        let mut recs = Vec::new();
        for rank in 0..6u32 {
            for i in 0..10u64 {
                recs.push(rec(0, rank, i, (i % 8) as f64, rank as u64 * 100 + i));
            }
        }
        store.ingest(recs);
        store.flush();
        // Probe predicate ≡ ProvQuery { min_score: 6.0, anomalies_only }.
        let probe = Arc::new(InstalledProbe::new(
            Probe::compile("fn:*.*:exit / score >= 6.0 && anomaly /").unwrap(),
        ));
        let via_probe = store.probe_scan(&probe);
        let q = ProvQuery {
            min_score: Some(6.0),
            anomalies_only: true,
            ..Default::default()
        };
        let via_query = store.query_encoded(&q);
        assert!(!via_probe.is_empty());
        assert_eq!(via_probe, via_query, "bit-identical to the query path");
        assert_eq!(
            probe.matches.load(Ordering::Relaxed) as usize,
            via_probe.len()
        );
        assert_eq!(probe.shed.load(Ordering::Relaxed), 0);
        handle.join();
    }

    #[test]
    fn sealing_rolls_segments_and_zone_maps_prune_queries() {
        let dir = tmpdir("seal");
        let retention = Retention::default().with_segment_knob(10);
        let (store, handle) = spawn_store(Some(dir.as_path()), 1, retention).unwrap();
        // 30 records, one step each: seals exactly three 10-record
        // segments during ingest and leaves the hot tier empty.
        store.ingest((0..30u64).map(|i| rec(0, 0, i, (i % 7) as f64, i)).collect());
        store.flush();
        let stats = store.stats();
        assert_eq!(stats.records, 30);
        assert_eq!(stats.segments_total, 3);
        assert_eq!(stats.segments_skipped, 0);
        assert_eq!(stats.zone_map_bytes, 3 * codec::SEG2_FOOTER_LEN as u64);
        for k in 0..3 {
            assert!(dir.join(format!("prov_app0_rank0_seg000{k}.provseg")).exists());
        }
        // The first seal removed the legacy single-file log.
        assert!(!dir.join("prov_app0_rank0.provseg").exists());
        // A step-range query over the first segment decodes it alone;
        // the other two are pruned by zone map without a read.
        let hits = store
            .query(&ProvQuery { step_range: Some((0, 4)), ..Default::default() });
        assert_eq!(hits.len(), 5);
        assert!(hits.iter().all(|r| r.step <= 4));
        assert_eq!(store.stats().segments_skipped, 2);
        handle.join();
        // Restart re-adopts the sealed segments from their footers.
        let (store, handle) = spawn_store(Some(dir.as_path()), 2, retention).unwrap();
        let all = store.query(&ProvQuery::default());
        assert_eq!(all.len(), 30);
        for w in all.windows(2) {
            assert!(w[0].entry_us <= w[1].entry_us);
        }
        let stats = store.stats();
        assert_eq!(stats.records, 30);
        assert_eq!(stats.segments_total, 3);
        handle.join();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn retention_bound_spans_hot_and_warm_tiers() {
        let dir = tmpdir("tiered-retention");
        let retention = Retention { max_records_per_rank: 5, ..Default::default() }
            .with_segment_knob(8);
        let (store, handle) = spawn_store(Some(dir.as_path()), 1, retention).unwrap();
        // 20 records with distinct scores: two segments seal during
        // ingest; the flush must rank *all* 20 records (demoting the
        // warm ones), not just the hot leftovers.
        store.ingest((0..20u64).map(|i| rec(0, 0, i, i as f64, i)).collect());
        store.flush();
        let kept = store.query(&ProvQuery { rank: Some((0, 0)), ..Default::default() });
        assert_eq!(kept.len(), 5);
        let mut scores: Vec<f64> = kept.iter().map(|r| r.score).collect();
        scores.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(scores, vec![15.0, 16.0, 17.0, 18.0, 19.0]);
        let stats = store.stats();
        assert_eq!(stats.records, 5);
        assert_eq!(stats.evicted, 15);
        assert_eq!(stats.segments_total, 0, "demoted segments are gone");
        handle.join();
        let db = crate::provenance::ProvDb::load(&dir).unwrap();
        assert_eq!(db.len(), 5);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn time_window_expires_whole_segments_by_zone_map() {
        let dir = tmpdir("window");
        let retention =
            Retention::default().with_segment_knob(10).with_window_knob(1_000);
        let (store, handle) = spawn_store(Some(dir.as_path()), 1, retention).unwrap();
        // entry_us = id × 100 → partition clock reaches 2900 and the
        // cutoff is 1900: segment 0 (entries 0..900) expires whole by
        // zone map, segment 1 (1000..1900) straddles and is demoted +
        // filtered to its single surviving record, segment 2 stays warm.
        store.ingest((0..30u64).map(|i| rec(0, 0, i, (i % 7) as f64, i)).collect());
        store.flush();
        let all = store.query(&ProvQuery::default());
        assert_eq!(all.len(), 11);
        assert!(all.iter().all(|r| r.entry_us >= 1900));
        let stats = store.stats();
        assert_eq!(stats.records, 11);
        assert_eq!(stats.evicted, 19);
        assert_eq!(stats.segments_total, 1);
        assert!(!dir.join("prov_app0_rank0_seg0000.provseg").exists());
        assert!(dir.join("prov_app0_rank0_seg0002.provseg").exists());
        handle.join();
        // The expired records are gone from disk too.
        let (store, handle) = spawn_store(Some(dir.as_path()), 1, retention).unwrap();
        assert_eq!(store.query(&ProvQuery::default()).len(), 11);
        handle.join();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn probe_scan_covers_warm_segments_bit_identically() {
        use crate::probe::{InstalledProbe, Probe};
        let dir = tmpdir("warm-probe");
        let retention = Retention::default().with_segment_knob(8);
        let (store, handle) = spawn_store(Some(dir.as_path()), 2, retention).unwrap();
        let mut recs = Vec::new();
        for rank in 0..6u32 {
            for i in 0..10u64 {
                recs.push(rec(0, rank, i, (i % 8) as f64, rank as u64 * 100 + i));
            }
        }
        store.ingest(recs);
        store.flush();
        assert!(store.stats().segments_total >= 6, "every partition sealed");
        let probe = Arc::new(InstalledProbe::new(
            Probe::compile("fn:*.*:exit / score >= 6.0 && anomaly /").unwrap(),
        ));
        let via_probe = store.probe_scan(&probe);
        let q = ProvQuery {
            min_score: Some(6.0),
            anomalies_only: true,
            ..Default::default()
        };
        let via_query = store.query_encoded(&q);
        assert_eq!(via_probe.len(), 12);
        assert_eq!(via_probe, via_query, "bit-identical across warm + hot tiers");
        handle.join();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn metadata_roundtrip_and_empty_store() {
        let (store, handle) = spawn_store(None, 1, Retention::default()).unwrap();
        assert!(store.metadata().is_none());
        store
            .set_metadata(Json::obj(vec![("run_id", Json::str("m"))]))
            .unwrap();
        let m = store.metadata().unwrap();
        assert_eq!(m.get("run_id").unwrap().as_str(), Some("m"));
        assert!(store.query(&ProvQuery::default()).is_empty());
        assert!(store.call_stack(0, 0, 0).is_empty());
        assert_eq!(store.stats().records, 0);
        handle.join();
    }
}
