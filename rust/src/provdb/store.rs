//! The sharded provenance document store behind the provDB service.
//!
//! [`spawn_store`] starts `n` shard worker threads; [`ProvStore`] is the
//! cloneable front-end that routes every record to the shard owning its
//! `(app, rank)` partition ([`prov_shard_of`]) and fans queries out. Each
//! shard owns:
//!
//! * the in-memory, queryable partitions — one per `(app, rank)`, bounded
//!   by the [`Retention`] policy (score-based eviction keeps the
//!   highest-score records, implementing the paper's "reduction for
//!   human-level processing" instead of growing unboundedly);
//! * the append log — one `prov_app<A>_rank<R>.jsonl` file per partition,
//!   byte-compatible with [`ProvDb`](crate::provenance::ProvDb)'s layout,
//!   so `chimbuko replay`/`ProvDb::load` work on a provDB data directory
//!   unchanged. A flush rewrites any partition that evicted records so
//!   the on-disk log matches the retained view.
//!
//! ## Ordering and equivalence
//!
//! The front-end stamps every ingested record with a global sequence
//! number. Query results are merged centrally and sorted by the query's
//! ordering with the sequence as tie-breaker — exactly the stable-sort
//! tie order of the local [`ProvDb`](crate::provenance::ProvDb) index
//! when records arrive in the same order, which is what the equivalence
//! property in `tests/provdb_service.rs` pins down for 1/2/4 shards.
//!
//! ## Consistency
//!
//! Shard channels are FIFO per sender: a [`ProvStore`] clone (or a TCP
//! connection, which owns one clone) always reads its own writes.
//! Cross-client visibility needs a [`ProvStore::flush`] barrier, which
//! drains every shard queue before returning.

use crate::provenance::{ProvQuery, ProvRecord};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;

/// Stable shard routing: which of `n_shards` owns `(app, rank)`.
///
/// The epoch-0 default of the shared [`Placement`](crate::placement)
/// abstraction — the same slot hashing as the PS's
/// [`ps::shard_of`](crate::ps::shard_of), but keyed by rank: provenance
/// is partitioned by *who produced it*, statistics by *which function*.
/// The provDB stays at epoch 0 for now (no live rebalancing); its
/// [`ProvStore`] routes through a `Placement` so the two subsystems
/// share one placement type.
pub fn prov_shard_of(app: u32, rank: u32, n_shards: usize) -> usize {
    crate::placement::Placement::default_shard_of(app, rank, n_shards)
}

/// Retention policy applied per `(app, rank)` partition.
#[derive(Clone, Copy, Debug)]
pub struct Retention {
    /// Retained records per `(app, rank)`; `usize::MAX` = unbounded.
    /// Over capacity, the lowest-score records are evicted first (oldest
    /// on score ties), so anomalies outlive their normal context
    /// records. Eviction sweeps run when a partition overshoots the
    /// bound by a slack (¼ of the bound, at least 64 — amortized
    /// O(log n) per insert) and exactly at every flush, so the bound is
    /// precise at flush barriers.
    pub max_records_per_rank: usize,
}

impl Default for Retention {
    fn default() -> Self {
        Retention { max_records_per_rank: usize::MAX }
    }
}

impl Retention {
    /// Knob form used by config/CLI: 0 means unbounded.
    pub fn from_knob(max_records_per_rank: usize) -> Retention {
        Retention {
            max_records_per_rank: if max_records_per_rank == 0 {
                usize::MAX
            } else {
                max_records_per_rank
            },
        }
    }
}

/// Aggregate store counters (summed over shards).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ProvDbStats {
    /// Retained records across all partitions.
    pub records: u64,
    /// JSONL bytes of the retained records (the provDB-resident size).
    pub resident_bytes: u64,
    /// Total JSONL bytes ever appended to the log (plus metadata) — the
    /// Fig 9 "reduced output" axis.
    pub log_bytes: u64,
    /// Retained anomaly records.
    pub anomalies: u64,
    /// Records evicted by retention so far.
    pub evicted: u64,
}

impl ProvDbStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("records", Json::num(self.records as f64)),
            ("resident_bytes", Json::num(self.resident_bytes as f64)),
            ("log_bytes", Json::num(self.log_bytes as f64)),
            ("anomalies", Json::num(self.anomalies as f64)),
            ("evicted", Json::num(self.evicted as f64)),
        ])
    }
}

/// Message to one shard worker.
enum ShardReq {
    /// Sequence-stamped records, all owned by this shard. `log: false`
    /// for recovery replay (the records are already in the append log).
    Ingest { batch: Vec<(u64, ProvRecord)>, log: bool },
    /// Run the query over this shard's partitions; reply with matches
    /// (unsorted — the front-end merges and orders).
    Query { q: ProvQuery, reply: Sender<Vec<(u64, ProvRecord)>> },
    /// Flush writers; compact logs of partitions that evicted records.
    Flush { reply: Sender<()> },
    Stats { reply: Sender<ProvDbStats> },
    Shutdown,
}

/// Cloneable front-end to a spawned shard constellation.
#[derive(Clone)]
pub struct ProvStore {
    shards: Vec<Sender<ShardReq>>,
    /// `(app, rank)` → shard routing table (epoch 0: the provDB has no
    /// live rebalancing yet, but shares the PS's placement abstraction).
    placement: crate::placement::Placement,
    seq: Arc<AtomicU64>,
    meta: Arc<RwLock<Option<Json>>>,
    meta_bytes: Arc<AtomicU64>,
    dir: Option<PathBuf>,
}

impl ProvStore {
    /// Number of shard workers.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Ingest a batch: stamp sequence numbers, group by owning shard,
    /// send one message per touched shard. Returns the number accepted.
    pub fn ingest(&self, records: Vec<ProvRecord>) -> usize {
        self.route(records, true)
    }

    fn route(&self, records: Vec<ProvRecord>, log: bool) -> usize {
        if records.is_empty() {
            return 0;
        }
        let n = records.len();
        let mut parts: Vec<Vec<(u64, ProvRecord)>> = vec![Vec::new(); self.shards.len()];
        for rec in records {
            let seq = self.seq.fetch_add(1, Ordering::Relaxed);
            let shard = self.placement.shard_of(rec.app, rec.rank);
            parts[shard].push((seq, rec));
        }
        for (i, part) in parts.into_iter().enumerate() {
            if !part.is_empty() {
                let _ = self.shards[i].send(ShardReq::Ingest { batch: part, log });
            }
        }
        n
    }

    /// Run a query: single-shard when filtered by `(app, rank)`, fan-out
    /// otherwise; merge, order (sequence-stable), truncate.
    pub fn query(&self, q: &ProvQuery) -> Vec<ProvRecord> {
        let targets: Vec<usize> = match q.rank {
            Some((app, rank)) => vec![self.placement.shard_of(app, rank)],
            None => (0..self.shards.len()).collect(),
        };
        let (tx, rx) = channel();
        let mut expected = 0usize;
        for &i in &targets {
            if self.shards[i]
                .send(ShardReq::Query { q: q.clone(), reply: tx.clone() })
                .is_ok()
            {
                expected += 1;
            }
        }
        drop(tx);
        let mut out: Vec<(u64, ProvRecord)> = Vec::new();
        for _ in 0..expected {
            match rx.recv() {
                Ok(mut part) => out.append(&mut part),
                Err(_) => break,
            }
        }
        sort_results(q, &mut out);
        if let Some(n) = q.limit {
            out.truncate(n);
        }
        out.into_iter().map(|(_, r)| r).collect()
    }

    /// All records of `(app, rank)` for `step`, entry-ordered — the
    /// call-stack reconstruction query (Fig 6).
    pub fn call_stack(&self, app: u32, rank: u32, step: u64) -> Vec<ProvRecord> {
        self.query(&ProvQuery {
            rank: Some((app, rank)),
            step: Some(step),
            ..ProvQuery::default()
        })
    }

    /// Store run metadata (served back via [`Self::metadata`]; persisted
    /// to `metadata.json` when the store has a data directory).
    pub fn set_metadata(&self, meta: Json) -> Result<()> {
        let text = meta.to_pretty();
        self.meta_bytes.store(text.len() as u64, Ordering::Relaxed);
        if let Some(dir) = &self.dir {
            std::fs::write(dir.join("metadata.json"), &text)
                .context("writing provdb metadata")?;
        }
        *self.meta.write().expect("provdb metadata lock") = Some(meta);
        Ok(())
    }

    /// Run metadata, if any was stored.
    pub fn metadata(&self) -> Option<Json> {
        self.meta.read().expect("provdb metadata lock").clone()
    }

    /// Barrier: drain every shard queue, flush writers, compact logs of
    /// partitions that evicted records since the last flush.
    pub fn flush(&self) {
        let (tx, rx) = channel();
        let mut expected = 0usize;
        for s in &self.shards {
            if s.send(ShardReq::Flush { reply: tx.clone() }).is_ok() {
                expected += 1;
            }
        }
        drop(tx);
        for _ in 0..expected {
            if rx.recv().is_err() {
                break;
            }
        }
    }

    /// Aggregate counters over all shards (consistent after a flush).
    pub fn stats(&self) -> ProvDbStats {
        let (tx, rx) = channel();
        let mut expected = 0usize;
        for s in &self.shards {
            if s.send(ShardReq::Stats { reply: tx.clone() }).is_ok() {
                expected += 1;
            }
        }
        drop(tx);
        let mut out = ProvDbStats::default();
        for _ in 0..expected {
            match rx.recv() {
                Ok(s) => {
                    out.records += s.records;
                    out.resident_bytes += s.resident_bytes;
                    out.log_bytes += s.log_bytes;
                    out.anomalies += s.anomalies;
                    out.evicted += s.evicted;
                }
                Err(_) => break,
            }
        }
        out.log_bytes += self.meta_bytes.load(Ordering::Relaxed);
        out
    }
}

/// Order merged shard results exactly like the local index: the query's
/// primary key, sequence (= arrival order) on ties.
fn sort_results(q: &ProvQuery, out: &mut [(u64, ProvRecord)]) {
    if q.order_by_score {
        out.sort_by(|a, b| {
            b.1.score
                .partial_cmp(&a.1.score)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.0.cmp(&b.0))
        });
    } else {
        out.sort_by(|a, b| a.1.entry_us.cmp(&b.1.entry_us).then(a.0.cmp(&b.0)));
    }
}

/// Joinable handle to the shard constellation.
pub struct ProvStoreHandle {
    shards: Vec<Sender<ShardReq>>,
    joins: Vec<JoinHandle<()>>,
}

impl ProvStoreHandle {
    /// Stop every shard (each flushes its log first) and join.
    /// Panics if a shard worker panicked.
    pub fn join(self) {
        for tx in &self.shards {
            let _ = tx.send(ShardReq::Shutdown);
        }
        for j in self.joins {
            j.join().expect("provdb shard panicked");
        }
    }
}

/// Spawn a sharded provenance store.
///
/// * `dir` — data directory for the append log + metadata (`None` =
///   memory only);
/// * `n_shards` — shard worker threads (1 = single-consumer layout);
/// * `retention` — per-partition bound (see [`Retention`]).
pub fn spawn_store(
    dir: Option<&Path>,
    n_shards: usize,
    retention: Retention,
) -> Result<(ProvStore, ProvStoreHandle)> {
    if let Some(d) = dir {
        std::fs::create_dir_all(d)
            .with_context(|| format!("creating provdb dir {}", d.display()))?;
    }
    let n = n_shards.max(1);
    anyhow::ensure!(
        n <= crate::placement::SLOTS,
        "at most {} provdb shards supported ({n} requested): placement routes \
         through that many fixed slots",
        crate::placement::SLOTS
    );
    let mut shard_txs: Vec<Sender<ShardReq>> = Vec::with_capacity(n);
    let mut joins = Vec::with_capacity(n);
    for i in 0..n {
        let (tx, rx): (Sender<ShardReq>, Receiver<ShardReq>) = channel();
        let shard_dir = dir.map(|d| d.to_path_buf());
        let join = std::thread::Builder::new()
            .name(format!("chimbuko-provdb-{i}"))
            .spawn(move || run_shard(shard_dir, retention, rx))
            .context("spawning provdb shard")?;
        shard_txs.push(tx);
        joins.push(join);
    }
    let store = ProvStore {
        shards: shard_txs.clone(),
        placement: crate::placement::Placement::new(n),
        seq: Arc::new(AtomicU64::new(0)),
        meta: Arc::new(RwLock::new(None)),
        meta_bytes: Arc::new(AtomicU64::new(0)),
        dir: dir.map(|d| d.to_path_buf()),
    };
    // Recover an existing data directory: restarting a provdb-server on
    // its dir must see (and never clobber) the previous run's records.
    if let Some(d) = dir {
        recover_logs(d, &store)
            .with_context(|| format!("recovering provdb logs in {}", d.display()))?;
    }
    Ok((store, ProvStoreHandle { shards: shard_txs, joins }))
}

/// Replay an existing data directory into the shards (without
/// re-appending to the log) and reload stored run metadata. Replay order
/// matches [`ProvDb::load`](crate::provenance::ProvDb::load): files in
/// path order, lines in file order.
fn recover_logs(dir: &Path, store: &ProvStore) -> Result<()> {
    use std::io::BufRead;
    if let Ok(text) = std::fs::read_to_string(dir.join("metadata.json")) {
        let meta = crate::util::json::parse(&text).context("parsing provdb metadata.json")?;
        store.meta_bytes.store(text.len() as u64, Ordering::Relaxed);
        *store.meta.write().expect("provdb metadata lock") = Some(meta);
    }
    let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("reading provdb dir {}", dir.display()))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .map(|n| n.starts_with("prov_") && n.ends_with(".jsonl"))
                .unwrap_or(false)
        })
        .collect();
    paths.sort();
    let mut records = Vec::new();
    for path in paths {
        let f = File::open(&path).with_context(|| format!("opening {}", path.display()))?;
        for line in std::io::BufReader::new(f).lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            records.push(
                ProvRecord::from_jsonl_line(&line)
                    .with_context(|| format!("parsing record in {}", path.display()))?,
            );
        }
    }
    store.route(records, false);
    Ok(())
}

/// One retained record with its global sequence stamp and serialized size.
struct Entry {
    seq: u64,
    bytes: u64,
    rec: ProvRecord,
}

/// One `(app, rank)` partition of a shard.
#[derive(Default)]
struct Partition {
    /// Arrival-ordered retained records.
    entries: Vec<Entry>,
    /// Evicted since the last log compaction.
    dirty: bool,
}

/// Shard worker state: the `prov_shard_of == i` partitions plus their
/// slice of the append log.
struct ShardState {
    dir: Option<PathBuf>,
    retention: Retention,
    parts: HashMap<(u32, u32), Partition>,
    writers: HashMap<(u32, u32), BufWriter<File>>,
    log_bytes: u64,
    resident_bytes: u64,
    anomalies: u64,
    evicted: u64,
}

fn log_path(dir: &Path, key: (u32, u32)) -> PathBuf {
    dir.join(format!("prov_app{}_rank{}.jsonl", key.0, key.1))
}

/// Batch-eviction trigger: let a partition overshoot its bound by this
/// slack before paying one O(n log n) eviction sweep, so retention costs
/// amortized O(log n) per insert instead of an O(n) victim scan each.
/// Flush always evicts down to the exact bound.
fn retention_trigger(max: usize) -> usize {
    max.saturating_add((max / 4).max(64))
}

/// Evict down to `max` records: lowest score first, oldest on score ties
/// — high-score anomalies outlive their context. Returns
/// `(evicted, freed_bytes, freed_anomalies)`.
fn evict_partition(part: &mut Partition, max: usize) -> (u64, u64, u64) {
    if part.entries.len() <= max {
        return (0, 0, 0);
    }
    let k = part.entries.len() - max;
    let mut order: Vec<usize> = (0..part.entries.len()).collect();
    order.sort_by(|&a, &b| {
        part.entries[a]
            .rec
            .score
            .partial_cmp(&part.entries[b].rec.score)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(part.entries[a].seq.cmp(&part.entries[b].seq))
    });
    let drop: std::collections::HashSet<u64> =
        order[..k].iter().map(|&i| part.entries[i].seq).collect();
    let mut freed_bytes = 0u64;
    let mut freed_anoms = 0u64;
    part.entries.retain(|e| {
        if drop.contains(&e.seq) {
            freed_bytes += e.bytes;
            if e.rec.is_anomaly() {
                freed_anoms += 1;
            }
            false
        } else {
            true
        }
    });
    part.dirty = true;
    (k as u64, freed_bytes, freed_anoms)
}

impl ShardState {
    fn ingest(&mut self, batch: Vec<(u64, ProvRecord)>, log: bool) {
        let max_per_rank = self.retention.max_records_per_rank;
        let trigger = retention_trigger(max_per_rank);
        for (seq, rec) in batch {
            let mut line = String::with_capacity(360);
            rec.write_jsonl(&mut line);
            let nbytes = line.len() as u64 + 1;
            let key = (rec.app, rec.rank);
            if log {
                self.append_log(key, &line);
            }
            self.log_bytes += nbytes;
            self.resident_bytes += nbytes;
            if rec.is_anomaly() {
                self.anomalies += 1;
            }
            let part = self.parts.entry(key).or_default();
            part.entries.push(Entry { seq, bytes: nbytes, rec });
            if part.entries.len() > trigger {
                let (ev, fb, fa) = evict_partition(part, max_per_rank);
                self.evicted += ev;
                self.resident_bytes -= fb;
                self.anomalies -= fa;
            }
        }
    }

    /// Enforce the exact retention bound on every partition (the ingest
    /// path lets partitions overshoot by a slack between sweeps).
    fn enforce_retention(&mut self) {
        let max = self.retention.max_records_per_rank;
        if max == usize::MAX {
            return;
        }
        for part in self.parts.values_mut() {
            let (ev, fb, fa) = evict_partition(part, max);
            self.evicted += ev;
            self.resident_bytes -= fb;
            self.anomalies -= fa;
        }
    }

    fn append_log(&mut self, key: (u32, u32), line: &str) {
        let Some(dir) = &self.dir else {
            return;
        };
        let w = self.writers.entry(key).or_insert_with(|| {
            let path = log_path(dir, key);
            let f = File::options()
                .create(true)
                .append(true)
                .open(&path)
                .unwrap_or_else(|e| panic!("opening {}: {e}", path.display()));
            BufWriter::new(f)
        });
        w.write_all(line.as_bytes()).expect("provdb log write");
        w.write_all(b"\n").expect("provdb log write");
    }

    fn query(&self, q: &ProvQuery) -> Vec<(u64, ProvRecord)> {
        let mut out = Vec::new();
        let mut scan = |part: &Partition| {
            for e in &part.entries {
                if q.matches(&e.rec) {
                    out.push((e.seq, e.rec.clone()));
                }
            }
        };
        match q.rank {
            Some(key) => {
                if let Some(part) = self.parts.get(&key) {
                    scan(part);
                }
            }
            None => {
                for part in self.parts.values() {
                    scan(part);
                }
            }
        }
        out
    }

    /// Enforce retention exactly, flush writers, and rewrite the log of
    /// every partition that evicted records so `ProvDb::load(dir)` sees
    /// exactly the retained view.
    fn flush(&mut self) {
        self.enforce_retention();
        if let Some(dir) = self.dir.clone() {
            let dirty: Vec<(u32, u32)> = self
                .parts
                .iter()
                .filter(|(_, p)| p.dirty)
                .map(|(k, _)| *k)
                .collect();
            for key in dirty {
                self.writers.remove(&key);
                let part = self.parts.get_mut(&key).expect("dirty partition exists");
                let mut text = String::with_capacity(part.entries.len() * 360);
                for e in &part.entries {
                    e.rec.write_jsonl(&mut text);
                    text.push('\n');
                }
                std::fs::write(log_path(&dir, key), text).expect("provdb log compact");
                part.dirty = false;
            }
        }
        for w in self.writers.values_mut() {
            let _ = w.flush();
        }
    }

    fn stats(&self) -> ProvDbStats {
        ProvDbStats {
            records: self.parts.values().map(|p| p.entries.len() as u64).sum(),
            resident_bytes: self.resident_bytes,
            log_bytes: self.log_bytes,
            anomalies: self.anomalies,
            evicted: self.evicted,
        }
    }
}

fn run_shard(dir: Option<PathBuf>, retention: Retention, rx: Receiver<ShardReq>) {
    let mut shard = ShardState {
        dir,
        retention,
        parts: HashMap::new(),
        writers: HashMap::new(),
        log_bytes: 0,
        resident_bytes: 0,
        anomalies: 0,
        evicted: 0,
    };
    while let Ok(req) = rx.recv() {
        match req {
            ShardReq::Ingest { batch, log } => shard.ingest(batch, log),
            ShardReq::Query { q, reply } => {
                let _ = reply.send(shard.query(&q));
            }
            ShardReq::Flush { reply } => {
                shard.flush();
                let _ = reply.send(());
            }
            ShardReq::Stats { reply } => {
                let _ = reply.send(shard.stats());
            }
            ShardReq::Shutdown => break,
        }
    }
    shard.flush();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(app: u32, rank: u32, step: u64, score: f64, id: u64) -> ProvRecord {
        let entry = id * 100;
        ProvRecord {
            call_id: id,
            app,
            rank,
            thread: 0,
            fid: (id % 5) as u32,
            func: format!("F{}", id % 5),
            step,
            entry_us: entry,
            exit_us: entry + 50,
            inclusive_us: 50,
            exclusive_us: 30,
            depth: 0,
            parent: None,
            n_children: 0,
            n_messages: 0,
            msg_bytes: 0,
            label: if score >= 6.0 { "anomaly_high".into() } else { "normal".into() },
            score,
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("chimbuko-provdb-{tag}-{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    #[test]
    fn shard_routing_is_stable_and_in_range() {
        for n in [1usize, 2, 4, 7] {
            for app in 0..3u32 {
                for rank in 0..64u32 {
                    let s = prov_shard_of(app, rank, n);
                    assert!(s < n);
                    assert_eq!(s, prov_shard_of(app, rank, n));
                }
            }
        }
        assert_eq!(prov_shard_of(5, 1234, 1), 0);
    }

    #[test]
    fn ingest_query_across_shards() {
        let (store, handle) = spawn_store(None, 4, Retention::default()).unwrap();
        let mut recs = Vec::new();
        for rank in 0..8u32 {
            for i in 0..10u64 {
                recs.push(rec(0, rank, i / 4, (i % 7) as f64, rank as u64 * 100 + i));
            }
        }
        store.ingest(recs);
        store.flush();
        let all = store.query(&ProvQuery::default());
        assert_eq!(all.len(), 80);
        // entry-ordered with sequence tie-break.
        for w in all.windows(2) {
            assert!(w[0].entry_us <= w[1].entry_us);
        }
        let one_rank = store.query(&ProvQuery { rank: Some((0, 3)), ..Default::default() });
        assert_eq!(one_rank.len(), 10);
        assert!(one_rank.iter().all(|r| r.rank == 3));
        let stack = store.call_stack(0, 3, 0);
        assert_eq!(stack.len(), 4);
        let top = store.query(&ProvQuery {
            order_by_score: true,
            limit: Some(3),
            ..Default::default()
        });
        assert_eq!(top.len(), 3);
        assert!(top[0].score >= top[1].score && top[1].score >= top[2].score);
        let stats = store.stats();
        assert_eq!(stats.records, 80);
        assert_eq!(stats.evicted, 0);
        assert_eq!(stats.resident_bytes, stats.log_bytes);
        handle.join();
    }

    #[test]
    fn retention_evicts_lowest_scores_first() {
        let (store, handle) =
            spawn_store(None, 2, Retention { max_records_per_rank: 5 }).unwrap();
        // 20 records on one rank with distinct scores 0..19.
        let recs: Vec<ProvRecord> =
            (0..20u64).map(|i| rec(0, 1, i, i as f64, i)).collect();
        store.ingest(recs);
        store.flush();
        let kept = store.query(&ProvQuery { rank: Some((0, 1)), ..Default::default() });
        assert_eq!(kept.len(), 5);
        // The five highest scores survive.
        let mut scores: Vec<f64> = kept.iter().map(|r| r.score).collect();
        scores.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(scores, vec![15.0, 16.0, 17.0, 18.0, 19.0]);
        let stats = store.stats();
        assert_eq!(stats.records, 5);
        assert_eq!(stats.evicted, 15);
        assert!(stats.resident_bytes < stats.log_bytes);
        handle.join();
    }

    #[test]
    fn log_is_provdb_compatible_and_compacts() {
        use crate::provenance::ProvDb;
        let dir = tmpdir("log");
        let (store, handle) =
            spawn_store(Some(dir.as_path()), 2, Retention { max_records_per_rank: 3 }).unwrap();
        let recs: Vec<ProvRecord> =
            (0..9u64).map(|i| rec(0, 2, i, i as f64, i)).collect();
        store.ingest(recs);
        store
            .set_metadata(Json::obj(vec![("run_id", Json::str("provdb-test"))]))
            .unwrap();
        store.flush();
        // The compacted log reloads through the classic loader and holds
        // exactly the retained view.
        let db = ProvDb::load(&dir).unwrap();
        assert_eq!(db.len(), 3);
        let meta = ProvDb::load_metadata(&dir).unwrap();
        assert_eq!(meta.get("run_id").unwrap().as_str(), Some("provdb-test"));
        let retained = store.query(&ProvQuery::default());
        let reloaded = db.query(&ProvQuery::default());
        assert_eq!(retained.len(), reloaded.len());
        for (a, b) in retained.iter().zip(reloaded.iter()) {
            assert_eq!(&a, b);
        }
        handle.join();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn restart_recovers_existing_logs() {
        let dir = tmpdir("recover");
        {
            let (store, handle) =
                spawn_store(Some(dir.as_path()), 2, Retention::default()).unwrap();
            let recs: Vec<ProvRecord> =
                (0..6u64).map(|i| rec(0, 1, i, i as f64, i)).collect();
            store.ingest(recs);
            store
                .set_metadata(Json::obj(vec![("run_id", Json::str("r1"))]))
                .unwrap();
            store.flush();
            handle.join();
        }
        // Restart on the same dir (different shard count): the previous
        // run's records and metadata are queryable, not clobbered.
        let (store, handle) =
            spawn_store(Some(dir.as_path()), 4, Retention::default()).unwrap();
        assert_eq!(store.query(&ProvQuery::default()).len(), 6);
        assert_eq!(
            store.metadata().unwrap().get("run_id").unwrap().as_str(),
            Some("r1")
        );
        let before = store.stats();
        assert_eq!(before.records, 6);
        assert!(before.log_bytes > 0);
        // New ingest appends; old data survives flush + reload.
        store.ingest(vec![rec(0, 1, 9, 99.0, 100)]);
        store.flush();
        assert_eq!(store.stats().records, 7);
        let db = crate::provenance::ProvDb::load(&dir).unwrap();
        assert_eq!(db.len(), 7);
        handle.join();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn metadata_roundtrip_and_empty_store() {
        let (store, handle) = spawn_store(None, 1, Retention::default()).unwrap();
        assert!(store.metadata().is_none());
        store
            .set_metadata(Json::obj(vec![("run_id", Json::str("m"))]))
            .unwrap();
        let m = store.metadata().unwrap();
        assert_eq!(m.get("run_id").unwrap().as_str(), Some("m"));
        assert!(store.query(&ProvQuery::default()).is_empty());
        assert!(store.call_stack(0, 0, 0).is_empty());
        assert_eq!(store.stats().records, 0);
        handle.join();
    }
}
