//! The **provenance database service** (paper §V) — a standalone,
//! queryable store for prescriptive-provenance records, decoupling the
//! provenance pillar from the analysis ranks the way the reference
//! implementation backs it with a distributed Sonata/Mochi document
//! database.
//!
//! ## Architecture
//!
//! ```text
//!  AD rank ──ProvClient──┐                       ┌─ shard 0 ─ partitions
//!  AD rank ──ProvClient──┤   ProvDbTcpServer     │            + log slice
//!      …                 ├──▶ (conn threads) ──▶ ├─ shard 1 ─ …
//!  viz server ─ProvClient┘        ProvStore      └─ shard N-1
//! ```
//!
//! * [`store`] — the sharded document store: records are partitioned by
//!   `(app, rank)` across per-shard worker threads; each shard holds its
//!   partitions in the *encoded* binary record form
//!   ([`provenance::codec`](crate::provenance::codec)) so query filters
//!   evaluate against fixed header offsets (predicate pushdown) and the
//!   append log is a compact `.provseg` segment log (CRC-tagged records;
//!   `RecordFormat::Jsonl` is the escape hatch keeping the classic
//!   [`ProvDb`](crate::provenance::ProvDb)-compatible JSONL layout), and
//!   applies the [`Retention`] policy (score-based eviction per
//!   partition — the paper's "reduction for human-level processing").
//! * [`net`] — the TCP protocol: hello handshake reporting the shard
//!   count + codec version, batched *binary* record writes with reused
//!   encode buffers (AD ranks never block per record and no `Json` tree
//!   is built anywhere on the ingest path), server-side queries covering
//!   every [`ProvQuery`](crate::provenance::ProvQuery) filter whose
//!   replies copy stored bytes verbatim, call-stack reconstruction,
//!   run-metadata storage/retrieval, stats, and a flush barrier. JSONL
//!   request kinds remain served for legacy/escape-hatch clients.
//!
//! With retention disabled, the service answers every query bit-identically
//! to a local `ProvDb` fed the same record stream, for any shard count —
//! `tests/provdb_service.rs` pins this down for N ∈ {1, 2, 4}, and pins
//! binary-logged vs JSONL-logged stores to identical answers across
//! flush + restart recovery.

pub mod net;
pub mod store;

pub use crate::provenance::RecordFormat;
pub use net::{ProbeInfo, ProvClient, ProvDbTcpServer, DEFAULT_BATCH};
pub use store::{
    prov_shard_of, spawn_store, spawn_store_fmt, ProvDbStats, ProvStore, ProvStoreHandle,
    Retention,
};
