//! The **provenance database service** (paper §V) — a standalone,
//! queryable store for prescriptive-provenance records, decoupling the
//! provenance pillar from the analysis ranks the way the reference
//! implementation backs it with a distributed Sonata/Mochi document
//! database.
//!
//! ## Architecture
//!
//! ```text
//!  AD rank ──ProvClient──┐                       ┌─ shard 0 ─ partitions
//!  AD rank ──ProvClient──┤   ProvDbTcpServer     │            + log slice
//!      …                 ├──▶ (conn threads) ──▶ ├─ shard 1 ─ …
//!  viz server ─ProvClient┘        ProvStore      └─ shard N-1
//! ```
//!
//! * [`store`] — the sharded document store: records are partitioned by
//!   `(app, rank)` across per-shard worker threads; each shard owns its
//!   partitions' in-memory index, its slice of the JSONL append log
//!   (byte-compatible with [`ProvDb`](crate::provenance::ProvDb)'s
//!   layout), and applies the [`Retention`] policy (score-based eviction
//!   per partition — the paper's "reduction for human-level processing").
//! * [`net`] — the TCP protocol: hello handshake reporting the shard
//!   count, batched record writes (AD ranks never block per record),
//!   server-side queries covering every
//!   [`ProvQuery`](crate::provenance::ProvQuery) filter, call-stack
//!   reconstruction, run-metadata storage/retrieval, stats, and a flush
//!   barrier.
//!
//! With retention disabled, the service answers every query bit-identically
//! to a local `ProvDb` fed the same record stream, for any shard count —
//! `tests/provdb_service.rs` pins this down for N ∈ {1, 2, 4}.

pub mod net;
pub mod store;

pub use net::{ProvClient, ProvDbTcpServer, DEFAULT_BATCH};
pub use store::{prov_shard_of, spawn_store, ProvDbStats, ProvStore, ProvStoreHandle, Retention};
