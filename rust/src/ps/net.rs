//! TCP transport for the parameter server — the cross-process deployment
//! shape of the paper's architecture (on-node AD modules on compute nodes,
//! one PS instance reachable over the interconnect; the reference
//! implementation used ZeroMQ).
//!
//! Wire protocol (v2, shard-aware): length-prefixed binary messages,
//! little-endian. A client first sends a `hello` to learn the server's
//! shard count, then groups every sync delta by [`shard_of`](super::shard_of)
//! so the server can forward each group to its shard without
//! re-partitioning — the wire carries the same batched, hash-routed shape
//! the in-proc router uses. The server re-checks each entry's hash (the
//! wire is a trust boundary) and drops the connection on a misgrouped
//! frame.
//!
//! ```text
//! request  := u32 len, u8 kind, payload
//!   kind 1 (sync):   app u32, rank u32, n_groups u32,
//!                    n_groups × (shard u32, n_entries u32,
//!                                n_entries × (fid u32, n u64, mean f64,
//!                                             m2 f64, min f64, max f64))
//!   kind 2 (report): app u32, rank u32, step u64, execs u64, anoms u64,
//!                    ts_lo u64, ts_hi u64
//!   kind 3 (hello):  (empty)
//! reply (sync)  := u32 len, n_entries u32, entries (as above),
//!                  n_events u32, n_events × (step u64, total u64,
//!                                            score f64)
//! reply (hello) := u32 len, n_shards u32
//! ```
//!
//! The server thread wraps a [`PsClient`] (so in-proc and TCP clients
//! share the same sharded server state); [`NetPsClient`] mirrors the
//! [`PsClient`] API over a socket.

use super::{shard_of, GlobalEvent, PsClient, StepStat};
use crate::stats::{RunStats, StatsTable};
use crate::util::wire::{read_msg, write_msg, Cursor};
use anyhow::{bail, Context, Result};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

const KIND_SYNC: u8 = 1;
const KIND_REPORT: u8 = 2;
const KIND_HELLO: u8 = 3;

fn put_stats(buf: &mut Vec<u8>, fid: u32, st: &RunStats) {
    buf.extend_from_slice(&fid.to_le_bytes());
    buf.extend_from_slice(&st.count().to_le_bytes());
    buf.extend_from_slice(&st.mean().to_le_bytes());
    buf.extend_from_slice(&st.m2().to_le_bytes());
    buf.extend_from_slice(&st.min().to_le_bytes());
    buf.extend_from_slice(&st.max().to_le_bytes());
}

fn read_stats(c: &mut Cursor) -> Result<(u32, RunStats)> {
    let fid = c.u32()?;
    let n = c.u64()?;
    let mean = c.f64()?;
    let m2 = c.f64()?;
    let min = c.f64()?;
    let max = c.f64()?;
    Ok((fid, RunStats::from_raw(n, mean, m2, min, max)))
}

/// TCP front-end for a parameter server; forwards to a [`PsClient`].
pub struct PsTcpServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl PsTcpServer {
    /// Bind and serve; each connection is one AD module (thread per conn).
    pub fn start(addr: &str, client: PsClient) -> Result<PsTcpServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let join = std::thread::Builder::new()
            .name("chimbuko-ps-tcp".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let c = client.clone();
                            std::thread::spawn(move || {
                                let _ = serve_conn(stream, c);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(PsTcpServer { addr: local, stop, join: Some(join) })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for PsTcpServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_conn(mut stream: TcpStream, client: PsClient) -> Result<()> {
    loop {
        let Some(msg) = read_msg(&mut stream)? else {
            return Ok(()); // clean disconnect
        };
        let mut c = Cursor::new(&msg);
        let kind = c.u8()?;
        match kind {
            KIND_HELLO => {
                let reply = (client.shard_count() as u32).to_le_bytes();
                write_msg(&mut stream, &reply)?;
            }
            KIND_SYNC => {
                let app = c.u32()?;
                let rank = c.u32()?;
                let n_groups = c.u32()? as usize;
                let mut parts: Vec<Vec<(u32, RunStats)>> =
                    vec![Vec::new(); client.shard_count()];
                for _ in 0..n_groups {
                    let shard = c.u32()? as usize;
                    let n = c.u32()? as usize;
                    if shard >= parts.len() {
                        bail!("shard id {shard} out of range (server has {})", parts.len());
                    }
                    for _ in 0..n {
                        let entry = read_stats(&mut c)?;
                        // The wire is a trust boundary: a misgrouped entry
                        // would silently fragment the global view across
                        // shards, so re-check the hash (cheap) and bail.
                        let want = shard_of(app, entry.0, parts.len());
                        if want != shard {
                            bail!(
                                "entry (app {app}, fid {}) grouped to shard {shard}, \
                                 shard_of says {want}",
                                entry.0
                            );
                        }
                        parts[shard].push(entry);
                    }
                }
                let (global, events) = client.sync_parts(app, rank, parts);
                let entries: Vec<(u32, &RunStats)> = global.iter().collect();
                let mut reply = Vec::with_capacity(8 + 44 * entries.len());
                reply.extend_from_slice(&(entries.len() as u32).to_le_bytes());
                for (fid, st) in entries {
                    put_stats(&mut reply, fid, st);
                }
                reply.extend_from_slice(&(events.len() as u32).to_le_bytes());
                for ev in events {
                    reply.extend_from_slice(&ev.step.to_le_bytes());
                    reply.extend_from_slice(&ev.total_anomalies.to_le_bytes());
                    reply.extend_from_slice(&ev.score.to_le_bytes());
                }
                write_msg(&mut stream, &reply)?;
            }
            KIND_REPORT => {
                let app = c.u32()?;
                let rank = c.u32()?;
                let step = c.u64()?;
                let execs = c.u64()?;
                let anoms = c.u64()?;
                let lo = c.u64()?;
                let hi = c.u64()?;
                client.report(StepStat {
                    app,
                    rank,
                    step,
                    n_executions: execs,
                    n_anomalies: anoms,
                    ts_range: (lo, hi),
                });
            }
            k => bail!("unknown request kind {k}"),
        }
    }
}

/// TCP client used by a remote AD module; same API shape as [`PsClient`].
pub struct NetPsClient {
    stream: TcpStream,
    /// Server shard count, learned from the hello handshake; sync deltas
    /// are grouped by `shard_of(app, fid, n_shards)` before hitting the
    /// wire.
    n_shards: usize,
}

impl NetPsClient {
    pub fn connect(addr: std::net::SocketAddr) -> Result<NetPsClient> {
        let mut stream = TcpStream::connect(addr).context("connecting to PS")?;
        stream.set_nodelay(true).ok();
        // Hello handshake: learn the server's shard count.
        write_msg(&mut stream, &[KIND_HELLO])?;
        let reply = read_msg(&mut stream)?.context("PS closed during hello")?;
        let mut c = Cursor::new(&reply);
        let n_shards = c.u32()? as usize;
        if n_shards == 0 {
            bail!("server reported zero shards");
        }
        Ok(NetPsClient { stream, n_shards })
    }

    /// Server shard count from the handshake.
    pub fn shard_count(&self) -> usize {
        self.n_shards
    }

    /// Stats exchange over the wire, grouped by destination shard.
    pub fn sync(
        &mut self,
        app: u32,
        rank: u32,
        delta: &StatsTable,
    ) -> Result<(StatsTable, Vec<GlobalEvent>)> {
        let mut parts: Vec<Vec<(u32, &RunStats)>> = vec![Vec::new(); self.n_shards];
        for (fid, st) in delta.iter() {
            parts[shard_of(app, fid, self.n_shards)].push((fid, st));
        }
        let n_entries: usize = parts.iter().map(|p| p.len()).sum();
        let n_groups = parts.iter().filter(|p| !p.is_empty()).count();
        let mut msg = Vec::with_capacity(16 + 8 * n_groups + 44 * n_entries);
        msg.push(KIND_SYNC);
        msg.extend_from_slice(&app.to_le_bytes());
        msg.extend_from_slice(&rank.to_le_bytes());
        msg.extend_from_slice(&(n_groups as u32).to_le_bytes());
        for (shard, part) in parts.iter().enumerate() {
            if part.is_empty() {
                continue;
            }
            msg.extend_from_slice(&(shard as u32).to_le_bytes());
            msg.extend_from_slice(&(part.len() as u32).to_le_bytes());
            for (fid, st) in part {
                put_stats(&mut msg, *fid, st);
            }
        }
        write_msg(&mut self.stream, &msg)?;
        let reply = read_msg(&mut self.stream)?.context("PS closed connection")?;
        let mut c = Cursor::new(&reply);
        let n = c.u32()? as usize;
        let mut global = StatsTable::new();
        for _ in 0..n {
            let (fid, st) = read_stats(&mut c)?;
            global.replace(fid, st);
        }
        let n_events = c.u32()? as usize;
        let mut events = Vec::with_capacity(n_events);
        for _ in 0..n_events {
            events.push(GlobalEvent {
                step: c.u64()?,
                total_anomalies: c.u64()?,
                score: c.f64()?,
            });
        }
        Ok((global, events))
    }

    /// Fire-and-forget anomaly accounting.
    pub fn report(&mut self, stat: &StepStat) -> Result<()> {
        let mut msg = Vec::with_capacity(64);
        msg.push(KIND_REPORT);
        msg.extend_from_slice(&stat.app.to_le_bytes());
        msg.extend_from_slice(&stat.rank.to_le_bytes());
        msg.extend_from_slice(&stat.step.to_le_bytes());
        msg.extend_from_slice(&stat.n_executions.to_le_bytes());
        msg.extend_from_slice(&stat.n_anomalies.to_le_bytes());
        msg.extend_from_slice(&stat.ts_range.0.to_le_bytes());
        msg.extend_from_slice(&stat.ts_range.1.to_le_bytes());
        write_msg(&mut self.stream, &msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn stats_of(values: &[f64]) -> StatsTable {
        let mut t = StatsTable::new();
        for &v in values {
            t.push(7, v);
        }
        t
    }

    #[test]
    fn tcp_sync_round_trip_matches_in_proc() {
        let (client, handle) = super::super::spawn(1, None, usize::MAX >> 1, 1);
        let mut srv = PsTcpServer::start("127.0.0.1:0", client.clone()).unwrap();

        let mut net = NetPsClient::connect(srv.addr()).unwrap();
        assert_eq!(net.shard_count(), 1);
        let (g1, ev1) = net.sync(0, 1, &stats_of(&[10.0, 20.0, 30.0])).unwrap();
        assert_eq!(g1.get(7).unwrap().count(), 3);
        assert!((g1.get(7).unwrap().mean() - 20.0).abs() < 1e-9);
        assert!(ev1.is_empty());

        // Second client (another "node") sees the merged view.
        let mut net2 = NetPsClient::connect(srv.addr()).unwrap();
        let (g2, _) = net2.sync(0, 2, &stats_of(&[40.0])).unwrap();
        assert_eq!(g2.get(7).unwrap().count(), 4);
        assert!((g2.get(7).unwrap().mean() - 25.0).abs() < 1e-9);

        // Reports flow through to rank summaries.
        net.report(&StepStat {
            app: 0,
            rank: 1,
            step: 0,
            n_executions: 50,
            n_anomalies: 2,
            ts_range: (0, 9),
        })
        .unwrap();
        // Report is async; give the PS thread a moment, then check.
        std::thread::sleep(std::time::Duration::from_millis(50));
        srv.stop();
        client.shutdown();
        let fin = handle.join();
        assert_eq!(fin.snapshot.total_anomalies, 2);
        assert_eq!(fin.snapshot.ranks.len(), 1);
    }

    #[test]
    fn sharded_server_over_tcp_reunites_stats() {
        // A 4-shard server behind TCP: the client groups by shard and the
        // reassembled reply covers every function it sent.
        let (client, handle) = super::super::spawn(4, None, usize::MAX >> 1, 1);
        let srv = PsTcpServer::start("127.0.0.1:0", client.clone()).unwrap();
        let mut net = NetPsClient::connect(srv.addr()).unwrap();
        assert_eq!(net.shard_count(), 4);
        let mut delta = StatsTable::new();
        for fid in 0..40u32 {
            delta.push(fid, fid as f64 + 1.0);
        }
        let (global, _) = net.sync(0, 0, &delta).unwrap();
        assert_eq!(global.len(), 40);
        for fid in 0..40u32 {
            assert_eq!(global.get(fid).unwrap().count(), 1);
        }
        // Second sync from another rank merges across shards.
        let mut net2 = NetPsClient::connect(srv.addr()).unwrap();
        let (global2, _) = net2.sync(0, 1, &delta).unwrap();
        assert_eq!(global2.get(3).unwrap().count(), 2);
        drop(srv);
        client.shutdown();
        let fin = handle.join();
        assert_eq!(fin.global_len(), 40);
    }

    #[test]
    fn many_concurrent_tcp_clients() {
        let (client, handle) = super::super::spawn(2, None, usize::MAX >> 1, 1);
        let srv = PsTcpServer::start("127.0.0.1:0", client.clone()).unwrap();
        let addr = srv.addr();
        let mut joins = Vec::new();
        for rank in 0..8u32 {
            joins.push(std::thread::spawn(move || {
                let mut net = NetPsClient::connect(addr).unwrap();
                for i in 0..20u64 {
                    let mut t = StatsTable::new();
                    t.push(1, i as f64 + rank as f64);
                    net.sync(0, rank, &t).unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        drop(srv);
        client.shutdown();
        let fin = handle.join();
        assert_eq!(fin.global_stats(0, 1).unwrap().count(), 160);
    }

    #[test]
    fn misgrouped_sync_frame_is_rejected() {
        // A frame whose shard id is in range but does not match
        // shard_of must be refused, not silently fragment the view.
        let (client, handle) = super::super::spawn(4, None, usize::MAX >> 1, 1);
        let srv = PsTcpServer::start("127.0.0.1:0", client.clone()).unwrap();
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        let fid = (0..64u32).find(|&f| shard_of(0, f, 4) != 0).unwrap();
        let mut st = RunStats::new();
        st.push(1.0);
        let mut msg = vec![KIND_SYNC];
        msg.extend_from_slice(&0u32.to_le_bytes()); // app
        msg.extend_from_slice(&0u32.to_le_bytes()); // rank
        msg.extend_from_slice(&1u32.to_le_bytes()); // n_groups
        msg.extend_from_slice(&0u32.to_le_bytes()); // wrong shard id
        msg.extend_from_slice(&1u32.to_le_bytes()); // n_entries
        put_stats(&mut msg, fid, &st);
        write_msg(&mut s, &msg).unwrap();
        // Server bails on the entry: no reply, connection closed.
        assert!(read_msg(&mut s).unwrap().is_none());
        drop(srv);
        client.shutdown();
        let fin = handle.join();
        assert_eq!(fin.global_len(), 0, "misgrouped entry must not be merged");
    }

    #[test]
    fn malformed_frame_drops_connection_not_server() {
        let (client, handle) = super::super::spawn(2, None, usize::MAX >> 1, 1);
        let srv = PsTcpServer::start("127.0.0.1:0", client.clone()).unwrap();
        // Send junk.
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        s.write_all(&5u32.to_le_bytes()).unwrap();
        s.write_all(&[0xFF; 5]).unwrap();
        s.flush().unwrap();
        drop(s);
        // Server still serves a good client afterwards.
        let mut net = NetPsClient::connect(srv.addr()).unwrap();
        let (g, _) = net.sync(0, 0, &stats_of(&[1.0])).unwrap();
        assert_eq!(g.get(7).unwrap().count(), 1);
        drop(srv);
        client.shutdown();
        handle.join();
    }
}
