//! TCP transport for the parameter server — the cross-process deployment
//! shape of the paper's architecture (on-node AD modules on compute
//! nodes, PS instances spread across the machine; the reference
//! implementation used ZeroMQ).
//!
//! Wire protocol (v5, placement-aware + multiplexed): length-prefixed
//! binary frames, little-endian (shared framing in
//! [`util::wire`](crate::util::wire); poll-based reactor servers and
//! reconnecting/multiplexing clients in [`util::net`](crate::util::net)).
//! Every frame carries a **stream id** — a driver's conn-pool slots share
//! one socket and the server answers on the stream that asked — and an
//! overloaded server sheds requests with a `Busy` control frame instead
//! of queueing unboundedly (clients treat it as a failed call and back
//! off through their `Reconnector`). Two server roles:
//!
//! * **Front-end** ([`PsTcpServer`]) — owns hello/topology, the
//!   committed [`Placement`] table, the rank/step timeline (reports),
//!   global events and their per-rank delivery cursors, and the
//!   aggregate stats query. Its hello reply carries a shard→address map
//!   *and* the placement; when every address is empty the front-end
//!   itself routes grouped sync frames (the degenerate single-endpoint
//!   deployment).
//! * **Shard endpoint** ([`PsShardTcpServer`], the `ps-shard-server`
//!   subcommand) — serves exactly one stat shard: sync frames go
//!   straight to the owning shard's endpoint, replies piggyback the
//!   aggregator event version (kept fresh by version pushes from the
//!   front-end), the rebalancer drives the migrate/install handshake
//!   through it, and the merge stage fetches partial snapshots from it.
//!
//! ```text
//! placement := epoch u64, n_shards u32, n_slots u32, n_slots × u32
//!
//! front-end request := u32 len, u32 stream, u8 kind, payload
//!   kind 1 (sync):    app u32, rank u32, epoch u64, n_groups u32,
//!                     n_groups × (shard u32, n_entries u32, n_entries ×
//!                       (fid u32, n u64, mean f64, m2 f64, min f64, max f64))
//!   kind 2 (report):  app u32, rank u32, step u64, execs u64, anoms u64,
//!                     ts_lo u64, ts_hi u64                      (one-way)
//!   kind 3 (hello):   (empty)
//!   kind 4 (fetch):   app u32, rank u32
//!   kind 5 (stats):   (empty)
//!   kind 9 (placement): (empty)
//! reply (sync)  := status u8: 0 → n_entries u32, entries, n_events u32,
//!                  n_events × (step u64, total u64, score f64)
//!                  1 → placement                 (stale epoch: rerouted)
//! reply (hello) := n_shards u32, n_shards × str shard_addr ("" = here),
//!                  placement
//! reply (fetch) := version u64, n_events u32, events
//! reply (stats) := anoms u64, execs u64, ranks u32, version u64,
//!                  n_events u32, events
//! reply (placement) := placement
//!
//! shard request := u32 len, u32 stream, u8 kind, payload
//!   kind 3 (hello):      (empty)
//!   kind 6 (shard sync): app u32, epoch u64, n_entries u32, entries
//!   kind 7 (version):    version u64                           (one-way)
//!   kind 8 (snapshot):   (empty)
//!   kind 10 (migrate):   placement
//!   kind 11 (install):   n u32, n × (app u32, entry)
//!   kind 12 (slot loads): (empty)
//! reply (hello)      := shard_id u32, n_shards u32
//! reply (shard sync) := status u8: 0 → n_entries u32, entries, version u64
//!                       1 → epoch u64             (stale epoch: rerouted)
//! reply (snapshot)   := functions u64, syncs u64, merges u64, shard u32,
//!                       epoch u64, slots u32, shed u64, queue_depth u64
//! reply (migrate)    := n u32, n × (app u32, entry)
//! reply (install)    := ack u8 (= 1)
//! reply (slot loads) := shard u32, epoch u64, n u32, n × (slot u32, merges u64)
//! ```
//!
//! The snapshot's trailing `shed`/`queue_depth` come from the endpoint's
//! transport counters ([`NetStats`]), so overload is visible wherever
//! shard loads surface (`/api/ps_stats`). Replies answer on the request
//! frame's stream id; a shed request answers with a `Busy` control frame
//! on that stream instead.
//!
//! The wire is a trust boundary on both roles: the front-end re-checks
//! every grouped entry against the placement at the claimed epoch, a
//! shard endpoint's *shard thread* re-checks that every entry belongs to
//! it at the same epoch, and either drops the connection on a misgrouped
//! frame — a silent mis-merge would fragment the global view. A frame
//! from a *different* epoch is not a violation: it gets a `Rerouted`
//! reply and the client refreshes its table and resends.
//!
//! [`NetPsClient`] is a thin compatibility wrapper: since the router
//! refactor, [`PsClient`] itself speaks TCP (`PsClient::connect` learns
//! the topology from hello and dials per-shard connections, each wrapped
//! in a [`Reconnector`](crate::util::net::Reconnector) so dropped
//! connections heal instead of stranding the client).

use super::shard::{run_shard, AggConn, Route, ShardConn, ShardMsg, ShardReply, ShardSlotLoads};
use super::{FuncKey, GlobalEvent, PsClient, PsStats, StepStat};
use crate::placement::Placement;
use crate::stats::{RunStats, StatsTable};
use crate::util::net::{
    mux_slot, serve_frames, FrameHandler, FrameSink, MuxCore, MuxSlot, NetStats, ReactorOpts,
    Reconnector, TcpServerHandle,
};
use crate::util::wire::{put_str, read_msg, write_msg, Cursor};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex, RwLock};

const KIND_SYNC: u8 = 1;
const KIND_REPORT: u8 = 2;
const KIND_HELLO: u8 = 3;
const KIND_EVENT_FETCH: u8 = 4;
const KIND_PS_STATS: u8 = 5;
const KIND_SHARD_SYNC: u8 = 6;
const KIND_VERSION_PUSH: u8 = 7;
const KIND_SHARD_SNAPSHOT: u8 = 8;
const KIND_PLACEMENT: u8 = 9;
const KIND_MIGRATE: u8 = 10;
const KIND_INSTALL: u8 = 11;
const KIND_SLOT_LOADS: u8 = 12;
// 13–16 belong to the aggregation-tree wire (`crate::aggtree::net`).
/// Chaos-plane checkpoint: non-destructive full-state dump (the
/// restart-with-state supervisor snapshots shards through this).
const KIND_EXTRACT: u8 = 17;
// Kinds 13–16 (agg-node hello / report / fetch / flush) belong to the
// hierarchical aggregation tree — see [`crate::aggtree::net`].

/// Sync reply status bytes (both roles).
const STATUS_OK: u8 = 0;
const STATUS_REROUTED: u8 = 1;

pub(crate) fn put_stats(buf: &mut Vec<u8>, fid: u32, st: &RunStats) {
    buf.extend_from_slice(&fid.to_le_bytes());
    buf.extend_from_slice(&st.count().to_le_bytes());
    buf.extend_from_slice(&st.mean().to_le_bytes());
    buf.extend_from_slice(&st.m2().to_le_bytes());
    buf.extend_from_slice(&st.min().to_le_bytes());
    buf.extend_from_slice(&st.max().to_le_bytes());
}

pub(crate) fn read_stats(c: &mut Cursor) -> Result<(u32, RunStats)> {
    let fid = c.u32()?;
    let n = c.u64()?;
    let mean = c.f64()?;
    let m2 = c.f64()?;
    let min = c.f64()?;
    let max = c.f64()?;
    Ok((fid, RunStats::from_raw(n, mean, m2, min, max)))
}

fn put_events(buf: &mut Vec<u8>, events: &[GlobalEvent]) {
    buf.extend_from_slice(&(events.len() as u32).to_le_bytes());
    for ev in events {
        buf.extend_from_slice(&ev.step.to_le_bytes());
        buf.extend_from_slice(&ev.total_anomalies.to_le_bytes());
        buf.extend_from_slice(&ev.score.to_le_bytes());
    }
}

fn read_events(c: &mut Cursor) -> Result<Vec<GlobalEvent>> {
    let n = c.u32()? as usize;
    // Count is peer-supplied: cap the pre-allocation.
    let mut out = Vec::with_capacity(n.min(4096));
    for _ in 0..n {
        out.push(GlobalEvent {
            step: c.u64()?,
            total_anomalies: c.u64()?,
            score: c.f64()?,
        });
    }
    Ok(out)
}

/// `(app, fid) → RunStats` entry list, the migrate/install payload.
fn put_keyed_entries(buf: &mut Vec<u8>, entries: &[(FuncKey, RunStats)]) {
    buf.extend_from_slice(&(entries.len() as u32).to_le_bytes());
    for ((app, fid), st) in entries {
        buf.extend_from_slice(&app.to_le_bytes());
        put_stats(buf, *fid, st);
    }
}

fn read_keyed_entries(c: &mut Cursor) -> Result<Vec<(FuncKey, RunStats)>> {
    let n = c.u32()? as usize;
    let mut out = Vec::with_capacity(n.min(4096));
    for _ in 0..n {
        let app = c.u32()?;
        let (fid, st) = read_stats(c)?;
        out.push(((app, fid), st));
    }
    Ok(out)
}

/// TCP front-end for a parameter server; forwards to a [`PsClient`] and
/// owns the topology announced to connecting clients.
pub struct PsTcpServer {
    inner: TcpServerHandle,
}

impl PsTcpServer {
    /// Bind and serve with no per-shard endpoints: the degenerate
    /// single-endpoint topology (hello announces every shard as served
    /// here; clients ship grouped sync frames).
    pub fn start(addr: &str, client: PsClient) -> Result<PsTcpServer> {
        Self::start_with_topology(addr, client, Vec::new())
    }

    /// Bind and serve, announcing `shard_addrs[i]` as the endpoint of
    /// shard `i` (empty vec = all shards served here). Clients receiving
    /// a fully-populated map dial the shard endpoints directly and use
    /// this front-end only for reports, event fetches, placement
    /// refreshes, and stats.
    pub fn start_with_topology(
        addr: &str,
        client: PsClient,
        shard_addrs: Vec<String>,
    ) -> Result<PsTcpServer> {
        Self::start_with_opts(addr, client, shard_addrs, ReactorOpts::default())
    }

    /// [`Self::start_with_topology`] with explicit reactor sizing and
    /// backpressure bounds (`Config::net_opts`, or tests pinning tiny
    /// queue limits).
    pub fn start_with_opts(
        addr: &str,
        client: PsClient,
        shard_addrs: Vec<String>,
        opts: ReactorOpts,
    ) -> Result<PsTcpServer> {
        let n = client.shard_count();
        let addrs = if shard_addrs.is_empty() {
            vec![String::new(); n]
        } else {
            anyhow::ensure!(
                shard_addrs.len() == n,
                "topology has {} endpoints but the server has {} shards",
                shard_addrs.len(),
                n
            );
            shard_addrs
        };
        let addrs = Arc::new(addrs);
        // The handler factory is shared across event loops; PsClient is
        // Send (not Sync — it holds mpsc senders), so clone it out from
        // under a mutex per connection.
        let client = Mutex::new(client);
        let inner = serve_frames("chimbuko-ps-tcp", addr, opts, NetStats::new(), move || {
            FrontHandler {
                client: client.lock().expect("ps tcp client lock").clone(),
                shard_addrs: addrs.clone(),
            }
        })?;
        Ok(PsTcpServer { inner })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.inner.addr()
    }

    /// Transport counters (accepted/shed/queue depth…) for this server.
    pub fn net_stats(&self) -> Arc<NetStats> {
        self.inner.stats().clone()
    }

    pub fn stop(&mut self) {
        self.inner.stop();
    }
}

/// Per-connection front-end protocol handler (runs on the reactor's
/// event-loop threads; replies answer on the request frame's stream).
struct FrontHandler {
    client: PsClient,
    shard_addrs: Arc<Vec<String>>,
}

impl FrameHandler for FrontHandler {
    fn on_frame(&mut self, stream: u32, payload: &[u8], out: &mut FrameSink) -> bool {
        // A malformed or trust-violating frame drops the connection.
        self.handle(stream, payload, out).is_ok()
    }
}

impl FrontHandler {
    fn handle(&mut self, stream: u32, msg: &[u8], out: &mut FrameSink) -> Result<()> {
        let mut c = Cursor::new(msg);
        let kind = c.u8()?;
        match kind {
            KIND_HELLO => {
                let placement = self.client.placement_snapshot();
                let mut reply = Vec::with_capacity(1048 + 24 * self.shard_addrs.len());
                reply.extend_from_slice(&(self.client.shard_count() as u32).to_le_bytes());
                for a in self.shard_addrs.iter() {
                    put_str(&mut reply, a);
                }
                placement.encode(&mut reply);
                out.send(stream, &reply);
            }
            KIND_SYNC => {
                let app = c.u32()?;
                let rank = c.u32()?;
                let epoch = c.u64()?;
                let placement = self.client.placement_snapshot();
                if epoch != placement.epoch() {
                    // Stale (or ahead-of-commit) client: hand it the
                    // committed table; it re-groups and resends. Nothing
                    // was merged.
                    let mut reply = Vec::with_capacity(1040);
                    reply.push(STATUS_REROUTED);
                    placement.encode(&mut reply);
                    out.send(stream, &reply);
                    return Ok(());
                }
                let n_groups = c.u32()? as usize;
                let mut entries: Vec<(u32, RunStats)> = Vec::new();
                for _ in 0..n_groups {
                    let shard = c.u32()? as usize;
                    let n = c.u32()? as usize;
                    if shard >= placement.n_shards() {
                        bail!(
                            "shard id {shard} out of range (server has {})",
                            placement.n_shards()
                        );
                    }
                    for _ in 0..n {
                        let entry = read_stats(&mut c)?;
                        // The wire is a trust boundary: a misgrouped entry
                        // at the *same* epoch would silently fragment the
                        // global view, so re-check the placement and bail.
                        let want = placement.shard_of(app, entry.0);
                        if want != shard {
                            bail!(
                                "entry (app {app}, fid {}) grouped to shard {shard}, \
                                 placement (epoch {epoch}) says {want}",
                                entry.0
                            );
                        }
                        entries.push(entry);
                    }
                }
                let (global, events) = self.client.sync_entries(app, rank, entries);
                let entries: Vec<(u32, &RunStats)> = global.iter().collect();
                let mut reply = Vec::with_capacity(9 + 44 * entries.len());
                reply.push(STATUS_OK);
                reply.extend_from_slice(&(entries.len() as u32).to_le_bytes());
                for (fid, st) in entries {
                    put_stats(&mut reply, fid, st);
                }
                put_events(&mut reply, &events);
                out.send(stream, &reply);
            }
            KIND_REPORT => {
                let app = c.u32()?;
                let rank = c.u32()?;
                let step = c.u64()?;
                let execs = c.u64()?;
                let anoms = c.u64()?;
                let lo = c.u64()?;
                let hi = c.u64()?;
                self.client.report(StepStat {
                    app,
                    rank,
                    step,
                    n_executions: execs,
                    n_anomalies: anoms,
                    ts_range: (lo, hi),
                });
            }
            KIND_EVENT_FETCH => {
                let app = c.u32()?;
                let rank = c.u32()?;
                let (version, events) = self.client.fetch_events(app, rank);
                let mut reply = Vec::with_capacity(16 + 24 * events.len());
                reply.extend_from_slice(&version.to_le_bytes());
                put_events(&mut reply, &events);
                out.send(stream, &reply);
            }
            KIND_PS_STATS => {
                let stats = self.client.stats().unwrap_or_default();
                let mut reply = Vec::with_capacity(40 + 24 * stats.global_events.len());
                reply.extend_from_slice(&stats.total_anomalies.to_le_bytes());
                reply.extend_from_slice(&stats.total_executions.to_le_bytes());
                reply.extend_from_slice(&stats.ranks.to_le_bytes());
                reply.extend_from_slice(&stats.event_version.to_le_bytes());
                put_events(&mut reply, &stats.global_events);
                out.send(stream, &reply);
            }
            KIND_PLACEMENT => {
                let mut reply = Vec::with_capacity(1040);
                self.client.placement_snapshot().encode(&mut reply);
                out.send(stream, &reply);
            }
            k => bail!("unknown request kind {k}"),
        }
        Ok(())
    }
}

/// A standalone shard thread's handle: the channel to stop it plus the
/// join handle returning its final partition.
type OwnedShard = (Sender<ShardMsg>, std::thread::JoinHandle<HashMap<FuncKey, RunStats>>);

/// TCP endpoint serving exactly one stat shard (the `ps-shard-server`
/// process, or a wrapper around one in-process shard for tests/benches).
pub struct PsShardTcpServer {
    inner: TcpServerHandle,
    shard_id: u32,
    /// Present when this server owns its shard thread (standalone mode):
    /// `stop` shuts the shard down too and returns nothing — the
    /// partition dies with the process, like the paper's PS instances.
    own_shard: Option<OwnedShard>,
}

impl PsShardTcpServer {
    /// Spawn a standalone shard (its own thread + version mirror) and
    /// serve it at `addr`. This is what `chimbuko ps-shard-server` runs.
    pub fn spawn_standalone(addr: &str, shard_id: u32, n_shards: u32) -> Result<PsShardTcpServer> {
        Self::spawn_standalone_with_opts(addr, shard_id, n_shards, ReactorOpts::default())
    }

    /// [`Self::spawn_standalone`] with explicit reactor sizing and
    /// backpressure bounds.
    pub fn spawn_standalone_with_opts(
        addr: &str,
        shard_id: u32,
        n_shards: u32,
        opts: ReactorOpts,
    ) -> Result<PsShardTcpServer> {
        anyhow::ensure!(n_shards > 0, "ps-shard-server needs --shards > 0");
        anyhow::ensure!(shard_id < n_shards, "shard id {shard_id} out of range (0..{n_shards})");
        let (tx, rx) = channel();
        let version = Arc::new(AtomicU64::new(0));
        let ver = version.clone();
        let join = std::thread::Builder::new()
            .name(format!("chimbuko-ps-shard-{shard_id}"))
            .spawn(move || run_shard(rx, shard_id, n_shards as usize, ver))
            .context("spawning standalone ps shard")?;
        let mut srv = Self::start_wrapping(addr, tx.clone(), shard_id, n_shards, version, opts)?;
        srv.own_shard = Some((tx, join));
        Ok(srv)
    }

    /// Serve an existing shard channel at `addr` (the shard's lifecycle
    /// stays with its owner — `PsHandle` for in-process constellations).
    pub(crate) fn start_wrapping(
        addr: &str,
        tx: Sender<ShardMsg>,
        shard_id: u32,
        n_shards: u32,
        version: Arc<AtomicU64>,
        opts: ReactorOpts,
    ) -> Result<PsShardTcpServer> {
        let tx = Mutex::new(tx);
        let stats = NetStats::new();
        let hstats = stats.clone();
        let inner = serve_frames(
            &format!("chimbuko-ps-shard-tcp-{shard_id}"),
            addr,
            opts,
            stats,
            move || ShardHandler {
                tx: tx.lock().expect("ps shard tx lock").clone(),
                shard_id,
                n_shards,
                version: version.clone(),
                stats: hstats.clone(),
            },
        )?;
        Ok(PsShardTcpServer { inner, shard_id, own_shard: None })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.inner.addr()
    }

    pub fn shard_id(&self) -> u32 {
        self.shard_id
    }

    /// Transport counters (accepted/shed/queue depth…) for this endpoint.
    pub fn net_stats(&self) -> Arc<NetStats> {
        self.inner.stats().clone()
    }

    /// Stop accepting; in standalone mode also stop the shard thread.
    pub fn stop(&mut self) {
        self.inner.stop();
        if let Some((tx, join)) = self.own_shard.take() {
            let _ = tx.send(ShardMsg::Shutdown);
            let _ = join.join();
        }
    }
}

impl Drop for PsShardTcpServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Per-connection shard-endpoint protocol handler. Holds the server's
/// own [`NetStats`] so snapshot replies can report shed/queue depth.
struct ShardHandler {
    tx: Sender<ShardMsg>,
    shard_id: u32,
    n_shards: u32,
    version: Arc<AtomicU64>,
    stats: Arc<NetStats>,
}

impl FrameHandler for ShardHandler {
    fn on_frame(&mut self, stream: u32, payload: &[u8], out: &mut FrameSink) -> bool {
        self.handle(stream, payload, out).is_ok()
    }
}

impl ShardHandler {
    fn handle(&mut self, stream: u32, msg: &[u8], out: &mut FrameSink) -> Result<()> {
        let mut c = Cursor::new(msg);
        let kind = c.u8()?;
        match kind {
            KIND_HELLO => {
                let mut reply = Vec::with_capacity(8);
                reply.extend_from_slice(&self.shard_id.to_le_bytes());
                reply.extend_from_slice(&self.n_shards.to_le_bytes());
                out.send(stream, &reply);
            }
            KIND_SHARD_SYNC => {
                let app = c.u32()?;
                let epoch = c.u64()?;
                let n = c.u32()? as usize;
                let mut delta = Vec::with_capacity(n.min(4096));
                for _ in 0..n {
                    delta.push(read_stats(&mut c)?);
                }
                // Ownership/epoch validation happens in the shard thread
                // (it owns the live placement): an entry this shard does
                // not own at the same epoch comes back `Refused` and we
                // drop the connection (trust boundary); a stale epoch
                // comes back `Rerouted` for the client to heal.
                let (rtx, rrx) = channel();
                self.tx
                    .send(ShardMsg::Sync { app, epoch, delta, reply: rtx })
                    .map_err(|_| anyhow::anyhow!("shard thread gone"))?;
                match rrx.recv().context("shard thread dropped reply")? {
                    ShardReply::Part(part) => {
                        let mut reply = Vec::with_capacity(13 + 44 * part.entries.len());
                        reply.push(STATUS_OK);
                        reply.extend_from_slice(&(part.entries.len() as u32).to_le_bytes());
                        for (fid, st) in &part.entries {
                            put_stats(&mut reply, *fid, st);
                        }
                        reply.extend_from_slice(&part.event_version.to_le_bytes());
                        out.send(stream, &reply);
                    }
                    ShardReply::Rerouted { epoch, .. } => {
                        let mut reply = Vec::with_capacity(9);
                        reply.push(STATUS_REROUTED);
                        reply.extend_from_slice(&epoch.to_le_bytes());
                        out.send(stream, &reply);
                    }
                    ShardReply::Refused => {
                        bail!("entry not owned by shard {} at epoch {epoch}", self.shard_id);
                    }
                }
            }
            KIND_VERSION_PUSH => {
                let v = c.u64()?;
                // Monotonic: a reordered stale push must not roll the
                // mirror back.
                self.version.fetch_max(v, Ordering::SeqCst);
            }
            KIND_SHARD_SNAPSHOT => {
                let (rtx, rrx) = channel();
                self.tx
                    .send(ShardMsg::Snapshot { reply: rtx })
                    .map_err(|_| anyhow::anyhow!("shard thread gone"))?;
                let snap = rrx.recv().context("shard thread dropped snapshot")?;
                let load = snap.shard_loads.first().copied().unwrap_or_default();
                let mut reply = Vec::with_capacity(60);
                reply.extend_from_slice(&snap.functions_tracked.to_le_bytes());
                reply.extend_from_slice(&load.syncs.to_le_bytes());
                reply.extend_from_slice(&load.merges.to_le_bytes());
                reply.extend_from_slice(&load.shard.to_le_bytes());
                reply.extend_from_slice(&snap.placement_epoch.to_le_bytes());
                reply.extend_from_slice(&load.slots.to_le_bytes());
                // Transport health rides along so shard loads carry it.
                reply.extend_from_slice(&self.stats.shed_count().to_le_bytes());
                reply.extend_from_slice(&self.stats.queue_depth().to_le_bytes());
                out.send(stream, &reply);
            }
            KIND_MIGRATE => {
                let placement = Placement::decode(&mut c)?;
                // Trust boundary: a table for a different topology would
                // silently reshape routing and hand this shard's state to
                // whoever asked — refuse and drop the connection.
                anyhow::ensure!(
                    placement.n_shards() == self.n_shards as usize,
                    "migrate placement covers {} shards, this endpoint serves shard \
                     {} of {}",
                    placement.n_shards(),
                    self.shard_id,
                    self.n_shards
                );
                let (rtx, rrx) = channel();
                self.tx
                    .send(ShardMsg::Migrate { placement, reply: rtx })
                    .map_err(|_| anyhow::anyhow!("shard thread gone"))?;
                let migrated = rrx.recv().context("shard thread dropped migrate reply")?;
                let mut reply = Vec::with_capacity(4 + 48 * migrated.len());
                put_keyed_entries(&mut reply, &migrated);
                out.send(stream, &reply);
            }
            KIND_INSTALL => {
                let entries = read_keyed_entries(&mut c)?;
                let (rtx, rrx) = channel();
                self.tx
                    .send(ShardMsg::Install { entries, reply: rtx })
                    .map_err(|_| anyhow::anyhow!("shard thread gone"))?;
                rrx.recv().context("shard thread dropped install ack")?;
                out.send(stream, &[1u8]);
            }
            KIND_EXTRACT => {
                let (rtx, rrx) = channel();
                self.tx
                    .send(ShardMsg::Extract { reply: rtx })
                    .map_err(|_| anyhow::anyhow!("shard thread gone"))?;
                let entries = rrx.recv().context("shard thread dropped extract reply")?;
                let mut reply = Vec::with_capacity(4 + 48 * entries.len());
                put_keyed_entries(&mut reply, &entries);
                out.send(stream, &reply);
            }
            KIND_SLOT_LOADS => {
                let (rtx, rrx) = channel();
                self.tx
                    .send(ShardMsg::SlotLoads { reply: rtx })
                    .map_err(|_| anyhow::anyhow!("shard thread gone"))?;
                let loads = rrx.recv().context("shard thread dropped slot loads")?;
                let mut reply = Vec::with_capacity(16 + 12 * loads.loads.len());
                reply.extend_from_slice(&loads.shard.to_le_bytes());
                reply.extend_from_slice(&loads.epoch.to_le_bytes());
                reply.extend_from_slice(&(loads.loads.len() as u32).to_le_bytes());
                for (slot, m) in &loads.loads {
                    reply.extend_from_slice(&slot.to_le_bytes());
                    reply.extend_from_slice(&m.to_le_bytes());
                }
                out.send(stream, &reply);
            }
            k => bail!("unknown shard request kind {k}"),
        }
        Ok(())
    }
}

/// A shard endpoint's reply to a sync frame.
pub(crate) enum ShardSyncResp {
    Ok { entries: Vec<(u32, RunStats)>, version: u64 },
    /// The frame's epoch does not match the shard's table; nothing was
    /// merged. A shard *ahead* of the frame means a commit is landing:
    /// refresh the placement (front-end `KIND_PLACEMENT`) and resend. A
    /// shard *behind* the frame missed a migration: drop its sub-frame
    /// (the rebalance cadence re-pushes the table).
    Rerouted { epoch: u64 },
}

/// Client side of one logical stream to a shard endpoint (used inside
/// the router's `ShardConn::Tcp` pools; verified against the expected
/// shard id at connect time so a mis-wired topology fails loudly).
///
/// A pool's slots share one socket: each slot is a `ShardWire` view onto
/// the endpoint's shared [`MuxCore`] with its own stream id, so slot k's
/// request/reply window never blocks slot j's. A dead socket fails every
/// slot; each slot's `Reconnector` redials through [`Self::connect`],
/// which revives the shared core once and reattaches the other slots to
/// it as they retry.
pub struct ShardWire {
    core: Arc<MuxCore>,
    stream: u32,
    shard_id: u32,
}

impl ShardWire {
    /// Attach stream `stream` to the endpoint's shared socket (dialing a
    /// fresh one if `slot` holds none, or a dead one), then hello on the
    /// stream to verify the peer's identity.
    pub(crate) fn connect(
        addr: &str,
        expect_id: u32,
        expect_n: u32,
        stream: u32,
        slot: &MuxSlot,
    ) -> Result<ShardWire> {
        let core = crate::util::net::mux_connect(slot, || {
            let s = TcpStream::connect(addr)
                .with_context(|| format!("connecting to ps shard {expect_id} at {addr}"))?;
            s.set_nodelay(true).ok();
            MuxCore::new(s)
        })?;
        let reply = core.call(stream, &[KIND_HELLO])?;
        let mut c = Cursor::new(&reply);
        let shard_id = c.u32()?;
        let n_shards = c.u32()?;
        if shard_id != expect_id || n_shards != expect_n {
            bail!(
                "shard endpoint {addr} is shard {shard_id}/{n_shards}, expected {expect_id}/{expect_n}"
            );
        }
        Ok(ShardWire { core, stream, shard_id })
    }

    /// Fresh single-stream connection (control paths and tests that talk
    /// to one endpoint directly, outside a pool).
    pub(crate) fn dial(addr: &str, expect_id: u32, expect_n: u32) -> Result<ShardWire> {
        Self::connect(addr, expect_id, expect_n, 0, &mux_slot())
    }

    fn call(&self, msg: &[u8]) -> Result<Vec<u8>> {
        self.core.call(self.stream, msg)
    }

    /// Write a shard-sync request stamped with the sender's placement
    /// epoch (the reply is read separately so the router can pipeline
    /// writes across endpoints before reading).
    pub(crate) fn send_sync(
        &mut self,
        app: u32,
        epoch: u64,
        entries: &[(u32, RunStats)],
    ) -> Result<()> {
        let mut msg = Vec::with_capacity(20 + 44 * entries.len());
        msg.push(KIND_SHARD_SYNC);
        msg.extend_from_slice(&app.to_le_bytes());
        msg.extend_from_slice(&epoch.to_le_bytes());
        msg.extend_from_slice(&(entries.len() as u32).to_le_bytes());
        for (fid, st) in entries {
            put_stats(&mut msg, *fid, st);
        }
        self.core.send(self.stream, &msg)
    }

    /// Read the reply to the last [`send_sync`](Self::send_sync).
    pub(crate) fn recv_sync(&mut self) -> Result<ShardSyncResp> {
        let reply = self.core.recv(self.stream)?;
        let mut c = Cursor::new(&reply);
        match c.u8()? {
            STATUS_OK => {
                let n = c.u32()? as usize;
                let mut entries = Vec::with_capacity(n.min(4096));
                for _ in 0..n {
                    entries.push(read_stats(&mut c)?);
                }
                let version = c.u64()?;
                Ok(ShardSyncResp::Ok { entries, version })
            }
            STATUS_REROUTED => Ok(ShardSyncResp::Rerouted { epoch: c.u64()? }),
            s => bail!("unknown shard sync status {s}"),
        }
    }

    /// Fetch this shard's partial snapshot (function count + load +
    /// transport health).
    pub(crate) fn snapshot(&mut self) -> Result<super::VizSnapshot> {
        let reply = self.call(&[KIND_SHARD_SNAPSHOT])?;
        let mut c = Cursor::new(&reply);
        let functions = c.u64()?;
        let syncs = c.u64()?;
        let merges = c.u64()?;
        let shard = c.u32()?;
        let epoch = c.u64()?;
        let slots = c.u32()?;
        // Trailing transport counters: absent from pre-reactor peers.
        let shed = c.u64().unwrap_or(0);
        let queue_depth = c.u64().unwrap_or(0);
        Ok(super::VizSnapshot {
            functions_tracked: functions,
            placement_epoch: epoch,
            shard_loads: vec![super::ShardLoad {
                shard,
                syncs,
                merges,
                functions,
                slots,
                shed,
                queue_depth,
            }],
            ..super::VizSnapshot::default()
        })
    }

    /// Migration phase 1: hand the shard the successor table; it adopts
    /// it and returns the entries it no longer owns.
    pub(crate) fn migrate(&mut self, placement: &Placement) -> Result<Vec<(FuncKey, RunStats)>> {
        let mut msg = Vec::with_capacity(1040);
        msg.push(KIND_MIGRATE);
        placement.encode(&mut msg);
        let reply = self.call(&msg)?;
        read_keyed_entries(&mut Cursor::new(&reply))
    }

    /// Chaos-plane checkpoint: dump the shard's full keyed state without
    /// disturbing it (unlike [`Self::migrate`], which moves entries out).
    /// The restart supervisor snapshots through this at each sync step
    /// and re-seeds a respawned shard with [`Self::install`].
    pub(crate) fn extract(&mut self) -> Result<Vec<(FuncKey, RunStats)>> {
        let reply = self.call(&[KIND_EXTRACT])?;
        read_keyed_entries(&mut Cursor::new(&reply))
    }

    /// Migration phase 2: install migrated entries (opens the shard's
    /// pending slots; blocks until the shard acknowledges).
    pub(crate) fn install(&mut self, entries: &[(FuncKey, RunStats)]) -> Result<()> {
        let mut msg = Vec::with_capacity(5 + 48 * entries.len());
        msg.push(KIND_INSTALL);
        put_keyed_entries(&mut msg, entries);
        let reply = self.call(&msg)?;
        let mut c = Cursor::new(&reply);
        anyhow::ensure!(c.u8()? == 1, "install not acknowledged");
        Ok(())
    }

    /// Cumulative per-slot merge counters (the rebalancer's skew signal).
    pub(crate) fn slot_loads(&mut self) -> Result<ShardSlotLoads> {
        let reply = self.call(&[KIND_SLOT_LOADS])?;
        let mut c = Cursor::new(&reply);
        let shard = c.u32()?;
        let epoch = c.u64()?;
        let n = c.u32()? as usize;
        let mut loads = Vec::with_capacity(n.min(4096));
        for _ in 0..n {
            loads.push((c.u32()?, c.u64()?));
        }
        Ok(ShardSlotLoads { shard, epoch, loads })
    }

    /// Push a new aggregator event version (one-way; the front-end calls
    /// this when a global event is flagged).
    pub(crate) fn push_version(&mut self, version: u64) -> Result<()> {
        let mut msg = Vec::with_capacity(9);
        msg.push(KIND_VERSION_PUSH);
        msg.extend_from_slice(&version.to_le_bytes());
        self.core.send(self.stream, &msg)
    }

    pub(crate) fn shard_id(&self) -> u32 {
        self.shard_id
    }
}

/// A front-end's reply to a grouped sync frame.
pub(crate) enum GroupedResp {
    Ok { entries: Vec<(u32, RunStats)>, events: Vec<GlobalEvent> },
    /// Stale epoch: the committed table rides along; re-group and resend.
    Rerouted(Placement),
}

/// Client side of one front-end connection (hello/topology + placement,
/// reports, gated event fetches, grouped degenerate syncs, stats).
/// Single logical stream: the front-end window is request/reply, so it
/// stays on the plain stream-0 [`write_msg`]/[`read_msg`] path.
pub struct AggWire {
    stream: TcpStream,
    n_shards: usize,
    shard_addrs: Vec<String>,
    placement: Placement,
}

impl AggWire {
    pub(crate) fn connect(addr: &str) -> Result<AggWire> {
        let mut stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to PS front-end {addr}"))?;
        stream.set_nodelay(true).ok();
        write_msg(&mut stream, &[KIND_HELLO])?;
        let reply = read_msg(&mut stream)?.context("PS closed during hello")?;
        let mut c = Cursor::new(&reply);
        let n_shards = c.u32()? as usize;
        if n_shards == 0 {
            bail!("server reported zero shards");
        }
        let mut shard_addrs = Vec::with_capacity(n_shards.min(4096));
        for _ in 0..n_shards {
            shard_addrs.push(c.str()?);
        }
        let placement = Placement::decode(&mut c)?;
        if placement.n_shards() != n_shards {
            bail!(
                "hello placement covers {} shards, topology has {n_shards}",
                placement.n_shards()
            );
        }
        Ok(AggWire { stream, n_shards, shard_addrs, placement })
    }

    pub(crate) fn n_shards(&self) -> usize {
        self.n_shards
    }

    pub(crate) fn shard_addrs(&self) -> &[String] {
        &self.shard_addrs
    }

    /// The placement table announced in the hello.
    pub(crate) fn placement(&self) -> &Placement {
        &self.placement
    }

    /// Grouped sync through the front-end (degenerate topology): the
    /// server validates the grouping against the placement at `epoch`,
    /// routes, and gates the event fetch with its own in-process client.
    pub(crate) fn sync_grouped(
        &mut self,
        app: u32,
        rank: u32,
        epoch: u64,
        parts: &[Vec<(u32, RunStats)>],
    ) -> Result<GroupedResp> {
        let n_entries: usize = parts.iter().map(|p| p.len()).sum();
        let n_groups = parts.iter().filter(|p| !p.is_empty()).count();
        let mut msg = Vec::with_capacity(24 + 8 * n_groups + 44 * n_entries);
        msg.push(KIND_SYNC);
        msg.extend_from_slice(&app.to_le_bytes());
        msg.extend_from_slice(&rank.to_le_bytes());
        msg.extend_from_slice(&epoch.to_le_bytes());
        msg.extend_from_slice(&(n_groups as u32).to_le_bytes());
        for (shard, part) in parts.iter().enumerate() {
            if part.is_empty() {
                continue;
            }
            msg.extend_from_slice(&(shard as u32).to_le_bytes());
            msg.extend_from_slice(&(part.len() as u32).to_le_bytes());
            for (fid, st) in part {
                put_stats(&mut msg, *fid, st);
            }
        }
        write_msg(&mut self.stream, &msg)?;
        let reply = read_msg(&mut self.stream)?.context("PS closed connection")?;
        let mut c = Cursor::new(&reply);
        match c.u8()? {
            STATUS_OK => {
                let n = c.u32()? as usize;
                let mut entries = Vec::with_capacity(n.min(4096));
                for _ in 0..n {
                    entries.push(read_stats(&mut c)?);
                }
                let events = read_events(&mut c)?;
                Ok(GroupedResp::Ok { entries, events })
            }
            STATUS_REROUTED => Ok(GroupedResp::Rerouted(Placement::decode(&mut c)?)),
            s => bail!("unknown sync status {s}"),
        }
    }

    /// Fire-and-forget anomaly accounting (serializes ahead of any later
    /// event fetch on this connection — the ordering the gating protocol
    /// relies on).
    pub(crate) fn report(&mut self, stat: &StepStat) -> Result<()> {
        let mut msg = Vec::with_capacity(64);
        msg.push(KIND_REPORT);
        msg.extend_from_slice(&stat.app.to_le_bytes());
        msg.extend_from_slice(&stat.rank.to_le_bytes());
        msg.extend_from_slice(&stat.step.to_le_bytes());
        msg.extend_from_slice(&stat.n_executions.to_le_bytes());
        msg.extend_from_slice(&stat.n_anomalies.to_le_bytes());
        msg.extend_from_slice(&stat.ts_range.0.to_le_bytes());
        msg.extend_from_slice(&stat.ts_range.1.to_le_bytes());
        write_msg(&mut self.stream, &msg)
    }

    /// Event-fetch round-trip: undelivered global events for this rank
    /// plus the aggregator's current event version.
    pub(crate) fn fetch_events(&mut self, app: u32, rank: u32) -> Result<(u64, Vec<GlobalEvent>)> {
        let mut msg = Vec::with_capacity(9);
        msg.push(KIND_EVENT_FETCH);
        msg.extend_from_slice(&app.to_le_bytes());
        msg.extend_from_slice(&rank.to_le_bytes());
        write_msg(&mut self.stream, &msg)?;
        let reply = read_msg(&mut self.stream)?.context("PS closed on event fetch")?;
        let mut c = Cursor::new(&reply);
        let version = c.u64()?;
        let events = read_events(&mut c)?;
        Ok((version, events))
    }

    /// Fetch the committed placement table (the reroute-healing path).
    pub(crate) fn fetch_placement(&mut self) -> Result<Placement> {
        write_msg(&mut self.stream, &[KIND_PLACEMENT])?;
        let reply = read_msg(&mut self.stream)?.context("PS closed on placement fetch")?;
        Placement::decode(&mut Cursor::new(&reply))
    }

    /// Aggregate PS counters.
    pub(crate) fn ps_stats(&mut self) -> Result<PsStats> {
        write_msg(&mut self.stream, &[KIND_PS_STATS])?;
        let reply = read_msg(&mut self.stream)?.context("PS closed on stats")?;
        let mut c = Cursor::new(&reply);
        Ok(PsStats {
            total_anomalies: c.u64()?,
            total_executions: c.u64()?,
            ranks: c.u32()?,
            event_version: c.u64()?,
            global_events: read_events(&mut c)?,
        })
    }
}

impl PsClient {
    /// Connect to a PS front-end and build the routed client its hello
    /// topology describes: per-shard TCP connections when the map names
    /// endpoints, a single grouped-frame route when it does not (the
    /// degenerate deployment). Every connection auto-reconnects with
    /// backoff after drops. The hello's placement table seeds routing;
    /// `Rerouted` replies keep it fresh across live rebalances.
    pub fn connect(addr: &str) -> Result<PsClient> {
        Self::connect_with_pool(addr, 1)
    }

    /// [`Self::connect`] with `pool` logical streams per shard endpoint
    /// (syncs pick `rank % pool`, so ranks sharing one client do not
    /// serialize behind a single request/reply window per shard). The
    /// streams multiplex over **one socket per endpoint**.
    pub fn connect_with_pool(addr: &str, pool: usize) -> Result<PsClient> {
        let wire = AggWire::connect(addr)?;
        let n = wire.n_shards();
        let addrs = wire.shard_addrs().to_vec();
        let placement = Arc::new(RwLock::new(Arc::new(wire.placement().clone())));
        let pool = pool.max(1);
        let route = if addrs.iter().all(|a| a.is_empty()) {
            Route::Frontend { n_shards: n }
        } else {
            anyhow::ensure!(
                addrs.iter().all(|a| !a.is_empty()),
                "mixed PS topology unsupported: every shard needs its own endpoint"
            );
            let mut conns = Vec::with_capacity(n);
            for (i, a) in addrs.iter().enumerate() {
                let (id, total) = (i as u32, n as u32);
                // One shared socket per endpoint; each pool slot is a
                // stream view, and redials converge on the shared slot.
                let shared = mux_slot();
                let mut slots = Vec::with_capacity(pool);
                for k in 0..pool as u32 {
                    let slot = shared.clone();
                    let dial = move |x: &str| ShardWire::connect(x, id, total, k, &slot);
                    slots.push(Mutex::new(if k == 0 {
                        Reconnector::connected(a, dial)?
                    } else {
                        Reconnector::new(a, dial)
                    }));
                }
                conns.push(ShardConn::Tcp(slots));
            }
            Route::Sharded(Arc::new(conns))
        };
        let agg = AggConn::Tcp(Mutex::new(Reconnector::seeded(addr, AggWire::connect, wire)));
        Ok(PsClient {
            route,
            agg: Arc::new(agg),
            placement,
            sync_count: Arc::new(AtomicU64::new(0)),
            agg_fetches: Arc::new(AtomicU64::new(0)),
            reroutes: Arc::new(AtomicU64::new(0)),
            sync_lost: Arc::new(AtomicU64::new(0)),
            gates: Arc::new(Mutex::new(HashMap::new())),
        })
    }
}

/// TCP client used by a remote AD module — a thin compatibility wrapper
/// around the routed [`PsClient`] (kept for the `&mut self`/`Result` API
/// the earlier protocol exposed; new code can use `PsClient::connect`).
///
/// Error contract change from the pre-router protocol: `connect` still
/// fails fast, but `sync`/`report` no longer return `Err` on a dropped
/// connection — the router degrades (empty slice of the reply for the
/// unreachable peer, warning logged) and its [`Reconnector`] redials on
/// the next call, so one PS restart no longer kills the AD module.
pub struct NetPsClient {
    inner: PsClient,
}

impl NetPsClient {
    pub fn connect(addr: std::net::SocketAddr) -> Result<NetPsClient> {
        Ok(NetPsClient { inner: PsClient::connect(&addr.to_string())? })
    }

    /// Server shard count from the handshake.
    pub fn shard_count(&self) -> usize {
        self.inner.shard_count()
    }

    /// The underlying router (cloneable, shareable across threads).
    pub fn client(&self) -> PsClient {
        self.inner.clone()
    }

    /// Stats exchange over the wire, grouped by destination shard.
    pub fn sync(
        &mut self,
        app: u32,
        rank: u32,
        delta: &StatsTable,
    ) -> Result<(StatsTable, Vec<GlobalEvent>)> {
        Ok(self.inner.sync(app, rank, delta))
    }

    /// Fire-and-forget anomaly accounting.
    pub fn report(&mut self, stat: &StepStat) -> Result<()> {
        self.inner.report(stat.clone());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats_of(values: &[f64]) -> StatsTable {
        let mut t = StatsTable::new();
        for &v in values {
            t.push(7, v);
        }
        t
    }

    #[test]
    fn tcp_sync_round_trip_matches_in_proc() {
        let (client, handle) = super::super::spawn(1, None, usize::MAX >> 1, 1);
        let mut srv = PsTcpServer::start("127.0.0.1:0", client.clone()).unwrap();

        let mut net = NetPsClient::connect(srv.addr()).unwrap();
        assert_eq!(net.shard_count(), 1);
        let (g1, ev1) = net.sync(0, 1, &stats_of(&[10.0, 20.0, 30.0])).unwrap();
        assert_eq!(g1.get(7).unwrap().count(), 3);
        assert!((g1.get(7).unwrap().mean() - 20.0).abs() < 1e-9);
        assert!(ev1.is_empty());

        // Second client (another "node") sees the merged view.
        let mut net2 = NetPsClient::connect(srv.addr()).unwrap();
        let (g2, _) = net2.sync(0, 2, &stats_of(&[40.0])).unwrap();
        assert_eq!(g2.get(7).unwrap().count(), 4);
        assert!((g2.get(7).unwrap().mean() - 25.0).abs() < 1e-9);

        // Reports flow through to rank summaries.
        net.report(&StepStat {
            app: 0,
            rank: 1,
            step: 0,
            n_executions: 50,
            n_anomalies: 2,
            ts_range: (0, 9),
        })
        .unwrap();
        // Report is async; give the PS thread a moment, then check.
        std::thread::sleep(std::time::Duration::from_millis(50));
        srv.stop();
        client.shutdown();
        let fin = handle.join();
        assert_eq!(fin.snapshot.total_anomalies, 2);
        assert_eq!(fin.snapshot.ranks.len(), 1);
    }

    #[test]
    fn sharded_server_over_tcp_reunites_stats() {
        // A 4-shard server behind TCP: the client groups by shard and the
        // reassembled reply covers every function it sent.
        let (client, handle) = super::super::spawn(4, None, usize::MAX >> 1, 1);
        let srv = PsTcpServer::start("127.0.0.1:0", client.clone()).unwrap();
        let mut net = NetPsClient::connect(srv.addr()).unwrap();
        assert_eq!(net.shard_count(), 4);
        let mut delta = StatsTable::new();
        for fid in 0..40u32 {
            delta.push(fid, fid as f64 + 1.0);
        }
        let (global, _) = net.sync(0, 0, &delta).unwrap();
        assert_eq!(global.len(), 40);
        for fid in 0..40u32 {
            assert_eq!(global.get(fid).unwrap().count(), 1);
        }
        // Second sync from another rank merges across shards.
        let mut net2 = NetPsClient::connect(srv.addr()).unwrap();
        let (global2, _) = net2.sync(0, 1, &delta).unwrap();
        assert_eq!(global2.get(3).unwrap().count(), 2);
        drop(srv);
        client.shutdown();
        let fin = handle.join();
        assert_eq!(fin.global_len(), 40);
    }

    #[test]
    fn many_concurrent_tcp_clients() {
        let (client, handle) = super::super::spawn(2, None, usize::MAX >> 1, 1);
        let srv = PsTcpServer::start("127.0.0.1:0", client.clone()).unwrap();
        let addr = srv.addr();
        let mut joins = Vec::new();
        for rank in 0..8u32 {
            joins.push(std::thread::spawn(move || {
                let mut net = NetPsClient::connect(addr).unwrap();
                for i in 0..20u64 {
                    let mut t = StatsTable::new();
                    t.push(1, i as f64 + rank as f64);
                    net.sync(0, rank, &t).unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        drop(srv);
        client.shutdown();
        let fin = handle.join();
        assert_eq!(fin.global_stats(0, 1).unwrap().count(), 160);
    }

    #[test]
    fn misgrouped_sync_frame_is_rejected() {
        // A frame whose shard id is in range but does not match the
        // placement at the claimed (current) epoch must be refused, not
        // silently fragment the view.
        let (client, handle) = super::super::spawn(4, None, usize::MAX >> 1, 1);
        let srv = PsTcpServer::start("127.0.0.1:0", client.clone()).unwrap();
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        let fid = (0..64u32).find(|&f| super::super::shard_of(0, f, 4) != 0).unwrap();
        let mut st = RunStats::new();
        st.push(1.0);
        let mut msg = vec![KIND_SYNC];
        msg.extend_from_slice(&0u32.to_le_bytes()); // app
        msg.extend_from_slice(&0u32.to_le_bytes()); // rank
        msg.extend_from_slice(&0u64.to_le_bytes()); // epoch (current)
        msg.extend_from_slice(&1u32.to_le_bytes()); // n_groups
        msg.extend_from_slice(&0u32.to_le_bytes()); // wrong shard id
        msg.extend_from_slice(&1u32.to_le_bytes()); // n_entries
        put_stats(&mut msg, fid, &st);
        write_msg(&mut s, &msg).unwrap();
        // Server bails on the entry: no reply, connection closed.
        assert!(read_msg(&mut s).unwrap().is_none());
        drop(srv);
        client.shutdown();
        let fin = handle.join();
        assert_eq!(fin.global_len(), 0, "misgrouped entry must not be merged");
    }

    #[test]
    fn stale_epoch_sync_is_rerouted_with_placement() {
        // A frame from a stale epoch is *not* a violation: the reply is
        // a Rerouted status carrying the committed table.
        let (client, handle) = super::super::spawn(4, None, usize::MAX >> 1, 1);
        let srv = PsTcpServer::start("127.0.0.1:0", client.clone()).unwrap();
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        let mut msg = vec![KIND_SYNC];
        msg.extend_from_slice(&0u32.to_le_bytes()); // app
        msg.extend_from_slice(&0u32.to_le_bytes()); // rank
        msg.extend_from_slice(&99u64.to_le_bytes()); // bogus epoch
        msg.extend_from_slice(&0u32.to_le_bytes()); // n_groups
        write_msg(&mut s, &msg).unwrap();
        let reply = read_msg(&mut s).unwrap().expect("rerouted reply");
        let mut c = Cursor::new(&reply);
        assert_eq!(c.u8().unwrap(), STATUS_REROUTED);
        let p = Placement::decode(&mut c).unwrap();
        assert_eq!(p.epoch(), 0);
        assert_eq!(p.n_shards(), 4);
        drop(srv);
        client.shutdown();
        handle.join();
    }

    #[test]
    fn malformed_frame_drops_connection_not_server() {
        let (client, handle) = super::super::spawn(2, None, usize::MAX >> 1, 1);
        let srv = PsTcpServer::start("127.0.0.1:0", client.clone()).unwrap();
        // A well-framed message with a garbage request kind.
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        write_msg(&mut s, &[0xFF; 5]).unwrap();
        assert!(read_msg(&mut s).unwrap().is_none(), "junk drops the connection");
        drop(s);
        // Server still serves a good client afterwards.
        let mut net = NetPsClient::connect(srv.addr()).unwrap();
        let (g, _) = net.sync(0, 0, &stats_of(&[1.0])).unwrap();
        assert_eq!(g.get(7).unwrap().count(), 1);
        drop(srv);
        client.shutdown();
        handle.join();
    }

    #[test]
    fn shard_endpoints_serve_routed_clients() {
        // Full multi-endpoint topology in one process: 3 local shards,
        // each behind its own TCP endpoint, plus a front-end announcing
        // the map. The routed client dials the shards directly.
        let (client, handle) = super::super::spawn(3, None, usize::MAX >> 1, 1);
        let shard_srvs = handle.serve_shard_endpoints().unwrap();
        let addrs: Vec<String> = shard_srvs.iter().map(|s| s.addr().to_string()).collect();
        let front =
            PsTcpServer::start_with_topology("127.0.0.1:0", client.clone(), addrs).unwrap();
        let routed = PsClient::connect(&front.addr().to_string()).unwrap();
        assert_eq!(routed.shard_count(), 3);
        assert_eq!(routed.placement_epoch(), 0);
        let mut delta = StatsTable::new();
        for fid in 0..30u32 {
            delta.push(fid, fid as f64 + 1.0);
        }
        let (global, events) = routed.sync(0, 0, &delta);
        assert!(events.is_empty());
        assert_eq!(global.len(), 30, "reply must cover the delta across endpoints");
        for fid in 0..30u32 {
            assert_eq!(global.get(fid).unwrap().count(), 1);
        }
        // Sync-only load: the gated client never messaged the aggregator.
        assert_eq!(routed.agg_fetch_count(), 0);
        // Reports go through the front-end and reach the aggregator.
        routed.report(StepStat {
            app: 0,
            rank: 0,
            step: 0,
            n_executions: 9,
            n_anomalies: 1,
            ts_range: (0, 1),
        });
        let (global2, _) = routed.sync(0, 0, &delta);
        assert_eq!(global2.get(3).unwrap().count(), 2);
        assert_eq!(routed.agg_fetch_count(), 1, "report dirties the gate → one fetch");
        let stats = routed.stats().expect("wire stats");
        assert_eq!(stats.total_anomalies, 1);
        assert_eq!(stats.ranks, 1);
        drop(front);
        drop(shard_srvs);
        client.shutdown();
        let fin = handle.join();
        assert_eq!(fin.global_len(), 30);
        assert_eq!(fin.snapshot.total_anomalies, 1);
    }

    #[test]
    fn shard_endpoint_rejects_foreign_entries() {
        let (client, handle) = super::super::spawn(4, None, usize::MAX >> 1, 1);
        let shard_srvs = handle.serve_shard_endpoints().unwrap();
        // Hand a shard an entry it does not own (at the current epoch).
        let fid = (0..64u32).find(|&f| super::super::shard_of(0, f, 4) != 0).unwrap();
        let mut st = RunStats::new();
        st.push(1.0);
        let mut s = TcpStream::connect(shard_srvs[0].addr()).unwrap();
        let mut msg = vec![KIND_SHARD_SYNC];
        msg.extend_from_slice(&0u32.to_le_bytes()); // app
        msg.extend_from_slice(&0u64.to_le_bytes()); // epoch (current)
        msg.extend_from_slice(&1u32.to_le_bytes()); // n_entries
        put_stats(&mut msg, fid, &st);
        write_msg(&mut s, &msg).unwrap();
        assert!(read_msg(&mut s).unwrap().is_none(), "conn must drop, no reply");
        drop(shard_srvs);
        client.shutdown();
        let fin = handle.join();
        assert_eq!(fin.global_len(), 0, "foreign entry must not be merged");
    }

    #[test]
    fn wire_migration_moves_state_between_standalone_shards() {
        // Two standalone shard processes' worth of servers, migration
        // driven entirely over the wire: extract at the source, pending
        // bounce at the destination, install, then serve the moved
        // history at the new epoch.
        let s0 = PsShardTcpServer::spawn_standalone("127.0.0.1:0", 0, 2).unwrap();
        let s1 = PsShardTcpServer::spawn_standalone("127.0.0.1:0", 1, 2).unwrap();
        let mut w0 = ShardWire::dial(&s0.addr().to_string(), 0, 2).unwrap();
        let mut w1 = ShardWire::dial(&s1.addr().to_string(), 1, 2).unwrap();
        let fid = (0..256u32).find(|&f| super::super::shard_of(0, f, 2) == 0).unwrap();
        let mut st = RunStats::new();
        st.push(5.0);
        st.push(9.0);
        w0.send_sync(0, 0, &[(fid, st)]).unwrap();
        assert!(matches!(w0.recv_sync().unwrap(), ShardSyncResp::Ok { .. }));

        // Phase 1: both shards adopt the successor table.
        let slot = Placement::slot_of(0, fid);
        let new = Placement::new(2).with_moves(&[(slot, 1)]).unwrap();
        let out0 = w0.migrate(&new).unwrap();
        assert_eq!(out0.len(), 1, "source must extract the moved entry");
        assert_eq!(out0[0].0, (0, fid));
        assert_eq!(out0[0].1.count(), 2);
        assert!(w1.migrate(&new).unwrap().is_empty(), "destination extracts nothing");

        // Between migrate and install the gained slot is pending: a sync
        // at the new epoch bounces instead of merging out of order.
        let mut probe = RunStats::new();
        probe.push(1.0);
        w1.send_sync(0, new.epoch(), &[(fid, probe)]).unwrap();
        assert!(matches!(w1.recv_sync().unwrap(), ShardSyncResp::Rerouted { .. }));

        // Phase 2: install opens the slot with the migrated history.
        w1.install(&out0).unwrap();
        let mut more = RunStats::new();
        more.push(7.0);
        w1.send_sync(0, new.epoch(), &[(fid, more)]).unwrap();
        match w1.recv_sync().unwrap() {
            ShardSyncResp::Ok { entries, .. } => {
                assert_eq!(entries[0].1.count(), 3, "migrated history + new merge");
            }
            ShardSyncResp::Rerouted { .. } => panic!("installed slot must serve"),
        }

        // A stale-epoch frame at the source bounces (nothing merged)…
        let mut stale = RunStats::new();
        stale.push(2.0);
        w0.send_sync(0, 0, &[(fid, stale)]).unwrap();
        match w0.recv_sync().unwrap() {
            ShardSyncResp::Rerouted { epoch } => assert_eq!(epoch, 1),
            ShardSyncResp::Ok { .. } => panic!("stale epoch must bounce"),
        }
        // …and a same-epoch frame for a slot the source no longer owns is
        // a protocol violation: the connection drops.
        let mut foreign = RunStats::new();
        foreign.push(2.0);
        w0.send_sync(0, new.epoch(), &[(fid, foreign)]).unwrap();
        assert!(w0.recv_sync().is_err(), "foreign entry at same epoch must drop the conn");
    }

    #[test]
    fn extract_checkpoints_without_disturbing_the_shard() {
        let src = PsShardTcpServer::spawn_standalone("127.0.0.1:0", 0, 1).unwrap();
        let mut w = ShardWire::dial(&src.addr().to_string(), 0, 1).unwrap();
        let mut st = RunStats::new();
        st.push(5.0);
        st.push(9.0);
        w.send_sync(0, 0, &[(1, st), (2, st)]).unwrap();
        assert!(matches!(w.recv_sync().unwrap(), ShardSyncResp::Ok { .. }));
        // The dump is key-sorted and non-destructive: a second extract
        // sees the same state, and the shard keeps serving.
        let dump = w.extract().unwrap();
        assert_eq!(dump.len(), 2);
        assert_eq!((dump[0].0, dump[1].0), ((0, 1), (0, 2)));
        assert_eq!(dump[0].1.count(), 2);
        assert_eq!(w.extract().unwrap(), dump, "extract must not drain the table");
        // Restart-with-state: install the checkpoint into a fresh shard
        // and keep merging on top of the restored history.
        let fresh = PsShardTcpServer::spawn_standalone("127.0.0.1:0", 0, 1).unwrap();
        let mut wf = ShardWire::dial(&fresh.addr().to_string(), 0, 1).unwrap();
        wf.install(&dump).unwrap();
        let mut more = RunStats::new();
        more.push(1.0);
        wf.send_sync(0, 0, &[(1, more)]).unwrap();
        match wf.recv_sync().unwrap() {
            ShardSyncResp::Ok { entries, .. } => {
                assert_eq!(entries[0].1.count(), 3, "restored history + new merge")
            }
            ShardSyncResp::Rerouted { .. } => panic!("restored shard must serve"),
        }
    }

    #[test]
    fn standalone_shard_server_round_trip() {
        let srv = PsShardTcpServer::spawn_standalone("127.0.0.1:0", 0, 1).unwrap();
        let addr = srv.addr().to_string();
        let mut w = ShardWire::dial(&addr, 0, 1).unwrap();
        assert_eq!(w.shard_id(), 0);
        let mut st = RunStats::new();
        st.push(5.0);
        st.push(7.0);
        w.send_sync(0, 0, &[(1, st)]).unwrap();
        let (entries, ver) = match w.recv_sync().unwrap() {
            ShardSyncResp::Ok { entries, version } => (entries, version),
            ShardSyncResp::Rerouted { .. } => panic!("epoch 0 must be accepted"),
        };
        assert_eq!(ver, 0, "no version pushed yet");
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].1.count(), 2);
        // Version push is reflected in the next sync reply.
        w.push_version(3).unwrap();
        let mut st2 = RunStats::new();
        st2.push(1.0);
        w.send_sync(0, 0, &[(1, st2)]).unwrap();
        let (entries2, ver2) = match w.recv_sync().unwrap() {
            ShardSyncResp::Ok { entries, version } => (entries, version),
            ShardSyncResp::Rerouted { .. } => panic!("epoch 0 must be accepted"),
        };
        assert_eq!(entries2[0].1.count(), 3);
        assert_eq!(ver2, 3);
        // A stale-epoch frame bounces with Rerouted, merging nothing.
        let mut st3 = RunStats::new();
        st3.push(9.0);
        w.send_sync(0, 42, &[(1, st3)]).unwrap();
        match w.recv_sync().unwrap() {
            ShardSyncResp::Rerouted { epoch } => assert_eq!(epoch, 0),
            ShardSyncResp::Ok { .. } => panic!("stale epoch must bounce"),
        }
        // Snapshot carries the load counters (the bounced frame did not
        // count or merge) plus transport health (nothing shed here).
        let snap = w.snapshot().unwrap();
        assert_eq!(snap.functions_tracked, 1);
        assert_eq!(snap.placement_epoch, 0);
        assert_eq!(snap.shard_loads.len(), 1);
        assert_eq!(snap.shard_loads[0].syncs, 2);
        assert_eq!(snap.shard_loads[0].merges, 2);
        assert_eq!(snap.shard_loads[0].slots as usize, crate::placement::SLOTS);
        assert_eq!(snap.shard_loads[0].shed, 0);
        // Per-slot counters surface through the wire too.
        let loads = w.slot_loads().unwrap();
        assert_eq!(loads.shard, 0);
        assert_eq!(loads.loads.len(), 1, "one touched slot");
        assert_eq!(loads.loads[0].1, 2);
        // Mismatched hello expectations fail loudly.
        assert!(ShardWire::dial(&addr, 1, 2).is_err());
    }

    #[test]
    fn pool_slots_multiplex_one_socket_per_endpoint() {
        // A pooled routed client against real shard endpoints: the pool's
        // slots are streams over one socket per endpoint, and a pooled
        // sync still reunites the reply.
        let (client, handle) = super::super::spawn(2, None, usize::MAX >> 1, 4);
        let shard_srvs = handle.serve_shard_endpoints().unwrap();
        let addrs: Vec<String> = shard_srvs.iter().map(|s| s.addr().to_string()).collect();
        let front =
            PsTcpServer::start_with_topology("127.0.0.1:0", client.clone(), addrs).unwrap();
        let routed = PsClient::connect_with_pool(&front.addr().to_string(), 4).unwrap();
        let mut delta = StatsTable::new();
        for fid in 0..32u32 {
            delta.push(fid, fid as f64 + 1.0);
        }
        // Ranks land on different pool slots (rank % pool) but share the
        // endpoint sockets.
        let mut joins = Vec::new();
        for rank in 0..8u32 {
            let cl = routed.clone();
            let d = delta.clone();
            joins.push(std::thread::spawn(move || {
                let (global, _) = cl.sync(0, rank, &d);
                assert_eq!(global.len(), 32);
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        // One socket per endpoint despite 4 pool slots × 8 ranks.
        for s in &shard_srvs {
            assert_eq!(
                s.net_stats().accepted.load(Ordering::Relaxed),
                1,
                "pool slots must share the endpoint socket"
            );
        }
        let (global, _) = routed.sync(0, 0, &delta);
        assert_eq!(global.get(3).unwrap().count(), 9);
        drop(front);
        drop(shard_srvs);
        client.shutdown();
        let fin = handle.join();
        assert_eq!(fin.global_len(), 32);
    }

    #[test]
    fn flooded_shard_endpoint_sheds_but_still_serves() {
        // Tiny reply-backlog bound: a client that never drains replies
        // must trip admission control (Busy + shed counter) without
        // degrading a well-behaved client on the same endpoint.
        let opts = ReactorOpts::new(1, 32 * 1024, 1 << 30);
        let srv =
            PsShardTcpServer::spawn_standalone_with_opts("127.0.0.1:0", 0, 1, opts).unwrap();
        let addr = srv.addr().to_string();
        let mut flood = TcpStream::connect(&addr).unwrap();
        // Each frame's reply echoes ~2048 stat entries (~90 KiB); 256
        // frames ≈ 23 MiB of replies — far past what the kernel's socket
        // buffers can cushion for a reader that never reads.
        let mut st = RunStats::new();
        st.push(1.0);
        let mut msg = vec![KIND_SHARD_SYNC];
        msg.extend_from_slice(&0u32.to_le_bytes()); // app
        msg.extend_from_slice(&0u64.to_le_bytes()); // epoch (current)
        msg.extend_from_slice(&2048u32.to_le_bytes());
        for fid in 0..2048u32 {
            put_stats(&mut msg, fid, &st);
        }
        for _ in 0..256 {
            if write_msg(&mut flood, &msg).is_err() {
                break; // server may sever us under the hard bound — fine
            }
        }
        let stats = srv.net_stats();
        let t0 = std::time::Instant::now();
        while stats.shed_count() == 0 && t0.elapsed() < std::time::Duration::from_secs(10) {
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert!(stats.shed_count() > 0, "non-draining flood must be shed");
        // A well-behaved client still gets exact service.
        let mut w = ShardWire::dial(&addr, 0, 1).unwrap();
        let mut fresh = RunStats::new();
        fresh.push(5.0);
        w.send_sync(0, 0, &[(100_000, fresh)]).unwrap();
        match w.recv_sync().unwrap() {
            ShardSyncResp::Ok { entries, .. } => {
                let e = entries.iter().find(|(fid, _)| *fid == 100_000).expect("merged entry");
                assert_eq!(e.1.count(), 1);
            }
            ShardSyncResp::Rerouted { .. } => panic!("well-behaved sync must be served"),
        }
        // And the snapshot surfaces the shed count over the wire.
        let snap = w.snapshot().unwrap();
        assert!(snap.shard_loads[0].shed > 0, "snapshot must carry the shed counter");
        drop(flood);
    }
}
