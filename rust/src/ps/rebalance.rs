//! Skew-driven shard rebalancing: watch the per-slot merge counters the
//! stat shards keep, plan slot moves when one shard runs hot
//! ([`plan_moves`](crate::placement::plan_moves)), migrate the affected
//! `RunStats` state shard→shard, and commit the successor
//! [`Placement`] epoch.
//!
//! ## Migration handshake
//!
//! ```text
//! phase 1 (Migrate, every shard):  adopt table E+1, mark gained slots
//!                                  pending, extract entries no longer
//!                                  owned, return them
//! phase 2 (Install, gaining shards): adopt migrated entries, open the
//!                                  pending slots
//! commit:                          write table E+1 into the shared
//!                                  placement (clients now see it)
//! ```
//!
//! Between phase 1 and the commit, clients still sync under epoch E;
//! shards answer `Rerouted` and the client retries until the commit
//! lands (milliseconds). Because a shard accepts or rejects each
//! sub-frame *wholesale* and pending slots block early traffic to the
//! destination, every delta merges exactly once and a migrated summary
//! is adopted bit-for-bit — which is how a rebalance fired mid-run stays
//! bit-identical to the static-placement reference
//! (`tests/ps_shard.rs`).
//!
//! A shard connection that fails mid-migration degrades exactly like a
//! crashed shard elsewhere in the protocol: its slice of the state is
//! lost for the slots it owned, the commit still lands, and the warning
//! log names the shard.

use super::shard::{ShardConn, ShardMsg, ShardSlotLoads, SharedPlacement};
use super::FuncKey;
use crate::placement::{load_ratio, plan_moves, Placement, SLOTS};
use crate::stats::RunStats;
use std::collections::HashMap;
use std::sync::mpsc::channel;
use std::sync::Arc;

/// Default trigger ratio: rebalance when windowed per-shard merge load
/// has max/mean above this.
pub const DEFAULT_MAX_RATIO: f64 = 1.5;

/// What one committed rebalance did.
#[derive(Clone, Copy, Debug)]
pub struct RebalanceReport {
    /// The committed epoch.
    pub epoch: u64,
    /// Slot moves applied.
    pub moves: usize,
    /// Windowed per-shard max/mean before the moves.
    pub ratio_before: f64,
    /// The planner's projected max/mean after the moves (over the same
    /// window; the next window measures the real effect).
    pub ratio_planned: f64,
}

/// Gather every shard's cumulative per-slot merge counters.
pub(crate) fn collect_slot_loads(conns: &[ShardConn]) -> Vec<ShardSlotLoads> {
    let mut out = Vec::with_capacity(conns.len());
    for conn in conns {
        match conn {
            ShardConn::Local(tx) => {
                let (rtx, rrx) = channel();
                if tx.send(ShardMsg::SlotLoads { reply: rtx }).is_ok() {
                    if let Ok(l) = rrx.recv() {
                        out.push(l);
                    }
                }
            }
            ShardConn::Tcp(pool) => {
                match pool[0].lock().expect("ps shard conn lock").with(|w| w.slot_loads()) {
                    Ok(l) => out.push(l),
                    Err(e) => crate::log_warn!("ps", "slot-load fetch failed: {e:#}"),
                }
            }
        }
    }
    out
}

/// The rebalancer: owned by the constellation handle, shared with the
/// optional background cadence thread. Holds the last-seen counters so
/// every skew judgement is over a *window* (load since the previous
/// check), not the whole run's history.
pub(crate) struct Rebalancer {
    conns: Arc<Vec<ShardConn>>,
    placement: SharedPlacement,
    /// Cumulative counters at the last consumed window, per (shard, slot).
    last: HashMap<(u32, u32), u64>,
    max_ratio: f64,
    min_merges: u64,
}

impl Rebalancer {
    pub(crate) fn new(
        conns: Arc<Vec<ShardConn>>,
        placement: SharedPlacement,
        max_ratio: f64,
        min_merges: u64,
    ) -> Rebalancer {
        Rebalancer {
            conns,
            placement,
            last: HashMap::new(),
            // 1.0 is a legal (most aggressive) trigger; only below-1.0
            // values — including the unset 0.0 default — fall back.
            max_ratio: if max_ratio >= 1.0 { max_ratio } else { DEFAULT_MAX_RATIO },
            min_merges,
        }
    }

    /// One skew check: returns `Ok(None)` when the window is balanced,
    /// too small, or nothing movable would improve it; otherwise
    /// migrates, commits, and reports.
    pub(crate) fn run_once(&mut self) -> anyhow::Result<Option<RebalanceReport>> {
        let now = collect_slot_loads(&self.conns);
        let cur = self.placement.read().expect("ps placement lock").clone();
        // Staleness probe: a shard whose table is behind the committed
        // epoch missed a Migrate (transient failure); clients fast-fail
        // its sub-frames until it catches up, so re-push the committed
        // table. State it extracts lands back at the live owners —
        // commutatively merged, since exact ordering was already
        // forfeited when the shard went stale.
        if now.iter().any(|s| s.epoch < cur.epoch()) {
            crate::log_warn!(
                "ps",
                "shard(s) behind committed epoch {}; re-pushing the placement",
                cur.epoch()
            );
            self.run_handshake(&cur);
        }
        let mut window = vec![0u64; SLOTS];
        let mut total = 0u64;
        for s in &now {
            for &(slot, m) in &s.loads {
                let prev = self.last.get(&(s.shard, slot)).copied().unwrap_or(0);
                let d = m.saturating_sub(prev);
                window[slot as usize] += d;
                total += d;
            }
        }
        if total < self.min_merges.max(1) {
            // Too little traffic to judge; leave `last` untouched so the
            // window keeps accumulating.
            return Ok(None);
        }
        let mut per_shard = vec![0u64; cur.n_shards()];
        for (slot, &m) in window.iter().enumerate() {
            per_shard[cur.shard_of_slot(slot)] += m;
        }
        let ratio_before = load_ratio(&per_shard);
        // Window consumed (judged), whatever the verdict. Merge — don't
        // replace — so a shard whose fetch failed this round keeps its
        // baseline instead of having its whole history count as one
        // window when it comes back.
        for s in &now {
            for &(slot, m) in &s.loads {
                self.last.insert((s.shard, slot), m);
            }
        }
        if ratio_before <= self.max_ratio {
            return Ok(None);
        }
        // Plan past the trigger, toward the midpoint between balanced and
        // the trigger ratio: stopping exactly at the trigger would leave
        // the next window hovering at the threshold (and re-triggering on
        // noise); the planner stops early anyway when no move improves.
        let target = 1.0 + (self.max_ratio - 1.0) / 2.0;
        let moves = plan_moves(&cur, &window, target);
        if moves.is_empty() {
            return Ok(None);
        }
        let new = cur.with_moves(&moves)?;
        let mut planned = vec![0u64; new.n_shards()];
        for (slot, &m) in window.iter().enumerate() {
            planned[new.shard_of_slot(slot)] += m;
        }
        let report = RebalanceReport {
            epoch: new.epoch(),
            moves: moves.len(),
            ratio_before,
            ratio_planned: load_ratio(&planned),
        };
        self.migrate_to(&cur, new)?;
        Ok(Some(report))
    }

    /// Execute the migration handshake for `old → new` and commit `new`
    /// as the constellation's table.
    pub(crate) fn migrate_to(&self, old: &Placement, new: Placement) -> anyhow::Result<()> {
        anyhow::ensure!(
            new.epoch() > old.epoch(),
            "migration target epoch {} is not newer than {}",
            new.epoch(),
            old.epoch()
        );
        // `old` must be the live table: every committer holds the
        // rebalancer lock, so this can only trip on a caller bug — and
        // tripping it beats migrating from a stale base (shards would
        // ignore the epoch and the commit would desync routing).
        {
            let live = self.placement.read().expect("ps placement lock");
            anyhow::ensure!(
                live.epoch() == old.epoch(),
                "placement moved to epoch {} during planning (expected {})",
                live.epoch(),
                old.epoch()
            );
        }
        self.run_handshake(&new);
        // Commit: clients (and the front-end's hello/placement replies)
        // now see the new table; in-flight stale syncs heal via Rerouted.
        *self.placement.write().expect("ps placement lock") = Arc::new(new);
        Ok(())
    }

    /// The two-phase Migrate/Install fan-out for `table`. Shards already
    /// at (or past) `table`'s epoch treat the Migrate as a no-op, so the
    /// same handshake serves both a fresh migration (every shard one
    /// epoch behind) and the staleness re-push (most shards current, one
    /// behind). Install goes to *every* shard: it routes each extracted
    /// entry to its owner under `table` — wherever it came from — and an
    /// empty install still opens a destination's pending slots.
    fn run_handshake(&self, table: &Placement) {
        let mut extracted: Vec<(FuncKey, RunStats)> = Vec::new();
        for (i, conn) in self.conns.iter().enumerate() {
            match conn {
                ShardConn::Local(tx) => {
                    let (rtx, rrx) = channel();
                    if tx
                        .send(ShardMsg::Migrate { placement: table.clone(), reply: rtx })
                        .is_ok()
                    {
                        match rrx.recv() {
                            Ok(out) => extracted.extend(out),
                            Err(_) => crate::log_warn!("ps", "shard {i} died during migrate"),
                        }
                    }
                }
                ShardConn::Tcp(pool) => {
                    match pool[0].lock().expect("ps shard conn lock").with(|w| w.migrate(table))
                    {
                        Ok(out) => extracted.extend(out),
                        Err(e) => crate::log_warn!(
                            "ps",
                            "shard {i} unreachable during migrate (its slice degrades): {e:#}"
                        ),
                    }
                }
            }
        }
        let n = table.n_shards();
        let mut per: Vec<Vec<(FuncKey, RunStats)>> = vec![Vec::new(); n];
        for ((app, id), st) in extracted {
            per[table.shard_of(app, id)].push(((app, id), st));
        }
        for (i, entries) in per.into_iter().enumerate() {
            match &self.conns[i] {
                ShardConn::Local(tx) => {
                    let (rtx, rrx) = channel();
                    if tx.send(ShardMsg::Install { entries, reply: rtx }).is_ok() {
                        let _ = rrx.recv();
                    }
                }
                ShardConn::Tcp(pool) => {
                    if let Err(e) = pool[0]
                        .lock()
                        .expect("ps shard conn lock")
                        .with(|w| w.install(&entries))
                    {
                        crate::log_warn!(
                            "ps",
                            "shard {i} unreachable during install (its slice degrades): {e:#}"
                        );
                    }
                }
            }
        }
    }
}
