//! The **online AD parameter server** (paper §III-B2) — sharded.
//!
//! Maintains the global view of the workflow: per-function execution-time
//! statistics (merged from the on-node AD modules with Pébay's formulas —
//! commutative, so **no synchronization barriers**) and the per-rank,
//! per-step anomaly timeline. Periodically publishes a snapshot to the
//! visualization ingest channel.
//!
//! ## Architecture
//!
//! Since the sharding refactor the server is a small constellation of
//! threads rather than one consumer (see [`shard`]):
//!
//! * **N stat shards** — each owns the partition of the per-function
//!   statistics with `shard_of(app, fid, N) == i` and drains its own
//!   channel. A `Sync` never touches more than the shards its delta maps
//!   to, so sync throughput scales with cores instead of serializing
//!   through one thread.
//! * **One aggregator** — a [`ParameterServer`] (kept as the
//!   single-threaded reference implementation) that owns everything
//!   keyed by rank/step: the per-rank anomaly timeline, per-step totals,
//!   global-event detection (§V), and the per-rank event-delivery
//!   cursors. It receives `Report`s and empty-delta `Sync`s (the event
//!   fetch leg of a routed sync).
//! * **One merge stage** — folds the aggregator's partial snapshot with
//!   one partial per stat shard using [`VizSnapshot::merge`] (Pébay
//!   merges are commutative, so shard arrival order cannot change the
//!   result) and forwards the folded snapshot to the viz ingest channel.
//!   No shard ever blocks on another: snapshots are barrier-free.
//!
//! ## Routing protocol
//!
//! [`PsClient`](shard::PsClient) is a router over *pluggable per-shard
//! connections* (in-process channels or per-shard TCP endpoints — see
//! [`net`] and `docs/ps.md`): `sync` splits the rank's delta under the
//! constellation's epoch-versioned [`Placement`](crate::placement)
//! table, batches each shard's sub-delta into a single message stamped
//! with the table's epoch, fans them out, and reassembles the reply
//! (global stats for the touched functions + fresh global events)
//! client-side. A shard that sees a frame from another epoch answers
//! `Rerouted`; the client refreshes its table and resends only the
//! bounced sub-frames — the healing step that makes live, skew-driven
//! rebalancing ([`rebalance`]) invisible in the results.
//!
//! The event-fetch leg is **version-gated**: the aggregator owns a
//! monotonic event-version counter (events flagged so far), every shard
//! sync reply piggybacks it, and a client only round-trips to the
//! aggregator when (a) it has sent a report since its last aggregator
//! contact — its own report may complete a step quorum and flag an
//! event, and the fetch must serialize behind it to preserve the
//! exactly-once, *next-sync* delivery order `tests/ps_shard.rs` pins
//! down — or (b) a piggybacked version exceeds what it has seen. In the
//! no-events steady state (e.g. sync-only load) the aggregator receives
//! **zero** messages per sync, removing it as the throughput ceiling
//! (ROADMAP "Event-fetch gating", now done).
//!
//! With one shard the constellation reproduces the single-server
//! behaviour exactly (see `tests/ps_shard.rs` for the equivalence
//! property over N ∈ {1, 2, 4, 7}, in-process and across per-shard TCP
//! endpoints).

pub mod net;
pub mod rebalance;
pub mod shard;

pub use rebalance::RebalanceReport;
pub use shard::{
    global_event_record, shard_of, spawn, spawn_with, PsClient, PsFinal, PsHandle, PsOpts,
    PsStats,
};

use crate::ad::Label;
use crate::stats::RunStats;
use std::collections::HashMap;
use std::sync::mpsc::Sender;

/// Function statistics key: apps have independent fid spaces.
pub type FuncKey = (u32, u32); // (app, fid)

/// One rank's per-step anomaly report.
#[derive(Clone, Debug)]
pub struct StepStat {
    pub app: u32,
    pub rank: u32,
    pub step: u64,
    pub n_executions: u64,
    pub n_anomalies: u64,
    /// Analysed virtual-time range of the step, µs.
    pub ts_range: (u64, u64),
}

/// Message from an AD module to the server.
pub enum PsRequest {
    /// Statistics sync: fold `delta` into the global view, reply with the
    /// global snapshot for the touched functions. An empty delta is the
    /// event-fetch leg of a routed sync: it only advances the rank's
    /// global-event cursor.
    Sync {
        app: u32,
        rank: u32,
        delta: Vec<(u32, RunStats)>,
        reply: Sender<PsReply>,
    },
    /// Anomaly accounting for the viz timeline (fire-and-forget).
    Report(StepStat),
    /// Read the aggregator's full current snapshot (the `/api/ps_stats`
    /// and PS wire-stats paths; does not drain `fresh`).
    Query { reply: Sender<VizSnapshot> },
    /// Flush a viz snapshot now (tests; the loop also does it on a cadence).
    Publish,
    /// Drain and stop.
    Shutdown,
}

/// Reply to a `Sync`: global statistics for the functions in the delta,
/// plus any globally detected events this rank has not seen yet (the
/// rank reacts by dumping its current context window to provenance), plus
/// the aggregator's event-version counter (total events flagged so far —
/// monotonic), which clients use to gate future event-fetch round-trips.
pub struct PsReply {
    pub global: Vec<(u32, RunStats)>,
    pub global_events: Vec<GlobalEvent>,
    /// Aggregator event version after this reply: `global_events` flagged
    /// so far, workflow-wide. A client that has seen version `v` and
    /// whose shard replies piggyback version `v` has no events waiting.
    pub event_version: u64,
}

/// Per-shard load counters (merge/sync counts) — the skew signal the
/// [`rebalance`] module acts on (per-slot counters drive the plan; these
/// per-shard aggregates are what `/api/ps_stats` surfaces). Published
/// inside each stat shard's partial snapshot.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardLoad {
    pub shard: u32,
    /// Sync messages this shard served.
    pub syncs: u64,
    /// Individual function-stat merges performed.
    pub merges: u64,
    /// Functions owned by this shard's partition.
    pub functions: u64,
    /// Placement slots this shard currently owns.
    pub slots: u32,
    /// Requests the shard's TCP endpoint shed with `Busy` under overload
    /// (0 for in-process shards — no transport, nothing to shed).
    pub shed: u64,
    /// Unflushed reply bytes queued on the endpoint when the snapshot
    /// was taken (0 for in-process shards).
    pub queue_depth: u64,
}

/// Per-aggregator-node fold counters, published by each node of the
/// hierarchical aggregation tree ([`crate::aggtree`]) inside its partial
/// snapshot and surfaced through `/api/ps_stats`. The flat aggregator
/// publishes none (its degenerate tree has no fold nodes to report).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AggNodeLoad {
    /// Tree-wide node id (root = 0, then level by level).
    pub node: u32,
    /// Distance from the root (root = 0).
    pub depth: u32,
    /// Contiguous rank-range this node owns: `[rank_lo, rank_hi)`.
    pub rank_lo: u32,
    pub rank_hi: u32,
    /// Messages folded (reports at leaves, child partials at interiors).
    pub folds: u64,
    /// Completed step partials pushed to the parent (or, at the root,
    /// step quorums completed).
    pub pushed: u64,
    /// Partials shed by step-distance expiry (quorum never met) plus
    /// straggler reports short-circuited past the fold.
    pub shed: u64,
}

/// Snapshot published to the visualization ingest channel.
///
/// In the sharded server each thread publishes a *partial* snapshot (the
/// aggregator contributes ranks/timeline/events, each stat shard its
/// function count and load counters) and the merge stage folds them with
/// [`Self::merge`]. Published partials are *deltas* ([`Self::delta`] set):
/// the aggregator includes only rank summaries that changed since the
/// previous publish, so the `ranks` vector no longer dominates each
/// publish at high rank counts; [`VizState::ingest`](crate::viz::VizState::ingest)
/// folds deltas incrementally with [`Self::fold_delta`].
#[derive(Clone, Debug, Default)]
pub struct VizSnapshot {
    /// Per-rank summaries (Fig 3's ranking dashboard feeds from this).
    /// In a delta snapshot: only the ranks that changed since the last
    /// publish (each entry still carries its *cumulative* statistics, so
    /// folding is replacement, not addition).
    pub ranks: Vec<RankSummary>,
    /// Newly reported step stats since the previous snapshot (Fig 4's
    /// streaming scatter feeds from this).
    pub fresh_steps: Vec<StepStat>,
    /// Total anomalies so far, workflow-wide (absolute, also in deltas).
    pub total_anomalies: u64,
    /// Total executions so far, workflow-wide (absolute, also in deltas).
    pub total_executions: u64,
    /// Distinct functions tracked in the global statistics view.
    pub functions_tracked: u64,
    /// Globally detected events (§V future work). In a delta snapshot:
    /// only events flagged since the last publish.
    pub global_events: Vec<GlobalEvent>,
    /// Per-shard load counters (absolute), from the stat shards' partials.
    pub shard_loads: Vec<ShardLoad>,
    /// Per-node fold counters (absolute) from the hierarchical
    /// aggregation tree; empty under the flat aggregator.
    pub agg_nodes: Vec<AggNodeLoad>,
    /// Epoch of the placement table the stat shards were serving when
    /// this snapshot's partials were taken (0 until a rebalance commits).
    pub placement_epoch: u64,
    /// True for incrementally-published snapshots: `ranks` and
    /// `global_events` carry only changes since the previous publish and
    /// must be folded with [`Self::fold_delta`], not adopted wholesale.
    pub delta: bool,
}

impl VizSnapshot {
    /// Fold another (partial) snapshot into this one. Commutative and
    /// associative up to the deterministic orderings applied here (ranks
    /// sorted by `(app, rank)`, events deduplicated by step and sorted),
    /// so the merge stage may fold shard partials in arrival order.
    pub fn merge(&mut self, other: &VizSnapshot) {
        self.ranks.extend(other.ranks.iter().cloned());
        self.ranks.sort_by_key(|r| (r.app, r.rank));
        self.fresh_steps.extend(other.fresh_steps.iter().cloned());
        // Deterministic order regardless of which partial carried a step:
        // the aggregation tree folds leaf partials child-by-child, the
        // flat aggregator appends in arrival order — the sort makes both
        // publish the identical sequence (sort_by_key is stable, so
        // same-key stragglers keep their arrival order too).
        self.fresh_steps.sort_by_key(|s| (s.step, s.app, s.rank));
        self.total_anomalies += other.total_anomalies;
        self.total_executions += other.total_executions;
        self.functions_tracked += other.functions_tracked;
        for ev in &other.global_events {
            if !self.global_events.iter().any(|e| e.step == ev.step) {
                self.global_events.push(*ev);
            }
        }
        self.global_events.sort_by_key(|e| e.step);
        self.shard_loads.extend(other.shard_loads.iter().copied());
        self.shard_loads.sort_by_key(|l| l.shard);
        self.agg_nodes.extend(other.agg_nodes.iter().copied());
        self.agg_nodes.sort_by_key(|n| n.node);
        self.placement_epoch = self.placement_epoch.max(other.placement_epoch);
    }

    /// Fold a *delta* snapshot into this (absolute) one: changed rank
    /// summaries replace their previous entries by `(app, rank)` key,
    /// cumulative totals and shard loads are adopted, and new global
    /// events are appended (deduplicated by step). `self.ranks` must be
    /// sorted by `(app, rank)` — every producer in this module keeps it
    /// so.
    pub fn fold_delta(&mut self, d: &VizSnapshot) {
        for r in &d.ranks {
            match self.ranks.binary_search_by_key(&(r.app, r.rank), |x| (x.app, x.rank)) {
                Ok(i) => self.ranks[i] = r.clone(),
                Err(i) => self.ranks.insert(i, r.clone()),
            }
        }
        self.fresh_steps = d.fresh_steps.clone();
        self.total_anomalies = d.total_anomalies;
        self.total_executions = d.total_executions;
        self.functions_tracked = d.functions_tracked;
        for ev in &d.global_events {
            if !self.global_events.iter().any(|e| e.step == ev.step) {
                self.global_events.push(*ev);
            }
        }
        self.global_events.sort_by_key(|e| e.step);
        if !d.shard_loads.is_empty() {
            self.shard_loads = d.shard_loads.clone();
        }
        if !d.agg_nodes.is_empty() {
            self.agg_nodes = d.agg_nodes.clone();
        }
        self.placement_epoch = self.placement_epoch.max(d.placement_epoch);
        self.delta = false;
    }
}

/// Per-rank anomaly summary: statistics over its per-step anomaly counts
/// (average/σ/max/min/total — exactly the dashboard's selectable metrics).
#[derive(Clone, Debug)]
pub struct RankSummary {
    pub app: u32,
    pub rank: u32,
    pub step_counts: RunStats,
    pub total_anomalies: u64,
}

/// A **globally detected event** (paper §V future work): a trace step
/// whose workflow-wide anomaly count is itself an outlier relative to the
/// recent per-step totals. The PS flags it and the coordinator triggers
/// context-provenance output on *all* ranks, not just the anomalous ones.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GlobalEvent {
    pub step: u64,
    /// Workflow-wide anomalies in that step.
    pub total_anomalies: u64,
    /// σ-distance of the step total from the per-step mean.
    pub score: f64,
}

/// The single-threaded server (usable directly in-thread for tests, the
/// semantic reference for the sharded constellation, and the aggregator
/// shard inside [`shard::spawn`]).
pub struct ParameterServer {
    global: HashMap<FuncKey, RunStats>,
    per_rank: HashMap<(u32, u32), RankAccum>,
    fresh: Vec<StepStat>,
    total_anomalies: u64,
    total_executions: u64,
    viz_tx: Option<Sender<VizSnapshot>>,
    /// Publish cadence, in number of Report messages (≈ steps) — the
    /// paper's 1-second periodicity maps to once per step-round.
    publish_every: usize,
    reports_since_publish: usize,
    pub sync_count: u64,
    /// Per-step workflow-wide accumulation toward global-event detection:
    /// step → (reports received, anomaly total). Entries that fall more
    /// than [`STEP_ACC_MAX_LAG`] behind the newest reported step are
    /// expired (their partial total folded into `step_totals`), so a
    /// misconfigured `ps-server --ranks` no longer leaks one entry per
    /// step.
    step_acc: HashMap<u64, (usize, u64)>,
    /// Newest step seen in any report; drives step-distance expiry.
    max_step_seen: u64,
    /// Reports expected per step (= number of reporting ranks);
    /// completes a step's total. An explicit constructor parameter: the
    /// publish cadence and the per-step report quorum are independent
    /// knobs, and conflating them completes steps early/late.
    reports_per_step: usize,
    /// Statistics over completed steps' anomaly totals.
    step_totals: RunStats,
    /// Flagged global events (chronological).
    global_events: Vec<GlobalEvent>,
    /// Global events not yet delivered to each rank (per-rank cursor).
    event_cursor: HashMap<(u32, u32), usize>,
    /// Ranks whose summaries changed since the last publish — the delta
    /// snapshot carries exactly these (see [`Self::snapshot_delta`]).
    dirty_ranks: std::collections::HashSet<(u32, u32)>,
    /// Global events already carried by a published delta.
    events_published: usize,
}

/// Global-event trigger: step total > μ + GLOBAL_BETA·σ over ≥ MIN_HISTORY
/// completed steps and at least GLOBAL_MIN_ANOMS anomalies.
const GLOBAL_BETA: f64 = 3.0;
const GLOBAL_MIN_HISTORY: u64 = 5;
const GLOBAL_MIN_ANOMS: u64 = 3;

/// A step accumulator this far behind the newest reported step can no
/// longer meet its quorum in practice (ranks report steps roughly in
/// lockstep); expire it with whatever partial total arrived. Quorum-met
/// steps still complete exactly — expiry only catches the leak when
/// `reports_per_step` overstates the reporting ranks.
pub const STEP_ACC_MAX_LAG: u64 = 64;

struct RankAccum {
    step_counts: RunStats,
    total: u64,
}

impl ParameterServer {
    /// `publish_every` is the viz publish cadence in Report messages;
    /// `reports_per_step` is the number of ranks reporting each step
    /// (the quorum that completes a step's workflow-wide anomaly total).
    pub fn new(
        viz_tx: Option<Sender<VizSnapshot>>,
        publish_every: usize,
        reports_per_step: usize,
    ) -> Self {
        ParameterServer {
            global: HashMap::new(),
            per_rank: HashMap::new(),
            fresh: Vec::new(),
            total_anomalies: 0,
            total_executions: 0,
            viz_tx,
            publish_every: publish_every.max(1),
            reports_since_publish: 0,
            sync_count: 0,
            step_acc: HashMap::new(),
            max_step_seen: 0,
            reports_per_step: reports_per_step.max(1),
            step_totals: RunStats::new(),
            global_events: Vec::new(),
            event_cursor: HashMap::new(),
            dirty_ranks: std::collections::HashSet::new(),
            events_published: 0,
        }
    }

    /// Event-version counter: total global events flagged so far.
    /// Monotonic; piggybacked on sync replies so clients can skip the
    /// aggregator event-fetch round-trip when nothing new exists.
    pub fn event_version(&self) -> u64 {
        self.global_events.len() as u64
    }

    /// Handle one request inline.
    pub fn handle(&mut self, req: PsRequest) -> bool {
        match req {
            PsRequest::Sync { app, rank, delta, reply } => {
                self.sync_count += 1;
                let mut global = Vec::with_capacity(delta.len());
                for (fid, st) in delta {
                    let g = self.global.entry((app, fid)).or_default();
                    g.merge(&st);
                    global.push((fid, *g));
                }
                // Deliver global events this rank has not seen yet.
                let cursor = self.event_cursor.entry((app, rank)).or_insert(0);
                let fresh_events = self.global_events[*cursor..].to_vec();
                *cursor = self.global_events.len();
                let _ = reply.send(PsReply {
                    global,
                    global_events: fresh_events,
                    event_version: self.global_events.len() as u64,
                });
            }
            PsRequest::Report(stat) => {
                self.dirty_ranks.insert((stat.app, stat.rank));
                let acc = self
                    .per_rank
                    .entry((stat.app, stat.rank))
                    .or_insert_with(|| RankAccum { step_counts: RunStats::new(), total: 0 });
                acc.step_counts.push(stat.n_anomalies as f64);
                acc.total += stat.n_anomalies;
                self.total_anomalies += stat.n_anomalies;
                self.total_executions += stat.n_executions;
                // Global-event detection on completed step totals (§V).
                if stat.step > self.max_step_seen {
                    self.max_step_seen = stat.step;
                    self.expire_stale_steps();
                }
                if stat.step < self.max_step_seen.saturating_sub(STEP_ACC_MAX_LAG) {
                    // Straggler for an already-expired step: don't
                    // re-open the accumulator (it would leak again).
                    self.fresh.push(stat);
                    self.reports_since_publish += 1;
                    if self.reports_since_publish >= self.publish_every {
                        self.publish();
                    }
                    return true;
                }
                self.accumulate_step(stat.step, 1, stat.n_anomalies);
                self.fresh.push(stat);
                self.reports_since_publish += 1;
                if self.reports_since_publish >= self.publish_every {
                    self.publish();
                }
            }
            PsRequest::Query { reply } => {
                let _ = reply.send(self.snapshot());
            }
            PsRequest::Publish => self.publish(),
            PsRequest::Shutdown => {
                self.publish();
                return false;
            }
        }
        true
    }

    /// Fold a per-step quorum contribution coming from a child node of
    /// the aggregation tree ([`crate::aggtree`]): `count` rank reports
    /// totalling `anoms` anomalies for `step`. Mirrors the `Report`
    /// step-accumulation path (step-distance expiry, straggler
    /// short-circuit, quorum completion, §V global-event trigger)
    /// without touching per-rank state — the tree's leaves own that.
    /// Returns `None` when the contribution was shed as a straggler,
    /// `Some(completed)` otherwise — the root's shed/pushed counters.
    pub fn fold_partial_step(&mut self, step: u64, count: u64, anoms: u64) -> Option<bool> {
        if step > self.max_step_seen {
            self.max_step_seen = step;
            self.expire_stale_steps();
        }
        if step < self.max_step_seen.saturating_sub(STEP_ACC_MAX_LAG) {
            return None;
        }
        Some(self.accumulate_step(step, count as usize, anoms))
    }

    /// Fold a range partial the aggregation tree *expired* at one of its
    /// nodes (it sat more than [`STEP_ACC_MAX_LAG`] behind the tree-wide
    /// step horizon): the contribution enters the step accumulator
    /// exactly like a live one — in the flat shape these reports were
    /// already sitting in the accumulator when their range stalled — so
    /// neither the straggler short-circuit nor the horizon advance of
    /// [`fold_partial_step`](Self::fold_partial_step) applies. The next
    /// expiry sweep then folds the step's *combined* total into the step
    /// statistics as one push, on the flat aggregator's schedule.
    /// Returns whether the contribution completed the global quorum.
    pub fn fold_expired_step(&mut self, step: u64, count: u64, anoms: u64) -> bool {
        self.accumulate_step(step, count as usize, anoms)
    }

    /// Advance the step-expiry horizon to `max_step` — the newest step
    /// the tree's ingress has seen in *any* report, carried by the flush
    /// barrier — and expire the accumulators behind it. The root only
    /// hears about steps through completed range quorums, so a stalled
    /// range would otherwise freeze part of the horizon that the flat
    /// aggregator (which advances on every report) keeps moving.
    pub fn expire_to(&mut self, max_step: u64) {
        if max_step > self.max_step_seen {
            self.max_step_seen = max_step;
        }
        self.expire_stale_steps();
    }

    /// Step-quorum accumulation and the §V global-event trigger, shared
    /// by the flat `Report` path (`count` = 1) and the tree's partial
    /// folds (`count` = reports behind the child's partial). Returns
    /// whether the contribution completed the step's global quorum.
    fn accumulate_step(&mut self, step: u64, count: usize, anoms: u64) -> bool {
        let entry = self.step_acc.entry(step).or_insert((0, 0));
        entry.0 += count;
        entry.1 += anoms;
        if entry.0 < self.reports_per_step {
            return false;
        }
        let (_, total) = self.step_acc.remove(&step).expect("entry just updated");
        if self.step_totals.count() >= GLOBAL_MIN_HISTORY && total >= GLOBAL_MIN_ANOMS {
            let sd = self.step_totals.stddev();
            let mean = self.step_totals.mean();
            let score = if sd > 0.0 { (total as f64 - mean) / sd } else { 0.0 };
            if sd > 0.0 && total as f64 > mean + GLOBAL_BETA * sd {
                self.global_events.push(GlobalEvent {
                    step,
                    total_anomalies: total,
                    score,
                });
            }
        }
        self.step_totals.push(total as f64);
        true
    }

    /// Drop per-step accumulators more than [`STEP_ACC_MAX_LAG`] behind
    /// the newest reported step, folding their partial totals into the
    /// step statistics (the best estimate available — no global event is
    /// flagged off partial data).
    fn expire_stale_steps(&mut self) {
        let horizon = self.max_step_seen.saturating_sub(STEP_ACC_MAX_LAG);
        if horizon == 0 {
            return;
        }
        let stale: Vec<u64> =
            self.step_acc.keys().filter(|&&s| s < horizon).copied().collect();
        for s in stale {
            if let Some((_, total)) = self.step_acc.remove(&s) {
                self.step_totals.push(total as f64);
            }
        }
    }

    /// Steps whose workflow-wide totals are still accumulating (bounded
    /// by [`STEP_ACC_MAX_LAG`] — see the expiry in `Report` handling).
    pub fn pending_steps(&self) -> usize {
        self.step_acc.len()
    }

    /// Build and send a viz snapshot *delta* (changed ranks, fresh steps,
    /// events flagged since the last publish, absolute totals); drains
    /// `fresh` and the dirty-rank set.
    pub fn publish(&mut self) {
        let snap = self.take_delta();
        if let Some(tx) = &self.viz_tx {
            let _ = tx.send(snap);
        }
    }

    /// [`Self::publish`] without the send: drain and return the delta
    /// snapshot. The aggregation-tree root uses this to fold the leaves'
    /// partial deltas in before forwarding one combined delta to viz.
    pub fn take_delta(&mut self) -> VizSnapshot {
        self.reports_since_publish = 0;
        let snap = self.snapshot_delta();
        self.fresh.clear();
        self.dirty_ranks.clear();
        self.events_published = self.global_events.len();
        snap
    }

    /// True when reports arrived since the last publish (the wall-clock
    /// cadence only publishes when there is something new to say).
    pub fn pending_publish(&self) -> bool {
        self.reports_since_publish > 0
    }

    /// Delta snapshot: only the rank summaries touched since the last
    /// publish (cumulative values — folding is replacement), only the
    /// global events not yet published, absolute totals. At high rank
    /// counts this is what keeps the publish path O(changed) instead of
    /// O(ranks).
    pub fn snapshot_delta(&self) -> VizSnapshot {
        let mut ranks: Vec<RankSummary> = self
            .dirty_ranks
            .iter()
            .filter_map(|&(app, rank)| {
                self.per_rank.get(&(app, rank)).map(|acc| RankSummary {
                    app,
                    rank,
                    step_counts: acc.step_counts,
                    total_anomalies: acc.total,
                })
            })
            .collect();
        ranks.sort_by_key(|r| (r.app, r.rank));
        // Deterministic fresh order: flat and tree aggregators must emit
        // bit-identical snapshots regardless of arrival interleaving.
        let mut fresh_steps = self.fresh.clone();
        fresh_steps.sort_by_key(|s| (s.step, s.app, s.rank));
        let published = self.events_published.min(self.global_events.len());
        VizSnapshot {
            ranks,
            fresh_steps,
            total_anomalies: self.total_anomalies,
            total_executions: self.total_executions,
            functions_tracked: self.global.len() as u64,
            global_events: self.global_events[published..].to_vec(),
            shard_loads: Vec::new(),
            agg_nodes: Vec::new(),
            // The aggregator has no placement view; the stat shards'
            // partials carry the epoch and the merge takes the max.
            placement_epoch: 0,
            delta: true,
        }
    }

    /// Current full snapshot (without draining when called directly in
    /// tests; also the final-state snapshot gathered at join time).
    pub fn snapshot(&self) -> VizSnapshot {
        let mut ranks: Vec<RankSummary> = self
            .per_rank
            .iter()
            .map(|(&(app, rank), acc)| RankSummary {
                app,
                rank,
                step_counts: acc.step_counts,
                total_anomalies: acc.total,
            })
            .collect();
        ranks.sort_by_key(|r| (r.app, r.rank));
        let mut fresh_steps = self.fresh.clone();
        fresh_steps.sort_by_key(|s| (s.step, s.app, s.rank));
        VizSnapshot {
            ranks,
            fresh_steps,
            total_anomalies: self.total_anomalies,
            total_executions: self.total_executions,
            functions_tracked: self.global.len() as u64,
            global_events: self.global_events.clone(),
            shard_loads: Vec::new(),
            agg_nodes: Vec::new(),
            placement_epoch: 0,
            delta: false,
        }
    }

    /// Drop the viz sender (the sharded constellation uses this to close
    /// the merge stage's job channel after the aggregator stops).
    pub fn detach_viz(&mut self) {
        self.viz_tx = None;
    }

    /// All globally detected events so far.
    pub fn global_events(&self) -> &[GlobalEvent] {
        &self.global_events
    }

    /// Global statistics for one function.
    pub fn global_stats(&self, app: u32, fid: u32) -> Option<&RunStats> {
        self.global.get(&(app, fid))
    }

    /// Iterate the full global statistics view.
    pub fn global_iter(&self) -> impl Iterator<Item = (FuncKey, &RunStats)> {
        self.global.iter().map(|(&k, s)| (k, s))
    }

    /// Number of functions tracked globally.
    pub fn global_len(&self) -> usize {
        self.global.len()
    }
}

/// Helper building a [`StepStat`] from an AD step result.
pub fn step_stat_of(res: &crate::ad::StepResult, frame_span: (u64, u64)) -> StepStat {
    StepStat {
        app: res.app,
        rank: res.rank,
        step: res.step,
        n_executions: res.n_executions,
        n_anomalies: res.n_anomalies,
        ts_range: frame_span,
    }
}

/// Convenience for tests: count anomalies in a labelled batch.
pub fn count_anomalies(labels: &[crate::ad::Labeled]) -> u64 {
    labels.iter().filter(|l| matches!(l.label, Label::AnomalyHigh | Label::AnomalyLow)).count()
        as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::StatsTable;
    use std::sync::mpsc::channel;

    fn stats_of(values: &[f64]) -> RunStats {
        let mut s = RunStats::new();
        for &v in values {
            s.push(v);
        }
        s
    }

    #[test]
    fn sync_merges_and_replies_global() {
        let mut ps = ParameterServer::new(None, 1000, 1);
        let (rtx, rrx) = channel();
        ps.handle(PsRequest::Sync {
            app: 0,
            rank: 1,
            delta: vec![(7, stats_of(&[10.0, 20.0]))],
            reply: rtx,
        });
        let (rtx2, rrx2) = channel();
        ps.handle(PsRequest::Sync {
            app: 0,
            rank: 2,
            delta: vec![(7, stats_of(&[30.0, 40.0]))],
            reply: rtx2,
        });
        let r1 = rrx.recv().unwrap();
        assert_eq!(r1.global[0].1.count(), 2);
        let r2 = rrx2.recv().unwrap();
        let g = r2.global[0].1;
        assert_eq!(g.count(), 4);
        assert!((g.mean() - 25.0).abs() < 1e-9);
        // Same fid in a different app is independent.
        assert!(ps.global_stats(1, 7).is_none());
        assert_eq!(ps.global_len(), 1);
        assert_eq!(ps.snapshot().functions_tracked, 1);
    }

    #[test]
    fn reports_build_rank_summaries() {
        let mut ps = ParameterServer::new(None, 1000, 1);
        for step in 0..4 {
            ps.handle(PsRequest::Report(StepStat {
                app: 0,
                rank: 3,
                step,
                n_executions: 100,
                n_anomalies: step, // 0,1,2,3
                ts_range: (0, 1),
            }));
        }
        let snap = ps.snapshot();
        assert_eq!(snap.ranks.len(), 1);
        let r = &snap.ranks[0];
        assert_eq!(r.total_anomalies, 6);
        assert!((r.step_counts.mean() - 1.5).abs() < 1e-12);
        assert_eq!(snap.total_executions, 400);
        assert_eq!(snap.fresh_steps.len(), 4);
    }

    #[test]
    fn publish_cadence_and_drain() {
        let (vtx, vrx) = channel();
        let mut ps = ParameterServer::new(Some(vtx), 2, 1);
        for step in 0..4 {
            ps.handle(PsRequest::Report(StepStat {
                app: 0,
                rank: 0,
                step,
                n_executions: 1,
                n_anomalies: 0,
                ts_range: (0, 1),
            }));
        }
        let s1 = vrx.recv().unwrap();
        let s2 = vrx.recv().unwrap();
        assert_eq!(s1.fresh_steps.len(), 2);
        assert_eq!(s2.fresh_steps.len(), 2);
        assert!(vrx.try_recv().is_err());
    }

    #[test]
    fn threaded_server_round_trip() {
        let (client, handle) = spawn(2, None, 10, 1);
        let mut delta = StatsTable::new();
        for v in [1.0, 2.0, 3.0] {
            delta.push(5, v);
        }
        let (g1, ev1) = client.sync(0, 0, &delta);
        assert_eq!(g1.get(5).unwrap().count(), 3);
        assert!(ev1.is_empty());
        let (g2, _) = client.sync(0, 1, &delta);
        assert_eq!(g2.get(5).unwrap().count(), 6);
        client.shutdown();
        let fin = handle.join();
        assert_eq!(fin.sync_count, 2);
    }

    #[test]
    fn concurrent_syncs_converge() {
        let (client, handle) = spawn(3, None, 1000, 1);
        let mut joins = Vec::new();
        for rank in 0..8u32 {
            let c = client.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..50u64 {
                    let mut d = StatsTable::new();
                    d.push(1, (rank as f64) + i as f64);
                    c.sync(0, rank, &d);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        client.shutdown();
        let fin = handle.join();
        assert_eq!(fin.global_stats(0, 1).unwrap().count(), 400);
    }

    #[test]
    fn global_event_detection_and_delivery() {
        // 4 ranks; 10 quiet steps then one step with a workflow-wide burst.
        let mut ps = ParameterServer::new(None, 4, 4);
        let report = |ps: &mut ParameterServer, step: u64, rank: u32, anoms: u64| {
            ps.handle(PsRequest::Report(StepStat {
                app: 0,
                rank,
                step,
                n_executions: 100,
                n_anomalies: anoms,
                ts_range: (0, 1),
            }));
        };
        for step in 0..10 {
            for rank in 0..4 {
                report(&mut ps, step, rank, u64::from(step % 3 == 0 && rank == 0));
            }
        }
        assert!(ps.global_events().is_empty(), "quiet phase must not trigger");
        // Burst: every rank anomalous in step 10.
        for rank in 0..4 {
            report(&mut ps, 10, rank, 5);
        }
        assert_eq!(ps.global_events().len(), 1);
        let ev = ps.global_events()[0];
        assert_eq!(ev.step, 10);
        assert_eq!(ev.total_anomalies, 20);
        assert!(ev.score > 3.0);
        // Delivery: first sync sees the event, second does not (cursor).
        let (rtx, rrx) = channel();
        ps.handle(PsRequest::Sync {
            app: 0,
            rank: 2,
            delta: vec![(0, stats_of(&[1.0]))],
            reply: rtx,
        });
        assert_eq!(rrx.recv().unwrap().global_events.len(), 1);
        let (rtx, rrx) = channel();
        ps.handle(PsRequest::Sync {
            app: 0,
            rank: 2,
            delta: vec![(0, stats_of(&[1.0]))],
            reply: rtx,
        });
        assert!(rrx.recv().unwrap().global_events.is_empty());
        // Snapshot carries the event for the viz layer.
        assert_eq!(ps.snapshot().global_events.len(), 1);
    }

    #[test]
    fn stale_step_accumulators_expire() {
        // Misconfigured quorum: the server expects 8 reports per step but
        // only one rank ever reports — without expiry this leaks one
        // accumulator per step forever.
        let mut ps = ParameterServer::new(None, usize::MAX >> 1, 8);
        for step in 0..500u64 {
            ps.handle(PsRequest::Report(StepStat {
                app: 0,
                rank: 0,
                step,
                n_executions: 10,
                n_anomalies: 1,
                ts_range: (0, 1),
            }));
        }
        assert!(
            ps.pending_steps() <= (STEP_ACC_MAX_LAG + 1) as usize,
            "step_acc leaked: {} entries after 500 steps",
            ps.pending_steps()
        );
        // A straggler for a long-expired step must not re-open it…
        ps.handle(PsRequest::Report(StepStat {
            app: 0,
            rank: 1,
            step: 3,
            n_executions: 10,
            n_anomalies: 0,
            ts_range: (0, 1),
        }));
        assert!(ps.pending_steps() <= (STEP_ACC_MAX_LAG + 1) as usize);
        // …but its anomaly accounting still lands in the summaries.
        assert_eq!(ps.snapshot().total_executions, 5010);

        // Correctly configured quorum: steps complete exactly, nothing
        // pends, and expiry never fires.
        let mut ok = ParameterServer::new(None, usize::MAX >> 1, 2);
        for step in 0..200u64 {
            for rank in 0..2u32 {
                ok.handle(PsRequest::Report(StepStat {
                    app: 0,
                    rank,
                    step,
                    n_executions: 10,
                    n_anomalies: 0,
                    ts_range: (0, 1),
                }));
            }
        }
        assert_eq!(ok.pending_steps(), 0);
    }

    #[test]
    fn empty_delta_skips_roundtrip() {
        let (client, handle) = spawn(2, None, 10, 1);
        let (g, ev) = client.sync(0, 0, &StatsTable::new());
        assert!(g.is_empty());
        assert!(ev.is_empty());
        client.shutdown();
        assert_eq!(handle.join().sync_count, 0);
    }

    #[test]
    fn snapshot_merge_is_order_independent() {
        let agg = {
            let mut ps = ParameterServer::new(None, 1000, 1);
            for step in 0..3 {
                ps.handle(PsRequest::Report(StepStat {
                    app: 0,
                    rank: 1,
                    step,
                    n_executions: 10,
                    n_anomalies: 1,
                    ts_range: (0, 1),
                }));
            }
            ps.snapshot()
        };
        let part_a = VizSnapshot { functions_tracked: 3, ..VizSnapshot::default() };
        let part_b = VizSnapshot { functions_tracked: 5, ..VizSnapshot::default() };

        let mut ab = agg.clone();
        ab.merge(&part_a);
        ab.merge(&part_b);
        let mut ba = part_b.clone();
        ba.merge(&part_a);
        ba.merge(&agg);

        assert_eq!(ab.functions_tracked, 8);
        assert_eq!(ba.functions_tracked, 8);
        assert_eq!(ab.total_anomalies, ba.total_anomalies);
        assert_eq!(ab.total_executions, ba.total_executions);
        assert_eq!(ab.ranks.len(), ba.ranks.len());
        assert_eq!(ab.fresh_steps.len(), ba.fresh_steps.len());
    }
}
